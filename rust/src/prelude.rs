//! One-import surface for the common workflow:
//!
//! ```no_run
//! use minmax::prelude::*;
//! ```
//!
//! Pulls in the trait layer ([`Sketcher`], [`Kernel`]), the concrete
//! hash families and kernel set, the [`Pipeline`] builder, the data
//! types, the serving stack, and the evaluation protocol helpers.

// Trait layer.
pub use crate::kernels::{Kernel, KernelKind, Normalization};
pub use crate::sketch::{MinwiseSketcher, Sketcher};

// Hashing: sampler, schemes, feature expansion.
pub use crate::cws::{
    collision_fraction, materialize_params, CwsHasher, CwsSample, DenseBatchHasher, KnnClassifier,
    LshConfig, LshError, LshIndex, MinwiseHasher, PackedLshIndex, QueryParams, QueryScratch,
    Scheme, SketchEngine, SketchScratch, Vote,
};
pub use crate::features::{CodeMatrix, Expansion, ExpansionError, PackedCodes};

// Kernel helpers.
pub use crate::kernels::gram::{GramSource, GramSpec, GramStats, OnTheFly, Precomputed, SubsetGram};
pub use crate::kernels::matrix::{kernel_matrix, kernel_matrix_sym};
pub use crate::kernels::{
    dense_chi2, dense_dot, dense_intersection, dense_minmax, dense_resemblance, sparse_minmax,
    sparse_resemblance,
};

// The composable pipeline.
pub use crate::pipeline::{Pipeline, PipelineBuilder, PipelineError, Scaling};

// The fused serving path.
pub use crate::serve::{ExportedWeights, Scorer, Scratch, ServeError, SlabPrecision};

// Data layer.
pub use crate::data::synth::{generate, SynthConfig};
pub use crate::data::{Csr, CsrBuilder, Dataset, Dense, Matrix, SparseRow};

// Learning + the §2 evaluation protocol.
pub use crate::svm::{
    c_grid, kernel_svm_sweep, kernel_svm_sweep_with, linear_svm_accuracy, KernelModel, KernelOvO,
    KernelSvmParams, LinearOvR, LinearSvmParams, RowSet, SweepResult,
};

// Serving stack.
pub use crate::coordinator::{
    silence_injected_panics, ClusterConfig, ClusterError, ClusterQueryResponse,
    ClusterScoreResponse, ClusterSnapshot, FaultPlan, HashResponse, HashService, NativeBackend,
    PipelineConfig, PjrtBackend, QueryRouter, RetryPolicy, Router, ScoreResponse, ScoreRouter,
    ServiceConfig, SketcherBackend, SubmitError, SubmittedQuery,
};

// Runtime bridge (stubbed without the `pjrt` feature).
pub use crate::runtime::{default_artifacts_dir, pjrt_enabled, Engine};
