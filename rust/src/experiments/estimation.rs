//! Drivers for Figures 4–5 (bias + MSE of full/0-bit/1-bit CWS) and
//! Figure 6 (all of t*, few bits of i*).
//!
//! The paper runs 10,000 simulations with k up to 1000 on 13 word pairs.
//! The dominant cost is `sims × k_max × (f1 + f2)` ICWS cell
//! evaluations, so the default configuration adapts `sims` per pair to a
//! fixed evaluation budget (`--full` restores paper scale).

use crate::cws::Scheme;
use crate::data::corpus::{generate_pair, table2_pairs, WordPair};
use crate::estimate::{fig45_schemes, fig6_schemes, simulate_pair, CellResult, SimConfig};
use crate::util::json::Json;
use crate::util::table::{fnum, fsci, Table};

use super::save_result;

#[derive(Debug, Clone)]
pub struct EstimationConfig {
    pub seed: u64,
    pub k_max: usize,
    pub sims: usize,
    /// Per-pair cap on `sims × k_max × (f1 + f2)`; sims is reduced to
    /// fit. 0 = no cap.
    pub cell_budget: u64,
    /// Restrict to pairs with f1 + f2 at most this (0 = all 13).
    pub max_pair_size: usize,
}

impl Default for EstimationConfig {
    fn default() -> Self {
        Self {
            seed: 2015,
            k_max: 256,
            sims: 2000,
            cell_budget: 2_000_000_000,
            max_pair_size: 12_000,
        }
    }
}

impl EstimationConfig {
    /// Paper-scale settings (hours of CPU on the large pairs).
    pub fn full() -> Self {
        Self {
            k_max: 1024,
            sims: 10_000,
            cell_budget: 0,
            max_pair_size: 0,
            ..Default::default()
        }
    }

    fn pairs(&self) -> Vec<WordPair> {
        table2_pairs()
            .into_iter()
            .filter(|p| self.max_pair_size == 0 || p.f1 + p.f2 <= self.max_pair_size)
            .collect()
    }

    fn sims_for(&self, p: &WordPair) -> usize {
        if self.cell_budget == 0 {
            return self.sims;
        }
        let per_sim = (self.k_max as u64) * ((p.f1 + p.f2) as u64);
        let floor = 200usize.min(self.sims);
        ((self.cell_budget / per_sim.max(1)) as usize).clamp(floor, self.sims)
    }
}

pub struct PairCells {
    pub pair: WordPair,
    pub realized_mm: f64,
    pub cells: Vec<CellResult>,
}

fn run_schemes(cfg: &EstimationConfig, schemes: &[Scheme]) -> Vec<PairCells> {
    let ks = SimConfig::log_ks(cfg.k_max);
    let mut out = Vec::new();
    for spec in cfg.pairs() {
        let g = generate_pair(&spec, cfg.seed, 0.004);
        let sims = cfg.sims_for(&spec);
        let sim_cfg = SimConfig { ks: ks.clone(), sims, seed: cfg.seed ^ 0xFEED };
        let cells = simulate_pair(g.u(), g.v(), g.realized_mm, schemes, &sim_cfg);
        crate::info!(
            "{}-{}: {} sims, K_MM={:.4}",
            spec.word1,
            spec.word2,
            sims,
            g.realized_mm
        );
        out.push(PairCells { pair: spec, realized_mm: g.realized_mm, cells });
    }
    out
}

fn cells_to_json(all: &[PairCells]) -> Json {
    Json::Arr(
        all.iter()
            .map(|p| {
                let mut j = Json::obj();
                j.set("word1", p.pair.word1).set("word2", p.pair.word2).set(
                    "k_mm",
                    p.realized_mm,
                );
                j.set(
                    "cells",
                    Json::Arr(
                        p.cells
                            .iter()
                            .map(|c| {
                                let mut cj = Json::obj();
                                cj.set("scheme", c.scheme.name())
                                    .set("k", c.k)
                                    .set("bias", c.bias)
                                    .set("mse", c.mse)
                                    .set("theory_var", c.theory_var)
                                    .set("sims", c.sims);
                                cj
                            })
                            .collect(),
                    ),
                );
                j
            })
            .collect(),
    )
}

/// Figures 4–5: bias + MSE per pair at a few representative k.
pub fn run_fig4_5(cfg: &EstimationConfig) -> Table {
    let all = run_schemes(cfg, &fig45_schemes());
    let mut t = Table::new(
        "Figures 4-5: estimation of K_MM — empirical bias / MSE (vs K(1-K)/k) at k = k_max",
    )
    .header(["Pair", "K_MM", "scheme", "bias", "MSE", "K(1-K)/k"]);
    for p in &all {
        let k_max = p.cells.iter().map(|c| c.k).max().unwrap();
        for c in p.cells.iter().filter(|c| c.k == k_max) {
            t.row([
                format!("{}-{}", p.pair.word1, p.pair.word2),
                fnum(p.realized_mm, 4),
                c.scheme.name(),
                fsci(c.bias),
                fsci(c.mse),
                fsci(c.theory_var),
            ]);
        }
    }
    save_result("fig4_5", &cells_to_json(&all));
    t
}

/// Figure 6: bias when keeping all of t* but only 0/1/2/4 bits of i*.
pub fn run_fig6(cfg: &EstimationConfig) -> Table {
    let all = run_schemes(cfg, &fig6_schemes());
    let mut t =
        Table::new("Figure 6: bias keeping ALL bits of t* and only 0/1/2/4 bits of i* (k = k_max)")
            .header(["Pair", "K_MM", "i* bits", "bias"]);
    for p in &all {
        let k_max = p.cells.iter().map(|c| c.k).max().unwrap();
        for c in p.cells.iter().filter(|c| c.k == k_max) {
            t.row([
                format!("{}-{}", p.pair.word1, p.pair.word2),
                fnum(p.realized_mm, 4),
                format!("{}", c.scheme.i_bits.unwrap()),
                fsci(c.bias),
            ]);
        }
    }
    save_result("fig6", &cells_to_json(&all));
    t
}

/// Shape assertions shared by the driver test and EXPERIMENTS.md: the
/// paper's qualitative claims about Figures 4–6.
pub fn check_fig45_shape(all: &[PairCells]) -> Result<(), String> {
    for p in all {
        let k_max = p.cells.iter().map(|c| c.k).max().unwrap();
        let get = |s: Scheme| p.cells.iter().find(|c| c.scheme == s && c.k == k_max).unwrap();
        let full = get(Scheme::FULL);
        let zero = get(Scheme::ZERO_BIT);
        // MSE(0-bit) ≈ MSE(full) ≈ K(1-K)/k (within 40% at k_max).
        for (name, c) in [("full", full), ("0-bit", zero)] {
            if (c.mse - c.theory_var).abs() > 0.4 * c.theory_var + 2e-4 {
                return Err(format!(
                    "{}-{} {name}: MSE {} vs theory {}",
                    p.pair.word1, p.pair.word2, c.mse, c.theory_var
                ));
            }
        }
        // |bias(0-bit)| stays small in the stabilized zone.
        if zero.bias.abs() > 0.02 {
            return Err(format!(
                "{}-{}: 0-bit bias {}",
                p.pair.word1, p.pair.word2, zero.bias
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> EstimationConfig {
        EstimationConfig {
            seed: 3,
            k_max: 64,
            sims: 400,
            cell_budget: 60_000_000,
            max_pair_size: 500,
        }
    }

    #[test]
    fn fig45_runs_on_small_pairs_and_shape_holds() {
        std::env::set_var("MINMAX_RESULTS", std::env::temp_dir().join("mm_res_f45"));
        let cfg = tiny();
        let all = run_schemes(&cfg, &fig45_schemes());
        assert!(!all.is_empty());
        check_fig45_shape(&all).unwrap();
    }

    #[test]
    fn fig6_bias_orders_with_i_bits() {
        std::env::set_var("MINMAX_RESULTS", std::env::temp_dir().join("mm_res_f6"));
        let cfg = tiny();
        let all = run_schemes(&cfg, &fig6_schemes());
        for p in &all {
            let k_max = p.cells.iter().map(|c| c.k).max().unwrap();
            let bias = |b: u8| {
                p.cells
                    .iter()
                    .find(|c| c.k == k_max && c.scheme.i_bits == Some(b))
                    .unwrap()
                    .bias
            };
            // 0 bits of i* → heavily biased up; 4 bits → much closer.
            assert!(bias(0) > bias(4) - 1e-9, "{}-{}", p.pair.word1, p.pair.word2);
        }
    }

    #[test]
    fn budget_caps_sims() {
        let cfg = EstimationConfig {
            cell_budget: 1_000_000,
            k_max: 100,
            sims: 10_000,
            ..Default::default()
        };
        let p = &table2_pairs()[4]; // GAMBIA-KIRIBATI: f1+f2=392
        let sims = cfg.sims_for(p);
        assert!(sims < 10_000);
        assert!(sims >= 200);
    }

    #[test]
    fn tables_render() {
        std::env::set_var("MINMAX_RESULTS", std::env::temp_dir().join("mm_res_f45b"));
        let t = run_fig4_5(&EstimationConfig { k_max: 16, sims: 100, ..tiny() });
        assert!(t.n_rows() > 0);
        let t6 = run_fig6(&EstimationConfig { k_max: 16, sims: 100, ..tiny() });
        assert!(t6.n_rows() > 0);
    }
}
