//! Experiment drivers — one per table/figure of the paper (DESIGN.md §5).
//!
//! Every driver prints the same rows/series the paper reports and writes
//! machine-readable JSON under `results/` so curves can be replotted
//! without rerunning. All drivers accept `--seed`, dataset/size knobs,
//! and a `--full` flag that switches from the fast default configuration
//! to the paper-scale one.
//!
//! | CLI            | Paper artifact                         |
//! |----------------|----------------------------------------|
//! | `table1`       | Table 1 (best accuracy per kernel)     |
//! | `fig1-3`       | Figures 1–3 (accuracy-vs-C curves)     |
//! | `table2`       | Table 2 (word pairs: f1, f2, R, MM)    |
//! | `fig4-5`       | Figures 4–5 (bias/MSE, full/0/1-bit)   |
//! | `fig6`         | Figure 6 (t* with 0/1/2/4 bits of i*)  |
//! | `fig7`         | Figure 7 (0-bit CWS + linear SVM)      |
//! | `fig8`         | Figure 8 (0-bit vs 2-bit t*)           |
//! | `perf`         | EXPERIMENTS.md §Perf measurements      |

pub mod estimation;
pub mod perf;
pub mod svm_tables;
pub mod table2;

use crate::util::json::{write_json, Json};
use std::path::PathBuf;

/// Where drivers drop their JSON results.
pub fn results_dir() -> PathBuf {
    std::env::var("MINMAX_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// Save a driver's JSON output as `results/<id>.json`.
pub fn save_result(id: &str, json: &Json) {
    let path = results_dir().join(format!("{id}.json"));
    match write_json(&path, json) {
        Ok(()) => println!("saved {}", path.display()),
        Err(e) => eprintln!("warning: could not save {}: {e}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_dir_honors_env() {
        // (Env-var tests mutate global state; keep them serial & restore.)
        let old = std::env::var("MINMAX_RESULTS").ok();
        std::env::set_var("MINMAX_RESULTS", "/tmp/minmax_results_test");
        assert_eq!(results_dir(), PathBuf::from("/tmp/minmax_results_test"));
        match old {
            Some(v) => std::env::set_var("MINMAX_RESULTS", v),
            None => std::env::remove_var("MINMAX_RESULTS"),
        }
    }
}
