//! Table 2 driver: regenerate the 13 word pairs (calibrated synthetic
//! corpus) and report target-vs-realized (f1, f2, R, MM).

use crate::data::corpus::{generate_table2, GeneratedPair};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::save_result;

pub fn run_table2(seed: u64, mm_tol: f64) -> (Table, Vec<GeneratedPair>) {
    let pairs = generate_table2(seed, mm_tol);
    let mut t = Table::new("Table 2: word pairs — paper targets vs calibrated synthetic corpus")
        .header([
            "Word 1", "Word 2", "f1", "f2", "R(paper)", "R(ours)", "MM(paper)", "MM(ours)",
        ]);
    let mut json_rows = Vec::new();
    for g in &pairs {
        t.row([
            g.spec.word1.to_string(),
            g.spec.word2.to_string(),
            g.u().nnz().to_string(),
            g.v().nnz().to_string(),
            fnum(g.spec.r, 4),
            fnum(g.realized_r, 4),
            fnum(g.spec.mm, 4),
            fnum(g.realized_mm, 4),
        ]);
        let mut j = Json::obj();
        j.set("word1", g.spec.word1)
            .set("word2", g.spec.word2)
            .set("f1", g.u().nnz())
            .set("f2", g.v().nnz())
            .set("r_paper", g.spec.r)
            .set("r_ours", g.realized_r)
            .set("mm_paper", g.spec.mm)
            .set("mm_ours", g.realized_mm);
        json_rows.push(j);
    }
    save_result("table2", &Json::Arr(json_rows));
    (t, pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_regenerates_13_rows_with_close_stats() {
        std::env::set_var("MINMAX_RESULTS", std::env::temp_dir().join("mm_res_t2"));
        let (t, pairs) = run_table2(42, 0.004);
        assert_eq!(t.n_rows(), 13);
        for g in &pairs {
            assert_eq!(g.u().nnz(), g.spec.f1, "{}", g.spec.word1);
            assert_eq!(g.v().nnz(), g.spec.f2, "{}", g.spec.word2);
            assert!(
                (g.realized_r - g.spec.r).abs() < 0.02,
                "{}-{}: R {} vs {}",
                g.spec.word1,
                g.spec.word2,
                g.realized_r,
                g.spec.r
            );
            assert!(
                (g.realized_mm - g.spec.mm).abs() < 0.03,
                "{}-{}: MM {} vs {}",
                g.spec.word1,
                g.spec.word2,
                g.realized_mm,
                g.spec.mm
            );
        }
    }
}
