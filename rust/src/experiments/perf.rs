//! §Perf driver: the whole-stack performance measurements recorded in
//! EXPERIMENTS.md §Perf (L3 native hot paths, the PJRT execute path, and
//! the online service). Complements `rust/benches/*` (which use the
//! criterion-style harness) with a one-shot snapshot.

use std::time::Instant;

use crate::coordinator::{HashService, NativeBackend, ServiceConfig};
use crate::cws::CwsHasher;
use crate::data::dense::Dense;
use crate::data::Matrix;
use crate::kernels::matrix::kernel_matrix;
use crate::kernels::KernelKind;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::table::{fnum, Table};

use super::save_result;

fn random_dense(rows: usize, cols: usize, seed: u64) -> Dense {
    let mut rng = Pcg64::new(seed);
    Dense::from_vec(
        rows,
        cols,
        (0..rows * cols).map(|_| rng.lognormal(0.0, 1.0) as f32).collect(),
    )
}

/// Time `f` for at least `min_time` seconds, returning seconds/iteration.
fn time_it<F: FnMut()>(min_time: f64, mut f: F) -> f64 {
    // Warmup.
    f();
    let start = Instant::now();
    let mut iters = 0u64;
    while start.elapsed().as_secs_f64() < min_time {
        f();
        iters += 1;
    }
    start.elapsed().as_secs_f64() / iters as f64
}

pub struct PerfReport {
    pub table: Table,
    pub json: Json,
}

pub fn run_perf(with_pjrt: bool) -> PerfReport {
    let mut t = Table::new("Perf snapshot (single run; see benches/ for distributions)")
        .header(["metric", "value", "unit"]);
    let mut j = Json::obj();

    // --- L3 native CWS hashing throughput (the paper's core cost).
    let d = 256;
    let k = 128;
    let x = random_dense(64, d, 1);
    let hasher = CwsHasher::new(7, k);
    let per_batch = time_it(1.0, || {
        for i in 0..x.rows() {
            std::hint::black_box(hasher.hash_dense(x.row(i)));
        }
    });
    let vectors_per_s = x.rows() as f64 / per_batch;
    let cells_per_s = vectors_per_s * (d * k) as f64;
    t.row(["native CWS hash (D=256,k=128)".into(), fnum(vectors_per_s, 1), "vec/s".to_string()]);
    t.row(["native CWS cell rate".into(), fnum(cells_per_s / 1e6, 1), "Mcell/s".to_string()]);
    j.set("native_cws_vec_per_s", vectors_per_s).set("native_cws_mcell_per_s", cells_per_s / 1e6);

    // --- SketchEngine chunked batch entry (loop-inverted slabs, shards
    // rows across MINMAX_THREADS; see EXPERIMENTS.md §Perf and
    // benches/bench_sketch.rs for the full lazy/materialized/engine
    // comparison).
    let threads = crate::util::pool::default_threads();
    let batch = hasher.dense_batch(d);
    let rows: Vec<&[f32]> = (0..x.rows()).map(|i| x.row(i)).collect();
    let per_batch = time_it(1.0, || {
        std::hint::black_box(batch.engine().sketch_rows(&rows));
    });
    let engine_vec_per_s = x.rows() as f64 / per_batch;
    t.row([
        format!("engine batch sketch (D=256,k=128,T={threads})"),
        fnum(engine_vec_per_s, 1),
        "vec/s".to_string(),
    ]);
    j.set("engine_batch_vec_per_s", engine_vec_per_s).set("engine_batch_threads", threads as u64);

    // --- L3 kernel-matrix throughput.
    let a = random_dense(256, 64, 2);
    let b = random_dense(256, 64, 3);
    let ma = Matrix::Dense(a);
    let mb = Matrix::Dense(b);
    let per = time_it(1.0, || {
        std::hint::black_box(kernel_matrix(KernelKind::MinMax, &ma, &mb));
    });
    let cells = (256 * 256) as f64 / per;
    t.row(["min-max kernel matrix (256x256,D=64)".into(), fnum(cells / 1e6, 2), "Mpair/s".into()]);
    j.set("minmax_matrix_mpair_per_s", cells / 1e6);

    // --- Online service (native backend): latency under closed-loop load.
    let cfg = ServiceConfig {
        seed: 1,
        k: 64,
        dim: 64,
        max_batch: 32,
        max_wait: std::time::Duration::from_micros(500),
        queue_cap: 4096,
    };
    let svc = HashService::start(cfg, NativeBackend).expect("start native service");
    let v: Vec<f32> = (1..=64).map(|i| i as f32 / 7.0).collect();
    let n = 2000;
    let start = Instant::now();
    for i in 0..n {
        let _ = svc.hash_blocking(i, &v).unwrap();
    }
    let elapsed = start.elapsed().as_secs_f64();
    let snap = svc.metrics().snapshot();
    t.row(["service closed-loop throughput".into(), fnum(n as f64 / elapsed, 1), "req/s".into()]);
    t.row(["service p50 latency".into(), fnum(snap.latency_p50_ms, 3), "ms".into()]);
    t.row(["service p99 latency".into(), fnum(snap.latency_p99_ms, 3), "ms".into()]);
    j.set("service_rps", n as f64 / elapsed)
        .set("service_p50_ms", snap.latency_p50_ms)
        .set("service_p99_ms", snap.latency_p99_ms);
    svc.shutdown();

    // --- Fused serving scorer: single-row latency on the zero-alloc
    // path (see benches/bench_serve.rs for the full baseline/fused
    // comparison and the allocation count).
    {
        use crate::data::synth::{generate, SynthConfig};
        use crate::pipeline::Pipeline;
        let ds = generate("letter", SynthConfig { seed: 5, n_train: 200, n_test: 200 })
            .expect("letter synth");
        let mut pipe =
            Pipeline::builder().seed(5).samples(128).i_bits(8).build().expect("pipeline");
        pipe.fit(&ds.train_x, &ds.train_y).expect("fit");
        let scorer = pipe.scorer(ds.dim()).expect("scorer");
        let test = ds.test_x.to_dense();
        let mut scratch = scorer.scratch();
        let mut i = 0usize;
        let per_row = time_it(1.0, || {
            std::hint::black_box(scorer.predict_dense(test.row(i % test.rows()), &mut scratch));
            i += 1;
        });
        t.row([
            "fused scorer single-row predict (D=16,k=128)".into(),
            fnum(per_row * 1e6, 2),
            "us/row".into(),
        ]);
        j.set("fused_scorer_row_us", per_row * 1e6);
    }

    // --- PJRT execute path (when artifacts exist).
    if with_pjrt {
        let dir = crate::runtime::default_artifacts_dir();
        if dir.join("manifest.json").exists() && crate::runtime::pjrt_enabled() {
            use crate::cws::materialize_params;
            use crate::runtime::{literal_f32, Engine};
            let engine = Engine::load_subset(&dir, &["cws_hash"]).expect("engine");
            let spec = engine.spec("cws_hash").unwrap().clone();
            let (b, dd) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
            let kk = spec.inputs[1].shape[0];
            let xb = random_dense(b, dd, 4);
            let (r, c, beta) = materialize_params(3, dd, kk);
            let xl = literal_f32(xb.data(), &[b, dd]).unwrap();
            let rl = literal_f32(&r, &[kk, dd]).unwrap();
            let cl = literal_f32(&c, &[kk, dd]).unwrap();
            let bl = literal_f32(&beta, &[kk, dd]).unwrap();
            let per = time_it(2.0, || {
                std::hint::black_box(
                    engine.run("cws_hash", &[xl.clone(), rl.clone(), cl.clone(), bl.clone()]).unwrap(),
                );
            });
            let vec_per_s = b as f64 / per;
            t.row([
                format!("PJRT cws_hash execute (B={b},D={dd},K={kk})"),
                fnum(per * 1e3, 2),
                "ms/batch".into(),
            ]);
            t.row(["PJRT cws_hash throughput".into(), fnum(vec_per_s, 1), "vec/s".into()]);
            j.set("pjrt_cws_ms_per_batch", per * 1e3).set("pjrt_cws_vec_per_s", vec_per_s);
        } else {
            t.row(["PJRT".to_string(), "skipped (no artifacts)".to_string(), String::new()]);
        }
    }

    save_result("perf", &j);
    PerfReport { table: t, json: j.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_positive() {
        let mut x = 0u64;
        let s = time_it(0.01, || {
            x = std::hint::black_box(x.wrapping_add(1));
        });
        assert!(s > 0.0);
    }
}
