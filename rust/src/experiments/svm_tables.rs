//! Drivers for Table 1, Figures 1–3 (kernel SVM comparison) and
//! Figures 7–8 (0-bit CWS + linear SVM).

use crate::coordinator::{hashed_linear_sweep, PipelineConfig};
use crate::data::synth::{generate, SynthConfig};

use crate::kernels::gram::GramSpec;
use crate::kernels::KernelKind;
use crate::svm::{c_grid, kernel_svm_sweep, kernel_svm_sweep_with, SweepResult};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::save_result;

/// The four kernels of Table 1, in the paper's column order.
pub fn table1_kernels() -> [KernelKind; 4] {
    [KernelKind::Linear, KernelKind::MinMax, KernelKind::NMinMax, KernelKind::Intersection]
}

#[derive(Debug, Clone)]
pub struct SvmExperimentConfig {
    pub datasets: Vec<String>,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    pub c_points: usize,
    /// Extra kernels beyond the paper's four (ablations: resemblance,
    /// chi2, CoRE-style product).
    pub extra_kernels: Vec<KernelKind>,
    /// How the train Gram is served to the OvO solver (`--gram
    /// {pre,otf}`): materialized up front, or streamed on demand behind
    /// a bounded row cache. Models are bit-identical either way.
    pub gram: GramSpec,
}

impl Default for SvmExperimentConfig {
    fn default() -> Self {
        Self {
            datasets: crate::data::synth::core_names().iter().map(|s| s.to_string()).collect(),
            seed: 2015,
            n_train: 400,
            n_test: 600,
            c_points: 9,
            extra_kernels: vec![],
            gram: GramSpec::Precomputed,
        }
    }
}

pub struct DatasetSweeps {
    pub dataset: String,
    pub n_train: usize,
    pub n_test: usize,
    pub sweeps: Vec<SweepResult>,
}

/// Run the §2 protocol on every configured dataset × kernel.
pub fn run_kernel_sweeps(cfg: &SvmExperimentConfig) -> Vec<DatasetSweeps> {
    let cs = c_grid(cfg.c_points);
    let mut out = Vec::new();
    for name in &cfg.datasets {
        let ds = generate(
            name,
            SynthConfig { seed: cfg.seed, n_train: cfg.n_train, n_test: cfg.n_test },
        )
        .unwrap_or_else(|e| panic!("{e}"));
        let mut kernels: Vec<KernelKind> = table1_kernels().to_vec();
        kernels.extend(cfg.extra_kernels.iter().copied());
        let sweeps: Vec<SweepResult> =
            kernels.iter().map(|&k| kernel_svm_sweep_with(&ds, k, &cs, cfg.gram)).collect();
        crate::info!(
            "{name}: {}",
            sweeps
                .iter()
                .map(|s| format!("{}={:.1}", s.kernel.name(), 100.0 * s.best_accuracy()))
                .collect::<Vec<_>>()
                .join(" ")
        );
        out.push(DatasetSweeps {
            dataset: name.clone(),
            n_train: ds.n_train(),
            n_test: ds.n_test(),
            sweeps,
        });
    }
    out
}

/// Table 1: best accuracy per kernel per dataset.
pub fn run_table1(cfg: &SvmExperimentConfig) -> Table {
    let all = run_kernel_sweeps(cfg);
    let mut header = vec!["Dataset".to_string(), "#train".into(), "#test".into()];
    let mut kernels: Vec<KernelKind> = table1_kernels().to_vec();
    kernels.extend(cfg.extra_kernels.iter().copied());
    header.extend(kernels.iter().map(|k| k.name().to_string()));
    let mut t = Table::new("Table 1 (synthetic analogs): best test accuracy (%) over C grid")
        .header(header);
    let mut json_rows = Vec::new();
    for d in &all {
        let mut row = vec![d.dataset.clone(), d.n_train.to_string(), d.n_test.to_string()];
        let mut jrow = Json::obj();
        jrow.set("dataset", d.dataset.as_str())
            .set("n_train", d.n_train)
            .set("n_test", d.n_test);
        for s in &d.sweeps {
            row.push(fnum(100.0 * s.best_accuracy(), 1));
            jrow.set(s.kernel.name(), 100.0 * s.best_accuracy());
        }
        t.row(row);
        json_rows.push(jrow);
    }
    save_result("table1", &Json::Arr(json_rows));
    t
}

/// Figures 1–3: the full accuracy-vs-C curves (JSON per dataset), plus a
/// compact printed summary (accuracy at min/mid/max C).
pub fn run_fig1_3(cfg: &SvmExperimentConfig) -> Table {
    let all = run_kernel_sweeps(cfg);
    let mut t = Table::new("Figures 1-3 (synthetic analogs): accuracy (%) at C=0.01 / C=1 / C=1000")
        .header(["Dataset", "kernel", "C=min", "C=mid", "C=max", "best"]);
    let mut json_all = Vec::new();
    for d in &all {
        for s in &d.sweeps {
            let n = s.curve.len();
            t.row([
                d.dataset.clone(),
                s.kernel.name().to_string(),
                fnum(100.0 * s.curve[0].1, 1),
                fnum(100.0 * s.curve[n / 2].1, 1),
                fnum(100.0 * s.curve[n - 1].1, 1),
                fnum(100.0 * s.best_accuracy(), 1),
            ]);
            let mut j = Json::obj();
            j.set("dataset", d.dataset.as_str()).set("kernel", s.kernel.name()).set(
                "curve",
                Json::Arr(
                    s.curve
                        .iter()
                        .map(|&(c, a)| {
                            let mut p = Json::obj();
                            p.set("c", c).set("acc", a);
                            p
                        })
                        .collect(),
                ),
            );
            json_all.push(j);
        }
    }
    save_result("fig1_3", &Json::Arr(json_all));
    t
}

// ------------------------------------------------------- Figures 7 & 8

#[derive(Debug, Clone)]
pub struct HashedSvmConfig {
    pub datasets: Vec<String>,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    pub i_bits: Vec<u8>,
    pub ks: Vec<usize>,
    /// t* bit variants (Figure 7 uses [0]; Figure 8 uses [0, 2]).
    pub t_bits: Vec<u8>,
    /// C for the linear SVM sweep (best-of grid like the paper's solid
    /// curves).
    pub c_points: usize,
}

impl Default for HashedSvmConfig {
    fn default() -> Self {
        Self {
            datasets: vec!["letter".into(), "m-basic".into(), "satimage".into(), "vowel".into()],
            seed: 2015,
            n_train: 400,
            n_test: 600,
            i_bits: vec![1, 2, 4, 8],
            ks: vec![32, 64, 128, 256, 512, 1024],
            t_bits: vec![0],
            c_points: 5,
        }
    }
}

/// Figures 7/8 driver: for each dataset, the hashed-linear accuracy per
/// (b_i, k, b_t), with the min-max-kernel and linear-kernel dashed
/// baselines of the paper's panels.
pub fn run_fig7_8(cfg: &HashedSvmConfig, id: &str) -> Table {
    let cs = c_grid(cfg.c_points);
    let mut t = Table::new(format!(
        "{id}: linear SVM on 0-bit CWS features — best accuracy (%) over C grid"
    ))
    .header(["Dataset", "b_t", "b_i", "k", "hashed", "minmax-kernel", "linear-kernel"]);
    let mut json_all = Vec::new();
    for name in &cfg.datasets {
        let ds = generate(
            name,
            SynthConfig { seed: cfg.seed, n_train: cfg.n_train, n_test: cfg.n_test },
        )
        .unwrap_or_else(|e| panic!("{e}"));
        // Dashed baselines (top: min-max kernel; bottom: linear kernel).
        let mm = kernel_svm_sweep(&ds, KernelKind::MinMax, &cs).best_accuracy();
        let lin = kernel_svm_sweep(&ds, KernelKind::Linear, &cs).best_accuracy();
        for &bt in &cfg.t_bits {
            for &bi in &cfg.i_bits {
                for &k in &cfg.ks {
                    let pcfg = PipelineConfig { seed: cfg.seed, k, i_bits: bi, t_bits: bt };
                    let curve = hashed_linear_sweep(&ds, &pcfg, &cs);
                    let best =
                        curve.iter().map(|&(_, a)| a).fold(f64::NEG_INFINITY, f64::max);
                    t.row([
                        name.clone(),
                        bt.to_string(),
                        bi.to_string(),
                        k.to_string(),
                        fnum(100.0 * best, 1),
                        fnum(100.0 * mm, 1),
                        fnum(100.0 * lin, 1),
                    ]);
                    let mut j = Json::obj();
                    j.set("dataset", name.as_str())
                        .set("t_bits", bt as i64)
                        .set("i_bits", bi as i64)
                        .set("k", k)
                        .set("hashed_acc", best)
                        .set("minmax_kernel_acc", mm)
                        .set("linear_kernel_acc", lin);
                    json_all.push(j);
                }
                crate::info!("{name}: b_t={bt} b_i={bi} done");
            }
        }
    }
    save_result(id, &Json::Arr(json_all));
    t
}

#[allow(dead_code)]
fn trend_holds(points: &[(usize, f64)]) -> bool {
    // Weakly increasing in k allowing small noise dips.
    points.windows(2).all(|w| w[1].1 >= w[0].1 - 0.03)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> SvmExperimentConfig {
        SvmExperimentConfig {
            datasets: vec!["vowel".into(), "letter".into()],
            seed: 7,
            n_train: 100,
            n_test: 120,
            c_points: 3,
            extra_kernels: vec![],
            gram: GramSpec::Precomputed,
        }
    }

    #[test]
    fn table1_shape_holds_minmax_beats_linear() {
        std::env::set_var("MINMAX_RESULTS", std::env::temp_dir().join("mm_res_t1"));
        let all = run_kernel_sweeps(&tiny_cfg());
        for d in &all {
            let best = |k: KernelKind| {
                d.sweeps.iter().find(|s| s.kernel == k).unwrap().best_accuracy()
            };
            assert!(
                best(KernelKind::MinMax) >= best(KernelKind::Linear) - 0.02,
                "{}: min-max {} vs linear {}",
                d.dataset,
                best(KernelKind::MinMax),
                best(KernelKind::Linear)
            );
        }
    }

    #[test]
    fn table1_table_renders() {
        std::env::set_var("MINMAX_RESULTS", std::env::temp_dir().join("mm_res_t1b"));
        let t = run_table1(&SvmExperimentConfig {
            datasets: vec!["vowel".into()],
            n_train: 80,
            n_test: 80,
            c_points: 3,
            ..tiny_cfg()
        });
        assert_eq!(t.n_rows(), 1);
        assert!(t.render().contains("vowel"));
    }

    #[test]
    fn on_the_fly_gram_reproduces_precomputed_table() {
        std::env::set_var("MINMAX_RESULTS", std::env::temp_dir().join("mm_res_t1c"));
        let mut cfg = SvmExperimentConfig {
            datasets: vec!["vowel".into()],
            n_train: 60,
            n_test: 60,
            ..tiny_cfg()
        };
        let pre = run_kernel_sweeps(&cfg);
        cfg.gram = GramSpec::OnTheFly { cache_rows: Some(15) };
        let otf = run_kernel_sweeps(&cfg);
        for (dp, do_) in pre.iter().zip(&otf) {
            for (sp, so) in dp.sweeps.iter().zip(&do_.sweeps) {
                assert_eq!(
                    sp.best_accuracy().to_bits(),
                    so.best_accuracy().to_bits(),
                    "{} differs across gram sources",
                    sp.kernel.name()
                );
            }
        }
    }

    #[test]
    fn fig7_trend_accuracy_grows_with_k() {
        std::env::set_var("MINMAX_RESULTS", std::env::temp_dir().join("mm_res_f7"));
        let cfg = HashedSvmConfig {
            datasets: vec!["letter".into()],
            n_train: 150,
            n_test: 150,
            i_bits: vec![8],
            ks: vec![16, 64, 256],
            t_bits: vec![0],
            c_points: 3,
            seed: 5,
        };
        let _ = run_fig7_8(&cfg, "fig7_test");
        // Re-run the pipeline directly to check the trend.
        let ds = generate(
            "letter",
            SynthConfig { seed: 5, n_train: 150, n_test: 150 },
        )
        .unwrap();
        let cs = c_grid(3);
        let points: Vec<(usize, f64)> = [16usize, 64, 256]
            .iter()
            .map(|&k| {
                let pcfg = PipelineConfig { seed: 5, k, i_bits: 8, t_bits: 0 };
                let best = hashed_linear_sweep(&ds, &pcfg, &cs)
                    .iter()
                    .map(|&(_, a)| a)
                    .fold(f64::NEG_INFINITY, f64::max);
                (k, best)
            })
            .collect();
        assert!(trend_holds(&points), "accuracy not increasing in k: {points:?}");
        assert!(points.last().unwrap().1 > points[0].1, "no growth: {points:?}");
    }
}
