//! The [`Sketcher`] trait — the crate's unified hashing abstraction.
//!
//! A `Sketcher` is anything that turns a nonnegative vector (sparse row
//! or dense slice) into a fixed-length stream of [`CwsSample`]s whose
//! collision statistics estimate some kernel:
//!
//! * [`CwsHasher`] — ICWS (Algorithm 1), collisions estimate the min-max
//!   kernel (Eq. 7); the paper's subject.
//! * [`DenseBatchHasher`] — the same sampler with `(r, c, β)`
//!   materialized once per `(seed, k, D)`; byte-identical output, used
//!   on the service hot path.
//!
//! Both ICWS impls execute on [`crate::cws::SketchEngine`] (loop
//! inversion, transposed slabs, chunked-parallel batches — their
//! `sketch_dense_batch`/`sketch_matrix` overrides shard rows across
//! `MINMAX_THREADS` scoped threads with identical output at any thread
//! count; the per-element argmin inner loop runs SIMD-chunked via
//! `util::simd` with a `MINMAX_SIMD=off` scalar fallback, bit-identical
//! either way).
//! * [`MinwiseSketcher`] — classical minwise hashing over the support
//!   (binarized view); collisions estimate the resemblance (Eq. 2).
//! * `coordinator::PjrtSketcher` — the AOT/PJRT executable behind the
//!   same interface (same counter-based randomness as [`CwsHasher`]).
//! * Future GCWS / generalized-min-max families (arXiv:1605.05721) slot
//!   in as new impls without touching the coordinator or the pipeline.
//!
//! The trait is deliberately NOT `Send + Sync`: backends like PJRT own
//! thread-bound clients. The coordinator constructs each sketcher on the
//! worker thread that will own it (see `coordinator::SketcherBackend`).
//!
//! Downstream composition is uniform: `Sketcher → cws::Scheme /
//! features::Expansion → linear model`, packaged by [`crate::pipeline`].

use crate::cws::engine;
use crate::cws::minwise::MinwiseHasher;
use crate::cws::sampler::{CwsHasher, CwsSample, DenseBatchHasher};
use crate::data::dense::Dense;
use crate::data::sparse::SparseRow;
use crate::data::Matrix;

/// Uniform interface over hash families producing `(i*, t*)` samples.
///
/// Implementations must be deterministic per `(seed, k)`: two sketchers
/// of the same family and configuration produce identical samples for
/// identical input, which is what makes train/test hashing, replicated
/// services, and native-vs-AOT backends interchangeable.
pub trait Sketcher {
    /// Samples per vector.
    fn k(&self) -> usize;

    /// The seed all randomness derives from.
    fn seed(&self) -> u64;

    /// Short family name (diagnostics, metrics labels).
    fn name(&self) -> &'static str;

    /// Sketch a sparse nonnegative row. Panics if the row is empty
    /// (CWS-style samplers are undefined on the zero vector; callers
    /// filter empty rows — see [`Sketcher::sketch_matrix`]).
    fn sketch_sparse(&self, row: SparseRow<'_>) -> Vec<CwsSample>;

    /// Sketch a dense nonnegative vector (zeros skipped). Panics if the
    /// vector has no positive entry.
    fn sketch_dense(&self, u: &[f32]) -> Vec<CwsSample>;

    /// Batch hook: sketch many dense rows at once. The default maps
    /// [`Sketcher::sketch_dense`]; batched backends (PJRT) override it
    /// to amortize dispatch over fixed-shape executions.
    fn sketch_dense_batch(&self, rows: &[&[f32]]) -> Vec<Vec<CwsSample>> {
        rows.iter().map(|r| self.sketch_dense(r)).collect()
    }

    /// Sketch every row of a matrix; rows with no positive entry yield
    /// `None` (hashing is undefined there, and the feature expansion
    /// maps `None` to an all-zero feature row).
    ///
    /// The dense arm funnels live rows through
    /// [`Sketcher::sketch_dense_batch`], so batched impls (the ICWS
    /// engine facades, PJRT) get their chunked/parallel path for free.
    /// The sparse arm here is sequential — the trait is not `Sync`, so
    /// only impls that are (the ICWS facades override this) can shard
    /// rows across threads.
    fn sketch_matrix(&self, m: &Matrix) -> Vec<Option<Vec<CwsSample>>> {
        match m {
            Matrix::Sparse(s) => (0..s.rows())
                .map(|i| {
                    let row = s.row(i);
                    if row.nnz() == 0 {
                        None
                    } else {
                        Some(self.sketch_sparse(row))
                    }
                })
                .collect(),
            Matrix::Dense(d) => dense_rows_via_batch(self, d),
        }
    }
}

/// The dense `sketch_matrix` arm, shared by the trait default and the
/// overriding impls: gather live rows, sketch them through
/// `sketch_dense_batch`, scatter back with `None` for empty rows.
fn dense_rows_via_batch<S: Sketcher + ?Sized>(s: &S, d: &Dense) -> Vec<Option<Vec<CwsSample>>> {
    let live: Vec<usize> = (0..d.rows()).filter(|&i| d.row(i).iter().any(|&v| v > 0.0)).collect();
    let rows: Vec<&[f32]> = live.iter().map(|&i| d.row(i)).collect();
    let mut sketched = s.sketch_dense_batch(&rows).into_iter();
    let mut out: Vec<Option<Vec<CwsSample>>> = vec![None; d.rows()];
    for &i in &live {
        out[i] = Some(sketched.next().expect("batch length"));
    }
    out
}

// ------------------------------------------------------------------ ICWS

impl Sketcher for CwsHasher {
    fn k(&self) -> usize {
        CwsHasher::k(self)
    }

    fn seed(&self) -> u64 {
        CwsHasher::seed(self)
    }

    fn name(&self) -> &'static str {
        "icws"
    }

    fn sketch_sparse(&self, row: SparseRow<'_>) -> Vec<CwsSample> {
        self.hash_sparse(row)
    }

    fn sketch_dense(&self, u: &[f32]) -> Vec<CwsSample> {
        self.hash_dense(u)
    }

    /// Multi-row batches of one dimension materialize the `(r, c, β)`
    /// slabs once and run the engine's chunked-parallel `sketch_rows`
    /// (identical output for any `MINMAX_THREADS`, large speedup). The
    /// engine is pinned to exact math: `CwsHasher`'s per-row paths are
    /// always exact, so honoring `MINMAX_FAST_MATH` only here would
    /// make the same vector sketch differently depending on batch size
    /// or matrix representation. Fast math is an explicit opt-in via
    /// [`crate::cws::SketchEngine`] / [`DenseBatchHasher`] instead.
    fn sketch_dense_batch(&self, rows: &[&[f32]]) -> Vec<Vec<CwsSample>> {
        match rows.first() {
            Some(first) if rows.len() > 1 && rows.iter().all(|r| r.len() == first.len()) => {
                engine::SketchEngine::new(CwsHasher::seed(self), CwsHasher::k(self), first.len())
                    .with_fast_math(false)
                    .sketch_rows(rows)
            }
            _ => rows.iter().map(|r| self.hash_dense(r)).collect(),
        }
    }

    /// Parallel whole-matrix sketching: the sparse arm shards rows
    /// across threads with lazy parameter derivation (`CwsHasher` is
    /// `Sync` — it owns only `(seed, k)`); the dense arm rides the
    /// batched path above.
    fn sketch_matrix(&self, m: &Matrix) -> Vec<Option<Vec<CwsSample>>> {
        match m {
            Matrix::Sparse(s) => {
                let (seed, k) = (CwsHasher::seed(self), CwsHasher::k(self));
                engine::sketch_csr_with(
                    s,
                    k,
                    engine::batch_threads(s.rows(), k),
                    |row, scratch, out| {
                        engine::sample_lazy_sparse_with(seed, k, row, scratch, out);
                    },
                )
            }
            Matrix::Dense(d) => dense_rows_via_batch(self, d),
        }
    }
}

impl Sketcher for DenseBatchHasher {
    fn k(&self) -> usize {
        DenseBatchHasher::k(self)
    }

    fn seed(&self) -> u64 {
        DenseBatchHasher::seed(self)
    }

    fn name(&self) -> &'static str {
        "icws-materialized"
    }

    fn sketch_sparse(&self, row: SparseRow<'_>) -> Vec<CwsSample> {
        self.hash_sparse(row)
    }

    fn sketch_dense(&self, u: &[f32]) -> Vec<CwsSample> {
        self.hash(u)
    }

    /// The engine's chunked-parallel batch entry — the coordinator's
    /// `HashService` worker lands here via `dyn Sketcher`.
    fn sketch_dense_batch(&self, rows: &[&[f32]]) -> Vec<Vec<CwsSample>> {
        self.engine().sketch_rows(rows)
    }

    /// Parallel whole-matrix sketching against the materialized slabs
    /// (row index bounds validated once per row).
    fn sketch_matrix(&self, m: &Matrix) -> Vec<Option<Vec<CwsSample>>> {
        match m {
            Matrix::Sparse(s) => engine::sketch_csr_with(
                s,
                DenseBatchHasher::k(self),
                engine::batch_threads(s.rows(), DenseBatchHasher::k(self)),
                |row, scratch, out| self.engine().sketch_sparse_with(row, scratch, out),
            ),
            Matrix::Dense(d) => dense_rows_via_batch(self, d),
        }
    }
}

// --------------------------------------------------------------- minwise

/// Minwise hashing behind the [`Sketcher`] interface: the vector's
/// SUPPORT is hashed (values are ignored — the binarized view), and the
/// 64-bit min-hash of sample `j` is packed as
/// `i* = high 32 bits`, `t* = low 32 bits`.
///
/// Full-sample collisions therefore occur iff the min-hashes collide,
/// so `collision_fraction(Scheme::FULL, …)` estimates the resemblance
/// (Eq. 2). The 0-bit scheme keeps the top 32 bits — accidental
/// collisions have probability ~2⁻³², negligible — so it estimates the
/// resemblance too. This is the b-bit-minwise baseline of §1/[20] as a
/// drop-in `Sketcher`.
#[derive(Debug, Clone)]
pub struct MinwiseSketcher {
    inner: MinwiseHasher,
    seed: u64,
}

impl MinwiseSketcher {
    pub fn new(seed: u64, k: usize) -> Self {
        Self { inner: MinwiseHasher::new(seed, k), seed }
    }

    fn pack(hashes: Vec<u64>) -> Vec<CwsSample> {
        hashes
            .into_iter()
            .map(|h| CwsSample { i_star: (h >> 32) as u32, t_star: (h & 0xffff_ffff) as i64 })
            .collect()
    }
}

impl Sketcher for MinwiseSketcher {
    fn k(&self) -> usize {
        self.inner.k()
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn name(&self) -> &'static str {
        "minwise"
    }

    fn sketch_sparse(&self, row: SparseRow<'_>) -> Vec<CwsSample> {
        Self::pack(self.inner.hash(row))
    }

    fn sketch_dense(&self, u: &[f32]) -> Vec<CwsSample> {
        let indices: Vec<u32> =
            u.iter().enumerate().filter(|(_, &v)| v > 0.0).map(|(i, _)| i as u32).collect();
        assert!(!indices.is_empty(), "minwise hashing is undefined on the empty set");
        let values = vec![1.0f32; indices.len()];
        Self::pack(self.inner.hash(SparseRow { indices: &indices, values: &values }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::schemes::{collision_fraction, Scheme};
    use crate::data::dense::Dense;
    use crate::data::sparse::Csr;
    use crate::kernels::dense_resemblance;
    use crate::util::rng::Pcg64;

    fn random_vec(rng: &mut Pcg64, dim: usize, zero_frac: f64) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim)
            .map(|_| if rng.uniform() < zero_frac { 0.0 } else { rng.lognormal(0.0, 1.0) as f32 })
            .collect();
        if !v.iter().any(|&x| x > 0.0) {
            v[0] = 1.0;
        }
        v
    }

    #[test]
    fn trait_and_inherent_paths_agree() {
        let mut rng = Pcg64::new(3);
        let h = CwsHasher::new(42, 16);
        let s: &dyn Sketcher = &h;
        for _ in 0..10 {
            let v = random_vec(&mut rng, 32, 0.4);
            assert_eq!(s.sketch_dense(&v), h.hash_dense(&v));
        }
        assert_eq!(s.k(), 16);
        assert_eq!(s.seed(), 42);
    }

    #[test]
    fn dense_batch_hasher_is_a_parity_sketcher() {
        if engine::fast_math_requested() {
            eprintln!("skipped: bit parity is only claimed without MINMAX_FAST_MATH");
            return;
        }
        let mut rng = Pcg64::new(7);
        let lazy = CwsHasher::new(9, 24);
        let mat = lazy.dense_batch(40);
        let a: &dyn Sketcher = &lazy;
        let b: &dyn Sketcher = &mat;
        for _ in 0..15 {
            let v = random_vec(&mut rng, 40, 0.5);
            assert_eq!(a.sketch_dense(&v), b.sketch_dense(&v));
            let d = Dense::from_rows(&[&v]);
            let s = Csr::from_dense(&d);
            assert_eq!(a.sketch_sparse(s.row(0)), b.sketch_sparse(s.row(0)));
        }
    }

    #[test]
    fn sketch_matrix_marks_empty_rows() {
        if engine::fast_math_requested() {
            eprintln!("skipped: bit parity is only claimed without MINMAX_FAST_MATH");
            return;
        }
        let d = Dense::from_rows(&[&[1.0, 0.0], &[0.0, 0.0], &[0.5, 2.0]]);
        for m in [Matrix::Dense(d.clone()), Matrix::Sparse(Csr::from_dense(&d))] {
            let h = CwsHasher::new(1, 8);
            let out = Sketcher::sketch_matrix(&h, &m);
            assert!(out[0].is_some());
            assert!(out[1].is_none());
            assert_eq!(out[2].as_ref().unwrap().len(), 8);
            assert_eq!(out[0], Some(h.hash_dense(&[1.0, 0.0])));
        }
    }

    #[test]
    fn minwise_sketcher_estimates_resemblance() {
        let mut rng = Pcg64::new(11);
        let d = 4000usize;
        let u: Vec<f32> =
            (0..d).map(|_| if rng.uniform() < 0.9 { 0.0 } else { 1.0 }).collect();
        let v: Vec<f32> = u
            .iter()
            .map(|&x| {
                if rng.uniform() < 0.15 {
                    1.0 - x
                } else {
                    x
                }
            })
            .collect();
        let truth = dense_resemblance(&u, &v);
        let k = 3000;
        let s = MinwiseSketcher::new(5, k);
        let (su, sv) = (s.sketch_dense(&u), s.sketch_dense(&v));
        let full = collision_fraction(Scheme::FULL, &su, &sv);
        let zero = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
        let tol = 4.0 * (truth * (1.0 - truth) / k as f64).sqrt() + 0.01;
        assert!((full - truth).abs() < tol, "full {full} vs R {truth}");
        assert!((zero - truth).abs() < tol, "0-bit {zero} vs R {truth}");
    }

    #[test]
    fn minwise_dense_matches_sparse() {
        let u = [0.0f32, 2.5, 0.0, 1.0, 3.0, 0.0];
        let d = Dense::from_rows(&[&u]);
        let c = Csr::from_dense(&d);
        let s = MinwiseSketcher::new(8, 32);
        assert_eq!(s.sketch_dense(&u), s.sketch_sparse(c.row(0)));
        assert_eq!(s.name(), "minwise");
        assert_eq!(s.k(), 32);
        assert_eq!(s.seed(), 8);
    }
}
