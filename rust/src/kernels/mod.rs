//! The paper's kernels (Eqs. 1–5) plus the extensions it references, with
//! dense and sparse (merge-join) fast paths, and the blocked, parallel
//! kernel-matrix computation used by the kernel-SVM experiments.
//!
//! Two layers live here:
//!
//! * the open [`Kernel`] **trait** — the public abstraction: an exact
//!   pairwise similarity (dense + sparse fast paths) together with its
//!   **hashed linearization** ([`KernelKind::sketcher`]), i.e. the
//!   [`crate::sketch::Sketcher`] family whose collision probability
//!   equals the kernel (Eq. 7 for min-max, Eq. 2 for resemblance);
//! * the closed [`KernelKind`] **enum** — the paper's concrete kernel
//!   set, implementing the trait, used by the experiment drivers and
//!   anywhere a `Copy + Eq` kernel id is convenient.
//!
//! The concrete forms:
//!
//! * [`KernelKind::MinMax`] — Eq. (1), the paper's subject.
//! * [`KernelKind::NMinMax`] — Eq. (4): min-max after ℓ₁ normalization.
//! * [`KernelKind::Intersection`] — Eq. (3): Σ min after ℓ₁ normalization.
//! * [`KernelKind::Linear`] — Eq. (5): inner product after ℓ₂
//!   normalization.
//! * [`KernelKind::Resemblance`] — Eq. (2): binary Jaccard (Table 2's
//!   "R" column and the b-bit-minwise baseline).
//! * [`KernelKind::Chi2`] — the chi-square kernel `Σ 2uᵢvᵢ/(uᵢ+vᵢ)`
//!   referenced in §2, used in the CoRE-style product-kernel ablation.
//!
//! Normalization is **the caller's job** (see [`crate::data::scale`] and
//! [`crate::pipeline::Scaling`]); these functions compute the raw
//! functional forms. The paper applies normalization before hashing too,
//! so kernels and sketchers see identical inputs.

pub mod gram;
pub mod matrix;

use crate::data::sparse::SparseRow;
use crate::sketch::{MinwiseSketcher, Sketcher};

/// An exact pairwise similarity plus (when one exists) its hashed
/// linearization. Implement this to plug a new kernel into the kernel
/// matrices, the SVM sweep protocol, and the [`crate::pipeline`] stack;
/// [`KernelKind`] provides the paper's concrete set.
pub trait Kernel {
    /// Short display name.
    fn name(&self) -> &'static str;

    /// Which row normalization the evaluation protocol applies before
    /// this kernel (the kernels themselves are raw functional forms).
    fn required_normalization(&self) -> Normalization {
        Normalization::None
    }

    /// Evaluate on dense rows (same length, nonnegative).
    fn eval_dense(&self, u: &[f32], v: &[f32]) -> f64;

    /// Evaluate on sorted sparse rows.
    fn eval_sparse(&self, u: SparseRow<'_>, v: SparseRow<'_>) -> f64;

    /// The kernel's hashed linearization: a [`Sketcher`] whose collision
    /// probability (full or 0-bit scheme; see [`crate::cws::Scheme`])
    /// equals this kernel on the normalized inputs, or `None` when no
    /// such sampler is known (linear, chi², intersection).
    fn sketcher(&self, seed: u64, k: usize) -> Option<Box<dyn Sketcher>> {
        let _ = (seed, k);
        None
    }
}

// References to kernels are kernels, so `kernel_matrix(&k, …)` and
// `&dyn Kernel` arguments both work.
impl<K: Kernel + ?Sized> Kernel for &K {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn required_normalization(&self) -> Normalization {
        (**self).required_normalization()
    }

    fn eval_dense(&self, u: &[f32], v: &[f32]) -> f64 {
        (**self).eval_dense(u, v)
    }

    fn eval_sparse(&self, u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
        (**self).eval_sparse(u, v)
    }

    fn sketcher(&self, seed: u64, k: usize) -> Option<Box<dyn Sketcher>> {
        (**self).sketcher(seed, k)
    }
}

/// The paper's kernel set (closed enum; see the [`Kernel`] trait for the
/// open extension point).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Linear,
    MinMax,
    /// Min-max evaluated on ℓ₁-normalized inputs (caller normalizes).
    NMinMax,
    /// Σ min on ℓ₁-normalized inputs (caller normalizes).
    Intersection,
    Resemblance,
    Chi2,
    /// CoRE-style product: MinMax × Chi2 (§2's "combine kernels" remark).
    MinMaxChi2,
}

impl KernelKind {
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Linear => "linear",
            KernelKind::MinMax => "min-max",
            KernelKind::NMinMax => "n-min-max",
            KernelKind::Intersection => "intersection",
            KernelKind::Resemblance => "resemblance",
            KernelKind::Chi2 => "chi2",
            KernelKind::MinMaxChi2 => "minmax*chi2",
        }
    }

    pub fn from_name(s: &str) -> Option<KernelKind> {
        Some(match s {
            "linear" => KernelKind::Linear,
            "min-max" | "minmax" => KernelKind::MinMax,
            "n-min-max" | "nminmax" => KernelKind::NMinMax,
            "intersection" => KernelKind::Intersection,
            "resemblance" => KernelKind::Resemblance,
            "chi2" => KernelKind::Chi2,
            "minmax*chi2" | "core" => KernelKind::MinMaxChi2,
            _ => return None,
        })
    }

    /// Which row normalization the paper's protocol applies before this
    /// kernel: Eq. (3)/(4) require ℓ₁ (sum-to-one), Eq. (5) requires ℓ₂.
    pub fn required_normalization(&self) -> Normalization {
        match self {
            KernelKind::Linear => Normalization::L2,
            KernelKind::NMinMax | KernelKind::Intersection => Normalization::L1,
            KernelKind::MinMax
            | KernelKind::Resemblance
            | KernelKind::Chi2
            | KernelKind::MinMaxChi2 => Normalization::None,
        }
    }

    /// Evaluate on dense rows (same length, nonnegative).
    pub fn eval_dense(&self, u: &[f32], v: &[f32]) -> f64 {
        match self {
            KernelKind::Linear => dense_dot(u, v),
            KernelKind::MinMax | KernelKind::NMinMax => dense_minmax(u, v),
            KernelKind::Intersection => dense_intersection(u, v),
            KernelKind::Resemblance => dense_resemblance(u, v),
            KernelKind::Chi2 => dense_chi2(u, v),
            KernelKind::MinMaxChi2 => dense_minmax(u, v) * dense_chi2(u, v),
        }
    }

    /// Evaluate on sorted sparse rows.
    pub fn eval_sparse(&self, u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
        match self {
            KernelKind::Linear => crate::data::sparse::dot(u, v),
            KernelKind::MinMax | KernelKind::NMinMax => sparse_minmax(u, v),
            KernelKind::Intersection => sparse_intersection(u, v),
            KernelKind::Resemblance => sparse_resemblance(u, v),
            KernelKind::Chi2 => sparse_chi2(u, v),
            KernelKind::MinMaxChi2 => sparse_minmax(u, v) * sparse_chi2(u, v),
        }
    }
}

impl Kernel for KernelKind {
    fn name(&self) -> &'static str {
        KernelKind::name(self)
    }

    fn required_normalization(&self) -> Normalization {
        KernelKind::required_normalization(self)
    }

    fn eval_dense(&self, u: &[f32], v: &[f32]) -> f64 {
        KernelKind::eval_dense(self, u, v)
    }

    fn eval_sparse(&self, u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
        KernelKind::eval_sparse(self, u, v)
    }

    fn sketcher(&self, seed: u64, k: usize) -> Option<Box<dyn Sketcher>> {
        match self {
            // ICWS collisions estimate K_MM (Eq. 7); n-min-max is the
            // same sampler on ℓ₁-normalized input (the pipeline's
            // Scaling stage applies it).
            KernelKind::MinMax | KernelKind::NMinMax => {
                Some(Box::new(crate::cws::CwsHasher::new(seed, k)))
            }
            // Minwise over the support estimates the resemblance.
            KernelKind::Resemblance => Some(Box::new(MinwiseSketcher::new(seed, k))),
            KernelKind::Linear
            | KernelKind::Intersection
            | KernelKind::Chi2
            | KernelKind::MinMaxChi2 => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Normalization {
    None,
    L1,
    L2,
}

// ---------------------------------------------------------------- dense

#[inline]
pub fn dense_dot(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut s = 0.0f64;
    for (&a, &b) in u.iter().zip(v) {
        s += a as f64 * b as f64;
    }
    s
}

/// Eq. (1): Σ min / Σ max. Returns 1.0 when both vectors are all-zero
/// (identical inputs — consistent with the hashing convention).
#[inline]
pub fn dense_minmax(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut smin = 0.0f64;
    let mut smax = 0.0f64;
    for (&a, &b) in u.iter().zip(v) {
        // branchless min/max
        let mn = a.min(b);
        let mx = a.max(b);
        smin += mn as f64;
        smax += mx as f64;
    }
    if smax == 0.0 {
        1.0
    } else {
        smin / smax
    }
}

/// Eq. (3): Σ min (the caller ℓ₁-normalizes per the definition).
#[inline]
pub fn dense_intersection(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut s = 0.0f64;
    for (&a, &b) in u.iter().zip(v) {
        s += a.min(b) as f64;
    }
    s
}

/// Eq. (2): |{u>0 ∧ v>0}| / |{u>0 ∨ v>0}| (1.0 for two empty vectors).
#[inline]
pub fn dense_resemblance(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut inter = 0u64;
    let mut union = 0u64;
    for (&a, &b) in u.iter().zip(v) {
        let pa = a > 0.0;
        let pb = b > 0.0;
        inter += (pa && pb) as u64;
        union += (pa || pb) as u64;
    }
    if union == 0 {
        1.0
    } else {
        inter as f64 / union as f64
    }
}

/// Additive chi-square kernel: Σ 2uv/(u+v) over entries where u+v > 0.
#[inline]
pub fn dense_chi2(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut s = 0.0f64;
    for (&a, &b) in u.iter().zip(v) {
        let d = a as f64 + b as f64;
        if d > 0.0 {
            s += 2.0 * a as f64 * b as f64 / d;
        }
    }
    s
}

// --------------------------------------------------------------- sparse
// Merge joins over sorted index lists; only nonzeros are touched. For
// min-max, indices present in exactly one vector contribute to Σmax only.

#[inline]
pub fn sparse_minmax(u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
    let mut smin = 0.0f64;
    let mut smax = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < u.indices.len() && j < v.indices.len() {
        let (iu, iv) = (u.indices[i], v.indices[j]);
        if iu == iv {
            let (a, b) = (u.values[i], v.values[j]);
            smin += a.min(b) as f64;
            smax += a.max(b) as f64;
            i += 1;
            j += 1;
        } else if iu < iv {
            smax += u.values[i] as f64;
            i += 1;
        } else {
            smax += v.values[j] as f64;
            j += 1;
        }
    }
    for &a in &u.values[i..] {
        smax += a as f64;
    }
    for &b in &v.values[j..] {
        smax += b as f64;
    }
    if smax == 0.0 {
        1.0
    } else {
        smin / smax
    }
}

#[inline]
pub fn sparse_intersection(u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
    let mut s = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < u.indices.len() && j < v.indices.len() {
        let (iu, iv) = (u.indices[i], v.indices[j]);
        if iu == iv {
            s += u.values[i].min(v.values[j]) as f64;
            i += 1;
            j += 1;
        } else if iu < iv {
            i += 1;
        } else {
            j += 1;
        }
    }
    s
}

#[inline]
pub fn sparse_resemblance(u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
    let mut inter = 0u64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < u.indices.len() && j < v.indices.len() {
        let (iu, iv) = (u.indices[i], v.indices[j]);
        if iu == iv {
            inter += 1;
            i += 1;
            j += 1;
        } else if iu < iv {
            i += 1;
        } else {
            j += 1;
        }
    }
    let union = u.indices.len() as u64 + v.indices.len() as u64 - inter;
    if union == 0 {
        1.0
    } else {
        inter as f64 / union as f64
    }
}

#[inline]
pub fn sparse_chi2(u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
    let mut s = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < u.indices.len() && j < v.indices.len() {
        let (iu, iv) = (u.indices[i], v.indices[j]);
        if iu == iv {
            let (a, b) = (u.values[i] as f64, v.values[j] as f64);
            let d = a + b;
            if d > 0.0 {
                s += 2.0 * a * b / d;
            }
            i += 1;
            j += 1;
        } else if iu < iv {
            i += 1;
        } else {
            j += 1;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::Dense;
    use crate::data::sparse::Csr;

    fn pair() -> (Vec<f32>, Vec<f32>) {
        (vec![0.0, 1.0, 3.0, 0.0, 2.0], vec![1.0, 2.0, 1.0, 0.0, 2.0])
    }

    #[test]
    fn minmax_hand_computed() {
        let (u, v) = pair();
        // min: 0+1+1+0+2=4 ; max: 1+2+3+0+2=8
        assert!((dense_minmax(&u, &v) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn intersection_hand_computed() {
        let (u, v) = pair();
        assert!((dense_intersection(&u, &v) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn resemblance_hand_computed() {
        let (u, v) = pair();
        // supports: u {1,2,4}, v {0,1,2,4} → inter 3, union 4
        assert!((dense_resemblance(&u, &v) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn chi2_hand_computed() {
        let u = [1.0f32, 0.0, 2.0];
        let v = [1.0f32, 3.0, 0.0];
        // 2*1*1/2 + 0 + 0 = 1
        assert!((dense_chi2(&u, &v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kernels_are_symmetric() {
        let (u, v) = pair();
        for k in [
            KernelKind::Linear,
            KernelKind::MinMax,
            KernelKind::Intersection,
            KernelKind::Resemblance,
            KernelKind::Chi2,
            KernelKind::MinMaxChi2,
        ] {
            assert!(
                (k.eval_dense(&u, &v) - k.eval_dense(&v, &u)).abs() < 1e-12,
                "{} not symmetric",
                k.name()
            );
        }
    }

    #[test]
    fn self_similarity_is_one_for_normalized_kernels() {
        let (u, _) = pair();
        assert!((dense_minmax(&u, &u) - 1.0).abs() < 1e-12);
        assert!((dense_resemblance(&u, &u) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn minmax_bounded_01() {
        let mut rng = crate::util::rng::Pcg64::new(7);
        for _ in 0..200 {
            let u: Vec<f32> = (0..16).map(|_| rng.lognormal(0.0, 1.0) as f32).collect();
            let v: Vec<f32> = (0..16).map(|_| rng.lognormal(0.0, 1.0) as f32).collect();
            let k = dense_minmax(&u, &v);
            assert!((0.0..=1.0).contains(&k));
        }
    }

    #[test]
    fn sparse_matches_dense_all_kernels() {
        let mut rng = crate::util::rng::Pcg64::new(11);
        for _ in 0..100 {
            let dim = 1 + rng.below(40) as usize;
            let gen_row = |rng: &mut crate::util::rng::Pcg64| -> Vec<f32> {
                (0..dim)
                    .map(|_| {
                        if rng.uniform() < 0.5 {
                            0.0
                        } else {
                            rng.lognormal(0.0, 1.0) as f32
                        }
                    })
                    .collect()
            };
            let u = gen_row(&mut rng);
            let v = gen_row(&mut rng);
            let d = Dense::from_rows(&[&u, &v]);
            let s = Csr::from_dense(&d);
            for k in [
                KernelKind::Linear,
                KernelKind::MinMax,
                KernelKind::Intersection,
                KernelKind::Resemblance,
                KernelKind::Chi2,
                KernelKind::MinMaxChi2,
            ] {
                let kd = k.eval_dense(&u, &v);
                let ks = k.eval_sparse(s.row(0), s.row(1));
                assert!(
                    (kd - ks).abs() < 1e-9 * (1.0 + kd.abs()),
                    "{}: dense {kd} vs sparse {ks}",
                    k.name()
                );
            }
        }
    }

    #[test]
    fn empty_vs_empty_conventions() {
        let z = [0.0f32; 4];
        assert_eq!(dense_minmax(&z, &z), 1.0);
        assert_eq!(dense_resemblance(&z, &z), 1.0);
        assert_eq!(dense_intersection(&z, &z), 0.0);
    }

    #[test]
    fn binary_data_collapses_minmax_to_resemblance() {
        // On 0/1 vectors, Eq. (1) == Eq. (2) — the generalization claim.
        let u = [1.0f32, 0.0, 1.0, 1.0, 0.0];
        let v = [1.0f32, 1.0, 0.0, 1.0, 0.0];
        assert!((dense_minmax(&u, &v) - dense_resemblance(&u, &v)).abs() < 1e-12);
    }

    #[test]
    fn name_roundtrip() {
        for k in [
            KernelKind::Linear,
            KernelKind::MinMax,
            KernelKind::NMinMax,
            KernelKind::Intersection,
            KernelKind::Resemblance,
            KernelKind::Chi2,
            KernelKind::MinMaxChi2,
        ] {
            assert_eq!(KernelKind::from_name(k.name()), Some(k));
        }
        assert_eq!(KernelKind::from_name("nope"), None);
    }
}
