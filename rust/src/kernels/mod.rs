//! The paper's kernels (Eqs. 1–5) plus the extensions it references, with
//! dense and sparse (merge-join) fast paths, and the blocked, parallel
//! kernel-matrix computation used by the kernel-SVM experiments.
//!
//! * [`Kernel::MinMax`] — Eq. (1), the paper's subject.
//! * [`Kernel::NMinMax`] — Eq. (4): min-max after ℓ₁ normalization.
//! * [`Kernel::Intersection`] — Eq. (3): Σ min after ℓ₁ normalization.
//! * [`Kernel::Linear`] — Eq. (5): inner product after ℓ₂ normalization.
//! * [`Kernel::Resemblance`] — Eq. (2): binary Jaccard (for Table 2's "R"
//!   column and the b-bit-minwise baseline).
//! * [`Kernel::Chi2`] — the chi-square kernel `Σ 2uᵢvᵢ/(uᵢ+vᵢ)` referenced
//!   in §2 (hashable by sign Cauchy projections), used in the CoRE-style
//!   product-kernel ablation.
//!
//! Normalization is **the caller's job** (see [`crate::data::scale`]);
//! these functions compute the raw functional forms. The paper applies
//! normalization before hashing too, so kernels and CWS see identical
//! inputs.

pub mod matrix;

use crate::data::sparse::SparseRow;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kernel {
    Linear,
    MinMax,
    /// Min-max evaluated on ℓ₁-normalized inputs (caller normalizes).
    NMinMax,
    /// Σ min on ℓ₁-normalized inputs (caller normalizes).
    Intersection,
    Resemblance,
    Chi2,
    /// CoRE-style product: MinMax × Chi2 (§2's "combine kernels" remark).
    MinMaxChi2,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Linear => "linear",
            Kernel::MinMax => "min-max",
            Kernel::NMinMax => "n-min-max",
            Kernel::Intersection => "intersection",
            Kernel::Resemblance => "resemblance",
            Kernel::Chi2 => "chi2",
            Kernel::MinMaxChi2 => "minmax*chi2",
        }
    }

    pub fn from_name(s: &str) -> Option<Kernel> {
        Some(match s {
            "linear" => Kernel::Linear,
            "min-max" | "minmax" => Kernel::MinMax,
            "n-min-max" | "nminmax" => Kernel::NMinMax,
            "intersection" => Kernel::Intersection,
            "resemblance" => Kernel::Resemblance,
            "chi2" => Kernel::Chi2,
            "minmax*chi2" | "core" => Kernel::MinMaxChi2,
            _ => return None,
        })
    }

    /// Which row normalization the paper's protocol applies before this
    /// kernel: Eq. (3)/(4) require ℓ₁ (sum-to-one), Eq. (5) requires ℓ₂.
    pub fn required_normalization(&self) -> Normalization {
        match self {
            Kernel::Linear => Normalization::L2,
            Kernel::NMinMax | Kernel::Intersection => Normalization::L1,
            Kernel::MinMax | Kernel::Resemblance | Kernel::Chi2 | Kernel::MinMaxChi2 => {
                Normalization::None
            }
        }
    }

    /// Evaluate on dense rows (same length, nonnegative).
    pub fn eval_dense(&self, u: &[f32], v: &[f32]) -> f64 {
        match self {
            Kernel::Linear => dense_dot(u, v),
            Kernel::MinMax | Kernel::NMinMax => dense_minmax(u, v),
            Kernel::Intersection => dense_intersection(u, v),
            Kernel::Resemblance => dense_resemblance(u, v),
            Kernel::Chi2 => dense_chi2(u, v),
            Kernel::MinMaxChi2 => dense_minmax(u, v) * dense_chi2(u, v),
        }
    }

    /// Evaluate on sorted sparse rows.
    pub fn eval_sparse(&self, u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
        match self {
            Kernel::Linear => crate::data::sparse::dot(u, v),
            Kernel::MinMax | Kernel::NMinMax => sparse_minmax(u, v),
            Kernel::Intersection => sparse_intersection(u, v),
            Kernel::Resemblance => sparse_resemblance(u, v),
            Kernel::Chi2 => sparse_chi2(u, v),
            Kernel::MinMaxChi2 => sparse_minmax(u, v) * sparse_chi2(u, v),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Normalization {
    None,
    L1,
    L2,
}

// ---------------------------------------------------------------- dense

#[inline]
pub fn dense_dot(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut s = 0.0f64;
    for (&a, &b) in u.iter().zip(v) {
        s += a as f64 * b as f64;
    }
    s
}

/// Eq. (1): Σ min / Σ max. Returns 1.0 when both vectors are all-zero
/// (identical inputs — consistent with the hashing convention).
#[inline]
pub fn dense_minmax(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut smin = 0.0f64;
    let mut smax = 0.0f64;
    for (&a, &b) in u.iter().zip(v) {
        // branchless min/max
        let mn = a.min(b);
        let mx = a.max(b);
        smin += mn as f64;
        smax += mx as f64;
    }
    if smax == 0.0 {
        1.0
    } else {
        smin / smax
    }
}

/// Eq. (3): Σ min (the caller ℓ₁-normalizes per the definition).
#[inline]
pub fn dense_intersection(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut s = 0.0f64;
    for (&a, &b) in u.iter().zip(v) {
        s += a.min(b) as f64;
    }
    s
}

/// Eq. (2): |{u>0 ∧ v>0}| / |{u>0 ∨ v>0}| (1.0 for two empty vectors).
#[inline]
pub fn dense_resemblance(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut inter = 0u64;
    let mut union = 0u64;
    for (&a, &b) in u.iter().zip(v) {
        let pa = a > 0.0;
        let pb = b > 0.0;
        inter += (pa && pb) as u64;
        union += (pa || pb) as u64;
    }
    if union == 0 {
        1.0
    } else {
        inter as f64 / union as f64
    }
}

/// Additive chi-square kernel: Σ 2uv/(u+v) over entries where u+v > 0.
#[inline]
pub fn dense_chi2(u: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), v.len());
    let mut s = 0.0f64;
    for (&a, &b) in u.iter().zip(v) {
        let d = a as f64 + b as f64;
        if d > 0.0 {
            s += 2.0 * a as f64 * b as f64 / d;
        }
    }
    s
}

// --------------------------------------------------------------- sparse
// Merge joins over sorted index lists; only nonzeros are touched. For
// min-max, indices present in exactly one vector contribute to Σmax only.

#[inline]
pub fn sparse_minmax(u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
    let mut smin = 0.0f64;
    let mut smax = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < u.indices.len() && j < v.indices.len() {
        let (iu, iv) = (u.indices[i], v.indices[j]);
        if iu == iv {
            let (a, b) = (u.values[i], v.values[j]);
            smin += a.min(b) as f64;
            smax += a.max(b) as f64;
            i += 1;
            j += 1;
        } else if iu < iv {
            smax += u.values[i] as f64;
            i += 1;
        } else {
            smax += v.values[j] as f64;
            j += 1;
        }
    }
    for &a in &u.values[i..] {
        smax += a as f64;
    }
    for &b in &v.values[j..] {
        smax += b as f64;
    }
    if smax == 0.0 {
        1.0
    } else {
        smin / smax
    }
}

#[inline]
pub fn sparse_intersection(u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
    let mut s = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < u.indices.len() && j < v.indices.len() {
        let (iu, iv) = (u.indices[i], v.indices[j]);
        if iu == iv {
            s += u.values[i].min(v.values[j]) as f64;
            i += 1;
            j += 1;
        } else if iu < iv {
            i += 1;
        } else {
            j += 1;
        }
    }
    s
}

#[inline]
pub fn sparse_resemblance(u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
    let mut inter = 0u64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < u.indices.len() && j < v.indices.len() {
        let (iu, iv) = (u.indices[i], v.indices[j]);
        if iu == iv {
            inter += 1;
            i += 1;
            j += 1;
        } else if iu < iv {
            i += 1;
        } else {
            j += 1;
        }
    }
    let union = u.indices.len() as u64 + v.indices.len() as u64 - inter;
    if union == 0 {
        1.0
    } else {
        inter as f64 / union as f64
    }
}

#[inline]
pub fn sparse_chi2(u: SparseRow<'_>, v: SparseRow<'_>) -> f64 {
    let mut s = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < u.indices.len() && j < v.indices.len() {
        let (iu, iv) = (u.indices[i], v.indices[j]);
        if iu == iv {
            let (a, b) = (u.values[i] as f64, v.values[j] as f64);
            let d = a + b;
            if d > 0.0 {
                s += 2.0 * a * b / d;
            }
            i += 1;
            j += 1;
        } else if iu < iv {
            i += 1;
        } else {
            j += 1;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::Dense;
    use crate::data::sparse::Csr;

    fn pair() -> (Vec<f32>, Vec<f32>) {
        (vec![0.0, 1.0, 3.0, 0.0, 2.0], vec![1.0, 2.0, 1.0, 0.0, 2.0])
    }

    #[test]
    fn minmax_hand_computed() {
        let (u, v) = pair();
        // min: 0+1+1+0+2=4 ; max: 1+2+3+0+2=8
        assert!((dense_minmax(&u, &v) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn intersection_hand_computed() {
        let (u, v) = pair();
        assert!((dense_intersection(&u, &v) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn resemblance_hand_computed() {
        let (u, v) = pair();
        // supports: u {1,2,4}, v {0,1,2,4} → inter 3, union 4
        assert!((dense_resemblance(&u, &v) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn chi2_hand_computed() {
        let u = [1.0f32, 0.0, 2.0];
        let v = [1.0f32, 3.0, 0.0];
        // 2*1*1/2 + 0 + 0 = 1
        assert!((dense_chi2(&u, &v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kernels_are_symmetric() {
        let (u, v) = pair();
        for k in [
            Kernel::Linear,
            Kernel::MinMax,
            Kernel::Intersection,
            Kernel::Resemblance,
            Kernel::Chi2,
            Kernel::MinMaxChi2,
        ] {
            assert!(
                (k.eval_dense(&u, &v) - k.eval_dense(&v, &u)).abs() < 1e-12,
                "{} not symmetric",
                k.name()
            );
        }
    }

    #[test]
    fn self_similarity_is_one_for_normalized_kernels() {
        let (u, _) = pair();
        assert!((dense_minmax(&u, &u) - 1.0).abs() < 1e-12);
        assert!((dense_resemblance(&u, &u) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn minmax_bounded_01() {
        let mut rng = crate::util::rng::Pcg64::new(7);
        for _ in 0..200 {
            let u: Vec<f32> = (0..16).map(|_| rng.lognormal(0.0, 1.0) as f32).collect();
            let v: Vec<f32> = (0..16).map(|_| rng.lognormal(0.0, 1.0) as f32).collect();
            let k = dense_minmax(&u, &v);
            assert!((0.0..=1.0).contains(&k));
        }
    }

    #[test]
    fn sparse_matches_dense_all_kernels() {
        let mut rng = crate::util::rng::Pcg64::new(11);
        for _ in 0..100 {
            let dim = 1 + rng.below(40) as usize;
            let gen_row = |rng: &mut crate::util::rng::Pcg64| -> Vec<f32> {
                (0..dim)
                    .map(|_| {
                        if rng.uniform() < 0.5 {
                            0.0
                        } else {
                            rng.lognormal(0.0, 1.0) as f32
                        }
                    })
                    .collect()
            };
            let u = gen_row(&mut rng);
            let v = gen_row(&mut rng);
            let d = Dense::from_rows(&[&u, &v]);
            let s = Csr::from_dense(&d);
            for k in [
                Kernel::Linear,
                Kernel::MinMax,
                Kernel::Intersection,
                Kernel::Resemblance,
                Kernel::Chi2,
                Kernel::MinMaxChi2,
            ] {
                let kd = k.eval_dense(&u, &v);
                let ks = k.eval_sparse(s.row(0), s.row(1));
                assert!(
                    (kd - ks).abs() < 1e-9 * (1.0 + kd.abs()),
                    "{}: dense {kd} vs sparse {ks}",
                    k.name()
                );
            }
        }
    }

    #[test]
    fn empty_vs_empty_conventions() {
        let z = [0.0f32; 4];
        assert_eq!(dense_minmax(&z, &z), 1.0);
        assert_eq!(dense_resemblance(&z, &z), 1.0);
        assert_eq!(dense_intersection(&z, &z), 0.0);
    }

    #[test]
    fn binary_data_collapses_minmax_to_resemblance() {
        // On 0/1 vectors, Eq. (1) == Eq. (2) — the generalization claim.
        let u = [1.0f32, 0.0, 1.0, 1.0, 0.0];
        let v = [1.0f32, 1.0, 0.0, 1.0, 0.0];
        assert!((dense_minmax(&u, &v) - dense_resemblance(&u, &v)).abs() < 1e-12);
    }

    #[test]
    fn name_roundtrip() {
        for k in [
            Kernel::Linear,
            Kernel::MinMax,
            Kernel::NMinMax,
            Kernel::Intersection,
            Kernel::Resemblance,
            Kernel::Chi2,
            Kernel::MinMaxChi2,
        ] {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
        }
        assert_eq!(Kernel::from_name("nope"), None);
    }
}
