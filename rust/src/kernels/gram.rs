//! Gram sources — the solver's view of the training kernel.
//!
//! The LIBSVM `-t 4` setup the paper's §2 experiments inherit trains on
//! a fully materialized n×n kernel matrix, which caps n at whatever n²
//! floats fit in RAM — exactly the scalability wall hashing exists to
//! remove. [`GramSource`] decouples the dual solver from that choice:
//!
//! * [`Precomputed`] (and [`Dense`] directly) — today's path, the whole
//!   Gram up front. O(n²) memory, O(1) row fetches.
//! * [`OnTheFly`] — kernel rows computed on demand from the stored
//!   [`Matrix`] via the existing dense/sparse fast paths, behind a
//!   bounded LRU row cache; cache-miss rows are filled in parallel
//!   chunks over [`crate::util::pool::par_chunks_mut`]. O(cache · n)
//!   memory, one O(n · nnz) computation per cache miss.
//! * [`SubsetGram`] — a lazy index-mapped view of any source (the
//!   one-vs-one wrapper hands each class pair one of these instead of
//!   copying an m×m sub-Gram).
//!
//! The hard invariant, pinned by `rust/tests/gram_parity.rs`: every
//! source yields **bit-identical** rows for the same training matrix, so
//! `Precomputed` vs `OnTheFly` (any cache size, any thread count)
//! produce bit-identical models. On-the-fly rows rely on the kernels
//! being bitwise symmetric (`k(u, v) == k(v, u)` exactly — every
//! [`Kernel`] here accumulates elementwise-commutative terms in index
//! order), which makes a streamed full row equal to the mirrored
//! upper-triangle row of [`super::matrix::kernel_matrix_sym`].

use std::collections::HashMap; // hash-ok: LRU row cache, keyed lookups only (see Lru).
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::dense::Dense;
use crate::data::Matrix;
use crate::util::pool;

use super::{Kernel, KernelKind};

/// Chunk floor for the parallel row fill: below this many kernel
/// evaluations per chunk, scoped-thread spawns dominate the work (and
/// nested parallelism inside the already-parallel OvO pair loop would
/// oversubscribe on small problems).
const ROW_MIN_CHUNK: usize = 256;

/// The solver's view of a symmetric training kernel: row fetches,
/// diagonal reads, and a materialization counter. `Sync` because
/// one-vs-one pairs train in parallel against a shared source.
///
/// The generic `with_row` visitor (instead of returning a slice) lets
/// cached sources hand out rows without copying while keeping eviction
/// safe: the row is guaranteed alive only for the callback's duration.
pub trait GramSource: Sync {
    /// Number of training rows (the Gram is `n × n`).
    fn n(&self) -> usize;

    /// Diagonal entry `K[i][i]`, at the same f32 precision the row path
    /// produces (the solver's Q̄ᵢᵢ must agree across sources bit-for-bit).
    fn diag(&self, i: usize) -> f32;

    /// Visit kernel row `i` (length [`GramSource::n`]).
    fn with_row<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R;

    /// Kernel rows materialized so far — the peak-memory/work proxy the
    /// benches record. A precomputed Gram counts all n up front; an
    /// on-the-fly source counts cache misses (recomputation after
    /// eviction counts again: it is a work proxy, not a high-water mark).
    fn rows_materialized(&self) -> usize;
}

/// A fully materialized symmetric Gram is a [`GramSource`] directly —
/// today's `train_binary(&Dense, …)` callers keep working unchanged.
impl GramSource for Dense {
    fn n(&self) -> usize {
        debug_assert_eq!(self.rows(), self.cols(), "gram must be square");
        self.rows()
    }

    fn diag(&self, i: usize) -> f32 {
        self.get(i, i)
    }

    fn with_row<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        f(self.row(i))
    }

    fn rows_materialized(&self) -> usize {
        self.rows()
    }
}

/// Named owner of a precomputed Gram (the LIBSVM `-t 4` path), for
/// symmetry with [`OnTheFly`] at call sites that own their matrix.
#[derive(Debug, Clone)]
pub struct Precomputed(pub Dense);

impl GramSource for Precomputed {
    fn n(&self) -> usize {
        GramSource::n(&self.0)
    }

    fn diag(&self, i: usize) -> f32 {
        self.0.diag(i)
    }

    fn with_row<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        self.0.with_row(i, f)
    }

    fn rows_materialized(&self) -> usize {
        self.0.rows_materialized()
    }
}

/// How a driver should build its training-kernel source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GramSpec {
    /// Materialize the full n×n Gram up front.
    Precomputed,
    /// Stream rows on demand behind an LRU cache of `cache_rows` rows
    /// (`None` = the default cap of n/4 — 25% of the precomputed
    /// footprint).
    OnTheFly { cache_rows: Option<usize> },
}

impl GramSpec {
    /// Resolve the cache cap for a problem of `n` training rows.
    pub fn cache_rows_for(&self, n: usize) -> usize {
        match self {
            GramSpec::Precomputed => n,
            GramSpec::OnTheFly { cache_rows } => cache_rows.unwrap_or(n / 4).min(n),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GramSpec::Precomputed => "pre",
            GramSpec::OnTheFly { .. } => "otf",
        }
    }
}

/// Cache-hit / materialization counters of an [`OnTheFly`] source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GramStats {
    /// Kernel rows computed (cache misses; eviction makes this a work
    /// counter, not a distinct-row count).
    pub rows_computed: usize,
    /// Row fetches served straight from the cache.
    pub cache_hits: usize,
}

#[derive(Debug)]
struct CacheEntry {
    /// Last-touch stamp for LRU eviction (unique per touch).
    stamp: u64,
    /// Shared so an in-flight reader keeps an evicted row alive.
    row: Arc<Vec<f32>>,
}

#[derive(Debug, Default)]
struct Lru {
    // hash-ok: row *values* never depend on map iteration — lookups
    // are keyed, and the one iteration (eviction in `fetch`) picks the
    // min-stamp victim, with stamps unique per touch, so the victim is
    // deterministic regardless of iteration order.
    map: HashMap<usize, CacheEntry>,
    clock: u64,
}

/// Kernel rows computed on demand from the stored training matrix —
/// the O(n²)-memory-free half of the [`GramSource`] pair.
///
/// Rows are served from a bounded LRU cache (`with_cache_rows`, default
/// n/4); a miss computes the full row via the kernel's dense/sparse
/// fast path, parallel over contiguous column chunks
/// ([`pool::par_chunks_mut`], `with_threads` — `MINMAX_THREADS` by
/// default). Row *values* are independent of cache size and thread
/// count by construction, so solvers above see bit-identical kernels
/// however this source is tuned.
pub struct OnTheFly<'a, K: Kernel + Sync = KernelKind> {
    kern: K,
    x: &'a Matrix,
    capacity: usize,
    threads: usize,
    cache: Mutex<Lru>,
    /// Diagonal K[i][i], precomputed once (one row's worth of kernel
    /// evaluations) — solvers rebuild their Q̄ᵢᵢ per training call, and
    /// OvO reads it once per pair member, so recomputing per call would
    /// redo O(n·d) work every retrain.
    diag: Vec<f32>,
    computed: AtomicUsize,
    hits: AtomicUsize,
}

impl<'a, K: Kernel + Sync> OnTheFly<'a, K> {
    /// Source over `x`'s rows (the caller applies the kernel's required
    /// normalization first, as everywhere else). Default cache cap is
    /// n/4 rows; default fill parallelism is [`pool::default_threads`].
    pub fn new(kern: K, x: &'a Matrix) -> Self {
        let n = x.rows();
        // Same f32 rounding as the row path, so Q̄ᵢᵢ agrees with a
        // precomputed Gram bit-for-bit.
        let diag: Vec<f32> = match x {
            Matrix::Dense(d) => {
                (0..n).map(|i| kern.eval_dense(d.row(i), d.row(i)) as f32).collect()
            }
            Matrix::Sparse(s) => {
                (0..n).map(|i| kern.eval_sparse(s.row(i), s.row(i)) as f32).collect()
            }
        };
        Self {
            kern,
            x,
            capacity: (n / 4).max(1),
            threads: pool::default_threads(),
            cache: Mutex::new(Lru::default()),
            diag,
            computed: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
        }
    }

    /// Cap the row cache at `rows` entries (`0` disables caching: every
    /// fetch recomputes — the pure streaming extreme).
    pub fn with_cache_rows(mut self, rows: usize) -> Self {
        self.capacity = rows;
        self
    }

    /// Thread count for cache-miss row fills. Callers fetching from an
    /// already-parallel loop (e.g. OvO pairs) should divide their
    /// budget here — `pairs × fill_threads` scoped threads are live on
    /// concurrent misses (see `svm::eval::kernel_svm_sweep_with`).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn cache_rows(&self) -> usize {
        self.capacity
    }

    /// Rows currently resident in the cache (≤ the cap).
    pub fn cached_rows(&self) -> usize {
        self.cache.lock().unwrap().map.len()
    }

    pub fn stats(&self) -> GramStats {
        GramStats {
            // relaxed-ok: monotonic observability counters; never used
            // to synchronize row data (rows travel behind the mutex).
            rows_computed: self.computed.load(Ordering::Relaxed),
            cache_hits: self.hits.load(Ordering::Relaxed),
        }
    }

    /// Compute kernel row `i` against every training row, filling
    /// contiguous column chunks in parallel. Each cell is an independent
    /// kernel evaluation, so the result is identical at any chunking.
    fn compute_row(&self, i: usize) -> Vec<f32> {
        let n = self.x.rows();
        let mut row = vec![0.0f32; n];
        match self.x {
            Matrix::Dense(d) => {
                let xi = d.row(i);
                pool::par_chunks_mut(&mut row, ROW_MIN_CHUNK, self.threads, |off, chunk| {
                    for (jj, cell) in chunk.iter_mut().enumerate() {
                        *cell = self.kern.eval_dense(xi, d.row(off + jj)) as f32;
                    }
                });
            }
            Matrix::Sparse(s) => {
                let xi = s.row(i);
                pool::par_chunks_mut(&mut row, ROW_MIN_CHUNK, self.threads, |off, chunk| {
                    for (jj, cell) in chunk.iter_mut().enumerate() {
                        *cell = self.kern.eval_sparse(xi, s.row(off + jj)) as f32;
                    }
                });
            }
        }
        row
    }

    /// Fetch row `i`, from cache when resident. Misses compute outside
    /// the lock (concurrent fetches of other rows stay servable; two
    /// threads racing on the same row both compute identical values and
    /// the loser's insert is a no-op overwrite).
    fn fetch(&self, i: usize) -> Arc<Vec<f32>> {
        assert!(i < self.x.rows(), "gram row {i} out of range");
        {
            let mut c = self.cache.lock().unwrap();
            c.clock += 1;
            let stamp = c.clock;
            if let Some(entry) = c.map.get_mut(&i) {
                entry.stamp = stamp;
                // relaxed-ok: observability tally only.
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(&entry.row);
            }
        }
        let row = Arc::new(self.compute_row(i));
        // relaxed-ok: observability tally only.
        self.computed.fetch_add(1, Ordering::Relaxed);
        if self.capacity > 0 {
            let mut c = self.cache.lock().unwrap();
            c.clock += 1;
            let stamp = c.clock;
            if !c.map.contains_key(&i) && c.map.len() >= self.capacity {
                // Evict the least-recently-touched row; stamps are
                // unique, so the victim is deterministic.
                if let Some(victim) = c.map.iter().min_by_key(|(_, e)| e.stamp).map(|(&k, _)| k) {
                    c.map.remove(&victim);
                }
            }
            c.map.insert(i, CacheEntry { stamp, row: Arc::clone(&row) });
        }
        row
    }
}

impl<K: Kernel + Sync> GramSource for OnTheFly<'_, K> {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn diag(&self, i: usize) -> f32 {
        self.diag[i]
    }

    fn with_row<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        f(&self.fetch(i))
    }

    fn rows_materialized(&self) -> usize {
        // relaxed-ok: observability tally only.
        self.computed.load(Ordering::Relaxed)
    }
}

/// Lazy index-mapped view of a subset of another source's rows — the
/// one-vs-one wrapper's per-pair Gram (replaces the old copied m×m
/// sub-Dense). Row fetches gather the parent row through the index map
/// into a reusable scratch buffer (no per-fetch allocation), so the
/// parent's cache is shared across every pair touching a row. The O(m)
/// gather per fetch is the same order as the O(m) gradient update every
/// fetch feeds, and fetches only happen when a coordinate moves.
pub struct SubsetGram<'a, G: GramSource> {
    parent: &'a G,
    idx: &'a [usize],
    /// Gather buffer, reused across fetches. A view is owned by one
    /// solver at a time, so the lock (needed only for `Sync`) is
    /// uncontended.
    scratch: Mutex<Vec<f32>>,
}

impl<'a, G: GramSource> SubsetGram<'a, G> {
    pub fn new(parent: &'a G, idx: &'a [usize]) -> Self {
        debug_assert!(idx.iter().all(|&i| i < parent.n()), "subset index out of range");
        Self { parent, idx, scratch: Mutex::new(Vec::with_capacity(idx.len())) }
    }
}

impl<G: GramSource> GramSource for SubsetGram<'_, G> {
    fn n(&self) -> usize {
        self.idx.len()
    }

    fn diag(&self, i: usize) -> f32 {
        self.parent.diag(self.idx[i])
    }

    fn with_row<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        self.parent.with_row(self.idx[i], |full| {
            let mut sub = self.scratch.lock().unwrap();
            sub.clear();
            sub.extend(self.idx.iter().map(|&j| full[j]));
            f(&sub)
        })
    }

    fn rows_materialized(&self) -> usize {
        self.parent.rows_materialized()
    }
}

#[cfg(test)]
mod tests {
    use super::super::matrix::kernel_matrix_sym;
    use super::*;
    use crate::data::sparse::Csr;
    use crate::util::rng::Pcg64;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Dense {
        let mut rng = Pcg64::new(seed);
        Dense::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| {
                    if rng.uniform() < 0.4 {
                        0.0
                    } else {
                        rng.lognormal(0.0, 0.8) as f32
                    }
                })
                .collect(),
        )
    }

    #[test]
    fn on_the_fly_rows_match_precomputed_bitwise() {
        let d = random_matrix(37, 9, 1);
        for m in [Matrix::Dense(d.clone()), Matrix::Sparse(Csr::from_dense(&d))] {
            let pre = kernel_matrix_sym(KernelKind::MinMax, &m);
            let otf = OnTheFly::new(KernelKind::MinMax, &m).with_cache_rows(5);
            for i in 0..37 {
                otf.with_row(i, |row| {
                    assert_eq!(row.len(), 37);
                    for (j, &v) in row.iter().enumerate() {
                        assert_eq!(v.to_bits(), pre.get(i, j).to_bits(), "row {i} col {j}");
                    }
                });
                assert_eq!(otf.diag(i).to_bits(), pre.get(i, i).to_bits(), "diag {i}");
            }
            assert!(otf.cached_rows() <= 5);
        }
    }

    #[test]
    fn cache_capacity_is_respected_and_hits_count() {
        let d = random_matrix(16, 6, 2);
        let m = Matrix::Dense(d);
        let otf = OnTheFly::new(KernelKind::MinMax, &m).with_cache_rows(3).with_threads(1);
        for i in 0..16 {
            otf.with_row(i, |_| {});
        }
        assert_eq!(otf.stats().rows_computed, 16);
        assert_eq!(otf.stats().cache_hits, 0);
        assert_eq!(otf.cached_rows(), 3);
        // The three most recent rows are resident.
        for i in [13usize, 14, 15] {
            otf.with_row(i, |_| {});
        }
        let s = otf.stats();
        assert_eq!(s.rows_computed, 16);
        assert_eq!(s.cache_hits, 3);
        // An older row was evicted: refetch recomputes.
        otf.with_row(0, |_| {});
        assert_eq!(otf.stats().rows_computed, 17);
    }

    #[test]
    fn zero_capacity_streams_every_fetch() {
        let d = random_matrix(8, 4, 3);
        let m = Matrix::Dense(d);
        let otf = OnTheFly::new(KernelKind::MinMax, &m).with_cache_rows(0);
        let pre = kernel_matrix_sym(KernelKind::MinMax, &m);
        for _ in 0..2 {
            for i in 0..8 {
                otf.with_row(i, |row| {
                    for (j, &v) in row.iter().enumerate() {
                        assert_eq!(v.to_bits(), pre.get(i, j).to_bits());
                    }
                });
            }
        }
        assert_eq!(otf.cached_rows(), 0);
        assert_eq!(otf.stats().rows_computed, 16);
    }

    #[test]
    fn subset_view_gathers_parent_rows() {
        let d = random_matrix(12, 5, 4);
        let m = Matrix::Dense(d);
        let pre = kernel_matrix_sym(KernelKind::MinMax, &m);
        let idx = [2usize, 3, 7, 11];
        let view = SubsetGram::new(&pre, &idx);
        assert_eq!(view.n(), 4);
        for (r, &i) in idx.iter().enumerate() {
            assert_eq!(view.diag(r).to_bits(), pre.get(i, i).to_bits());
            view.with_row(r, |row| {
                assert_eq!(row.len(), 4);
                for (c, &j) in idx.iter().enumerate() {
                    assert_eq!(row[c].to_bits(), pre.get(i, j).to_bits());
                }
            });
        }
    }

    #[test]
    fn row_fill_is_thread_count_invariant() {
        let d = random_matrix(40, 8, 5);
        let m = Matrix::Dense(d);
        let one = OnTheFly::new(KernelKind::MinMax, &m).with_threads(1);
        let four = OnTheFly::new(KernelKind::MinMax, &m).with_threads(4);
        for i in 0..40 {
            one.with_row(i, |a| {
                four.with_row(i, |b| {
                    assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
                });
            });
        }
    }

    #[test]
    fn gram_spec_resolves_cache_cap() {
        assert_eq!(GramSpec::Precomputed.cache_rows_for(100), 100);
        assert_eq!(GramSpec::OnTheFly { cache_rows: None }.cache_rows_for(100), 25);
        assert_eq!(GramSpec::OnTheFly { cache_rows: Some(7) }.cache_rows_for(100), 7);
        assert_eq!(GramSpec::OnTheFly { cache_rows: Some(500) }.cache_rows_for(100), 100);
        assert_eq!(GramSpec::Precomputed.name(), "pre");
        assert_eq!(GramSpec::OnTheFly { cache_rows: None }.name(), "otf");
    }
}
