//! Blocked, parallel kernel-matrix computation — the substrate for the
//! LIBSVM-style "precomputed kernel" experiments (Table 1, Figures 1–3).
//!
//! `kernel_matrix(kern, a, b)` returns the `a.rows() × b.rows()` Gram
//! block `K[i][j] = kern(a_i, b_j)`. For training, `a == b` and the
//! symmetric fast path computes only the upper triangle. Rows are
//! processed in parallel via [`crate::util::pool::par_rows`]; the dense
//! path walks contiguous row slices (cache-friendly, auto-vectorizable),
//! the sparse path merge-joins nonzeros.

use crate::data::dense::Dense;
use crate::data::Matrix;
use crate::util::pool::par_rows;

use super::Kernel;

/// Rectangular Gram block between `a`'s rows and `b`'s rows. Generic
/// over the [`Kernel`] trait (`Sync` because rows are evaluated in
/// parallel); pass a [`super::KernelKind`] or any custom kernel.
pub fn kernel_matrix<K: Kernel + Sync>(kern: K, a: &Matrix, b: &Matrix) -> Dense {
    assert_eq!(a.cols(), b.cols(), "dimension mismatch");
    let (m, n) = (a.rows(), b.rows());
    let mut out = Dense::zeros(m, n);
    match (a, b) {
        (Matrix::Dense(da), Matrix::Dense(db)) => {
            par_rows(out.data_mut(), n, |i, row| {
                let ai = da.row(i);
                for (j, cell) in row.iter_mut().enumerate() {
                    *cell = kern.eval_dense(ai, db.row(j)) as f32;
                }
            });
        }
        (Matrix::Sparse(sa), Matrix::Sparse(sb)) => {
            par_rows(out.data_mut(), n, |i, row| {
                let ai = sa.row(i);
                for (j, cell) in row.iter_mut().enumerate() {
                    *cell = kern.eval_sparse(ai, sb.row(j)) as f32;
                }
            });
        }
        // Mixed representations: densify the smaller side.
        _ => {
            let da = a.to_dense();
            let db = b.to_dense();
            return kernel_matrix(kern, &Matrix::Dense(da), &Matrix::Dense(db));
        }
    }
    out
}

/// Symmetric Gram matrix of one row set: computes the upper triangle and
/// mirrors, roughly halving work for the train-kernel case.
pub fn kernel_matrix_sym<K: Kernel + Sync>(kern: K, a: &Matrix) -> Dense {
    let n = a.rows();
    let mut out = Dense::zeros(n, n);
    match a {
        Matrix::Dense(d) => {
            par_rows(out.data_mut(), n, |i, row| {
                let ai = d.row(i);
                for (j, cell) in row.iter_mut().enumerate().skip(i) {
                    *cell = kern.eval_dense(ai, d.row(j)) as f32;
                }
            });
        }
        Matrix::Sparse(s) => {
            par_rows(out.data_mut(), n, |i, row| {
                let ai = s.row(i);
                for (j, cell) in row.iter_mut().enumerate().skip(i) {
                    *cell = kern.eval_sparse(ai, s.row(j)) as f32;
                }
            });
        }
    }
    // Mirror the strict upper triangle down — blocked parallel
    // transpose-copy, so the symmetric path stays parallel end to end
    // (the old serial `get`/`set` tail was an O(n²) single-thread drag
    // after the parallel fill).
    mirror_upper_blocked(out.data_mut(), n, 0, n);
    out
}

/// Rows per block below which the mirror runs serially: a block copy
/// this small is cheaper than a scoped-thread spawn.
const MIRROR_SERIAL_ROWS: usize = 64;

/// Copy every strict-upper entry `(i, j)` with `lo ≤ i < j < hi` to its
/// mirror `(j, i)`, recursively: the off-diagonal block (`i < m ≤ j`)
/// is a parallel transpose-copy — `split_at_mut` at row `m` separates
/// the read side (rows `lo..m`, already filled upper triangle) from the
/// write side (rows `m..hi`, lower-triangle columns `lo..m`), so
/// [`par_rows`] can shard the destination rows with no aliasing — and
/// the two diagonal sub-blocks recurse until they fit the serial base
/// case. Every entry is copied exactly once.
fn mirror_upper_blocked(buf: &mut [f32], n: usize, lo: usize, hi: usize) {
    if hi - lo < 2 {
        return;
    }
    if hi - lo <= MIRROR_SERIAL_ROWS {
        for i in lo..hi {
            for j in (i + 1)..hi {
                buf[j * n + i] = buf[i * n + j];
            }
        }
        return;
    }
    let m = (lo + hi) / 2;
    {
        let (top, bottom) = buf[lo * n..hi * n].split_at_mut((m - lo) * n);
        let top: &[f32] = top;
        par_rows(bottom, n, |jj, row| {
            let j = m + jj;
            for (i, cell) in row[lo..m].iter_mut().enumerate() {
                *cell = top[i * n + j];
            }
        });
    }
    mirror_upper_blocked(buf, n, lo, m);
    mirror_upper_blocked(buf, n, m, hi);
}

/// Check positive semi-definiteness of a symmetric matrix empirically by
/// running a few steps of Lanczos-free power iteration on `-K` shifted;
/// used by tests (small n) as a sanity check that min-max is PD in
/// practice (the paper: K_MM is an expectation of inner products).
pub fn min_eigenvalue_estimate(k: &Dense, iters: usize, seed: u64) -> f64 {
    let n = k.rows();
    assert_eq!(n, k.cols());
    // Gershgorin upper bound on the spectrum.
    let mut upper: f64 = 0.0;
    for i in 0..n {
        let s: f64 = (0..n).map(|j| k.get(i, j).abs() as f64).sum();
        upper = upper.max(s);
    }
    // Power iteration on (upper*I - K) converges to upper - λ_min.
    // Iterates are kept unit-norm (including the initial vector and any
    // restart), so `lam = ‖(upper·I − K) v‖` is a valid Rayleigh-style
    // estimate even when the loop ends one step after a (re)start.
    let mut rng = crate::util::rng::Pcg64::new(seed);
    let fresh_unit = |rng: &mut crate::util::rng::Pcg64| -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 0.0 {
            for x in &mut v {
                *x /= norm;
            }
        } else if !v.is_empty() {
            v[0] = 1.0; // measure-zero fallback
        }
        v
    };
    let mut v = fresh_unit(&mut rng);
    let mut lam = 0.0;
    for _ in 0..iters {
        let mut w = vec![0.0f64; n];
        for i in 0..n {
            let mut acc = upper * v[i];
            for j in 0..n {
                acc -= k.get(i, j) as f64 * v[j];
            }
            w[i] = acc;
        }
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            // The iterate landed exactly in the null space of
            // (upper·I − K) — i.e. on an eigenvector of K at the
            // Gershgorin bound. Returning `upper` here is only correct
            // for K == upper·I; restart from a fresh random vector
            // instead. (If K really is upper·I, every restart maps to
            // zero, `lam` stays 0, and `upper − 0` is the right
            // answer.)
            v = fresh_unit(&mut rng);
            lam = 0.0;
            continue;
        }
        for x in &mut w {
            *x /= norm;
        }
        lam = norm;
        v = w;
    }
    upper - lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;
    use crate::kernels::KernelKind;
    use crate::util::rng::Pcg64;

    fn random_dense(rows: usize, cols: usize, zero_frac: f64, seed: u64) -> Dense {
        let mut rng = Pcg64::new(seed);
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| {
                if rng.uniform() < zero_frac {
                    0.0
                } else {
                    rng.lognormal(0.0, 0.8) as f32
                }
            })
            .collect();
        Dense::from_vec(rows, cols, data)
    }

    #[test]
    fn rect_matches_pointwise() {
        let a = random_dense(7, 12, 0.3, 1);
        let b = random_dense(5, 12, 0.3, 2);
        let k = kernel_matrix(KernelKind::MinMax, &Matrix::Dense(a.clone()), &Matrix::Dense(b.clone()));
        for i in 0..7 {
            for j in 0..5 {
                let want = KernelKind::MinMax.eval_dense(a.row(i), b.row(j)) as f32;
                assert!((k.get(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn sym_matches_rect() {
        let a = random_dense(9, 8, 0.4, 3);
        let m = Matrix::Dense(a);
        for kern in [KernelKind::MinMax, KernelKind::Linear, KernelKind::Chi2] {
            let full = kernel_matrix(kern, &m, &m);
            let sym = kernel_matrix_sym(kern, &m);
            for i in 0..9 {
                for j in 0..9 {
                    assert!(
                        (full.get(i, j) - sym.get(i, j)).abs() < 1e-6,
                        "{} at ({i},{j})",
                        kern.name()
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_path_matches_dense_path() {
        let a = random_dense(6, 20, 0.6, 4);
        let b = random_dense(4, 20, 0.6, 5);
        let ka = kernel_matrix(
            KernelKind::MinMax,
            &Matrix::Dense(a.clone()),
            &Matrix::Dense(b.clone()),
        );
        let kb = kernel_matrix(
            KernelKind::MinMax,
            &Matrix::Sparse(Csr::from_dense(&a)),
            &Matrix::Sparse(Csr::from_dense(&b)),
        );
        for i in 0..6 {
            for j in 0..4 {
                assert!((ka.get(i, j) - kb.get(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn diagonal_is_one_for_minmax() {
        let a = random_dense(8, 10, 0.2, 6);
        let k = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(a));
        for i in 0..8 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn sym_mirror_is_exact_at_blocked_sizes() {
        // 150 rows forces the recursive parallel mirror (serial base
        // case is ≤64 rows); the result must be perfectly symmetric and
        // agree with the rectangular path.
        let a = random_dense(150, 12, 0.4, 11);
        let m = Matrix::Dense(a);
        let sym = kernel_matrix_sym(KernelKind::MinMax, &m);
        let full = kernel_matrix(KernelKind::MinMax, &m, &m);
        for i in 0..150 {
            for j in 0..150 {
                assert_eq!(
                    sym.get(i, j).to_bits(),
                    sym.get(j, i).to_bits(),
                    "mirror asymmetry at ({i},{j})"
                );
                assert!((sym.get(i, j) - full.get(i, j)).abs() < 1e-6, "value at ({i},{j})");
            }
        }
    }

    #[test]
    fn degenerate_identity_gram_estimates_upper() {
        // K = 2I: every iterate maps to zero; the restart loop must
        // still land on λ_min = 2 (= upper), not loop forever or panic.
        let mut k = Dense::zeros(3, 3);
        for i in 0..3 {
            k.set(i, i, 2.0);
        }
        let lam = min_eigenvalue_estimate(&k, 50, 1);
        assert!((lam - 2.0).abs() < 1e-9, "λ_min estimate {lam}");
    }

    #[test]
    fn rank_deficient_gram_estimates_zero_not_upper() {
        // K = 𝟙𝟙ᵀ (rank one): eigenvalues {n, 0, …, 0}, so λ_min = 0
        // while the Gershgorin bound is n — a degenerate bail that
        // returned `upper` would be off by the whole spectrum width.
        let n = 6;
        let mut k = Dense::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k.set(i, j, 1.0);
            }
        }
        let lam = min_eigenvalue_estimate(&k, 400, 3);
        assert!(lam.abs() < 1e-6, "λ_min estimate {lam} (must not bail to upper = {n})");
    }

    #[test]
    fn minmax_gram_is_psd_empirically() {
        // The paper argues K_MM is PD (expectation of inner products);
        // verify λ_min ≥ -1e-4 on random nonnegative data.
        let a = random_dense(24, 16, 0.3, 7);
        let k = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(a));
        let lam_min = min_eigenvalue_estimate(&k, 300, 8);
        assert!(lam_min > -1e-4, "λ_min estimate {lam_min}");
    }

    #[test]
    fn mixed_representation_works() {
        let a = random_dense(3, 6, 0.5, 9);
        let b = random_dense(2, 6, 0.5, 10);
        let k1 = kernel_matrix(
            KernelKind::Linear,
            &Matrix::Dense(a.clone()),
            &Matrix::Sparse(Csr::from_dense(&b)),
        );
        let k2 = kernel_matrix(KernelKind::Linear, &Matrix::Dense(a), &Matrix::Dense(b));
        assert_eq!(k1, k2);
    }
}
