//! Blocked, parallel kernel-matrix computation — the substrate for the
//! LIBSVM-style "precomputed kernel" experiments (Table 1, Figures 1–3).
//!
//! `kernel_matrix(kern, a, b)` returns the `a.rows() × b.rows()` Gram
//! block `K[i][j] = kern(a_i, b_j)`. For training, `a == b` and the
//! symmetric fast path computes only the upper triangle. Rows are
//! processed in parallel via [`crate::util::pool::par_rows`]; the dense
//! path walks contiguous row slices (cache-friendly, auto-vectorizable),
//! the sparse path merge-joins nonzeros.

use crate::data::dense::Dense;
use crate::data::Matrix;
use crate::util::pool::par_rows;

use super::Kernel;

/// Rectangular Gram block between `a`'s rows and `b`'s rows. Generic
/// over the [`Kernel`] trait (`Sync` because rows are evaluated in
/// parallel); pass a [`super::KernelKind`] or any custom kernel.
pub fn kernel_matrix<K: Kernel + Sync>(kern: K, a: &Matrix, b: &Matrix) -> Dense {
    assert_eq!(a.cols(), b.cols(), "dimension mismatch");
    let (m, n) = (a.rows(), b.rows());
    let mut out = Dense::zeros(m, n);
    match (a, b) {
        (Matrix::Dense(da), Matrix::Dense(db)) => {
            par_rows(out.data_mut(), n, |i, row| {
                let ai = da.row(i);
                for (j, cell) in row.iter_mut().enumerate() {
                    *cell = kern.eval_dense(ai, db.row(j)) as f32;
                }
            });
        }
        (Matrix::Sparse(sa), Matrix::Sparse(sb)) => {
            par_rows(out.data_mut(), n, |i, row| {
                let ai = sa.row(i);
                for (j, cell) in row.iter_mut().enumerate() {
                    *cell = kern.eval_sparse(ai, sb.row(j)) as f32;
                }
            });
        }
        // Mixed representations: densify the smaller side.
        _ => {
            let da = a.to_dense();
            let db = b.to_dense();
            return kernel_matrix(kern, &Matrix::Dense(da), &Matrix::Dense(db));
        }
    }
    out
}

/// Symmetric Gram matrix of one row set: computes the upper triangle and
/// mirrors, roughly halving work for the train-kernel case.
pub fn kernel_matrix_sym<K: Kernel + Sync>(kern: K, a: &Matrix) -> Dense {
    let n = a.rows();
    let mut out = Dense::zeros(n, n);
    match a {
        Matrix::Dense(d) => {
            par_rows(out.data_mut(), n, |i, row| {
                let ai = d.row(i);
                for (j, cell) in row.iter_mut().enumerate().skip(i) {
                    *cell = kern.eval_dense(ai, d.row(j)) as f32;
                }
            });
        }
        Matrix::Sparse(s) => {
            par_rows(out.data_mut(), n, |i, row| {
                let ai = s.row(i);
                for (j, cell) in row.iter_mut().enumerate().skip(i) {
                    *cell = kern.eval_sparse(ai, s.row(j)) as f32;
                }
            });
        }
    }
    // Mirror the strict upper triangle down.
    for i in 0..n {
        for j in (i + 1)..n {
            let v = out.get(i, j);
            out.set(j, i, v);
        }
    }
    out
}

/// Check positive semi-definiteness of a symmetric matrix empirically by
/// running a few steps of Lanczos-free power iteration on `-K` shifted;
/// used by tests (small n) as a sanity check that min-max is PD in
/// practice (the paper: K_MM is an expectation of inner products).
pub fn min_eigenvalue_estimate(k: &Dense, iters: usize, seed: u64) -> f64 {
    let n = k.rows();
    assert_eq!(n, k.cols());
    // Gershgorin upper bound on the spectrum.
    let mut upper: f64 = 0.0;
    for i in 0..n {
        let s: f64 = (0..n).map(|j| k.get(i, j).abs() as f64).sum();
        upper = upper.max(s);
    }
    // Power iteration on (upper*I - K) converges to upper - λ_min.
    let mut rng = crate::util::rng::Pcg64::new(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut lam = 0.0;
    for _ in 0..iters {
        let mut w = vec![0.0f64; n];
        for i in 0..n {
            let mut acc = upper * v[i];
            for j in 0..n {
                acc -= k.get(i, j) as f64 * v[j];
            }
            w[i] = acc;
        }
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            return upper; // K == upper*I ⇒ λ_min == upper? degenerate; bail
        }
        for x in &mut w {
            *x /= norm;
        }
        lam = norm;
        v = w;
    }
    upper - lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;
    use crate::util::rng::Pcg64;

    fn random_dense(rows: usize, cols: usize, zero_frac: f64, seed: u64) -> Dense {
        let mut rng = Pcg64::new(seed);
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| {
                if rng.uniform() < zero_frac {
                    0.0
                } else {
                    rng.lognormal(0.0, 0.8) as f32
                }
            })
            .collect();
        Dense::from_vec(rows, cols, data)
    }

    #[test]
    fn rect_matches_pointwise() {
        let a = random_dense(7, 12, 0.3, 1);
        let b = random_dense(5, 12, 0.3, 2);
        let k = kernel_matrix(KernelKind::MinMax, &Matrix::Dense(a.clone()), &Matrix::Dense(b.clone()));
        for i in 0..7 {
            for j in 0..5 {
                let want = KernelKind::MinMax.eval_dense(a.row(i), b.row(j)) as f32;
                assert!((k.get(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn sym_matches_rect() {
        let a = random_dense(9, 8, 0.4, 3);
        let m = Matrix::Dense(a);
        for kern in [KernelKind::MinMax, KernelKind::Linear, KernelKind::Chi2] {
            let full = kernel_matrix(kern, &m, &m);
            let sym = kernel_matrix_sym(kern, &m);
            for i in 0..9 {
                for j in 0..9 {
                    assert!(
                        (full.get(i, j) - sym.get(i, j)).abs() < 1e-6,
                        "{} at ({i},{j})",
                        kern.name()
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_path_matches_dense_path() {
        let a = random_dense(6, 20, 0.6, 4);
        let b = random_dense(4, 20, 0.6, 5);
        let ka = kernel_matrix(
            KernelKind::MinMax,
            &Matrix::Dense(a.clone()),
            &Matrix::Dense(b.clone()),
        );
        let kb = kernel_matrix(
            KernelKind::MinMax,
            &Matrix::Sparse(Csr::from_dense(&a)),
            &Matrix::Sparse(Csr::from_dense(&b)),
        );
        for i in 0..6 {
            for j in 0..4 {
                assert!((ka.get(i, j) - kb.get(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn diagonal_is_one_for_minmax() {
        let a = random_dense(8, 10, 0.2, 6);
        let k = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(a));
        for i in 0..8 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn minmax_gram_is_psd_empirically() {
        // The paper argues K_MM is PD (expectation of inner products);
        // verify λ_min ≥ -1e-4 on random nonnegative data.
        let a = random_dense(24, 16, 0.3, 7);
        let k = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(a));
        let lam_min = min_eigenvalue_estimate(&k, 300, 8);
        assert!(lam_min > -1e-4, "λ_min estimate {lam_min}");
    }

    #[test]
    fn mixed_representation_works() {
        let a = random_dense(3, 6, 0.5, 9);
        let b = random_dense(2, 6, 0.5, 10);
        let k1 = kernel_matrix(
            KernelKind::Linear,
            &Matrix::Dense(a.clone()),
            &Matrix::Sparse(Csr::from_dense(&b)),
        );
        let k2 = kernel_matrix(KernelKind::Linear, &Matrix::Dense(a), &Matrix::Dense(b));
        assert_eq!(k1, k2);
    }
}
