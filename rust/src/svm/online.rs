//! Online linear learners — the "(batch **or online**) linear methods"
//! of the paper's §5 and the ad-click lineage it cites ([25] FTRL-style
//! streaming training). These plug into the coordinator so a deployment
//! can train *while* hashing a stream, never materializing the feature
//! matrix.
//!
//! Implemented: Passive-Aggressive I (Crammer et al. 2006), the averaged
//! perceptron, and SGD logistic with inverse-sqrt decay. All updates are
//! O(nnz) and the hashed rows have exactly `k` nonzeros, so per-request
//! training cost is O(k).

use crate::data::sparse::SparseRow;

/// Common interface: binary online learner over sparse rows, y ∈ {±1}.
pub trait OnlineLearner {
    /// Consume one example (predict-then-update).
    fn update(&mut self, x: SparseRow<'_>, y: i32);
    /// Current decision value (uses the averaged/current weights as the
    /// learner defines).
    fn decision(&self, x: SparseRow<'_>) -> f64;
    fn predict(&self, x: SparseRow<'_>) -> i32 {
        if self.decision(x) >= 0.0 {
            1
        } else {
            -1
        }
    }
    /// Examples consumed so far.
    fn seen(&self) -> u64;
}

// ---------------------------------------------------------------- PA-I

/// Passive-Aggressive I: on hinge violation, project onto the satisfying
/// halfspace with step `τ = min(C, loss / ‖x‖²)`.
#[derive(Debug, Clone)]
pub struct PassiveAggressive {
    w: Vec<f64>,
    b: f64,
    c: f64,
    n: u64,
}

impl PassiveAggressive {
    pub fn new(dim: usize, c: f64) -> Self {
        assert!(c > 0.0);
        Self { w: vec![0.0; dim], b: 0.0, c, n: 0 }
    }
}

impl OnlineLearner for PassiveAggressive {
    fn update(&mut self, x: SparseRow<'_>, y: i32) {
        debug_assert!(y == 1 || y == -1);
        self.n += 1;
        let f = self.decision(x);
        let loss = (1.0 - y as f64 * f).max(0.0);
        if loss > 0.0 {
            let norm2: f64 =
                x.values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() + 1.0;
            let tau = (loss / norm2).min(self.c) * y as f64;
            for (&j, &v) in x.indices.iter().zip(x.values) {
                self.w[j as usize] += tau * v as f64;
            }
            self.b += tau;
        }
    }

    fn decision(&self, x: SparseRow<'_>) -> f64 {
        let mut s = self.b;
        for (&j, &v) in x.indices.iter().zip(x.values) {
            s += self.w[j as usize] * v as f64;
        }
        s
    }

    fn seen(&self) -> u64 {
        self.n
    }
}

// -------------------------------------------------- averaged perceptron

/// Perceptron with weight averaging (the average is what predicts —
/// drastically more stable on stream order).
#[derive(Debug, Clone)]
pub struct AveragedPerceptron {
    w: Vec<f64>,
    b: f64,
    /// Accumulated (survival-weighted) sums for the average.
    wa: Vec<f64>,
    ba: f64,
    n: u64,
}

impl AveragedPerceptron {
    pub fn new(dim: usize) -> Self {
        Self { w: vec![0.0; dim], b: 0.0, wa: vec![0.0; dim], ba: 0.0, n: 0 }
    }
}

impl OnlineLearner for AveragedPerceptron {
    fn update(&mut self, x: SparseRow<'_>, y: i32) {
        self.n += 1;
        let mut f = self.b;
        for (&j, &v) in x.indices.iter().zip(x.values) {
            f += self.w[j as usize] * v as f64;
        }
        if y as f64 * f <= 0.0 {
            let yy = y as f64;
            for (&j, &v) in x.indices.iter().zip(x.values) {
                self.w[j as usize] += yy * v as f64;
                // Lazy trick avoided for clarity: weight the update by the
                // remaining stream length contribution implicitly via n.
                self.wa[j as usize] += yy * v as f64 * self.n as f64;
            }
            self.b += yy;
            self.ba += yy * self.n as f64;
        }
    }

    fn decision(&self, x: SparseRow<'_>) -> f64 {
        // Averaged weights: w_avg = w − wa / (n+1).
        let n1 = (self.n + 1) as f64;
        let mut s = self.b - self.ba / n1;
        for (&j, &v) in x.indices.iter().zip(x.values) {
            s += (self.w[j as usize] - self.wa[j as usize] / n1) * v as f64;
        }
        s
    }

    fn seen(&self) -> u64 {
        self.n
    }
}

// --------------------------------------------------------- SGD logistic

/// Logistic regression by SGD with η_t = η₀ / √t and ℓ₂ regularization.
#[derive(Debug, Clone)]
pub struct SgdLogistic {
    w: Vec<f64>,
    b: f64,
    eta0: f64,
    lambda: f64,
    n: u64,
}

impl SgdLogistic {
    pub fn new(dim: usize, eta0: f64, lambda: f64) -> Self {
        Self { w: vec![0.0; dim], b: 0.0, eta0, lambda, n: 0 }
    }
}

impl OnlineLearner for SgdLogistic {
    fn update(&mut self, x: SparseRow<'_>, y: i32) {
        self.n += 1;
        let eta = self.eta0 / (self.n as f64).sqrt();
        let f = self.decision(x);
        let yy = y as f64;
        let sig = 1.0 / (1.0 + (yy * f).exp()); // σ(−y f)
        let g = eta * yy * sig;
        // ℓ₂ shrink applied multiplicatively on touched coordinates only
        // (approximation that keeps updates O(nnz)).
        let shrink = 1.0 - eta * self.lambda;
        for (&j, &v) in x.indices.iter().zip(x.values) {
            let w = &mut self.w[j as usize];
            *w = *w * shrink + g * v as f64;
        }
        self.b = self.b * shrink + g;
    }

    fn decision(&self, x: SparseRow<'_>) -> f64 {
        let mut s = self.b;
        for (&j, &v) in x.indices.iter().zip(x.values) {
            s += self.w[j as usize] * v as f64;
        }
        s
    }

    fn seen(&self) -> u64 {
        self.n
    }
}

// ----------------------------------------------------- multiclass OvR

/// One-vs-rest over any online learner.
pub struct OnlineOvR<L: OnlineLearner> {
    pub learners: Vec<L>,
}

impl<L: OnlineLearner> OnlineOvR<L> {
    pub fn new(mut make: impl FnMut() -> L, n_classes: usize) -> Self {
        Self { learners: (0..n_classes).map(|_| make()).collect() }
    }

    pub fn update(&mut self, x: SparseRow<'_>, y: i32) {
        for (c, l) in self.learners.iter_mut().enumerate() {
            l.update(x, if c as i32 == y { 1 } else { -1 });
        }
    }

    pub fn predict(&self, x: SparseRow<'_>) -> i32 {
        let mut best = 0usize;
        let mut best_d = f64::NEG_INFINITY;
        for (c, l) in self.learners.iter().enumerate() {
            let d = l.decision(x);
            if d > best_d {
                best_d = d;
                best = c;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{Csr, CsrBuilder};
    use crate::util::rng::Pcg64;

    fn stream(n: usize, dim: usize, seed: u64) -> (Csr, Vec<i32>) {
        let mut rng = Pcg64::new(seed);
        let mut b = CsrBuilder::new(dim);
        let mut y = Vec::new();
        for i in 0..n {
            let label = if i % 2 == 0 { 1 } else { -1 };
            let c = if label == 1 { 1.6 } else { 0.4 };
            b.push_row(
                (0..dim)
                    .map(|j| (j as u32, (c * rng.lognormal(0.0, 0.25)).max(0.01) as f32))
                    .collect(),
            );
            y.push(label);
        }
        (b.finish(), y)
    }

    fn train_and_score<L: OnlineLearner>(mut l: L, x: &Csr, y: &[i32]) -> f64 {
        let n = x.rows();
        let train = n * 2 / 3;
        for i in 0..train {
            l.update(x.row(i), y[i]);
        }
        let mut ok = 0;
        for i in train..n {
            if l.predict(x.row(i)) == y[i] {
                ok += 1;
            }
        }
        assert_eq!(l.seen(), train as u64);
        ok as f64 / (n - train) as f64
    }

    #[test]
    fn pa_learns_stream() {
        let (x, y) = stream(600, 12, 1);
        let acc = train_and_score(PassiveAggressive::new(12, 1.0), &x, &y);
        assert!(acc > 0.9, "PA accuracy {acc}");
    }

    #[test]
    fn averaged_perceptron_learns_stream() {
        let (x, y) = stream(600, 12, 2);
        let acc = train_and_score(AveragedPerceptron::new(12), &x, &y);
        assert!(acc > 0.9, "AvgPerceptron accuracy {acc}");
    }

    #[test]
    fn sgd_logistic_learns_stream() {
        let (x, y) = stream(600, 12, 3);
        let acc = train_and_score(SgdLogistic::new(12, 0.5, 1e-4), &x, &y);
        assert!(acc > 0.9, "SGD-LR accuracy {acc}");
    }

    #[test]
    fn ovr_learns_three_classes() {
        let mut rng = Pcg64::new(4);
        let dim = 9;
        let mut b = CsrBuilder::new(dim);
        let mut y = Vec::new();
        for i in 0..900 {
            let c = (i % 3) as i32;
            b.push_row(
                (0..dim)
                    .map(|j| {
                        let boost = if j / 3 == c as usize { 2.0 } else { 0.3 };
                        (j as u32, (boost * rng.lognormal(0.0, 0.3)).max(0.01) as f32)
                    })
                    .collect(),
            );
            y.push(c);
        }
        let x = b.finish();
        let mut ovr = OnlineOvR::new(|| PassiveAggressive::new(dim, 1.0), 3);
        for i in 0..600 {
            ovr.update(x.row(i), y[i]);
        }
        let ok = (600..900).filter(|&i| ovr.predict(x.row(i)) == y[i]).count();
        assert!(ok > 270, "OvR accuracy {ok}/300");
    }

    #[test]
    fn online_on_hashed_cws_features() {
        // The coordinator use-case: stream hashed rows into PA.
        use crate::coordinator::{hash_dataset, PipelineConfig};
        use crate::data::synth::{generate, SynthConfig};
        let ds = generate("vowel", SynthConfig { seed: 5, n_train: 250, n_test: 250 }).unwrap();
        let hashed = hash_dataset(&ds, &PipelineConfig::new(6, 64, 6)).unwrap();
        // Online learners stream SparseRows: use the CSR export path.
        let (train, test) = (hashed.train_csr(), hashed.test_csr());
        let dim = train.cols();
        let mut ovr =
            OnlineOvR::new(|| PassiveAggressive::new(dim, 1.0), ds.n_classes());
        // Two passes over the training stream.
        for _ in 0..2 {
            for i in 0..train.rows() {
                ovr.update(train.row(i), ds.train_y[i]);
            }
        }
        let ok = (0..test.rows())
            .filter(|&i| ovr.predict(test.row(i)) == ds.test_y[i])
            .count();
        let acc = ok as f64 / test.rows() as f64;
        // Not far from the batch solver's quality on this dataset.
        assert!(acc > 0.6, "online hashed accuracy {acc}");
    }

    #[test]
    fn averaging_beats_last_iterate_on_noisy_tail() {
        // Plain perceptron final weights thrash on noisy data; the
        // averaged decision should be at least as good.
        let (x, y) = stream(400, 8, 7);
        // Flip 10% of labels to add noise.
        let mut rng = Pcg64::new(8);
        let noisy: Vec<i32> =
            y.iter().map(|&v| if rng.uniform() < 0.1 { -v } else { v }).collect();
        let acc_avg = train_and_score(AveragedPerceptron::new(8), &x, &noisy);
        assert!(acc_avg > 0.8, "averaged perceptron under noise {acc_avg}");
    }
}
