//! Model persistence: save/load trained models as JSON so a hashed
//! linear classifier trained by one process can be served by another
//! (the offline-train / online-serve split of the coordinator).

use std::path::Path;

use crate::util::json::{write_json, Json};

use super::linear::LinearModel;
use super::multiclass::LinearOvR;

/// Everything needed to re-create the serving configuration: the model
/// weights plus the hashing parameters they were trained under.
#[derive(Debug, Clone, PartialEq)]
pub struct SavedModel {
    pub seed: u64,
    pub k: usize,
    pub i_bits: u8,
    pub t_bits: u8,
    pub n_classes: usize,
    /// Per-class (weights, bias).
    pub classes: Vec<(Vec<f64>, f64)>,
}

impl SavedModel {
    pub fn from_ovr(
        ovr: &LinearOvR,
        seed: u64,
        k: usize,
        i_bits: u8,
        t_bits: u8,
    ) -> SavedModel {
        SavedModel {
            seed,
            k,
            i_bits,
            t_bits,
            n_classes: ovr.n_classes,
            classes: ovr.models().iter().map(|m| (m.w.clone(), m.b)).collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("format", "minmax-linear-ovr-v1")
            .set("seed", self.seed)
            .set("k", self.k)
            .set("i_bits", self.i_bits as i64)
            .set("t_bits", self.t_bits as i64)
            .set("n_classes", self.n_classes);
        j.set(
            "classes",
            Json::Arr(
                self.classes
                    .iter()
                    .map(|(w, b)| {
                        let mut c = Json::obj();
                        c.set("bias", *b)
                            .set("w", Json::Arr(w.iter().map(|&x| Json::Num(x)).collect()));
                        c
                    })
                    .collect(),
            ),
        );
        j
    }

    pub fn from_json(j: &Json) -> Result<SavedModel, String> {
        if j.get("format").and_then(Json::as_str) != Some("minmax-linear-ovr-v1") {
            return Err("unknown model format".into());
        }
        let get_n = |k: &str| -> Result<f64, String> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing {k}"))
        };
        let classes_json =
            j.get("classes").and_then(Json::as_arr).ok_or("missing classes")?;
        let mut classes = Vec::new();
        for c in classes_json {
            let b = c.get("bias").and_then(Json::as_f64).ok_or("missing bias")?;
            let w = c
                .get("w")
                .and_then(Json::as_arr)
                .ok_or("missing w")?
                .iter()
                .map(|x| x.as_f64().ok_or("bad weight".to_string()))
                .collect::<Result<Vec<f64>, _>>()?;
            classes.push((w, b));
        }
        Ok(SavedModel {
            seed: get_n("seed")? as u64,
            k: get_n("k")? as usize,
            i_bits: get_n("i_bits")? as u8,
            t_bits: get_n("t_bits")? as u8,
            n_classes: get_n("n_classes")? as usize,
            classes,
        })
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        write_json(path, &self.to_json())
    }

    pub fn load(path: &Path) -> Result<SavedModel, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Rebuild the in-memory predictor.
    pub fn to_models(&self) -> Vec<LinearModel> {
        self.classes
            .iter()
            .map(|(w, b)| LinearModel { w: w.clone(), b: *b, epochs_run: 0 })
            .collect()
    }

    /// Predict with the reconstructed models.
    pub fn predict(&self, x: crate::data::sparse::SparseRow<'_>) -> i32 {
        let mut best = 0usize;
        let mut best_d = f64::NEG_INFINITY;
        for (c, (w, b)) in self.classes.iter().enumerate() {
            let mut d = *b;
            for (&j, &v) in x.indices.iter().zip(x.values) {
                d += w[j as usize] * v as f64;
            }
            if d > best_d {
                best_d = d;
                best = c;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{hash_dataset, PipelineConfig};
    use crate::data::synth::{generate, SynthConfig};
    use crate::svm::LinearSvmParams;

    fn trained() -> (SavedModel, crate::data::Csr, Vec<i32>) {
        let ds = generate("vowel", SynthConfig { seed: 3, n_train: 120, n_test: 120 }).unwrap();
        let cfg = PipelineConfig::new(9, 32, 4);
        let hashed = hash_dataset(&ds, &cfg).unwrap();
        let ovr = LinearOvR::train(
            &hashed.train,
            &ds.train_y,
            ds.n_classes(),
            &LinearSvmParams::default(),
        );
        let saved = SavedModel::from_ovr(&ovr, cfg.seed, cfg.k, cfg.i_bits, cfg.t_bits);
        (saved, hashed.test_csr(), ds.test_y)
    }

    #[test]
    fn json_roundtrip_preserves_model() {
        let (m, _, _) = trained();
        let j = m.to_json();
        let back = SavedModel::from_json(&j).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn file_roundtrip_and_identical_predictions() {
        let (m, test, _y) = trained();
        let dir = std::env::temp_dir().join("minmax_model_io");
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        let back = SavedModel::load(&path).unwrap();
        for i in 0..test.rows() {
            assert_eq!(m.predict(test.row(i)), back.predict(test.row(i)), "row {i}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_format() {
        let j = Json::parse(r#"{"format":"other"}"#).unwrap();
        assert!(SavedModel::from_json(&j).is_err());
    }

    #[test]
    fn reconstructed_models_match_predict() {
        let (m, test, _) = trained();
        let models = m.to_models();
        for i in 0..test.rows().min(20) {
            let row = test.row(i);
            let via_models = models
                .iter()
                .enumerate()
                .max_by(|a, b| {
                    a.1.decision(row).partial_cmp(&b.1.decision(row)).unwrap()
                })
                .unwrap()
                .0 as i32;
            assert_eq!(via_models, m.predict(row));
        }
    }
}
