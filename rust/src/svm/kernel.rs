//! Kernel SVM on a **precomputed kernel matrix** — the LIBSVM
//! `-t 4` setup of the paper's §2 experiments (Table 1, Figures 1–3).
//!
//! Binary C-SVM dual, solved by coordinate descent over the box:
//!
//! ```text
//! min_α  ½ Σᵢⱼ αᵢαⱼ yᵢyⱼ (K(xᵢ,xⱼ) + 1) − Σᵢ αᵢ ,   0 ≤ αᵢ ≤ C
//! ```
//!
//! The `+1` augments the kernel with a regularized bias (equivalent to a
//! constant feature in RKHS), which removes the equality constraint that
//! SMO exists to handle — coordinate descent then converges directly
//! (same approach as LIBSVM's `-s 0` with an augmented kernel; accuracy
//! differences vs a true unregularized bias are negligible at the C
//! ranges swept here). A gradient vector is maintained incrementally so
//! one epoch costs O(n · n_active).

use crate::data::dense::Dense;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct KernelSvmParams {
    pub c: f64,
    pub max_epochs: usize,
    pub eps: f64,
    pub seed: u64,
}

impl Default for KernelSvmParams {
    fn default() -> Self {
        Self { c: 1.0, max_epochs: 120, eps: 1e-3, seed: 1 }
    }
}

/// A trained binary kernel machine: coefficients over the training set.
#[derive(Debug, Clone)]
pub struct KernelModel {
    /// yᵢ αᵢ for every training point (zeros for non-SVs).
    pub coef: Vec<f64>,
    pub epochs_run: usize,
}

impl KernelModel {
    /// Decision value given this test point's kernel row against the
    /// training set (length n_train).
    #[inline]
    pub fn decision(&self, k_row: &[f32]) -> f64 {
        debug_assert_eq!(k_row.len(), self.coef.len());
        let mut s = 0.0f64;
        for (&c, &k) in self.coef.iter().zip(k_row) {
            if c != 0.0 {
                s += c * (k as f64 + 1.0);
            }
        }
        s
    }

    pub fn n_svs(&self) -> usize {
        self.coef.iter().filter(|&&c| c != 0.0).count()
    }
}

/// Train on a precomputed symmetric train-kernel `k` (n × n) with ±1
/// labels.
pub fn train_binary(k: &Dense, y: &[i32], p: &KernelSvmParams) -> KernelModel {
    let n = y.len();
    assert_eq!(k.rows(), n);
    assert_eq!(k.cols(), n);
    assert!(y.iter().all(|&v| v == 1 || v == -1), "labels must be ±1");
    let mut alpha = vec![0.0f64; n];
    // grad[i] = Σ_j Q_ij α_j − 1, Q_ij = y_i y_j (K_ij + 1); starts at −1.
    let mut grad = vec![-1.0f64; n];
    let qii: Vec<f64> = (0..n).map(|i| k.get(i, i) as f64 + 1.0).collect();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(p.seed);
    let mut epochs_run = 0;
    for epoch in 0..p.max_epochs {
        rng.shuffle(&mut order);
        let mut max_pg: f64 = 0.0;
        for &i in &order {
            let g = grad[i];
            let pg = if alpha[i] <= 0.0 {
                g.min(0.0)
            } else if alpha[i] >= p.c {
                g.max(0.0)
            } else {
                g
            };
            if pg.abs() < 1e-14 {
                continue;
            }
            max_pg = max_pg.max(pg.abs());
            let old = alpha[i];
            let denom = qii[i].max(1e-12);
            let new = (old - g / denom).clamp(0.0, p.c);
            let delta = new - old;
            if delta != 0.0 {
                alpha[i] = new;
                // grad_j += Q_ji Δ = y_j y_i (K_ji + 1) Δ
                let yi = y[i] as f64;
                let krow = k.row(i);
                for j in 0..n {
                    grad[j] += (y[j] as f64) * yi * (krow[j] as f64 + 1.0) * delta;
                }
            }
        }
        epochs_run = epoch + 1;
        if max_pg < p.eps {
            break;
        }
    }
    let coef: Vec<f64> = alpha.iter().zip(y).map(|(&a, &yy)| a * yy as f64).collect();
    KernelModel { coef, epochs_run }
}

/// Dual objective (for tests): ½ αᵀQα − Σα expressed via coef and grad
/// recomputation.
pub fn dual_objective(k: &Dense, y: &[i32], m: &KernelModel) -> f64 {
    let n = y.len();
    let alpha: Vec<f64> = m.coef.iter().zip(y).map(|(&c, &yy)| c * yy as f64).collect();
    let mut obj = -alpha.iter().sum::<f64>();
    for i in 0..n {
        if alpha[i] == 0.0 {
            continue;
        }
        let krow = k.row(i);
        let mut s = 0.0;
        for j in 0..n {
            if alpha[j] != 0.0 {
                s += (y[i] * y[j]) as f64 * (krow[j] as f64 + 1.0) * alpha[j];
            }
        }
        obj += 0.5 * alpha[i] * s;
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Matrix;
    use crate::kernels::matrix::{kernel_matrix, kernel_matrix_sym};
    use crate::kernels::KernelKind;

    /// XOR-ish dataset: linearly inseparable, min-max kernel separable.
    fn ring_data(n: usize, seed: u64) -> (Dense, Vec<i32>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Dense::zeros(n, 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label = if i % 2 == 0 { 1 } else { -1 };
            // Class +1: radius ~0.5; class −1: radius ~1.5 (shifted to
            // stay nonnegative).
            let radius = if label == 1 { 0.5 } else { 1.5 };
            let th = rng.uniform() * std::f64::consts::TAU;
            x.set(i, 0, (2.0 + radius * th.cos() + 0.05 * rng.normal()) as f32);
            x.set(i, 1, (2.0 + radius * th.sin() + 0.05 * rng.normal()) as f32);
            y.push(label);
        }
        (x, y)
    }

    #[test]
    fn solves_nonlinear_problem_linear_cannot() {
        let (xtr, ytr) = ring_data(120, 1);
        let (xte, yte) = ring_data(80, 2);
        let mtr = Matrix::Dense(xtr);
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &mtr);
        let m = train_binary(&ktr, &ytr, &KernelSvmParams { c: 32.0, ..Default::default() });
        let kte = kernel_matrix(KernelKind::MinMax, &Matrix::Dense(xte), &mtr);
        let acc = (0..yte.len())
            .filter(|&i| {
                let pred = if m.decision(kte.row(i)) >= 0.0 { 1 } else { -1 };
                pred == yte[i]
            })
            .count() as f64
            / yte.len() as f64;
        assert!(acc > 0.9, "min-max kernel SVM accuracy {acc}");
    }

    #[test]
    fn alphas_respect_box() {
        let (xtr, ytr) = ring_data(60, 3);
        let c = 2.0;
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(xtr));
        let m = train_binary(&ktr, &ytr, &KernelSvmParams { c, ..Default::default() });
        for (i, (&coef, &yy)) in m.coef.iter().zip(&ytr).enumerate() {
            let a = coef * yy as f64;
            assert!((-1e-9..=c + 1e-9).contains(&a), "alpha[{i}] = {a}");
        }
        assert!(m.n_svs() > 0);
    }

    #[test]
    fn longer_training_does_not_worsen_dual() {
        let (xtr, ytr) = ring_data(60, 4);
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(xtr));
        let m1 = train_binary(&ktr, &ytr, &KernelSvmParams { max_epochs: 1, ..Default::default() });
        let m2 =
            train_binary(&ktr, &ytr, &KernelSvmParams { max_epochs: 80, ..Default::default() });
        assert!(dual_objective(&ktr, &ytr, &m2) <= dual_objective(&ktr, &ytr, &m1) + 1e-9);
    }

    #[test]
    fn degenerate_one_class_heavy_c_small() {
        // Extremely small C: all alphas pinned at C; decision is sum of
        // class-weighted kernels — must not panic or produce NaN.
        let (xtr, ytr) = ring_data(30, 5);
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(xtr));
        let m = train_binary(&ktr, &ytr, &KernelSvmParams { c: 1e-6, ..Default::default() });
        for i in 0..30 {
            assert!(m.decision(ktr.row(i)).is_finite());
        }
    }

    #[test]
    fn linear_kernel_svm_agrees_with_linear_solver_direction() {
        // Same optimization problem two ways: precomputed linear kernel
        // vs the primal/dual linear solver. Decisions should correlate
        // strongly (not identical: bias handling differs slightly).
        use crate::data::sparse::Csr;
        use crate::svm::linear::{train_binary as train_lin, LinearSvmParams, Loss};
        let (xtr, ytr) = ring_data(60, 6);
        // Make it linearly separable-ish instead: shift class +1 up.
        let mut x2 = xtr.clone();
        for i in 0..60 {
            if ytr[i] == 1 {
                let v = x2.get(i, 0) + 2.0;
                x2.set(i, 0, v);
            }
        }
        let ktr = kernel_matrix_sym(KernelKind::Linear, &Matrix::Dense(x2.clone()));
        let mk = train_binary(&ktr, &ytr, &KernelSvmParams { c: 1.0, ..Default::default() });
        let ml = train_lin(
            &Csr::from_dense(&x2),
            &ytr,
            &LinearSvmParams { c: 1.0, loss: Loss::L1, ..Default::default() },
        );
        let mut agree = 0;
        for i in 0..60 {
            let pk = mk.decision(ktr.row(i)) >= 0.0;
            let pl = ml.decision(Csr::from_dense(&x2).row(i)) >= 0.0;
            if pk == pl {
                agree += 1;
            }
        }
        assert!(agree >= 55, "agreement {agree}/60");
    }
}
