//! Kernel SVM over a [`GramSource`] — the LIBSVM `-t 4` setup of the
//! paper's §2 experiments (Table 1, Figures 1–3), no longer tied to a
//! materialized n×n kernel matrix.
//!
//! Binary C-SVM dual, solved by coordinate descent over the box:
//!
//! ```text
//! min_α  ½ Σᵢⱼ αᵢαⱼ yᵢyⱼ (K(xᵢ,xⱼ) + 1) − Σᵢ αᵢ ,   0 ≤ αᵢ ≤ C
//! ```
//!
//! The `+1` augments the kernel with a regularized bias (equivalent to a
//! constant feature in RKHS), which removes the equality constraint that
//! SMO exists to handle — coordinate descent then converges directly
//! (same approach as LIBSVM's `-s 0` with an augmented kernel; accuracy
//! differences vs a true unregularized bias are negligible at the C
//! ranges swept here).
//!
//! Two cost levers, both new with the [`GramSource`] rework:
//!
//! * **Row fetches only on movement.** The gradient vector is
//!   maintained incrementally for *all* n coordinates, so a coordinate's
//!   projected gradient costs O(1); the kernel row is fetched (from the
//!   precomputed Gram or the on-the-fly cache) only when the coordinate
//!   actually moves. One epoch costs O(n · n_moved) gradient work and
//!   `n_moved` row fetches.
//! * **LIBLINEAR-style shrinking** (`KernelSvmParams::shrink`, on by
//!   default). Coordinates pinned at a bound whose gradient points
//!   hard outward (beyond the previous epoch's projected-gradient
//!   envelope) are dropped from the sweep; when the shrunk active set
//!   converges, everything is reactivated and the solver only stops
//!   once a full-set epoch passes the same ε check — so the final
//!   model satisfies the exact same optimality criterion as the
//!   unshrunk solver (same objective within ε, not necessarily the
//!   same bits). Because the full gradient is maintained through every
//!   update, reactivation is exact and costs no extra row fetches.
//!
//! Shrinking only *skips* coordinates and consumes no randomness, so for
//! a fixed `shrink` setting the trained model is a pure function of the
//! Gram values — `Precomputed` vs `OnTheFly` (any cache size, any
//! thread count) produce bit-identical models
//! (`rust/tests/gram_parity.rs`).

use crate::data::dense::Dense;
use crate::kernels::gram::GramSource;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct KernelSvmParams {
    pub c: f64,
    pub max_epochs: usize,
    pub eps: f64,
    pub seed: u64,
    /// Drop bound-pinned coordinates from the sweep (reactivated for the
    /// final convergence check). Purely a throughput knob at the
    /// optimum: on/off reach the same dual objective within `eps`.
    pub shrink: bool,
}

impl Default for KernelSvmParams {
    fn default() -> Self {
        Self { c: 1.0, max_epochs: 120, eps: 1e-3, seed: 1, shrink: true }
    }
}

/// A trained binary kernel machine: coefficients over the training set.
#[derive(Debug, Clone)]
pub struct KernelModel {
    /// yᵢ αᵢ for every training point (zeros for non-SVs).
    pub coef: Vec<f64>,
    pub epochs_run: usize,
}

impl KernelModel {
    /// Decision value given this test point's kernel row against the
    /// training set (length n_train).
    #[inline]
    pub fn decision(&self, k_row: &[f32]) -> f64 {
        debug_assert_eq!(k_row.len(), self.coef.len());
        let mut s = 0.0f64;
        for (&c, &k) in self.coef.iter().zip(k_row) {
            if c != 0.0 {
                s += c * (k as f64 + 1.0);
            }
        }
        s
    }

    pub fn n_svs(&self) -> usize {
        self.coef.iter().filter(|&&c| c != 0.0).count()
    }
}

/// Train on a precomputed symmetric train-kernel `k` (n × n) with ±1
/// labels — the historical entry, now a thin alias of
/// [`train_binary_on`] (a [`Dense`] Gram is a [`GramSource`]).
pub fn train_binary(k: &Dense, y: &[i32], p: &KernelSvmParams) -> KernelModel {
    assert_eq!(k.rows(), y.len());
    assert_eq!(k.cols(), y.len());
    train_binary_on(k, y, p)
}

/// Train against any [`GramSource`] (precomputed, on-the-fly, or a
/// subset view) with ±1 labels.
pub fn train_binary_on<G: GramSource>(g: &G, y: &[i32], p: &KernelSvmParams) -> KernelModel {
    let n = y.len();
    assert_eq!(g.n(), n, "gram size mismatch");
    assert!(y.iter().all(|&v| v == 1 || v == -1), "labels must be ±1");
    let mut alpha = vec![0.0f64; n];
    // grad[i] = Σ_j Q_ij α_j − 1, Q_ij = y_i y_j (K_ij + 1); starts at −1.
    let mut grad = vec![-1.0f64; n];
    let qii: Vec<f64> = (0..n).map(|i| g.diag(i) as f64 + 1.0).collect();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(p.seed);
    // Shrinking state: `active` marks swept coordinates; the previous
    // epoch's projected-gradient envelope decides who gets dropped
    // (LIBLINEAR's rule). With `shrink` off the thresholds stay at ±∞
    // and the loop is exactly the historical solver.
    let mut active = vec![true; n];
    let mut n_active = n;
    let mut pg_hi = f64::INFINITY;
    let mut pg_lo = f64::NEG_INFINITY;
    let mut epochs_run = 0;
    for epoch in 0..p.max_epochs {
        rng.shuffle(&mut order);
        let mut max_pg: f64 = 0.0;
        let mut pgmax: f64 = f64::NEG_INFINITY;
        let mut pgmin: f64 = f64::INFINITY;
        for &i in &order {
            if !active[i] {
                continue;
            }
            let g_i = grad[i];
            let pg = if alpha[i] <= 0.0 {
                if g_i > pg_hi {
                    active[i] = false;
                    n_active -= 1;
                    continue;
                }
                g_i.min(0.0)
            } else if alpha[i] >= p.c {
                if g_i < pg_lo {
                    active[i] = false;
                    n_active -= 1;
                    continue;
                }
                g_i.max(0.0)
            } else {
                g_i
            };
            pgmax = pgmax.max(pg);
            pgmin = pgmin.min(pg);
            if pg.abs() < 1e-14 {
                continue;
            }
            max_pg = max_pg.max(pg.abs());
            let old = alpha[i];
            let denom = qii[i].max(1e-12);
            let new = (old - g_i / denom).clamp(0.0, p.c);
            let delta = new - old;
            if delta != 0.0 {
                alpha[i] = new;
                // The one place a kernel row is needed: maintain the
                // full gradient, grad_j += Q_ji Δ = y_j y_i (K_ji + 1) Δ.
                let yi = y[i] as f64;
                g.with_row(i, |krow| {
                    debug_assert_eq!(krow.len(), n);
                    for (gj, (&yj, &kij)) in grad.iter_mut().zip(y.iter().zip(krow)) {
                        *gj += (yj as f64) * yi * (kij as f64 + 1.0) * delta;
                    }
                });
            }
        }
        epochs_run = epoch + 1;
        if max_pg < p.eps {
            if n_active == n {
                break; // converged over the full set
            }
            // The shrunk active set converged: reactivate everything and
            // rerun the check over the full set (no row fetches needed —
            // the gradient was maintained for every coordinate).
            active.fill(true);
            n_active = n;
            pg_hi = f64::INFINITY;
            pg_lo = f64::NEG_INFINITY;
            continue;
        }
        if p.shrink {
            // Next epoch shrinks against this epoch's envelope
            // (LIBLINEAR's rule: a one-sided envelope that never made
            // progress resets to ∞ so it cannot over-shrink).
            pg_hi = if pgmax <= 0.0 { f64::INFINITY } else { pgmax };
            pg_lo = if pgmin >= 0.0 { f64::NEG_INFINITY } else { pgmin };
        }
    }
    let coef: Vec<f64> = alpha.iter().zip(y).map(|(&a, &yy)| a * yy as f64).collect();
    KernelModel { coef, epochs_run }
}

/// Dual objective (for tests): ½ αᵀQα − Σα expressed via coef and grad
/// recomputation.
pub fn dual_objective(k: &Dense, y: &[i32], m: &KernelModel) -> f64 {
    let n = y.len();
    let alpha: Vec<f64> = m.coef.iter().zip(y).map(|(&c, &yy)| c * yy as f64).collect();
    let mut obj = -alpha.iter().sum::<f64>();
    for i in 0..n {
        if alpha[i] == 0.0 {
            continue;
        }
        let krow = k.row(i);
        let mut s = 0.0;
        for j in 0..n {
            if alpha[j] != 0.0 {
                s += (y[i] * y[j]) as f64 * (krow[j] as f64 + 1.0) * alpha[j];
            }
        }
        obj += 0.5 * alpha[i] * s;
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Matrix;
    use crate::kernels::matrix::{kernel_matrix, kernel_matrix_sym};
    use crate::kernels::KernelKind;

    /// XOR-ish dataset: linearly inseparable, min-max kernel separable.
    fn ring_data(n: usize, seed: u64) -> (Dense, Vec<i32>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Dense::zeros(n, 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label = if i % 2 == 0 { 1 } else { -1 };
            // Class +1: radius ~0.5; class −1: radius ~1.5 (shifted to
            // stay nonnegative).
            let radius = if label == 1 { 0.5 } else { 1.5 };
            let th = rng.uniform() * std::f64::consts::TAU;
            x.set(i, 0, (2.0 + radius * th.cos() + 0.05 * rng.normal()) as f32);
            x.set(i, 1, (2.0 + radius * th.sin() + 0.05 * rng.normal()) as f32);
            y.push(label);
        }
        (x, y)
    }

    #[test]
    fn solves_nonlinear_problem_linear_cannot() {
        let (xtr, ytr) = ring_data(120, 1);
        let (xte, yte) = ring_data(80, 2);
        let mtr = Matrix::Dense(xtr);
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &mtr);
        let m = train_binary(&ktr, &ytr, &KernelSvmParams { c: 32.0, ..Default::default() });
        let kte = kernel_matrix(KernelKind::MinMax, &Matrix::Dense(xte), &mtr);
        let acc = (0..yte.len())
            .filter(|&i| {
                let pred = if m.decision(kte.row(i)) >= 0.0 { 1 } else { -1 };
                pred == yte[i]
            })
            .count() as f64
            / yte.len() as f64;
        assert!(acc > 0.9, "min-max kernel SVM accuracy {acc}");
    }

    #[test]
    fn alphas_respect_box() {
        let (xtr, ytr) = ring_data(60, 3);
        let c = 2.0;
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(xtr));
        let m = train_binary(&ktr, &ytr, &KernelSvmParams { c, ..Default::default() });
        for (i, (&coef, &yy)) in m.coef.iter().zip(&ytr).enumerate() {
            let a = coef * yy as f64;
            assert!((-1e-9..=c + 1e-9).contains(&a), "alpha[{i}] = {a}");
        }
        assert!(m.n_svs() > 0);
    }

    #[test]
    fn longer_training_does_not_worsen_dual() {
        let (xtr, ytr) = ring_data(60, 4);
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(xtr));
        let m1 = train_binary(&ktr, &ytr, &KernelSvmParams { max_epochs: 1, ..Default::default() });
        let m2 =
            train_binary(&ktr, &ytr, &KernelSvmParams { max_epochs: 80, ..Default::default() });
        assert!(dual_objective(&ktr, &ytr, &m2) <= dual_objective(&ktr, &ytr, &m1) + 1e-9);
    }

    #[test]
    fn degenerate_one_class_heavy_c_small() {
        // Extremely small C: all alphas pinned at C; decision is sum of
        // class-weighted kernels — must not panic or produce NaN.
        let (xtr, ytr) = ring_data(30, 5);
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(xtr));
        let m = train_binary(&ktr, &ytr, &KernelSvmParams { c: 1e-6, ..Default::default() });
        for i in 0..30 {
            assert!(m.decision(ktr.row(i)).is_finite());
        }
    }

    #[test]
    fn shrinking_reaches_the_unshrunk_objective() {
        // Shrinking is a throughput knob: both settings satisfy the same
        // ε-optimality check over the full coordinate set, so the dual
        // objectives agree to within the convergence tolerance.
        let (xtr, ytr) = ring_data(100, 8);
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(xtr));
        for c in [0.5, 32.0] {
            let base = KernelSvmParams { c, max_epochs: 400, ..Default::default() };
            let m_on = train_binary(&ktr, &ytr, &KernelSvmParams { shrink: true, ..base.clone() });
            let m_off =
                train_binary(&ktr, &ytr, &KernelSvmParams { shrink: false, ..base.clone() });
            let o_on = dual_objective(&ktr, &ytr, &m_on);
            let o_off = dual_objective(&ktr, &ytr, &m_off);
            assert!(
                (o_on - o_off).abs() < 1e-2 * (1.0 + o_off.abs()),
                "C={c}: shrink {o_on} vs plain {o_off}"
            );
        }
    }

    #[test]
    fn linear_kernel_svm_agrees_with_linear_solver_direction() {
        // Same optimization problem two ways: precomputed linear kernel
        // vs the primal/dual linear solver. Decisions should correlate
        // strongly (not identical: bias handling differs slightly).
        use crate::data::sparse::Csr;
        use crate::svm::linear::{train_binary as train_lin, LinearSvmParams, Loss};
        let (xtr, ytr) = ring_data(60, 6);
        // Make it linearly separable-ish instead: shift class +1 up.
        let mut x2 = xtr.clone();
        for i in 0..60 {
            if ytr[i] == 1 {
                let v = x2.get(i, 0) + 2.0;
                x2.set(i, 0, v);
            }
        }
        let ktr = kernel_matrix_sym(KernelKind::Linear, &Matrix::Dense(x2.clone()));
        let mk = train_binary(&ktr, &ytr, &KernelSvmParams { c: 1.0, ..Default::default() });
        let ml = train_lin(
            &Csr::from_dense(&x2),
            &ytr,
            &LinearSvmParams { c: 1.0, loss: Loss::L1, ..Default::default() },
        );
        let mut agree = 0;
        for i in 0..60 {
            let pk = mk.decision(ktr.row(i)) >= 0.0;
            let pl = ml.decision(Csr::from_dense(&x2).row(i)) >= 0.0;
            if pk == pl {
                agree += 1;
            }
        }
        assert!(agree >= 55, "agreement {agree}/60");
    }
}
