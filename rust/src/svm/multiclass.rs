//! Multiclass wrappers: one-vs-one for kernel machines (LIBSVM's
//! strategy) and one-vs-rest for linear models (LIBLINEAR's strategy) —
//! matching the tools the paper used for each half of its experiments.
//!
//! Both wrappers train their constituent binary problems in parallel
//! over `util::pool::par_claim` (classes for OvR, pairs for OvO): the
//! subproblems are embarrassingly parallel and each binary solve is
//! deterministic per seed, so results are **identical at any thread
//! count** — `MINMAX_THREADS` is purely a throughput knob, pinned by
//! `rust/tests/svm_parity.rs`.

use crate::data::sparse::SparseRow;
use crate::features::Expansion;
use crate::kernels::gram::{GramSource, SubsetGram};
use crate::serve::{quantize_slab, ExportedWeights, SlabPrecision};
use crate::util::pool;

use super::kernel::{train_binary_on as train_kernel_binary, KernelModel, KernelSvmParams};
use super::linear::{train_binary as train_linear_binary, LinearModel, LinearSvmParams};
use super::rowset::RowSet;

// ------------------------------------------------------------- kernel OvO

/// One-vs-one kernel SVM over any [`GramSource`] train kernel —
/// precomputed `Dense` (the historical path) or an on-the-fly source.
#[derive(Debug)]
pub struct KernelOvO {
    pub n_classes: usize,
    /// For each pair (a < b): the training-subset indices and the model.
    pairs: Vec<(i32, i32, Vec<usize>, KernelModel)>,
}

impl KernelOvO {
    /// `gram` is the n×n training kernel behind a [`GramSource`]; `y`
    /// holds labels in `0..n_classes`. Pair subproblems run across
    /// `MINMAX_THREADS`.
    pub fn train<G: GramSource>(
        gram: &G,
        y: &[i32],
        n_classes: usize,
        p: &KernelSvmParams,
    ) -> Self {
        Self::train_with_threads(gram, y, n_classes, p, pool::default_threads())
    }

    /// [`KernelOvO::train`] with an explicit thread count. Each pair
    /// trains against a lazy index-mapped [`SubsetGram`] view of the
    /// shared source (no m×m sub-Gram copies — and with an on-the-fly
    /// source, pairs share one row cache); slots preserve the
    /// sequential `(a, b)` pair order, so the result is identical at
    /// any thread count.
    pub fn train_with_threads<G: GramSource>(
        gram: &G,
        y: &[i32],
        n_classes: usize,
        p: &KernelSvmParams,
        threads: usize,
    ) -> Self {
        assert_eq!(gram.n(), y.len());
        let combos: Vec<(i32, i32)> = (0..n_classes as i32)
            .flat_map(|a| ((a + 1)..n_classes as i32).map(move |b| (a, b)))
            .collect();
        let trained = pool::par_map_claim(combos.len(), threads, |pi| {
            let (a, b) = combos[pi];
            let idx: Vec<usize> = (0..y.len()).filter(|&i| y[i] == a || y[i] == b).collect();
            if idx.is_empty() {
                return None;
            }
            let yy: Vec<i32> = idx.iter().map(|&i| if y[i] == a { 1 } else { -1 }).collect();
            if yy.iter().all(|&v| v == 1) || yy.iter().all(|&v| v == -1) {
                return None; // one of the classes absent — skip pair
            }
            let view = SubsetGram::new(gram, &idx);
            let model = train_kernel_binary(&view, &yy, p);
            Some((a, b, idx, model))
        });
        let pairs = trained.into_iter().flatten().collect();
        Self { n_classes, pairs }
    }

    /// Predict from the test point's kernel row against the full training
    /// set (length n_train). Majority vote; ties broken by summed margins.
    pub fn predict(&self, k_row: &[f32]) -> i32 {
        let mut votes = vec![0u32; self.n_classes];
        let mut margins = vec![0.0f64; self.n_classes];
        let mut sub_row: Vec<f32> = Vec::new();
        for (a, b, idx, model) in &self.pairs {
            sub_row.clear();
            sub_row.extend(idx.iter().map(|&i| k_row[i]));
            let dec = model.decision(&sub_row);
            if dec >= 0.0 {
                votes[*a as usize] += 1;
                margins[*a as usize] += dec;
            } else {
                votes[*b as usize] += 1;
                margins[*b as usize] -= dec;
            }
        }
        let mut best = 0usize;
        for c in 1..self.n_classes {
            if votes[c] > votes[best]
                || (votes[c] == votes[best] && margins[c] > margins[best])
            {
                best = c;
            }
        }
        best as i32
    }

    pub fn n_models(&self) -> usize {
        self.pairs.len()
    }
}

// ------------------------------------------------------------- linear OvR

/// One-vs-rest linear SVM over any [`RowSet`] training representation
/// — the one-hot [`crate::features::CodeMatrix`] fast path by default
/// (`Pipeline`, `hash_dataset`), CSR for general sparse features.
#[derive(Debug)]
pub struct LinearOvR {
    pub n_classes: usize,
    models: Vec<LinearModel>,
}

impl LinearOvR {
    /// Train one binary model per class, classes sharded across
    /// `MINMAX_THREADS` worker threads.
    pub fn train<X: RowSet + ?Sized>(
        x: &X,
        y: &[i32],
        n_classes: usize,
        p: &LinearSvmParams,
    ) -> Self {
        Self::train_with_threads(x, y, n_classes, p, pool::default_threads())
    }

    /// [`LinearOvR::train`] with an explicit thread count (tests pin
    /// thread-count invariance with it). Classes are claimed one at a
    /// time by a work-stealing counter; every class's solve is
    /// deterministic per `p.seed`, so the model set is identical at any
    /// `threads`.
    pub fn train_with_threads<X: RowSet + ?Sized>(
        x: &X,
        y: &[i32],
        n_classes: usize,
        p: &LinearSvmParams,
        threads: usize,
    ) -> Self {
        assert_eq!(x.rows(), y.len());
        let models = pool::par_map_claim(n_classes, threads, |c| {
            let yy: Vec<i32> = y.iter().map(|&v| if v == c as i32 { 1 } else { -1 }).collect();
            train_linear_binary(x, &yy, p)
        });
        Self { n_classes, models }
    }

    pub fn predict(&self, x: SparseRow<'_>) -> i32 {
        let mut best = 0usize;
        let mut best_dec = f64::NEG_INFINITY;
        for (c, m) in self.models.iter().enumerate() {
            let d = m.decision(x);
            if d > best_dec {
                best_dec = d;
                best = c;
            }
        }
        best as i32
    }

    /// Argmax class for row `i` of any [`RowSet`] (code matrices score
    /// with `k` gathers per class).
    pub fn predict_on<X: RowSet + ?Sized>(&self, x: &X, i: usize) -> i32 {
        let mut best = 0usize;
        let mut best_dec = f64::NEG_INFINITY;
        for (c, m) in self.models.iter().enumerate() {
            let d = m.decision_on(x, i);
            if d > best_dec {
                best_dec = d;
                best = c;
            }
        }
        best as i32
    }

    pub fn decisions(&self, x: SparseRow<'_>) -> Vec<f64> {
        let mut out = vec![0.0f64; self.models.len()];
        self.decisions_sparse_into(x, &mut out);
        out
    }

    /// [`LinearOvR::decisions`] into a caller-owned buffer
    /// (`len == n_classes`) — the allocation-free serving variant.
    pub fn decisions_sparse_into(&self, x: SparseRow<'_>, out: &mut [f64]) {
        assert_eq!(out.len(), self.models.len(), "decision buffer must hold n_classes values");
        for (slot, m) in out.iter_mut().zip(&self.models) {
            *slot = m.decision(x);
        }
    }

    /// Per-class decision values for row `i` of any [`RowSet`] — thin
    /// allocating wrapper over [`LinearOvR::decisions_into`].
    pub fn decisions_on<X: RowSet + ?Sized>(&self, x: &X, i: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; self.models.len()];
        self.decisions_into(x, i, &mut out);
        out
    }

    /// [`LinearOvR::decisions_on`] into a caller-owned buffer
    /// (`len == n_classes`): one `decision_on` per class, no per-row
    /// allocation. Same values in the same order as `decisions_on`
    /// (pinned by `rust/tests/svm_parity.rs`).
    pub fn decisions_into<X: RowSet + ?Sized>(&self, x: &X, i: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.models.len(), "decision buffer must hold n_classes values");
        for (slot, m) in out.iter_mut().zip(&self.models) {
            *slot = m.decision_on(x, i);
        }
    }

    /// Binary shortcut: with 2 classes train a single model.
    pub fn models(&self) -> &[LinearModel] {
        &self.models
    }

    /// Export the class-minor `[K, 2^bits, C]` serving slab at a chosen
    /// precision, with each class bias folded into every code of slot 0
    /// (the serving gather has no bias input; every live row selects
    /// exactly one code per slot, so the fold is exact). The `F32` arm
    /// reproduces the historical `coordinator::export_scorer_weights`
    /// bytes bit-for-bit (one f64→f32 rounding per weight); the `Int8`
    /// arm quantizes with the same per-class affine scheme
    /// `serve::Scorer::with_precision` uses, so a scorer built from
    /// this export serves the exact arithmetic the trainer gated.
    /// Consumed by [`crate::serve::Scorer::from_exported_slab`].
    pub fn export_scorer_weights(
        &self,
        expansion: &Expansion,
        precision: SlabPrecision,
    ) -> ExportedWeights {
        let codes = expansion.code_space();
        let k = expansion.k;
        let c = self.models.len();
        let mut w = vec![0.0f64; k * codes * c];
        for (cls, m) in self.models.iter().enumerate() {
            assert_eq!(
                m.w.len(),
                k * codes,
                "model weight vector must cover the expansion's columns"
            );
            for j in 0..k {
                let bias_share = if j == 0 { m.b } else { 0.0 };
                for code in 0..codes {
                    let fidx = j * codes + code;
                    w[fidx * c + cls] = m.w[fidx] + bias_share;
                }
            }
        }
        match precision {
            SlabPrecision::F64 => ExportedWeights::F64(w),
            SlabPrecision::F32 => {
                ExportedWeights::F32(w.iter().map(|&v| v as f32).collect())
            }
            SlabPrecision::Int8 => {
                let (q, scale, offset) = quantize_slab(&w, c);
                ExportedWeights::Int8 { q, scale, offset }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::Dense;
    use crate::data::sparse::{Csr, CsrBuilder};
    use crate::data::Matrix;
    use crate::kernels::matrix::{kernel_matrix, kernel_matrix_sym};
    use crate::kernels::KernelKind;
    use crate::util::rng::Pcg64;

    fn three_class_dense(n: usize, seed: u64) -> (Dense, Vec<i32>) {
        let mut rng = Pcg64::new(seed);
        let protos = [[3.0, 0.5, 0.5], [0.5, 3.0, 0.5], [0.5, 0.5, 3.0]];
        let mut x = Dense::zeros(n, 3);
        let mut y = Vec::new();
        for i in 0..n {
            let c = i % 3;
            for j in 0..3 {
                x.set(i, j, (protos[c][j] * rng.lognormal(0.0, 0.2)) as f32);
            }
            y.push(c as i32);
        }
        (x, y)
    }

    #[test]
    fn kernel_ovo_classifies_three_classes() {
        let (xtr, ytr) = three_class_dense(90, 1);
        let (xte, yte) = three_class_dense(45, 2);
        let mtr = Matrix::Dense(xtr);
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &mtr);
        let ovo = KernelOvO::train(&ktr, &ytr, 3, &KernelSvmParams::default());
        assert_eq!(ovo.n_models(), 3);
        let kte = kernel_matrix(KernelKind::MinMax, &Matrix::Dense(xte), &mtr);
        let acc = (0..yte.len())
            .filter(|&i| ovo.predict(kte.row(i)) == yte[i])
            .count() as f64
            / yte.len() as f64;
        assert!(acc > 0.9, "OvO accuracy {acc}");
    }

    #[test]
    fn linear_ovr_classifies_three_classes() {
        let (xtr, ytr) = three_class_dense(90, 3);
        let (xte, yte) = three_class_dense(45, 4);
        let str_ = Csr::from_dense(&xtr);
        let ste = Csr::from_dense(&xte);
        let ovr = LinearOvR::train(&str_, &ytr, 3, &LinearSvmParams::default());
        let acc = (0..yte.len())
            .filter(|&i| ovr.predict(ste.row(i)) == yte[i])
            .count() as f64
            / yte.len() as f64;
        assert!(acc > 0.9, "OvR accuracy {acc}");
        assert_eq!(ovr.decisions(ste.row(0)).len(), 3);
    }

    #[test]
    fn ovo_handles_missing_pair_gracefully() {
        // Class 2 absent from training: pairs with it are skipped.
        let (xtr, mut ytr) = three_class_dense(60, 5);
        for y in ytr.iter_mut() {
            if *y == 2 {
                *y = 0;
            }
        }
        let ktr = kernel_matrix_sym(KernelKind::MinMax, &Matrix::Dense(xtr));
        let ovo = KernelOvO::train(&ktr, &ytr, 3, &KernelSvmParams::default());
        assert_eq!(ovo.n_models(), 1); // only (0,1) trainable
        let _ = ovo.predict(ktr.row(0)); // must not panic
    }

    #[test]
    fn binary_ovr_matches_single_binary_model() {
        let mut b = CsrBuilder::new(2);
        for i in 0..20 {
            b.push_row(vec![(0, 1.0 + (i % 2) as f32), (1, 2.0 - (i % 2) as f32)]);
        }
        let x = b.finish();
        let y: Vec<i32> = (0..20).map(|i| (i % 2) as i32).collect();
        let ovr = LinearOvR::train(&x, &y, 2, &LinearSvmParams::default());
        for i in 0..20 {
            assert_eq!(ovr.predict(x.row(i)), y[i]);
        }
    }
}
