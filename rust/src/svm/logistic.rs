//! ℓ₂-regularized logistic regression — the paper's §1 pitch is "linear
//! SVM **or logistic regression**" on hashed features, so both linear
//! learners exist. Solved in the primal by batch gradient descent with
//! backtracking line search (objective is smooth and strongly convex;
//! each pass is O(nnz)).
//!
//! Like the dual-CD SVM, the trainer is generic over
//! [`RowSet`], so one-hot [`crate::features::CodeMatrix`] features get
//! the gather-only gradient/objective passes (no values array, no
//! multiplies) from the same body that serves general CSR rows.

use crate::data::sparse::SparseRow;

use super::rowset::RowSet;

#[derive(Debug, Clone)]
pub struct LogisticParams {
    pub c: f64,
    pub max_iters: usize,
    /// Stop when the gradient inf-norm falls below this.
    pub eps: f64,
    pub bias: bool,
}

impl Default for LogisticParams {
    fn default() -> Self {
        Self { c: 1.0, max_iters: 300, eps: 1e-4, bias: true }
    }
}

#[derive(Debug, Clone)]
pub struct LogisticModel {
    pub w: Vec<f64>,
    pub b: f64,
    pub iters_run: usize,
}

impl LogisticModel {
    #[inline]
    pub fn decision(&self, x: SparseRow<'_>) -> f64 {
        let mut s = self.b;
        for (&j, &v) in x.indices.iter().zip(x.values) {
            s += self.w[j as usize] * v as f64;
        }
        s
    }

    /// P(y = +1 | x).
    pub fn probability(&self, x: SparseRow<'_>) -> f64 {
        1.0 / (1.0 + (-self.decision(x)).exp())
    }

    pub fn predict(&self, x: SparseRow<'_>) -> i32 {
        if self.decision(x) >= 0.0 {
            1
        } else {
            -1
        }
    }

    /// Decision value for row `i` of any [`RowSet`] representation.
    #[inline]
    pub fn decision_on<X: RowSet + ?Sized>(&self, x: &X, i: usize) -> f64 {
        self.b + x.dot(i, &self.w)
    }
}

/// Objective: ½‖w‖² + C Σ log(1 + exp(−yᵢ f(xᵢ))).
fn objective<X: RowSet + ?Sized>(x: &X, y: &[i32], w: &[f64], b: f64, c: f64, bias: bool) -> f64 {
    let mut obj = 0.5 * (w.iter().map(|v| v * v).sum::<f64>() + if bias { b * b } else { 0.0 });
    for i in 0..x.rows() {
        let f = b + x.dot(i, w);
        let m = -(y[i] as f64) * f;
        // log(1+e^m), stable.
        obj += c * if m > 30.0 { m } else { (1.0 + m.exp()).ln() };
    }
    obj
}

pub fn train_binary<X: RowSet + ?Sized>(x: &X, y: &[i32], p: &LogisticParams) -> LogisticModel {
    let n = x.rows();
    assert_eq!(n, y.len());
    assert!(y.iter().all(|&v| v == 1 || v == -1), "labels must be ±1");
    let d = x.cols();
    let mut w = vec![0.0f64; d];
    let mut b = 0.0f64;
    let mut iters_run = 0;
    let mut step = 1.0f64;
    let mut fcur = objective(x, y, &w, b, p.c, p.bias);
    for iter in 0..p.max_iters {
        // Gradient: w + C Σ −yᵢ σ(−yᵢ fᵢ) xᵢ
        let mut gw = w.clone();
        let mut gb = if p.bias { b } else { 0.0 };
        for i in 0..n {
            let f = b + x.dot(i, &w);
            let yi = y[i] as f64;
            let sig = 1.0 / (1.0 + (yi * f).exp()); // σ(−yᵢ fᵢ)
            x.add_scaled(i, -p.c * yi * sig, &mut gw);
            if p.bias {
                gb += -p.c * yi * sig;
            }
        }
        let gnorm = gw.iter().map(|v| v.abs()).fold(gb.abs(), f64::max);
        iters_run = iter + 1;
        if gnorm < p.eps {
            break;
        }
        // Backtracking line search on the full objective.
        step = (step * 2.0).min(1e4);
        let g2: f64 = gw.iter().map(|v| v * v).sum::<f64>() + gb * gb;
        loop {
            let wt: Vec<f64> = w.iter().zip(&gw).map(|(wi, gi)| wi - step * gi).collect();
            let bt = b - step * gb;
            let ft = objective(x, y, &wt, bt, p.c, p.bias);
            if ft <= fcur - 0.25 * step * g2 || step < 1e-12 {
                w = wt;
                b = bt;
                fcur = ft;
                break;
            }
            step *= 0.5;
        }
    }
    LogisticModel { w, b, iters_run }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{Csr, CsrBuilder};
    use crate::util::rng::Pcg64;

    fn clusters(n: usize, seed: u64) -> (Csr, Vec<i32>) {
        let mut rng = Pcg64::new(seed);
        let mut b = CsrBuilder::new(4);
        let mut y = Vec::new();
        for i in 0..n {
            let label = if i % 2 == 0 { 1 } else { -1 };
            let c = if label == 1 { 1.5 } else { 0.3 };
            b.push_row((0..4).map(|j| (j, (c + 0.2 * rng.normal()).max(0.0) as f32)).collect());
            y.push(label);
        }
        (b.finish(), y)
    }

    #[test]
    fn learns_separable_clusters() {
        let (x, y) = clusters(80, 1);
        let m = train_binary(&x, &y, &LogisticParams::default());
        let acc = (0..x.rows()).filter(|&i| m.predict(x.row(i)) == y[i]).count();
        assert!(acc as f64 / x.rows() as f64 > 0.95);
    }

    #[test]
    fn probabilities_calibrated_direction() {
        let (x, y) = clusters(80, 2);
        let m = train_binary(&x, &y, &LogisticParams::default());
        // Mean probability of the positive class must be higher on
        // positive examples.
        let (mut pp, mut pn, mut np, mut nn) = (0.0, 0usize, 0.0, 0usize);
        for i in 0..x.rows() {
            let p = m.probability(x.row(i));
            assert!((0.0..=1.0).contains(&p));
            if y[i] == 1 {
                pp += p;
                pn += 1;
            } else {
                np += p;
                nn += 1;
            }
        }
        assert!(pp / pn as f64 > np / nn as f64 + 0.2);
    }

    #[test]
    fn objective_monotone_in_iterations() {
        let (x, y) = clusters(60, 3);
        let m1 = train_binary(&x, &y, &LogisticParams { max_iters: 2, ..Default::default() });
        let m2 = train_binary(&x, &y, &LogisticParams { max_iters: 100, ..Default::default() });
        let o1 = objective(&x, &y, &m1.w, m1.b, 1.0, true);
        let o2 = objective(&x, &y, &m2.w, m2.b, 1.0, true);
        assert!(o2 <= o1 + 1e-9, "{o2} > {o1}");
    }

    #[test]
    fn regularization_bounds_weights() {
        let (x, y) = clusters(60, 4);
        let m = train_binary(&x, &y, &LogisticParams { c: 1e-4, ..Default::default() });
        assert!(m.w.iter().map(|v| v.abs()).fold(0.0, f64::max) < 0.5);
    }
}
