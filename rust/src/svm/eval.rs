//! The paper's §2 evaluation protocol: apply each kernel's required
//! normalization, precompute the train/test kernel blocks **once**, then
//! sweep the SVM regularization parameter C over a wide log grid and
//! report test accuracy per C (Figures 1–3) and the per-kernel best
//! (Table 1).

use crate::data::{Dataset, Matrix};
use crate::kernels::gram::{GramSource, GramSpec, OnTheFly};
use crate::kernels::matrix::{kernel_matrix, kernel_matrix_sym};
use crate::kernels::KernelKind;
use crate::pipeline::Scaling;
use crate::svm::kernel::KernelSvmParams;
use crate::svm::multiclass::KernelOvO;

/// The paper's C grid: 10^-2 … 10^3, `points` log-spaced values
/// (Figures 1–3 use a fine grid over exactly this range).
pub fn c_grid(points: usize) -> Vec<f64> {
    assert!(points >= 2);
    (0..points)
        .map(|i| 10f64.powf(-2.0 + 5.0 * i as f64 / (points - 1) as f64))
        .collect()
}

/// Apply `kern`'s required row normalization, returning new matrices.
/// (One implementation for the whole crate: delegates to the pipeline's
/// [`Scaling`] stage.)
pub fn normalize_for(kern: KernelKind, m: &Matrix) -> Matrix {
    Scaling::for_normalization(kern.required_normalization()).apply(m)
}

/// Accuracy-vs-C curve for one (dataset, kernel) pair.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub kernel: KernelKind,
    pub dataset: String,
    /// (C, test accuracy in [0,1]) per grid point.
    pub curve: Vec<(f64, f64)>,
}

impl SweepResult {
    /// The Table-1 number: best accuracy over the grid.
    pub fn best_accuracy(&self) -> f64 {
        self.curve.iter().map(|&(_, a)| a).fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn best_c(&self) -> f64 {
        self.curve
            .iter()
            .fold((0.0, f64::NEG_INFINITY), |acc, &(c, a)| if a > acc.1 { (c, a) } else { acc })
            .0
    }
}

/// Run the full §2 protocol for one kernel on one dataset with a
/// precomputed train Gram (the historical default).
pub fn kernel_svm_sweep(ds: &Dataset, kern: KernelKind, cs: &[f64]) -> SweepResult {
    kernel_svm_sweep_with(ds, kern, cs, GramSpec::Precomputed)
}

/// [`kernel_svm_sweep`] with an explicit [`GramSpec`]: `Precomputed`
/// materializes the n×n train kernel once and reuses it per C;
/// `OnTheFly` streams rows on demand behind a bounded LRU cache, so
/// training never holds more than `cache_rows` kernel rows. Both
/// produce bit-identical models (`rust/tests/gram_parity.rs`); the
/// test-side n_test×n_train block is always computed directly.
/// Multiclass is one-vs-one (LIBSVM's strategy).
pub fn kernel_svm_sweep_with(
    ds: &Dataset,
    kern: KernelKind,
    cs: &[f64],
    gram: GramSpec,
) -> SweepResult {
    let train = normalize_for(kern, &ds.train_x);
    let test = normalize_for(kern, &ds.test_x);
    let k_test = kernel_matrix(kern, &test, &train);
    let curve = match gram {
        GramSpec::Precomputed => {
            let k_train = kernel_matrix_sym(kern, &train);
            sweep_curve(&k_train, &k_test, ds, cs)
        }
        GramSpec::OnTheFly { .. } => {
            // Split the thread budget between the OvO pair loop and the
            // row fills: with enough pairs to saturate the pool, misses
            // fill serially (avoids pairs × fill-threads
            // oversubscription); a binary problem gets the whole budget
            // for its fills.
            let n_classes = ds.n_classes();
            let pairs = (n_classes * n_classes.saturating_sub(1) / 2).max(1);
            let fill_threads = (crate::util::pool::default_threads() / pairs).max(1);
            let src = OnTheFly::new(kern, &train)
                .with_cache_rows(gram.cache_rows_for(train.rows()))
                .with_threads(fill_threads);
            sweep_curve(&src, &k_test, ds, cs)
        }
    };
    SweepResult { kernel: kern, dataset: ds.name.clone(), curve }
}

/// One OvO train/eval per C against any training-kernel source.
fn sweep_curve<G: GramSource>(
    gram: &G,
    k_test: &crate::data::Dense,
    ds: &Dataset,
    cs: &[f64],
) -> Vec<(f64, f64)> {
    let n_classes = ds.n_classes();
    let mut curve = Vec::with_capacity(cs.len());
    for &c in cs {
        let p = KernelSvmParams { c, ..Default::default() };
        let model = KernelOvO::train(gram, &ds.train_y, n_classes, &p);
        let mut acc = crate::util::stats::Accuracy::default();
        for i in 0..ds.n_test() {
            acc.push(model.predict(k_test.row(i)), ds.test_y[i]);
        }
        curve.push((c, acc.value()));
    }
    curve
}

/// Accuracy of a single train/predict round at one C (used by drivers
/// that do their own feature engineering, e.g. the hashed pipelines).
/// Generic over [`crate::svm::RowSet`]: hashed one-hot features pass a
/// `CodeMatrix` (the default fast path), general features a `Csr`.
pub fn linear_svm_accuracy<X: crate::svm::RowSet + ?Sized>(
    train: &X,
    train_y: &[i32],
    test: &X,
    test_y: &[i32],
    n_classes: usize,
    c: f64,
) -> f64 {
    use crate::svm::linear::LinearSvmParams;
    use crate::svm::multiclass::LinearOvR;
    let p = LinearSvmParams { c, ..Default::default() };
    let model = LinearOvR::train(train, train_y, n_classes, &p);
    let mut acc = crate::util::stats::Accuracy::default();
    for i in 0..test.rows() {
        acc.push(model.predict_on(test, i), test_y[i]);
    }
    acc.value()
}

/// Sweep C for a linear SVM on explicit features; returns the curve
/// like [`kernel_svm_sweep`].
pub fn linear_svm_sweep<X: crate::svm::RowSet + ?Sized>(
    train: &X,
    train_y: &[i32],
    test: &X,
    test_y: &[i32],
    n_classes: usize,
    cs: &[f64],
) -> Vec<(f64, f64)> {
    cs.iter()
        .map(|&c| (c, linear_svm_accuracy(train, train_y, test, test_y, n_classes, c)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn c_grid_spans_paper_range() {
        let g = c_grid(11);
        assert!((g[0] - 0.01).abs() < 1e-12);
        assert!((g[10] - 1000.0).abs() < 1e-9);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn normalization_is_applied_per_kernel() {
        let ds = generate("letter", SynthConfig { seed: 1, n_train: 30, n_test: 30 }).unwrap();
        let l1 = normalize_for(KernelKind::Intersection, &ds.train_x).to_dense();
        for row in l1.iter_rows() {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
        let l2 = normalize_for(KernelKind::Linear, &ds.train_x).to_dense();
        for row in l2.iter_rows() {
            let s: f32 = row.iter().map(|v| v * v).sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
        // MinMax: untouched.
        let raw = normalize_for(KernelKind::MinMax, &ds.train_x).to_dense();
        assert_eq!(raw, ds.train_x.to_dense());
    }

    #[test]
    fn sweep_runs_and_minmax_beats_linear_on_letter_analog() {
        // The paper's headline Table-1 effect, on a small instance.
        let ds = generate("letter", SynthConfig { seed: 5, n_train: 150, n_test: 150 }).unwrap();
        let cs = c_grid(5);
        let mm = kernel_svm_sweep(&ds, KernelKind::MinMax, &cs);
        let lin = kernel_svm_sweep(&ds, KernelKind::Linear, &cs);
        assert!(
            mm.best_accuracy() > lin.best_accuracy(),
            "min-max {} vs linear {}",
            mm.best_accuracy(),
            lin.best_accuracy()
        );
        assert!(mm.best_accuracy() > 0.5);
        assert_eq!(mm.curve.len(), 5);
    }

    #[test]
    fn on_the_fly_sweep_matches_precomputed() {
        // The tentpole invariant at the protocol level: an OnTheFly
        // sweep with a tight row cache reproduces the precomputed sweep
        // exactly (bit-identical accuracies at every C).
        let ds = generate("vowel", SynthConfig { seed: 3, n_train: 60, n_test: 60 }).unwrap();
        let cs = c_grid(3);
        let pre = kernel_svm_sweep_with(&ds, KernelKind::MinMax, &cs, GramSpec::Precomputed);
        let otf = kernel_svm_sweep_with(
            &ds,
            KernelKind::MinMax,
            &cs,
            GramSpec::OnTheFly { cache_rows: Some(15) },
        );
        assert_eq!(pre.curve.len(), otf.curve.len());
        for (&(c1, a1), &(c2, a2)) in pre.curve.iter().zip(&otf.curve) {
            assert_eq!(c1, c2);
            assert_eq!(a1.to_bits(), a2.to_bits(), "accuracy differs at C={c1}");
        }
    }

    #[test]
    fn best_c_is_argmax() {
        let r = SweepResult {
            kernel: KernelKind::Linear,
            dataset: "x".into(),
            curve: vec![(0.1, 0.5), (1.0, 0.9), (10.0, 0.7)],
        };
        assert_eq!(r.best_c(), 1.0);
        assert!((r.best_accuracy() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn linear_sweep_on_sparse_features() {
        let ds = generate("splice", SynthConfig { seed: 2, n_train: 100, n_test: 100 }).unwrap();
        let tr = ds.train_x.to_csr();
        let te = ds.test_x.to_csr();
        let curve =
            linear_svm_sweep(&tr, &ds.train_y, &te, &ds.test_y, ds.n_classes(), &c_grid(4));
        assert_eq!(curve.len(), 4);
        assert!(curve.iter().all(|&(_, a)| (0.0..=1.0).contains(&a)));
        // Splice analog is learnable by a linear model reasonably well.
        assert!(curve.iter().map(|&(_, a)| a).fold(0.0, f64::max) > 0.7);
    }
}
