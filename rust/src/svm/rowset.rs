//! [`RowSet`] — the row-access abstraction the linear learners train
//! over, so the dual-CD SVM and logistic regression have ONE solver
//! body for both training-set representations:
//!
//! * [`Csr`] — general sparse rows: `Σ w[j]·v` with per-element value
//!   loads, f32→f64 converts, and multiplies.
//! * [`CodeMatrix`] — one-hot hashed features: the same inner products
//!   collapse to `k` gathers (`Σ w[code]`, no values array, no
//!   multiplies) and `xᵢᵀxᵢ` is the constant `k`, read O(1) instead of
//!   summed O(k) per row.
//!
//! **Bit-parity contract** (pinned by `rust/tests/svm_parity.rs`): on a
//! one-hot CSR (all stored values exactly 1.0) every method must return
//! bit-identical results to the [`CodeMatrix`] of the same rows —
//! `w[j] * 1.0` is exact, so this reduces to keeping the *reduction
//! tree* of the two `dot` impls identical. Both use the same 4-lane
//! accumulator shape below; change one, change both.

use crate::data::sparse::Csr;
use crate::features::CodeMatrix;

/// Row access for linear-learner training: row count/width, squared
/// row norms (for `Q̄ᵢᵢ`), inner products against a weight vector, and
/// scaled row additions into it.
///
/// `Sync` is a supertrait so one training set can be shared across the
/// one-vs-rest class threads (`LinearOvR::train_with_threads`).
pub trait RowSet: Sync {
    fn rows(&self) -> usize;

    /// Feature dimensionality — the weight-vector length.
    fn cols(&self) -> usize;

    /// `xᵢᵀxᵢ` (0.0 for an empty row).
    fn row_sq_norm(&self, i: usize) -> f64;

    /// `Σⱼ w[j]·xᵢⱼ` over row `i`'s support.
    fn dot(&self, i: usize, w: &[f64]) -> f64;

    /// `w += δ·xᵢ` over row `i`'s support.
    fn add_scaled(&self, i: usize, delta: f64, w: &mut [f64]);
}

/// 4-lane unrolled sparse dot: breaks the f64 add dependency chain
/// (the latency bound on one-hot rows) while fixing the summation
/// order independent of representation. Mirror of [`dot_onehot`].
#[inline]
fn dot_sparse(idx: &[u32], val: &[f32], w: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), val.len());
    let mut ic = idx.chunks_exact(4);
    let mut vc = val.chunks_exact(4);
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for (q, r) in ic.by_ref().zip(vc.by_ref()) {
        a0 += w[q[0] as usize] * r[0] as f64;
        a1 += w[q[1] as usize] * r[1] as f64;
        a2 += w[q[2] as usize] * r[2] as f64;
        a3 += w[q[3] as usize] * r[3] as f64;
    }
    let mut tail = 0.0f64;
    for (&j, &v) in ic.remainder().iter().zip(vc.remainder()) {
        tail += w[j as usize] * v as f64;
    }
    ((a0 + a1) + (a2 + a3)) + tail
}

/// One-hot dot: `k` gathers, no value loads, no multiplies. MUST keep
/// the exact reduction tree of [`dot_sparse`] (bit-parity contract).
#[inline]
fn dot_onehot(codes: &[u32], w: &[f64]) -> f64 {
    let mut cc = codes.chunks_exact(4);
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for q in cc.by_ref() {
        a0 += w[q[0] as usize];
        a1 += w[q[1] as usize];
        a2 += w[q[2] as usize];
        a3 += w[q[3] as usize];
    }
    let mut tail = 0.0f64;
    for &c in cc.remainder() {
        tail += w[c as usize];
    }
    ((a0 + a1) + (a2 + a3)) + tail
}

impl RowSet for Csr {
    fn rows(&self) -> usize {
        Csr::rows(self)
    }

    fn cols(&self) -> usize {
        Csr::cols(self)
    }

    fn row_sq_norm(&self, i: usize) -> f64 {
        // Sequential sum: on all-ones rows each add is exact integer
        // arithmetic, so this equals CodeMatrix's `k as f64` bitwise.
        self.row(i).values.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    #[inline]
    fn dot(&self, i: usize, w: &[f64]) -> f64 {
        let r = self.row(i);
        dot_sparse(r.indices, r.values, w)
    }

    #[inline]
    fn add_scaled(&self, i: usize, delta: f64, w: &mut [f64]) {
        let r = self.row(i);
        for (&j, &v) in r.indices.iter().zip(r.values) {
            w[j as usize] += delta * v as f64;
        }
    }
}

impl RowSet for CodeMatrix {
    fn rows(&self) -> usize {
        CodeMatrix::rows(self)
    }

    fn cols(&self) -> usize {
        CodeMatrix::cols(self)
    }

    fn row_sq_norm(&self, i: usize) -> f64 {
        // Exactly k ones per non-empty row — the constant `Q̄ᵢᵢ` the
        // one-hot structure guarantees, with no per-row values pass.
        if self.is_empty_row(i) {
            0.0
        } else {
            self.k() as f64
        }
    }

    #[inline]
    fn dot(&self, i: usize, w: &[f64]) -> f64 {
        dot_onehot(self.codes_of(i), w)
    }

    #[inline]
    fn add_scaled(&self, i: usize, delta: f64, w: &mut [f64]) {
        // Each code is distinct within a row, so order is irrelevant;
        // `delta · 1.0 = delta` keeps parity with the CSR path exact.
        for &c in self.codes_of(i) {
            w[c as usize] += delta;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::sampler::CwsHasher;
    use crate::data::sparse::CsrBuilder;
    use crate::features::Expansion;
    use crate::util::rng::Pcg64;

    #[test]
    fn csr_rowset_matches_naive_ops() {
        let mut b = CsrBuilder::new(6);
        b.push_row(vec![(0, 1.5), (2, 2.0), (5, 0.5)]);
        b.push_row(vec![]);
        b.push_row(vec![(1, 4.0)]);
        let x = b.finish();
        let w: Vec<f64> = (0..6).map(|i| (i + 1) as f64 * 0.1).collect();
        assert!((x.dot(0, &w) - (0.1 * 1.5 + 0.3 * 2.0 + 0.6 * 0.5)).abs() < 1e-12);
        assert_eq!(x.dot(1, &w), 0.0);
        assert!((x.row_sq_norm(0) - (1.5f64 * 1.5 + 4.0 + 0.25)).abs() < 1e-12);
        assert_eq!(x.row_sq_norm(1), 0.0);
        let mut w2 = w.clone();
        x.add_scaled(2, 2.0, &mut w2);
        assert!((w2[1] - (0.2 + 8.0)).abs() < 1e-12);
        assert_eq!(RowSet::rows(&x), 3);
        assert_eq!(RowSet::cols(&x), 6);
    }

    #[test]
    fn onehot_csr_and_codes_agree_bitwise() {
        // The parity contract: every RowSet op over a one-hot CSR must
        // be bit-identical to the CodeMatrix of the same samples.
        let mut rng = Pcg64::new(5);
        let e = Expansion::new(37, 5); // odd k exercises the unroll tail
        let h = CwsHasher::new(2, 37);
        let samples: Vec<_> = (0..8)
            .map(|i| {
                if i == 3 {
                    None // empty row in the middle
                } else {
                    let v: Vec<f32> =
                        (0..12).map(|_| rng.lognormal(0.0, 1.0) as f32).collect();
                    Some(h.hash_dense(&v))
                }
            })
            .collect();
        let cm = e.encode(&samples);
        let csr = e.expand(&samples);
        let w: Vec<f64> = (0..e.dim()).map(|_| rng.normal()).collect();
        for i in 0..cm.rows() {
            assert_eq!(cm.dot(i, &w).to_bits(), csr.dot(i, &w).to_bits(), "row {i}");
            assert_eq!(cm.row_sq_norm(i).to_bits(), csr.row_sq_norm(i).to_bits());
            let (mut wa, mut wb) = (w.clone(), w.clone());
            cm.add_scaled(i, 0.3, &mut wa);
            csr.add_scaled(i, 0.3, &mut wb);
            assert!(wa.iter().zip(&wb).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }
}
