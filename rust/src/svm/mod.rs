//! SVM / linear-learner substrates: the LIBLINEAR-style dual coordinate
//! descent linear SVM, ℓ₂-regularized logistic regression, the
//! LIBSVM-style precomputed-kernel SVM, multiclass wrappers (OvO for
//! kernel machines, OvR for linear), and the paper's C-grid evaluation
//! protocol.

pub mod eval;
pub mod kernel;
pub mod linear;
pub mod logistic;
pub mod model_io;
pub mod multiclass;
pub mod online;

pub use eval::{c_grid, kernel_svm_sweep, linear_svm_accuracy, linear_svm_sweep, SweepResult};
pub use kernel::{KernelModel, KernelSvmParams};
pub use linear::{LinearModel, LinearSvmParams, Loss};
pub use logistic::{LogisticModel, LogisticParams};
pub use multiclass::{KernelOvO, LinearOvR};
pub use online::{AveragedPerceptron, OnlineLearner, OnlineOvR, PassiveAggressive, SgdLogistic};
