//! SVM / linear-learner substrates: the LIBLINEAR-style dual coordinate
//! descent linear SVM, ℓ₂-regularized logistic regression, the
//! LIBSVM-style kernel SVM (generic over
//! [`crate::kernels::gram::GramSource`] — precomputed or on-the-fly
//! Gram, with LIBLINEAR-style shrinking), multiclass wrappers (OvO for
//! kernel machines, OvR for linear), and the paper's C-grid evaluation
//! protocol.
//!
//! The linear learners are generic over [`rowset::RowSet`] — the row
//! abstraction that lets one solver body serve both general CSR rows
//! and the one-hot [`crate::features::CodeMatrix`] fast path (gathers
//! instead of multiply-adds, constant `Q̄ᵢᵢ`), with bit-identical
//! results on one-hot data. OvR classes and OvO pairs train in
//! parallel over `util::pool` (`MINMAX_THREADS`), thread-count
//! invariant.

pub mod eval;
pub mod kernel;
pub mod linear;
pub mod logistic;
pub mod model_io;
pub mod multiclass;
pub mod online;
pub mod rowset;

pub use eval::{
    c_grid, kernel_svm_sweep, kernel_svm_sweep_with, linear_svm_accuracy, linear_svm_sweep,
    SweepResult,
};
pub use kernel::{train_binary_on as train_kernel_binary_on, KernelModel, KernelSvmParams};
pub use linear::{LinearModel, LinearSvmParams, Loss};
pub use logistic::{LogisticModel, LogisticParams};
pub use multiclass::{KernelOvO, LinearOvR};
pub use online::{AveragedPerceptron, OnlineLearner, OnlineOvR, PassiveAggressive, SgdLogistic};
pub use rowset::RowSet;
