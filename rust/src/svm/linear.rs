//! L2-regularized linear SVM by dual coordinate descent — the LIBLINEAR
//! algorithm (Hsieh et al., ICML 2008) the paper uses for the hashed-CWS
//! experiments (§4: "we then use the popular LIBLINEAR package").
//!
//! Solves, for binary labels `y ∈ {−1,+1}` over sparse rows `xᵢ`:
//!
//! ```text
//! min_w  ½‖w‖² + C Σᵢ loss(yᵢ wᵀxᵢ)
//! ```
//!
//! with `loss` the hinge (L1-SVM) or squared hinge (L2-SVM), via its dual
//!
//! ```text
//! min_α  ½ αᵀQ̄α − eᵀα ,  0 ≤ αᵢ ≤ U,   Q̄ = Q + D
//! ```
//!
//! (`U = C, D = 0` for L1; `U = ∞, Dᵢᵢ = 1/(2C)` for L2). One coordinate
//! update is O(nnz(xᵢ)); `w` is maintained incrementally. A bias term is
//! handled the LIBLINEAR `-B 1` way: an implicit constant-1 feature.
//!
//! The solver body is generic over [`RowSet`], so the same code runs
//! the general CSR path and the one-hot [`crate::features::CodeMatrix`]
//! fast path (gather-only inner products, constant `Q̄ᵢᵢ = k + bias +
//! Dᵢᵢ`) with bit-identical results on one-hot data — see
//! `svm::rowset` for the parity contract.

use crate::data::sparse::SparseRow;
use crate::util::rng::Pcg64;

use super::rowset::RowSet;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loss {
    /// Hinge loss (LIBLINEAR -s 3).
    L1,
    /// Squared hinge (LIBLINEAR -s 1, its default dual solver).
    L2,
}

#[derive(Debug, Clone)]
pub struct LinearSvmParams {
    pub c: f64,
    pub loss: Loss,
    pub max_epochs: usize,
    /// Stop when the maximal projected-gradient violation over an epoch
    /// falls below this.
    pub eps: f64,
    /// Train with an implicit constant-1 bias feature.
    pub bias: bool,
    pub seed: u64,
}

impl Default for LinearSvmParams {
    fn default() -> Self {
        Self { c: 1.0, loss: Loss::L2, max_epochs: 200, eps: 1e-3, bias: true, seed: 1 }
    }
}

/// A trained binary linear model: `f(x) = wᵀx + b`.
#[derive(Debug, Clone)]
pub struct LinearModel {
    pub w: Vec<f64>,
    pub b: f64,
    pub epochs_run: usize,
}

impl LinearModel {
    #[inline]
    pub fn decision(&self, x: SparseRow<'_>) -> f64 {
        let mut s = self.b;
        for (&j, &v) in x.indices.iter().zip(x.values) {
            s += self.w[j as usize] * v as f64;
        }
        s
    }

    #[inline]
    pub fn decision_dense(&self, x: &[f32]) -> f64 {
        let mut s = self.b;
        for (wj, &v) in self.w.iter().zip(x) {
            s += wj * v as f64;
        }
        s
    }

    pub fn predict(&self, x: SparseRow<'_>) -> i32 {
        if self.decision(x) >= 0.0 {
            1
        } else {
            -1
        }
    }

    /// Decision value for row `i` of any [`RowSet`] representation —
    /// the training-set-shaped counterpart of [`LinearModel::decision`]
    /// (one-hot code matrices decide with `k` gathers, no multiplies).
    #[inline]
    pub fn decision_on<X: RowSet + ?Sized>(&self, x: &X, i: usize) -> f64 {
        self.b + x.dot(i, &self.w)
    }

    pub fn predict_on<X: RowSet + ?Sized>(&self, x: &X, i: usize) -> i32 {
        if self.decision_on(x, i) >= 0.0 {
            1
        } else {
            -1
        }
    }
}

/// Train a binary linear SVM. `y` must be ±1 and contain both classes.
pub fn train_binary<X: RowSet + ?Sized>(x: &X, y: &[i32], p: &LinearSvmParams) -> LinearModel {
    train_binary_with_alpha(x, y, p).0
}

/// [`train_binary`] also returning the dual variables, so convergence
/// tests can evaluate [`dual_objective`] at the solution.
pub fn train_binary_with_alpha<X: RowSet + ?Sized>(
    x: &X,
    y: &[i32],
    p: &LinearSvmParams,
) -> (LinearModel, Vec<f64>) {
    let n = x.rows();
    assert_eq!(n, y.len());
    assert!(y.iter().all(|&v| v == 1 || v == -1), "labels must be ±1");
    assert!(p.c > 0.0);
    let d = x.cols();
    let (upper, diag) = match p.loss {
        Loss::L1 => (p.c, 0.0),
        Loss::L2 => (f64::INFINITY, 1.0 / (2.0 * p.c)),
    };
    // Q̄ᵢᵢ = xᵢᵀxᵢ (+ bias 1) + Dᵢᵢ. For a CodeMatrix this is the
    // constant k + bias + Dᵢᵢ (an O(1) read per row, no values pass).
    let qii: Vec<f64> = (0..n)
        .map(|i| {
            let mut s = x.row_sq_norm(i);
            if p.bias {
                s += 1.0;
            }
            s + diag
        })
        .collect();

    let mut w = vec![0.0f64; d];
    let mut b = 0.0f64;
    let mut alpha = vec![0.0f64; n];
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(p.seed);
    let mut epochs_run = 0;

    for epoch in 0..p.max_epochs {
        rng.shuffle(&mut order);
        let mut max_pg: f64 = 0.0;
        for &i in &order {
            if qii[i] <= diag {
                continue; // empty row: only the bias/diag — skip degenerate
            }
            let yi = y[i] as f64;
            // G = yᵢ f(xᵢ) − 1 + Dᵢᵢ αᵢ
            let fx = b + x.dot(i, &w);
            let g = yi * fx - 1.0 + diag * alpha[i];
            // Projected gradient for the box [0, U].
            let pg = if alpha[i] <= 0.0 {
                g.min(0.0)
            } else if alpha[i] >= upper {
                g.max(0.0)
            } else {
                g
            };
            max_pg = max_pg.max(pg.abs());
            if pg.abs() > 1e-14 {
                let old = alpha[i];
                alpha[i] = (old - g / qii[i]).clamp(0.0, upper);
                let delta = (alpha[i] - old) * yi;
                if delta != 0.0 {
                    x.add_scaled(i, delta, &mut w);
                    if p.bias {
                        b += delta;
                    }
                }
            }
        }
        epochs_run = epoch + 1;
        if max_pg < p.eps {
            break;
        }
    }
    (LinearModel { w, b, epochs_run }, alpha)
}

/// Dual objective ½‖w‖² + ½b² − Σα + ½DΣα² — the value of the dual
/// minimization ½αᵀQ̄α − eᵀα at this α (with `w = Σαᵢyᵢxᵢ`, `b = Σαᵢyᵢ`,
/// `Q̄ = Q + D`; `D = 0` for L1, `Dᵢᵢ = 1/(2C)` for L2). At the optimum
/// strong duality gives `primal ≈ −dual`, which the convergence test
/// pins for both losses.
pub fn dual_objective(model: &LinearModel, alpha: &[f64], p: &LinearSvmParams) -> f64 {
    let diag = match p.loss {
        Loss::L1 => 0.0,
        Loss::L2 => 1.0 / (2.0 * p.c),
    };
    let wnorm: f64 = model.w.iter().map(|v| v * v).sum::<f64>() + model.b * model.b;
    let alpha_sum: f64 = alpha.iter().sum();
    let alpha_sq_sum: f64 = alpha.iter().map(|a| a * a).sum();
    0.5 * wnorm - alpha_sum + 0.5 * diag * alpha_sq_sum
}

/// Primal objective ½‖w‖² + C Σ loss — exposed for convergence tests.
pub fn primal_objective<X: RowSet + ?Sized>(
    x: &X,
    y: &[i32],
    m: &LinearModel,
    p: &LinearSvmParams,
) -> f64 {
    let mut obj: f64 =
        0.5 * (m.w.iter().map(|v| v * v).sum::<f64>() + if p.bias { m.b * m.b } else { 0.0 });
    for i in 0..x.rows() {
        let margin = 1.0 - y[i] as f64 * m.decision_on(x, i);
        if margin > 0.0 {
            obj += p.c
                * match p.loss {
                    Loss::L1 => margin,
                    Loss::L2 => margin * margin,
                };
        }
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{Csr, CsrBuilder};

    fn separable() -> (Csr, Vec<i32>) {
        // Two clusters on the x-axis.
        let rows: Vec<Vec<(u32, f32)>> = vec![
            vec![(0, 2.0), (1, 0.1)],
            vec![(0, 2.5), (1, 0.3)],
            vec![(0, 3.0)],
            vec![(0, 0.2), (1, 0.2)],
            vec![(0, 0.1), (1, 0.4)],
            vec![(1, 0.3)],
        ];
        let mut b = CsrBuilder::new(2);
        for r in rows {
            b.push_row(r);
        }
        (b.finish(), vec![1, 1, 1, -1, -1, -1])
    }

    #[test]
    fn separates_separable_data() {
        let (x, y) = separable();
        for loss in [Loss::L1, Loss::L2] {
            let m = train_binary(&x, &y, &LinearSvmParams { loss, c: 10.0, ..Default::default() });
            for i in 0..x.rows() {
                assert_eq!(m.predict(x.row(i)), y[i], "{loss:?} row {i}");
            }
        }
    }

    #[test]
    fn decision_dense_matches_sparse() {
        let (x, y) = separable();
        let m = train_binary(&x, &y, &LinearSvmParams::default());
        let d = x.to_dense();
        for i in 0..x.rows() {
            assert!((m.decision(x.row(i)) - m.decision_dense(d.row(i))).abs() < 1e-12);
        }
    }

    #[test]
    fn converges_before_max_epochs_on_easy_data() {
        let (x, y) = separable();
        let m = train_binary(&x, &y, &LinearSvmParams::default());
        assert!(m.epochs_run < 200, "ran {} epochs", m.epochs_run);
    }

    #[test]
    fn more_regularization_shrinks_weights() {
        let (x, y) = separable();
        let m_small_c =
            train_binary(&x, &y, &LinearSvmParams { c: 1e-3, ..Default::default() });
        let m_big_c = train_binary(&x, &y, &LinearSvmParams { c: 100.0, ..Default::default() });
        let n = |m: &LinearModel| m.w.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(n(&m_small_c) < n(&m_big_c));
    }

    #[test]
    fn primal_objective_decreases_with_epochs() {
        // Train 1 epoch vs 50 epochs: the longer run cannot be worse.
        let mut rng = Pcg64::new(3);
        let n = 60;
        let mut b = CsrBuilder::new(8);
        let mut y = Vec::new();
        for i in 0..n {
            let label = if i % 2 == 0 { 1 } else { -1 };
            let center = if label == 1 { 1.2 } else { 0.4 };
            let row: Vec<(u32, f32)> =
                (0..8).map(|j| (j, (center * rng.lognormal(0.0, 0.4)) as f32)).collect();
            b.push_row(row);
            y.push(label);
        }
        let x = b.finish();
        let p1 = LinearSvmParams { max_epochs: 1, ..Default::default() };
        let p50 = LinearSvmParams { max_epochs: 50, ..Default::default() };
        let m1 = train_binary(&x, &y, &p1);
        let m50 = train_binary(&x, &y, &p50);
        assert!(
            primal_objective(&x, &y, &m50, &p50) <= primal_objective(&x, &y, &m1, &p1) + 1e-9
        );
    }

    #[test]
    fn dual_objective_matches_primal_at_convergence() {
        // Strong duality: at the optimum the primal equals −dual. For
        // L2 loss the ½DΣα² term is strictly positive, so the old
        // formula (which dropped it) cannot close the gap there.
        let (x, y) = separable();
        for loss in [Loss::L1, Loss::L2] {
            let p = LinearSvmParams {
                loss,
                c: 1.0,
                eps: 1e-10,
                max_epochs: 20_000,
                ..Default::default()
            };
            let (m, alpha) = train_binary_with_alpha(&x, &y, &p);
            let primal = primal_objective(&x, &y, &m, &p);
            let dual = dual_objective(&m, &alpha, &p);
            assert!(
                (primal + dual).abs() < 1e-3 * (1.0 + primal.abs()),
                "{loss:?}: primal {primal} vs -dual {}",
                -dual
            );
            if loss == Loss::L2 {
                let alpha_sq_sum: f64 = alpha.iter().map(|a| a * a).sum();
                let d_term = 0.5 * (1.0 / (2.0 * p.c)) * alpha_sq_sum;
                assert!(d_term > 0.0, "L2 must activate the D term");
                let without = dual - d_term;
                assert!(
                    (primal + without).abs() > (primal + dual).abs(),
                    "dropping ½DΣα² must worsen the duality gap"
                );
            }
        }
    }

    #[test]
    fn handles_empty_rows() {
        let mut b = CsrBuilder::new(2);
        b.push_row(vec![(0, 1.0)]);
        b.push_row(vec![]);
        b.push_row(vec![(1, 1.0)]);
        b.push_row(vec![]);
        let x = b.finish();
        let y = vec![1, 1, -1, -1];
        // Must not panic; empty rows are decided by the bias.
        let m = train_binary(&x, &y, &LinearSvmParams::default());
        assert_eq!(m.predict(x.row(0)), 1);
    }

    #[test]
    fn dense_one_hot_cws_features_learnable() {
        // End-to-end-ish: two distinct base vectors hashed with 0-bit CWS;
        // a linear SVM on the expanded features must tell them apart.
        use crate::cws::CwsHasher;
        use crate::features::Expansion;
        let mut rng = Pcg64::new(7);
        let proto_a: Vec<f32> = (0..32).map(|_| rng.lognormal(0.0, 1.0) as f32).collect();
        let proto_b: Vec<f32> = (0..32).map(|_| rng.lognormal(0.0, 1.0) as f32).collect();
        let k = 64;
        let e = Expansion::new(k, 8);
        let h = CwsHasher::new(11, k);
        let mut samples = Vec::new();
        let mut y = Vec::new();
        for i in 0..40 {
            let proto = if i % 2 == 0 { &proto_a } else { &proto_b };
            let v: Vec<f32> =
                proto.iter().map(|&x| (x as f64 * rng.lognormal(0.0, 0.2)) as f32).collect();
            samples.push(Some(h.hash_dense(&v)));
            y.push(if i % 2 == 0 { 1 } else { -1 });
        }
        let feat = e.expand(&samples);
        let m = train_binary(&feat, &y, &LinearSvmParams { c: 1.0, ..Default::default() });
        let acc = (0..feat.rows())
            .filter(|&i| m.predict(feat.row(i)) == y[i])
            .count() as f64
            / feat.rows() as f64;
        assert!(acc > 0.95, "train accuracy {acc}");
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn rejects_bad_labels() {
        let (x, _) = separable();
        train_binary(&x, &[0, 1, 1, -1, -1, -1], &LinearSvmParams::default());
    }
}
