//! Concurrency facade: the one place the serving stack imports
//! synchronization primitives from.
//!
//! By default every re-export is the `std` primitive — this module
//! compiles to nothing but `pub use` lines and two `#[inline]` shims,
//! so the dependency-free build is unchanged. Under `--cfg loom` the
//! same paths resolve to the [loom] model checker's instrumented
//! equivalents, which lets `rust/tests/loom_models.rs` exhaustively
//! explore thread interleavings of the real queue/swap/drain/metrics
//! code instead of a hand-copied model of it.
//!
//! `coordinator::{cluster, queue, service, metrics, router}` and
//! `util::pool` MUST import `Arc`/`Mutex`/`Condvar`/`RwLock`/atomics/
//! threads from here, never from `std::sync`/`std::thread` directly —
//! `xtask lint` enforces that ban, because one stray `std::Mutex` in a
//! modeled protocol silently removes it from loom's exploration.
//!
//! ## What stays `std` even under loom
//!
//! * **`mpsc`** — loom has no channel model. Channels only carry
//!   *responses* out of the modeled protocols (and the service's
//!   drop-sender drain, which the loom shutdown model reproduces with
//!   queue close instead), so the models are written against
//!   [`crate::coordinator::queue`] primitives and never block on a
//!   channel.
//! * **`thread::scope` / `thread::available_parallelism`** — loom has
//!   neither. The scoped helpers in [`crate::util::pool`] are
//!   fork-join data parallelism over disjoint indices (no protocol to
//!   model); they are exercised by Miri/TSan instead.
//!
//! ## Loom caveats the facade papers over
//!
//! * loom has no time model, so [`wait_timeout`] maps to a plain
//!   `Condvar::wait` that *always reports a timeout* on wakeup. Callers
//!   must treat `timed_out == true` as "re-check state", never as "the
//!   duration elapsed" — which is exactly how
//!   `queue::ShardQueue::pop_wait` uses it.
//! * loom has no `thread::Builder`, so [`spawn_named`] drops the name
//!   under loom. Thread names are observability, not semantics.
//!
//! Loom is deliberately NOT a `Cargo.toml` dependency of the default
//! build: even an optional registry dependency would break offline
//! resolution (same reasoning as the `pjrt` feature — see the manifest
//! comment). CI's `loom` job appends the
//! `[target.'cfg(loom)'.dependencies]` table before building with
//! `RUSTFLAGS="--cfg loom"`; see `.github/workflows/ci.yml`.

use std::time::Duration;

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};

/// Channels are always `std` — see the module docs.
pub use std::sync::mpsc;

/// Thread spawning/yielding: loom-instrumented under `--cfg loom`;
/// `scope` and `available_parallelism` are always `std` (see the
/// module docs for why that is sound).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};

    pub use std::thread::{available_parallelism, scope};

    /// `std::thread::sleep` by default; under loom (which has no
    /// clock) a yield — callers must treat sleeps as pacing hints,
    /// never as synchronization, which is exactly how the supervisor
    /// poll and the backoff delays use them.
    #[cfg(not(loom))]
    pub fn sleep(dur: std::time::Duration) {
        std::thread::sleep(dur);
    }

    /// Loom variant of [`sleep`] — see the `std` variant's docs.
    #[cfg(loom)]
    pub fn sleep(_dur: std::time::Duration) {
        loom::thread::yield_now();
    }
}

/// Non-blocking "has this thread terminated?" probe, used by the
/// worker supervisor to detect shard deaths without joining live
/// threads. Loom's `JoinHandle` has no such probe, so the loom shim
/// always answers `false` — supervision is exercised by the chaos
/// harness and TSan, while the loom respawn model drives the
/// join/respawn handoff directly.
#[cfg(not(loom))]
pub fn is_finished<T>(handle: &thread::JoinHandle<T>) -> bool {
    handle.is_finished()
}

/// Loom variant of [`is_finished`] — see the `std` variant's docs.
#[cfg(loom)]
pub fn is_finished<T>(_handle: &thread::JoinHandle<T>) -> bool {
    false
}

/// `thread::Builder::new().name(name).spawn(f)` under `std`; a plain
/// (nameless) `loom::thread::spawn` under loom. Every long-lived
/// worker in the serving stack goes through here so worker threads
/// keep their `minmax-*` names in production while staying modelable.
#[cfg(not(loom))]
pub fn spawn_named<F, T>(name: String, f: F) -> std::io::Result<thread::JoinHandle<T>>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    std::thread::Builder::new().name(name).spawn(f)
}

/// Loom variant of [`spawn_named`]: loom has no `Builder`, so the name
/// is dropped (names are observability only).
#[cfg(loom)]
pub fn spawn_named<F, T>(_name: String, f: F) -> std::io::Result<thread::JoinHandle<T>>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    Ok(loom::thread::spawn(f))
}

/// `Condvar::wait_timeout` with the poisoning unwrapped: returns the
/// reacquired guard and whether the wait timed out.
///
/// Under loom this is a plain `wait` that always reports
/// `timed_out == true` (loom has no clock): callers must use the flag
/// only as a "re-check shared state now" signal, never as proof that
/// wall-clock time passed. `ShardQueue::pop_wait` re-checks the queue
/// and the closed flag on every timeout report, so it is correct under
/// both meanings.
#[cfg(not(loom))]
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    let (guard, res) = cv.wait_timeout(guard, dur).unwrap();
    (guard, res.timed_out())
}

/// Loom variant of [`wait_timeout`] — see the `std` variant's docs.
#[cfg(loom)]
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    _dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    (cv.wait(guard).unwrap(), true)
}

#[cfg(test)]
mod tests {
    use super::atomic::{AtomicUsize, Ordering};
    use super::*;

    #[test]
    fn spawn_named_runs_and_joins() {
        let h = spawn_named("minmax-facade-test".into(), || 41 + 1).unwrap();
        assert_eq!(h.join().unwrap(), 42);
    }

    #[test]
    fn is_finished_flips_after_exit() {
        let gate = Arc::new(Mutex::new(()));
        let held = gate.lock().unwrap();
        let g2 = Arc::clone(&gate);
        let h = spawn_named("minmax-finish-probe".into(), move || {
            drop(g2.lock().unwrap());
        })
        .unwrap();
        // The worker is blocked on the gate, so it cannot be finished.
        assert!(!is_finished(&h));
        drop(held);
        h.join().unwrap();
    }

    #[test]
    fn wait_timeout_reports_timeout_on_silence() {
        let m = Mutex::new(0u32);
        let cv = Condvar::new();
        let g = m.lock().unwrap();
        let (g, timed_out) = wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(timed_out);
        assert_eq!(*g, 0);
    }

    #[test]
    fn wait_timeout_wakes_on_notify() {
        let shared = Arc::new((Mutex::new(false), Condvar::new()));
        let s2 = Arc::clone(&shared);
        let h = thread::spawn(move || {
            *s2.0.lock().unwrap() = true;
            s2.1.notify_all();
        });
        let mut g = shared.0.lock().unwrap();
        // Re-check-state loop: the only contract wait_timeout offers.
        while !*g {
            let (g2, _) = wait_timeout(&shared.1, g, Duration::from_millis(50));
            g = g2;
        }
        drop(g);
        h.join().unwrap();
        let done = AtomicUsize::new(0);
        done.store(1, Ordering::Release);
        assert_eq!(done.load(Ordering::Acquire), 1);
    }
}
