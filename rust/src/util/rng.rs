//! Deterministic pseudo-random number generation and the samplers the
//! paper's algorithms need.
//!
//! The offline vendor set has no `rand` crate, so this module implements
//! the full stack from scratch:
//!
//! * [`SplitMix64`] — seed expander (Steele et al., used to key PCG).
//! * [`Pcg64`] — PCG-XSL-RR 128/64, the main generator. Small state,
//!   excellent statistical quality, trivially seedable per-stream which is
//!   what CWS needs (one independent stream per hash sample column).
//! * Distributions: uniform, exponential, normal (Box–Muller),
//!   `Gamma(2,1)` (the CWS-specific fast path: sum of two exponentials),
//!   general `Gamma(shape,1)` (Marsaglia–Tsang), Zipf, log-normal.
//!
//! Everything is deterministic given a seed: the experiment drivers and
//! the rust↔python cross-checks depend on that.

/// SplitMix64: a tiny, high-quality 64-bit seed expander.
///
/// Used to derive independent sub-seeds (e.g. one per CWS column or per
/// worker thread) from a single user-facing experiment seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: 128-bit LCG state with a 64-bit xorshift-low,
/// random-rotate output function. Period 2^128 per stream; distinct odd
/// increments select statistically independent streams.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // must be odd
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed from a single u64 (stream 0). Sub-seeds are expanded through
    /// SplitMix64 so nearby seeds give unrelated states.
    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0)
    }

    /// Seed with an explicit stream id; different streams from the same
    /// seed are independent generators.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64();
        let s1 = sm.next_u64();
        let mut smi = SplitMix64::new(stream ^ 0xDA3E_39CB_94B9_5BDB);
        let i0 = smi.next_u64();
        let i1 = smi.next_u64();
        let state = ((s0 as u128) << 64) | s1 as u128;
        let inc = (((i0 as u128) << 64) | i1 as u128) | 1;
        let mut rng = Self { state, inc };
        // Advance once so the first output depends on the whole state.
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a `ln` argument.
    #[inline]
    pub fn uniform_pos(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0,1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            // Rejection zone: only when lo < n do we need the threshold.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Exponential(1) via inverse CDF.
    #[inline]
    pub fn exp1(&mut self) -> f64 {
        -self.uniform_pos().ln()
    }

    /// Standard normal via Box–Muller (uses both outputs lazily is not
    /// worth the state here; we just draw two uniforms per call's pair).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform_pos();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(2, 1) — the exact distribution Algorithm 1 of the paper
    /// draws `r_i` and `c_i` from. Shape-2 gamma is the sum of two unit
    /// exponentials: `-ln(U1 * U2)`.
    #[inline]
    pub fn gamma2(&mut self) -> f64 {
        -(self.uniform_pos() * self.uniform_pos()).ln()
    }

    /// General Gamma(shape, 1) for shape > 0 via Marsaglia–Tsang, with
    /// the shape<1 boost. Used by the synthetic data generators.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            // Boost: G(a) = G(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            return g * self.uniform_pos().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform_pos();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2 {
                return d * v3;
            }
            if u.ln() < 0.5 * x2 + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Log-normal with parameters of the underlying normal.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-distributed integer in [1, n] with exponent `s` (s > 0),
    /// via rejection-inversion (Hörmann–Derflinger; the commons-math
    /// `RejectionInversionZipfSampler` formulation). O(1) per draw.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        if n == 1 {
            return 1;
        }
        // For s == 1 the integral has a removable singularity; nudge.
        let s = if (s - 1.0).abs() < 1e-9 { 1.0 + 1e-9 } else { s };
        // h(x) = x^{-s};  H(x) = (x^{1-s} - 1) / (1 - s)  (antiderivative,
        // shifted so H(1) = 0);  Hinv(y) = (1 + (1-s) y)^{1/(1-s)}.
        let h = |x: f64| x.powf(-s);
        let hi = |x: f64| (x.powf(1.0 - s) - 1.0) / (1.0 - s);
        let hinv = |y: f64| (1.0 + (1.0 - s) * y).powf(1.0 / (1.0 - s));
        let h_half = hi(1.5) - 1.0; // H(1.5) - h(1)
        let h_n = hi(n as f64 + 0.5);
        // Acceptance shortcut threshold (commons-math `s` constant).
        let thresh = 2.0 - hinv(hi(2.5) - h(2.0));
        loop {
            let u = h_n + self.uniform() * (h_half - h_n);
            let x = hinv(u);
            let k = x.round().clamp(1.0, n as f64);
            if k - x <= thresh || u >= hi(k + 0.5) - h(k) {
                return k as u64;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (m <= n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        if m * 3 > n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(m);
            return idx;
        }
        // Sparse Floyd's algorithm.
        let mut chosen = std::collections::HashSet::with_capacity(m);
        let mut out = Vec::with_capacity(m);
        for j in (n - m)..n {
            let t = self.below(j as u64 + 1) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Draw from a discrete distribution given cumulative weights
    /// (last element == total). Binary search, O(log n).
    pub fn discrete_cdf(&mut self, cdf: &[f64]) -> usize {
        let total = *cdf.last().expect("empty cdf");
        let x = self.uniform() * total;
        match cdf.binary_search_by(|p| p.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cdf.len() - 1),
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg64::new_stream(7, 0);
        let mut b = Pcg64::new_stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn pcg_reproducible() {
        let mut a = Pcg64::new(123);
        let mut b = Pcg64::new(123);
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Pcg64::new(1);
        let xs: Vec<f64> = (0..200_000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let (m, v) = moments(&xs);
        assert!((m - 0.5).abs() < 5e-3, "mean {m}");
        assert!((v - 1.0 / 12.0).abs() < 5e-3, "var {v}");
    }

    #[test]
    fn below_is_unbiased_small_n() {
        let mut r = Pcg64::new(2);
        let n = 7u64;
        let mut counts = [0usize; 7];
        let trials = 140_000;
        for _ in 0..trials {
            counts[r.below(n) as usize] += 1;
        }
        let expect = trials as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < 0.05 * expect,
                "bucket {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn exp1_moments() {
        let mut r = Pcg64::new(3);
        let xs: Vec<f64> = (0..200_000).map(|_| r.exp1()).collect();
        let (m, v) = moments(&xs);
        assert!((m - 1.0).abs() < 2e-2, "mean {m}");
        assert!((v - 1.0).abs() < 5e-2, "var {v}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(4);
        let xs: Vec<f64> = (0..200_000).map(|_| r.normal()).collect();
        let (m, v) = moments(&xs);
        assert!(m.abs() < 1e-2, "mean {m}");
        assert!((v - 1.0).abs() < 3e-2, "var {v}");
    }

    #[test]
    fn gamma2_moments_match_shape2() {
        // Gamma(2,1): mean 2, var 2.
        let mut r = Pcg64::new(5);
        let xs: Vec<f64> = (0..200_000).map(|_| r.gamma2()).collect();
        let (m, v) = moments(&xs);
        assert!((m - 2.0).abs() < 2e-2, "mean {m}");
        assert!((v - 2.0).abs() < 1e-1, "var {v}");
    }

    #[test]
    fn gamma_general_matches_gamma2_fast_path() {
        let mut r = Pcg64::new(6);
        let xs: Vec<f64> = (0..200_000).map(|_| r.gamma(2.0)).collect();
        let (m, v) = moments(&xs);
        assert!((m - 2.0).abs() < 2e-2, "mean {m}");
        assert!((v - 2.0).abs() < 1e-1, "var {v}");
    }

    #[test]
    fn gamma_small_shape() {
        // Gamma(0.5,1): mean 0.5, var 0.5.
        let mut r = Pcg64::new(7);
        let xs: Vec<f64> = (0..200_000).map(|_| r.gamma(0.5)).collect();
        let (m, v) = moments(&xs);
        assert!((m - 0.5).abs() < 1e-2, "mean {m}");
        assert!((v - 0.5).abs() < 5e-2, "var {v}");
    }

    #[test]
    fn zipf_bounds_and_monotone_mass() {
        let mut r = Pcg64::new(8);
        let n = 1000u64;
        let mut counts = vec![0usize; n as usize + 1];
        for _ in 0..100_000 {
            let k = r.zipf(n, 1.2);
            assert!((1..=n).contains(&k));
            counts[k as usize] += 1;
        }
        // Rank-1 must dominate rank-10 which must dominate rank-100.
        assert!(counts[1] > counts[10]);
        assert!(counts[10] > counts[100]);
        // Rough Zipf check: p(1)/p(2) ≈ 2^1.2 ≈ 2.3.
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((1.8..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(10);
        for &(n, m) in &[(100usize, 5usize), (100, 80), (10, 10), (1, 1)] {
            let idx = r.sample_indices(n, m);
            assert_eq!(idx.len(), m);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), m);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn discrete_cdf_respects_weights() {
        let mut r = Pcg64::new(11);
        let cdf = [1.0, 3.0, 6.0]; // weights 1,2,3
        let mut counts = [0usize; 3];
        for _ in 0..60_000 {
            counts[r.discrete_cdf(&cdf)] += 1;
        }
        assert!((counts[0] as f64 / 10_000.0 - 1.0).abs() < 0.1);
        assert!((counts[1] as f64 / 10_000.0 - 2.0).abs() < 0.15);
        assert!((counts[2] as f64 / 10_000.0 - 3.0).abs() < 0.2);
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Pcg64::new(12);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 1.5) > 0.0);
        }
    }
}
