//! Mini property-based testing harness (the vendor set has no proptest).
//!
//! Design: a [`Gen`] wraps the seeded RNG; properties are closures from
//! `&mut Gen` to `Result<(), String>`. [`check`] runs N seeded cases and,
//! on failure, reruns the failing seed with shrink hints disabled and
//! reports the seed so the case is reproducible with
//! `MINMAX_PROP_SEED=<seed> cargo test <name>`.
//!
//! There is no structural shrinking (inputs are regenerated from seeds),
//! but generators take size hints that `check` ramps up from small to
//! large, so the first failure found tends to be near-minimal anyway —
//! the property-testing behaviour that matters in practice.

use super::rng::Pcg64;

pub struct Gen {
    pub rng: Pcg64,
    /// Current size hint in [0,1]; generators should scale dimensions
    /// with it so early cases are small.
    pub size: f64,
}

impl Gen {
    /// Integer in [lo, hi] scaled by the size hint (at least lo).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.below(span as u64 + 1) as usize
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Nonnegative f32 vector with a sparsity knob (fraction of zeros)
    /// and heavy-tailed magnitudes — the paper's data regime.
    pub fn nonneg_vec(&mut self, dim: usize, zero_frac: f64) -> Vec<f32> {
        (0..dim)
            .map(|_| {
                if self.rng.uniform() < zero_frac {
                    0.0
                } else {
                    self.rng.lognormal(0.0, 1.0) as f32
                }
            })
            .collect()
    }

    /// Boolean with probability p.
    pub fn bool_p(&mut self, p: f64) -> bool {
        self.rng.uniform() < p
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Outcome of a property over one generated case.
pub type PropResult = Result<(), String>;

/// Run `cases` seeded cases of `prop`. Panics with the failing seed and
/// message on first failure. Honors `MINMAX_PROP_SEED` to replay one case.
pub fn check<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: u64, mut prop: F) {
    if let Ok(seed_s) = std::env::var("MINMAX_PROP_SEED") {
        let seed: u64 = seed_s.parse().expect("MINMAX_PROP_SEED must be u64");
        let mut g = Gen { rng: Pcg64::new_stream(seed, 0xA11CE), size: 1.0 };
        if let Err(msg) = prop(&mut g) {
            panic!("[{name}] replay seed {seed} failed: {msg}");
        }
        return;
    }
    for case in 0..cases {
        // Deterministic per-test-name seeding so the suite is stable.
        let seed = fnv1a(name) ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // Ramp the size hint: first 20% of cases are small.
        let size = ((case + 1) as f64 / cases as f64).min(1.0).max(0.05);
        let mut g = Gen { rng: Pcg64::new_stream(seed, 0xA11CE), size };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "[{name}] case {case}/{cases} failed (replay: MINMAX_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper for properties: approximate equality with context.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Assert helper: plain condition.
pub fn ensure(cond: bool, what: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            close(a + b, b + a, 1e-12, "a+b == b+a")
        });
    }

    #[test]
    #[should_panic(expected = "replay: MINMAX_PROP_SEED=")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_g| Err("nope".to_string()));
    }

    #[test]
    fn size_ramp_is_monotone_nondecreasing_envelope() {
        // Generators with small size hints must produce small dims.
        check("size-hint", 20, |g| {
            let n = g.usize_in(1, 1000);
            ensure(n <= 1 + (999.0 * g.size) as usize + 1, "scaled dim")
        });
    }

    #[test]
    fn nonneg_vec_is_nonneg() {
        check("nonneg-vec", 20, |g| {
            let dim = g.usize_in(1, 64);
            let v = g.nonneg_vec(dim, 0.5);
            ensure(v.len() == dim && v.iter().all(|&x| x >= 0.0), "nonneg + len")
        });
    }
}
