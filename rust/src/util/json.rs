//! Minimal JSON value model + serializer (the vendor set has no serde).
//!
//! Experiment drivers emit machine-readable results under `results/` so
//! figures can be replotted without rerunning; this covers exactly that
//! write path plus a small reader used by tests.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — builder misuse).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    // JSON has no NaN/Inf; emit null (documented lossy).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    x.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// Parse a JSON string (strict enough for round-tripping our output).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { s: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Self {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && (self.s[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.i += 1;
                let mut v = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.skip_ws();
                    v.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    self.skip_ws();
                    let v = self.value()?;
                    m.insert(k, v);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("eof in escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                return Err("eof in \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // Re-borrow full utf8 char.
                    let start = self.i - 1;
                    let s = std::str::from_utf8(&self.s[start..]).map_err(|_| "bad utf8")?;
                    let ch = s.chars().next().unwrap();
                    self.i = start + ch.len_utf8();
                    out.push(ch);
                }
            }
        }
        Err("eof in string".into())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.s[start..self.i]).map_err(|_| "bad num")?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }
}

/// Write `json` to `path`, creating parent directories.
pub fn write_json(path: &std::path::Path, json: &Json) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, json.to_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "table1")
            .set("acc", 0.953)
            .set("n", 128usize)
            .set("ok", true)
            .set("ks", vec![32usize, 64, 128]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn roundtrip_pretty() {
        let mut j = Json::obj();
        j.set("a", Json::Arr(vec![Json::Null, Json::Bool(false), Json::Num(1.5)]));
        let back = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd\tे".to_string());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn integers_stay_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn parse_rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn nested_access() {
        let j = Json::parse(r#"{"a":{"b":[1,2,3]}}"#).unwrap();
        let arr = j.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_f64(), Some(3.0));
    }
}
