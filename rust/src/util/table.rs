//! Aligned plain-text table rendering for experiment outputs — the
//! drivers print the same rows/columns the paper's tables/figures report.

#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), ..Default::default() }
    }

    pub fn header<S: Into<String>>(mut self, cols: impl IntoIterator<Item = S>) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        if !self.header.is_empty() {
            assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        }
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len().max(self.rows.iter().map(Vec::len).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| {
                    let c = cells.get(i).map(String::as_str).unwrap_or("");
                    format!(" {c:<width$} ", width = widths[i])
                })
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `digits` decimals (common cell helper).
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format in scientific notation (for bias/MSE cells).
pub fn fsci(x: f64) -> String {
    format!("{x:.3e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(["dataset", "acc"]);
        t.row(["Letter", "96.2"]);
        t.row(["MNIST10k-analog", "95.7"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        // Columns align: every line containing '|' has it at the same offset.
        let pipe_pos: Vec<usize> =
            lines.iter().filter_map(|l| l.find('|')).collect();
        assert!(pipe_pos.len() >= 3);
        assert!(pipe_pos.windows(2).all(|w| w[0] == w[1]), "{pipe_pos:?}");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x").header(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn fnum_fsci() {
        assert_eq!(fnum(80.43, 1), "80.4");
        assert!(fsci(1.5e-5).contains('e'));
    }
}
