//! Minimal data-parallel execution helpers (the vendor set has no rayon).
//!
//! Two entry points:
//!
//! * [`par_for`] — run a closure over index chunks on scoped threads.
//! * [`ThreadPool`] — a long-lived worker pool with a submission queue,
//!   used by the coordinator so workers (each owning a PJRT executable
//!   handle) persist across batches.
//!
//! On this container `available_parallelism()` is typically 1, in which
//! case everything degrades to sequential execution with zero thread
//! overhead — important for honest single-core benchmarks.

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::{mpsc, spawn_named, thread, Arc, Mutex};

/// Number of worker threads to use by default: `available_parallelism`,
/// overridable with the `MINMAX_THREADS` environment variable.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("MINMAX_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on up to
/// `default_threads()` scoped threads. `f` must be `Sync` (it receives
/// disjoint ranges, so data writes should be pre-partitioned by the
/// caller — see [`par_map_chunks`] for the common slice case).
pub fn par_for<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    par_for_with(n, min_chunk, default_threads(), f)
}

/// [`par_for`] with an explicit thread count, so callers (and tests)
/// can pin parallelism independently of `MINMAX_THREADS`. `threads <= 1`
/// runs `f(0, n)` inline with zero thread overhead.
pub fn par_for_with<F>(n: usize, min_chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1);
    if threads <= 1 || n <= min_chunk {
        f(0, n);
        return;
    }
    let nchunks = threads.min(n.div_ceil(min_chunk)).max(1);
    let next = AtomicUsize::new(0);
    let chunk = n.div_ceil(nchunks);
    thread::scope(|s| {
        for _ in 0..nchunks {
            s.spawn(|| loop {
                // relaxed-ok: work-claim counter — fetch_add is atomic
                // (each chunk claimed once); scope join publishes writes.
                let i = next.fetch_add(1, Ordering::Relaxed);
                let start = i * chunk;
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                f(start, end);
            });
        }
    });
}

/// Claim units `0..n` one at a time across up to `threads` scoped
/// threads via a work-stealing counter — the dynamic-balancing
/// primitive behind [`par_rows`] and the sketch engine's chunked
/// batches (a straggler unit never serializes the others behind a
/// static partition). `threads <= 1` runs inline.
pub fn par_claim<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                // relaxed-ok: work-claim counter — fetch_add is atomic
                // (each unit claimed once); scope join publishes writes.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel ordered map over units `0..n`: claim units like
/// [`par_claim`], collect each unit's result into its own slot, return
/// the results in unit order. The order (and, for deterministic `f`,
/// the content) is identical at any thread count — the primitive
/// behind the multiclass trainers' parallel classes/pairs.
pub fn par_map_claim<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    par_claim(n, threads, |i| {
        *slots[i].lock().unwrap() = Some(f(i));
    });
    // take() under a (now uncontended) lock rather than into_inner():
    // the facade's loom Mutex has no into_inner, and this keeps the
    // module compilable under `--cfg loom`.
    slots.iter().map(|s| s.lock().unwrap().take().expect("claimed unit completed")).collect()
}

/// Split `out` into at most `threads` contiguous chunks of at least
/// `min_chunk` elements and run `f(offset, chunk)` on each chunk across
/// scoped threads — the "fill one long row cooperatively" primitive
/// behind the on-the-fly Gram row computation
/// ([`crate::kernels::gram::OnTheFly`]). `threads <= 1`, or a slice no
/// longer than `min_chunk`, runs `f(0, out)` inline with zero thread
/// overhead. Chunk boundaries never affect results when `f` writes each
/// cell independently of the chunking, which is the intended use.
pub fn par_chunks_mut<T: Send, F>(out: &mut [T], min_chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let threads = threads.max(1);
    let min_chunk = min_chunk.max(1);
    if threads <= 1 || n <= min_chunk {
        f(0, out);
        return;
    }
    let nchunks = threads.min(n.div_ceil(min_chunk)).max(1);
    let chunk = n.div_ceil(nchunks);
    // Hand each chunk's &mut out exactly once via take-slots (the
    // par_rows pattern), so workers write disjoint memory without
    // unsafe.
    let slots: Vec<Mutex<Option<(usize, &mut [T])>>> = out
        .chunks_mut(chunk)
        .enumerate()
        .map(|(ci, slab)| Mutex::new(Some((ci * chunk, slab))))
        .collect();
    par_claim(slots.len(), threads, |ci| {
        let (off, slab) = slots[ci].lock().unwrap().take().expect("chunk claimed twice");
        f(off, slab);
    });
}

/// Map over mutable chunks of an output slice in parallel: the slice is
/// split into per-row blocks of `row_len` and `f(row_index, row_slice)`
/// is called for each row. This is the kernel-matrix fill pattern.
pub fn par_rows<T: Send, F>(out: &mut [T], row_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0);
    let n_rows = out.len() / row_len;
    let threads = default_threads();
    if threads <= 1 || n_rows <= 1 {
        for (i, row) in out.chunks_mut(row_len).enumerate() {
            f(i, row);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // Hand each thread rows via a work-stealing counter; rows are claimed
    // one block at a time to balance ragged costs.
    let rows: Vec<Mutex<Option<&mut [T]>>> =
        out.chunks_mut(row_len).map(|c| Mutex::new(Some(c))).collect();
    thread::scope(|s| {
        for _ in 0..threads.min(n_rows) {
            s.spawn(|| loop {
                // relaxed-ok: work-claim counter — fetch_add is atomic
                // (each row claimed once); scope join publishes writes.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_rows {
                    break;
                }
                let row = rows[i].lock().unwrap().take().expect("row claimed twice");
                f(i, row);
            });
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A long-lived thread pool with a simple FIFO queue.
///
/// Workers are named `minmax-worker-<i>`; jobs are `FnOnce` boxes. The
/// pool joins all workers on drop. Panics in jobs abort that worker but
/// are surfaced at drop time via [`ThreadPool::panicked`].
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Msg>>,
    handles: Vec<thread::JoinHandle<()>>,
    panicked: Arc<AtomicUsize>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let panicked = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            let panicked = Arc::clone(&panicked);
            let h = spawn_named(format!("minmax-worker-{i}"), move || loop {
                let msg = { rx.lock().unwrap().recv() };
                match msg {
                    Ok(Msg::Run(job)) => {
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                        if res.is_err() {
                            // relaxed-ok: monotonic panic tally read by
                            // `panicked()` for observability only.
                            panicked.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                }
            })
            .expect("spawn worker");
            handles.push(h);
        }
        Self { tx: Some(tx), handles, panicked, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Msg::Run(Box::new(f)))
            .expect("worker queue closed");
    }

    /// Number of jobs that panicked so far.
    pub fn panicked(&self) -> usize {
        // relaxed-ok: monotonic observability tally; callers polling it
        // (see `pool_counts_panics_and_survives`) loop until visible.
        self.panicked.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            for _ in 0..self.handles.len() {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A job submitted through [`ThreadPool::submit_with_result`] panicked;
/// the panic message is captured so the waiter can report it.
#[derive(Debug, Clone)]
pub struct JobPanicked {
    pub message: String,
}

impl std::fmt::Display for JobPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pool job panicked: {}", self.message)
    }
}
impl std::error::Error for JobPanicked {}

/// A one-shot result slot for submitting a job and waiting for its value.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<Result<T, JobPanicked>>,
}

impl<T> JobHandle<T> {
    /// Wait for the job. A panicked job comes back as `Err(JobPanicked)`
    /// with the message captured — it used to drop its sender, leaving
    /// the waiter to panic on a closed channel instead of learning what
    /// went wrong.
    pub fn wait(self) -> Result<T, JobPanicked> {
        self.rx.recv().unwrap_or_else(|_| {
            // The job was dropped without ever running — only possible
            // if the pool shut down first; surface it the same typed way.
            Err(JobPanicked { message: "job dropped without running (pool shut down)".into() })
        })
    }
}

impl ThreadPool {
    /// Submit a job that returns a value; wait on the returned handle.
    /// A panic inside `f` is captured for the waiter (see
    /// [`JobHandle::wait`]) and then re-propagated so the pool's
    /// [`panicked`](ThreadPool::panicked) tally still counts it.
    pub fn submit_with_result<T: Send + 'static, F: FnOnce() -> T + Send + 'static>(
        &self,
        f: F,
    ) -> JobHandle<T> {
        let (tx, rx) = mpsc::channel();
        self.submit(move || {
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
                Ok(v) => {
                    let _ = tx.send(Ok(v));
                }
                Err(payload) => {
                    let message = if let Some(s) = payload.downcast_ref::<&str>() {
                        (*s).to_string()
                    } else if let Some(s) = payload.downcast_ref::<String>() {
                        s.clone()
                    } else {
                        "panic payload of unknown type".to_string()
                    };
                    let _ = tx.send(Err(JobPanicked { message }));
                    std::panic::resume_unwind(payload);
                }
            }
        });
        JobHandle { rx }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_for(n, 16, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_for_empty_and_tiny() {
        par_for(0, 8, |_s, _e| panic!("must not be called"));
        let sum = AtomicU64::new(0);
        par_for(3, 8, |s, e| {
            sum.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn par_for_with_explicit_threads_covers_once() {
        for threads in [1usize, 2, 4, 7] {
            let n = 1000;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            par_for_with(n, 8, threads, |s, e| {
                for i in s..e {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={threads}");
        }
    }

    #[test]
    fn par_claim_visits_each_unit_once() {
        for threads in [1usize, 3, 8] {
            let n = 500;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            par_claim(n, threads, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={threads}");
        }
        par_claim(0, 4, |_| panic!("must not be called"));
    }

    #[test]
    fn par_map_claim_is_ordered_at_any_thread_count() {
        for threads in [1usize, 2, 5] {
            let out = par_map_claim(100, threads, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
        assert!(par_map_claim(0, 4, |i| i).is_empty());
    }

    #[test]
    fn par_chunks_mut_covers_with_correct_offsets() {
        for threads in [1usize, 2, 3, 8] {
            let n = 1013; // deliberately not a multiple of any chunking
            let mut out = vec![0usize; n];
            par_chunks_mut(&mut out, 16, threads, |off, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = off + i + 1;
                }
            });
            assert!(
                out.iter().enumerate().all(|(i, &v)| v == i + 1),
                "threads={threads}: offset mismatch"
            );
        }
        let mut empty: Vec<usize> = Vec::new();
        par_chunks_mut(&mut empty, 8, 4, |_, _| panic!("must not be called"));
        // At or below min_chunk: one inline call over the whole slice.
        let mut small = vec![0u32; 8];
        par_chunks_mut(&mut small, 8, 4, |off, chunk| {
            assert_eq!(off, 0);
            assert_eq!(chunk.len(), 8);
            chunk.fill(7);
        });
        assert!(small.iter().all(|&v| v == 7));
    }

    #[test]
    fn par_rows_fills_every_row() {
        let mut out = vec![0u32; 12 * 7];
        par_rows(&mut out, 7, |i, row| {
            for v in row.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        for (i, row) in out.chunks(7).enumerate() {
            assert!(row.iter().all(|&v| v == i as u32 + 1));
        }
    }

    #[test]
    fn pool_runs_jobs_and_returns_values() {
        let pool = ThreadPool::new(4);
        let handles: Vec<_> = (0..32).map(|i| pool.submit_with_result(move || i * i)).collect();
        let vals: Vec<i32> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        assert_eq!(vals, (0..32).map(|i| i * i).collect::<Vec<_>>());
        assert_eq!(pool.panicked(), 0);
    }

    #[test]
    fn pool_counts_panics_and_survives() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        let ok = pool.submit_with_result(|| 41 + 1).wait().unwrap();
        assert_eq!(ok, 42);
        // The panicking job has definitely retired because the queue is FIFO
        // per worker... but with 2 workers ordering isn't guaranteed; wait.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while pool.panicked() == 0 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(pool.panicked(), 1);
    }

    #[test]
    fn panicked_result_job_surfaces_as_error_not_hang() {
        let pool = ThreadPool::new(1);
        let h = pool.submit_with_result(|| -> u32 { panic!("exploded on purpose") });
        // Regression: this used to panic on a closed channel ("job
        // dropped without result") instead of reporting the job's panic.
        let err = h.wait().expect_err("panicked job must yield JobPanicked");
        assert!(err.message.contains("exploded on purpose"), "got: {}", err.message);
        // The worker survived and keeps serving...
        assert_eq!(pool.submit_with_result(|| 7u32).wait().unwrap(), 7);
        // ...and the pool's panic tally still counts the job.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while pool.panicked() == 0 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(pool.panicked(), 1);
    }
}
