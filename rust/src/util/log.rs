//! Leveled stderr logging + wall-clock timers.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

/// Set global log level (also honors `MINMAX_LOG={debug,info,warn,error}`
/// via [`init_from_env`]).
pub fn set_level(level: Level) {
    // relaxed-ok: the level flag gates log emission only; no data is
    // published through it, so staleness just delays filtering.
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn init_from_env() {
    if let Ok(s) = std::env::var("MINMAX_LOG") {
        let lvl = match s.to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "warn" => Level::Warn,
            "error" => Level::Error,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

pub fn enabled(level: Level) -> bool {
    // relaxed-ok: see `set_level` — filter flag, not a data carrier.
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{tag} {:>9.3}s] {args}", elapsed_secs());
}

fn start_instant() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Seconds since process start (first logging call).
pub fn elapsed_secs() -> f64 {
    start_instant().elapsed().as_secs_f64()
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}

/// RAII scope timer: logs at Info on drop.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), start: Instant::now() }
    }

    pub fn elapsed(&self) -> std::time::Duration {
        self.start.elapsed()
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        log(Level::Info, format_args!("{}: {:.3}s", self.label, self.start.elapsed().as_secs_f64()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_filter() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn timer_measures_positive() {
        let t = Timer::new("test");
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed().as_secs_f64() > 0.0);
    }
}
