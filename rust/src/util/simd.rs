//! Runtime-dispatched SIMD substrate for the serving hot loops.
//!
//! The fused serving path (DESIGN.md §2.4/§2.6) is memory-bandwidth
//! bound: the argmin inner loop streams the transposed parameter slabs
//! and the gather stage streams class-minor weight rows. Both loops are
//! *element-wise* — the argmin tracks a per-slot running minimum and the
//! gather adds disjoint lanes — so a vectorized variant performs exactly
//! the same scalar operations on exactly the same elements and is
//! **bit-identical** to the scalar fallback at every level. That is the
//! contract this module exports: dispatch changes speed, never bits.
//!
//! Three levels, resolved once per process and cached:
//!
//! * [`SimdLevel::Scalar`] — the pre-SIMD loops, verbatim. Forced with
//!   `MINMAX_SIMD=off` (the CI SIMD-off leg).
//! * [`SimdLevel::Lanes`] — portable chunks-of-N kernels shaped so the
//!   autovectorizer lowers them to whatever the target offers
//!   (SSE2/AVX on x86, NEON on aarch64). No `unsafe`, no feature
//!   detection; this is the default on non-x86 targets.
//! * [`SimdLevel::Avx2`] — hand-written `core::arch::x86_64`
//!   intrinsics behind `#[target_feature(enable = "avx2")]`, selected
//!   only after `is_x86_feature_detected!` confirms the CPU supports
//!   them. Falls back to `Lanes` when compiled for another arch.
//!
//! Like `MINMAX_FAST_MATH`, the `MINMAX_SIMD` variable is a *request*:
//! asking for vector code on a CPU without AVX2 silently lands on the
//! portable kernels, and every landing spot computes the same bits.

use std::sync::OnceLock;

/// Dispatch level for the vectorized serving kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Plain scalar loops — bit-identical reference paths.
    Scalar,
    /// Portable chunks-of-N kernels left to the autovectorizer.
    Lanes,
    /// Runtime-detected AVX2 intrinsics (x86_64 only).
    Avx2,
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Lanes => "lanes",
            SimdLevel::Avx2 => "avx2",
        })
    }
}

/// Parse a `MINMAX_SIMD` override. `off`/`0`/`false`/`scalar` force the
/// scalar fallback; `lanes`/`portable` skip the intrinsics paths;
/// anything else defers to hardware detection.
fn parse_override(value: &str) -> Option<SimdLevel> {
    match value.trim().to_ascii_lowercase().as_str() {
        "off" | "0" | "false" | "scalar" => Some(SimdLevel::Scalar),
        "lanes" | "portable" => Some(SimdLevel::Lanes),
        _ => None,
    }
}

fn detect() -> SimdLevel {
    if let Ok(value) = std::env::var("MINMAX_SIMD") {
        if let Some(forced) = parse_override(&value) {
            return forced;
        }
    }
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return SimdLevel::Avx2;
    }
    SimdLevel::Lanes
}

/// The process-wide dispatch decision: `MINMAX_SIMD` override first,
/// then hardware detection, cached after the first call.
pub fn level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(detect)
}

/// `true` unless the scalar fallback is forced. The argmin kernels
/// branch on this once per nonzero, so it must stay a cached load.
#[inline]
pub fn wide() -> bool {
    level() != SimdLevel::Scalar
}

/// Portable chunk width. Eight f64 lanes span two AVX2 registers (or
/// four SSE2/NEON ones), enough to keep the add ports busy without
/// spilling the staging arrays used by the argmin kernels.
pub const CHUNK: usize = 8;

/// `acc[i] += src[i]` over the paired prefix, dispatched at [`level`].
///
/// Slices may differ in length; only the common prefix is touched (the
/// gather stage passes equal-length class rows, but the contract keeps
/// the helper panic-free). All levels are bit-identical.
#[inline]
pub fn add_assign(acc: &mut [f64], src: &[f64]) {
    add_assign_at(level(), acc, src);
}

/// [`add_assign`] with an explicit level — the testable entry point and
/// the hook benches use to time one path from a single process.
pub(crate) fn add_assign_at(level: SimdLevel, acc: &mut [f64], src: &[f64]) {
    match level {
        SimdLevel::Scalar => {
            for (a, &s) in acc.iter_mut().zip(src) {
                *a += s;
            }
        }
        SimdLevel::Lanes => add_assign_lanes(acc, src),
        SimdLevel::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Avx2` is only ever produced by `detect` after
            // `is_x86_feature_detected!("avx2")` (or handed in by a
            // test that performed the same probe).
            unsafe {
                x86::add_assign_avx2(acc, src)
            };
            #[cfg(not(target_arch = "x86_64"))]
            add_assign_lanes(acc, src);
        }
    }
}

#[allow(clippy::needless_range_loop)]
fn add_assign_lanes(acc: &mut [f64], src: &[f64]) {
    let n = acc.len().min(src.len());
    let (acc, src) = (&mut acc[..n], &src[..n]);
    let mut a = acc.chunks_exact_mut(CHUNK);
    let mut s = src.chunks_exact(CHUNK);
    for (av, sv) in (&mut a).zip(&mut s) {
        for l in 0..CHUNK {
            av[l] += sv[l];
        }
    }
    for (av, &sv) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *av += sv;
    }
}

/// `acc[i] += src[i] as f64` over the paired prefix — the f32-slab
/// gather. Widening an f32 to f64 is exact, so every level (including
/// the AVX2 `cvtps_pd` path) produces identical bits.
#[inline]
pub fn add_assign_f32(acc: &mut [f64], src: &[f32]) {
    add_assign_f32_at(level(), acc, src);
}

/// [`add_assign_f32`] with an explicit level (tests/benches).
pub(crate) fn add_assign_f32_at(level: SimdLevel, acc: &mut [f64], src: &[f32]) {
    match level {
        SimdLevel::Scalar => {
            for (a, &s) in acc.iter_mut().zip(src) {
                *a += s as f64;
            }
        }
        SimdLevel::Lanes => add_assign_f32_lanes(acc, src),
        SimdLevel::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: see `add_assign_at` — `Avx2` implies a positive
            // runtime AVX2 probe.
            unsafe {
                x86::add_assign_f32_avx2(acc, src)
            };
            #[cfg(not(target_arch = "x86_64"))]
            add_assign_f32_lanes(acc, src);
        }
    }
}

#[allow(clippy::needless_range_loop)]
fn add_assign_f32_lanes(acc: &mut [f64], src: &[f32]) {
    let n = acc.len().min(src.len());
    let (acc, src) = (&mut acc[..n], &src[..n]);
    let mut a = acc.chunks_exact_mut(CHUNK);
    let mut s = src.chunks_exact(CHUNK);
    for (av, sv) in (&mut a).zip(&mut s) {
        for l in 0..CHUNK {
            av[l] += sv[l] as f64;
        }
    }
    for (av, &sv) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *av += sv as f64;
    }
}

/// `acc[i] += src[i] as i32` over the paired prefix — the int8-slab
/// gather. Integer widening adds are exact at every level and the
/// chunked shape lowers to `pmovsxbd`+`paddd` (or the NEON equivalent)
/// without hand-written intrinsics, so dispatch here is just
/// scalar-vs-chunked.
#[inline]
pub fn add_assign_i8(acc: &mut [i32], src: &[i8]) {
    add_assign_i8_at(wide(), acc, src);
}

/// [`add_assign_i8`] with the chunked path explicit (tests/benches).
#[allow(clippy::needless_range_loop)]
pub(crate) fn add_assign_i8_at(wide: bool, acc: &mut [i32], src: &[i8]) {
    if !wide {
        for (a, &s) in acc.iter_mut().zip(src) {
            *a += s as i32;
        }
        return;
    }
    let n = acc.len().min(src.len());
    let (acc, src) = (&mut acc[..n], &src[..n]);
    let mut a = acc.chunks_exact_mut(CHUNK);
    let mut s = src.chunks_exact(CHUNK);
    for (av, sv) in (&mut a).zip(&mut s) {
        for l in 0..CHUNK {
            av[l] += sv[l] as i32;
        }
    }
    for (av, &sv) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *av += sv as i32;
    }
}

/// Count mismatching b-bit code slots between two equal-length packed
/// rows (the `features::PackedCodes::word_row` layout: slot `j` at bit
/// `(j mod 64/b)·b` of word `j/(64/b)`, zero-padded tail). Because the
/// tail padding is zero in *both* rows it never mismatches, so the
/// result counts real slots only — agreement is `k − mismatches`.
///
/// This is the LSH candidate prefilter: a handful of XOR + popcount
/// words per candidate instead of an O(nnz) exact kernel.
#[inline]
pub fn packed_mismatch(a: &[u64], b: &[u64], bits: u8) -> u32 {
    packed_mismatch_at(wide(), a, b, bits)
}

/// OR-fold each b-bit group of `x` down to its lowest bit, mask the
/// group LSBs, popcount — the SWAR "any bit set per group" reduction.
/// Pure integer ops, so scalar and chunked paths are exactly equal.
#[inline]
fn mismatch_word(mut x: u64, bits: u8) -> u32 {
    let mut s = (bits / 2) as u32;
    while s > 0 {
        x |= x >> s;
        s /= 2;
    }
    let lsb = match bits {
        1 => u64::MAX,
        2 => 0x5555_5555_5555_5555,
        4 => 0x1111_1111_1111_1111,
        8 => 0x0101_0101_0101_0101,
        _ => 0x0001_0001_0001_0001, // 16
    };
    (x & lsb).count_ones()
}

/// [`packed_mismatch`] with the chunked path explicit (tests/benches).
/// `bits` must be one of {1, 2, 4, 8, 16} — the widths
/// `features::PackedCodes::supported_bits` admits.
#[allow(clippy::needless_range_loop)]
pub(crate) fn packed_mismatch_at(wide: bool, a: &[u64], b: &[u64], bits: u8) -> u32 {
    debug_assert!(matches!(bits, 1 | 2 | 4 | 8 | 16), "unsupported packed width {bits}");
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    if !wide {
        let mut total = 0u32;
        for (&x, &y) in a.iter().zip(b) {
            total += mismatch_word(x ^ y, bits);
        }
        return total;
    }
    let mut av = a.chunks_exact(CHUNK);
    let mut bv = b.chunks_exact(CHUNK);
    let mut lanes = [0u32; CHUNK];
    for (ac, bc) in (&mut av).zip(&mut bv) {
        for l in 0..CHUNK {
            lanes[l] += mismatch_word(ac[l] ^ bc[l], bits);
        }
    }
    let mut total: u32 = lanes.iter().sum();
    for (&x, &y) in av.remainder().iter().zip(bv.remainder()) {
        total += mismatch_word(x ^ y, bits);
    }
    total
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// # Safety
    /// The caller must have verified AVX2 support at runtime
    /// (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_avx2(acc: &mut [f64], src: &[f64]) {
        let n = acc.len().min(src.len());
        let ap = acc.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: `i + 4 <= n <= len` for both slices, so the
            // unaligned 4-lane loads/stores stay in bounds; `acc` and
            // `src` cannot alias (`&mut` vs `&`); AVX2 is guaranteed by
            // the caller contract above.
            unsafe {
                let a = _mm256_loadu_pd(ap.add(i));
                let s = _mm256_loadu_pd(sp.add(i));
                _mm256_storeu_pd(ap.add(i), _mm256_add_pd(a, s));
            }
            i += 4;
        }
        while i < n {
            // SAFETY: `i < n <= len` for both slices — scalar tail.
            unsafe {
                *ap.add(i) += *sp.add(i);
            }
            i += 1;
        }
    }

    /// # Safety
    /// The caller must have verified AVX2 support at runtime
    /// (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_f32_avx2(acc: &mut [f64], src: &[f32]) {
        let n = acc.len().min(src.len());
        let ap = acc.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0;
        while i + 4 <= n {
            // Widen four f32s to f64 (exact), then add in f64 — same
            // arithmetic as the scalar `as f64` loop.
            // SAFETY: `i + 4 <= n <= len` for both slices, so the
            // 4-lane f32 load and f64 load/store stay in bounds; no
            // aliasing (`&mut` vs `&`); AVX2 guaranteed by the caller
            // contract above.
            unsafe {
                let s = _mm256_cvtps_pd(_mm_loadu_ps(sp.add(i)));
                let a = _mm256_loadu_pd(ap.add(i));
                _mm256_storeu_pd(ap.add(i), _mm256_add_pd(a, s));
            }
            i += 4;
        }
        while i < n {
            // SAFETY: `i < n <= len` for both slices — scalar tail.
            unsafe {
                *ap.add(i) += *sp.add(i) as f64;
            }
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Every level available on this host, scalar first.
    fn levels() -> Vec<SimdLevel> {
        let mut out = vec![SimdLevel::Scalar, SimdLevel::Lanes];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            out.push(SimdLevel::Avx2);
        }
        out
    }

    #[test]
    fn f64_add_is_bit_identical_across_levels() {
        let mut rng = Pcg64::new(0x51D0);
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 26, 64, 129] {
            let base: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
            let src: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let mut want = base.clone();
            add_assign_at(SimdLevel::Scalar, &mut want, &src);
            for level in levels() {
                let mut got = base.clone();
                add_assign_at(level, &mut got, &src);
                let same = want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "f64 add diverged at {level} for n={n}");
            }
        }
    }

    #[test]
    fn f32_widening_add_is_bit_identical_across_levels() {
        let mut rng = Pcg64::new(0x51D1);
        for n in [0usize, 1, 2, 4, 6, 8, 13, 33, 100] {
            let base: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let src: Vec<f32> = (0..n).map(|_| rng.range_f64(-4.0, 4.0) as f32).collect();
            let mut want = base.clone();
            add_assign_f32_at(SimdLevel::Scalar, &mut want, &src);
            for level in levels() {
                let mut got = base.clone();
                add_assign_f32_at(level, &mut got, &src);
                let same = want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "f32 widening add diverged at {level} for n={n}");
            }
        }
    }

    #[test]
    fn i8_widening_add_matches_scalar_exactly() {
        let mut rng = Pcg64::new(0x51D2);
        for n in [0usize, 1, 4, 7, 8, 11, 40, 255] {
            let base: Vec<i32> = (0..n).map(|_| rng.below(2_000) as i32 - 1_000).collect();
            let src: Vec<i8> = (0..n).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
            let mut want = base.clone();
            add_assign_i8_at(false, &mut want, &src);
            let mut got = base.clone();
            add_assign_i8_at(true, &mut got, &src);
            assert_eq!(want, got, "i8 widening add diverged for n={n}");
        }
    }

    #[test]
    fn mismatched_lengths_touch_only_the_paired_prefix() {
        for level in levels() {
            let mut acc = vec![1.0f64; 10];
            add_assign_at(level, &mut acc, &[1.0; 6]);
            assert_eq!(&acc[..6], &[2.0; 6], "prefix not added at {level}");
            assert_eq!(&acc[6..], &[1.0; 4], "suffix disturbed at {level}");
        }
    }

    #[test]
    fn env_override_parsing() {
        for v in ["off", "0", "false", "scalar", " OFF ", "Scalar"] {
            assert_eq!(parse_override(v), Some(SimdLevel::Scalar), "{v:?}");
        }
        for v in ["lanes", "portable", "LANES"] {
            assert_eq!(parse_override(v), Some(SimdLevel::Lanes), "{v:?}");
        }
        for v in ["", "on", "1", "auto", "avx2"] {
            assert_eq!(parse_override(v), None, "{v:?}");
        }
    }

    #[test]
    fn level_is_cached_and_consistent_with_wide() {
        assert_eq!(level(), level());
        assert_eq!(wide(), level() != SimdLevel::Scalar);
    }

    /// Slot-by-slot reference: unpack both rows and compare codes.
    fn mismatch_reference(a: &[u64], b: &[u64], bits: u8, slots: usize) -> u32 {
        let cpw = 64 / bits as usize;
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        (0..slots)
            .filter(|&j| {
                let x = (a[j / cpw] >> ((j % cpw) * bits as usize)) & mask;
                let y = (b[j / cpw] >> ((j % cpw) * bits as usize)) & mask;
                x != y
            })
            .count() as u32
    }

    #[test]
    fn packed_mismatch_matches_slotwise_reference() {
        let mut rng = Pcg64::new(0x51D3);
        for bits in [1u8, 2, 4, 8, 16] {
            let cpw = 64 / bits as usize;
            for words in [0usize, 1, 2, 7, 8, 9, 33] {
                let mut a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
                let mut b: Vec<u64> = a
                    .iter()
                    .map(|&w| if rng.uniform() < 0.5 { w } else { w ^ rng.next_u64() })
                    .collect();
                // Zero-pad an arbitrary tail in both rows, as PackedCodes
                // does for k not a multiple of 64/b: padding never counts.
                if words > 0 {
                    let keep = rng.below(cpw as u64 + 1) as usize;
                    let tail_mask = if keep == cpw {
                        u64::MAX
                    } else {
                        (1u64 << (keep * bits as usize)).wrapping_sub(1)
                    };
                    a[words - 1] &= tail_mask;
                    b[words - 1] &= tail_mask;
                }
                let want = mismatch_reference(&a, &b, bits, words * cpw);
                assert_eq!(packed_mismatch_at(false, &a, &b, bits), want, "scalar b={bits}");
                assert_eq!(packed_mismatch_at(true, &a, &b, bits), want, "wide b={bits}");
            }
        }
    }

    #[test]
    fn packed_mismatch_identity_and_disjoint() {
        for bits in [1u8, 2, 4, 8, 16] {
            let a = vec![0xdead_beef_cafe_f00du64; 9];
            assert_eq!(packed_mismatch_at(false, &a, &a, bits), 0);
            assert_eq!(packed_mismatch_at(true, &a, &a, bits), 0);
            // All-ones vs all-zeros: every slot mismatches.
            let ones = vec![u64::MAX; 9];
            let zeros = vec![0u64; 9];
            let slots = (9 * 64 / bits as usize) as u32;
            assert_eq!(packed_mismatch_at(false, &ones, &zeros, bits), slots);
            assert_eq!(packed_mismatch_at(true, &ones, &zeros, bits), slots);
        }
    }
}
