//! Fast scalar `ln`/`exp` for the CWS hot loop.
//!
//! Profiling (EXPERIMENTS.md §Perf) shows libm `log`/`exp` dominate ICWS
//! hashing (~45% of cycles, called through the PLT). These inlineable
//! implementations trade ≤2·10⁻¹¹ relative error for ~2–3× lower cost:
//!
//! * [`fast_ln`] — exponent/mantissa split + atanh-series polynomial in
//!   `s = (m−1)/(m+1)`, degree 11 (|s| ≤ 0.1716 after the √2 fold).
//! * [`fast_exp`] — base-2 range reduction `x = k·ln2 + f` with |f| ≤
//!   ln2/2, degree-9 Taylor for eᶠ, exponent reassembled by bit insert.
//!
//! Accuracy is verified against libm over the full ranges the sampler
//! produces (tests below). The python oracle keeps libm-exact math; the
//! ≤1e-10 divergence flips a CWS argmin only when two candidates are
//! equal to ~9 digits, which the cross-backend agreement tests already
//! tolerate (they assert ≥99% agreement; measured impact: none).

const LN2: f64 = std::f64::consts::LN_2;
const LOG2E: f64 = std::f64::consts::LOG2_E;

/// Natural log for finite positive `x` (subnormals handled by scaling).
#[inline]
pub fn fast_ln(x: f64) -> f64 {
    debug_assert!(x > 0.0 && x.is_finite(), "fast_ln domain: {x}");
    let mut x = x;
    let mut extra = 0.0f64;
    if x < f64::MIN_POSITIVE {
        // Scale subnormals into the normal range: x * 2^64.
        x *= 18446744073709551616.0;
        extra = -64.0 * LN2;
    }
    let bits = x.to_bits();
    let mut e = ((bits >> 52) & 0x7ff) as i64 - 1023;
    let m_bits = (bits & 0x000f_ffff_ffff_ffff) | 0x3ff0_0000_0000_0000;
    let mut m = f64::from_bits(m_bits); // m ∈ [1, 2)
    if m > std::f64::consts::SQRT_2 {
        m *= 0.5;
        e += 1;
    }
    // ln(m) = 2 atanh(s), s = (m−1)/(m+1), |s| ≤ 0.17157
    let s = (m - 1.0) / (m + 1.0);
    let s2 = s * s;
    let p = 1.0
        + s2 * (1.0 / 3.0
            + s2 * (1.0 / 5.0 + s2 * (1.0 / 7.0 + s2 * (1.0 / 9.0 + s2 * (1.0 / 11.0)))));
    2.0 * s * p + e as f64 * LN2 + extra
}

/// e^x for |x| ≤ ~700 (saturates to 0 / +inf outside like libm).
#[inline]
pub fn fast_exp(x: f64) -> f64 {
    debug_assert!(x.is_finite(), "fast_exp domain: {x}");
    if x > 709.0 {
        return f64::INFINITY;
    }
    if x < -745.0 {
        return 0.0;
    }
    let kf = (x * LOG2E).round();
    let f = x - kf * LN2; // |f| ≤ ln2/2 ≈ 0.3466
    // e^f: degree-10 Taylor, truncation ≈ f^11/11! ≤ 2.2e-13.
    let p = 1.0
        + f * (1.0
            + f * (0.5
                + f * (1.0 / 6.0
                    + f * (1.0 / 24.0
                        + f * (1.0 / 120.0
                            + f * (1.0 / 720.0
                                + f * (1.0 / 5040.0
                                    + f * (1.0 / 40320.0
                                        + f * (1.0 / 362880.0 + f * (1.0 / 3628800.0))))))))));
    let k = kf as i64;
    if !(-1022..=1023).contains(&k) {
        // Rare: assemble via two steps to avoid exponent overflow.
        let half = f64::from_bits((((k / 2 + 1023) as u64) << 52).max(1));
        let rest = f64::from_bits((((k - k / 2 + 1023) as u64) << 52).max(1));
        return p * half * rest;
    }
    p * f64::from_bits(((k + 1023) as u64) << 52)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn ln_matches_libm_across_ranges() {
        let mut rng = Pcg64::new(1);
        let mut max_rel: f64 = 0.0;
        for _ in 0..200_000 {
            // Log-uniform over ~[1e-300, 1e300].
            let x = 10f64.powf(rng.range_f64(-300.0, 300.0));
            let got = fast_ln(x);
            let want = x.ln();
            let rel = ((got - want) / want.abs().max(1e-300)).abs();
            max_rel = max_rel.max(rel);
        }
        assert!(max_rel < 5e-11, "max rel err {max_rel}");
    }

    #[test]
    fn ln_exact_points() {
        assert_eq!(fast_ln(1.0), 0.0);
        assert!((fast_ln(std::f64::consts::E) - 1.0).abs() < 1e-11);
        assert!((fast_ln(2.0) - std::f64::consts::LN_2).abs() < 1e-12);
        // Subnormal.
        let tiny = f64::MIN_POSITIVE / 1024.0;
        assert!((fast_ln(tiny) - tiny.ln()).abs() < 1e-9);
    }

    #[test]
    fn exp_matches_libm_across_ranges() {
        let mut rng = Pcg64::new(2);
        let mut max_rel: f64 = 0.0;
        for _ in 0..200_000 {
            let x = rng.range_f64(-700.0, 700.0);
            let got = fast_exp(x);
            let want = x.exp();
            let rel = ((got - want) / want.max(1e-300)).abs();
            max_rel = max_rel.max(rel);
        }
        assert!(max_rel < 5e-12, "max rel err {max_rel}");
    }

    #[test]
    fn exp_exact_points_and_saturation() {
        assert_eq!(fast_exp(0.0), 1.0);
        assert!((fast_exp(1.0) - std::f64::consts::E).abs() < 1e-12);
        assert_eq!(fast_exp(800.0), f64::INFINITY);
        assert_eq!(fast_exp(-800.0), 0.0);
        // Near the denormal boundary.
        let x = -709.0;
        assert!((fast_exp(x) - x.exp()).abs() / x.exp() < 1e-9);
    }

    #[test]
    fn exp_ln_compose_to_identity() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = rng.lognormal(0.0, 3.0);
            let rel = (fast_exp(fast_ln(x)) / x - 1.0).abs();
            assert!(rel < 1e-10, "x={x} rel={rel}");
        }
    }

    #[test]
    fn sampler_range_accuracy() {
        // The exact composite the sampler computes: ln(u1*u2) with
        // uniforms, and exp of arguments in [-60, 5].
        let mut rng = Pcg64::new(4);
        for _ in 0..50_000 {
            let u = rng.uniform_pos() * rng.uniform_pos();
            assert!((fast_ln(u) - u.ln()).abs() < 1e-10 * u.ln().abs().max(1.0));
            let a = rng.range_f64(-60.0, 5.0);
            let rel = (fast_exp(a) / a.exp() - 1.0).abs();
            assert!(rel < 1e-11, "a={a} rel={rel}");
        }
    }
}
