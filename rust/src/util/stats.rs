//! Streaming statistics used across the estimation study, the bench
//! harness, and coordinator metrics.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    /// Sample variance (n-1).
    pub fn sample_var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Accumulator for estimator quality vs a known truth: tracks empirical
/// bias and MSE, exactly what Figures 4–6 of the paper plot.
#[derive(Debug, Clone)]
pub struct EstimatorError {
    truth: f64,
    err: Online,
    sq: Online,
}

impl EstimatorError {
    pub fn new(truth: f64) -> Self {
        Self { truth, err: Online::new(), sq: Online::new() }
    }

    #[inline]
    pub fn push(&mut self, estimate: f64) {
        let e = estimate - self.truth;
        self.err.push(e);
        self.sq.push(e * e);
    }

    pub fn truth(&self) -> f64 {
        self.truth
    }
    /// Empirical bias: mean(est) - truth.
    pub fn bias(&self) -> f64 {
        self.err.mean()
    }
    /// Empirical mean squared error.
    pub fn mse(&self) -> f64 {
        self.sq.mean()
    }
    pub fn count(&self) -> u64 {
        self.err.count()
    }
}

/// Exact percentile over a recorded sample (used by coordinator metrics:
/// p50/p95/p99 latency). Stores all values; fine at service scale here.
#[derive(Debug, Clone, Default)]
pub struct Reservoir {
    values: Vec<f64>,
    sorted: bool,
}

impl Reservoir {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.values.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Percentile in [0,100] by linear interpolation.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.values.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let n = self.values.len();
        if n == 1 {
            return self.values[0];
        }
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi.min(n - 1)] * frac
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            f64::NAN
        } else {
            self.values.iter().sum::<f64>() / self.values.len() as f64
        }
    }
}

/// Fixed-bucket histogram over static upper bounds (a Prometheus-style
/// cumulative-free bucket layout): `counts[i]` holds the observations
/// `x <= bounds[i]` that no earlier bucket claimed, and the final slot
/// is the overflow bucket (`x > bounds.last()`). Unlike [`Reservoir`]
/// (which stores every value for exact percentiles) this is O(buckets)
/// memory forever — the shape the coordinator exports for per-request
/// latency so long-lived services don't grow without bound.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: &'static [f64],
    counts: Vec<u64>,
}

impl Histogram {
    /// `bounds` must be strictly increasing.
    pub fn new(bounds: &'static [f64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must increase");
        Self { bounds, counts: vec![0u64; bounds.len() + 1] }
    }

    /// Rebuild a histogram from exported bucket counts (e.g. a metrics
    /// snapshot's `latency_hist`) so aggregators can [`Histogram::merge`]
    /// shard-level exports and estimate fleet-wide quantiles without
    /// access to the live histograms. `counts` must have one slot per
    /// bound plus the overflow slot.
    pub fn with_counts(bounds: &'static [f64], counts: Vec<u64>) -> Self {
        assert_eq!(
            counts.len(),
            bounds.len() + 1,
            "counts must hold bounds.len() + 1 slots (incl. overflow)"
        );
        Self { bounds, counts }
    }

    /// Element-wise merge of another histogram over the SAME bucket
    /// layout (panics on a layout mismatch — merging incompatible
    /// histograms would silently misattribute observations).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "histogram bounds must match to merge");
        for (a, &b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|&b| b < x);
        self.counts[idx] += 1;
    }

    pub fn bounds(&self) -> &'static [f64] {
        self.bounds
    }

    /// Per-bucket counts; the last entry is the overflow bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Estimated quantile (`p` in `[0, 100]`) from the bucket counts —
    /// the Prometheus `histogram_quantile` estimator: find the bucket
    /// the target rank falls in, then interpolate linearly between its
    /// edges. The first bucket's lower edge is 0 (every histogram in
    /// this crate records nonnegative quantities — latencies), and
    /// ranks landing in the overflow bucket clamp to the last finite
    /// bound (there is no upper edge to interpolate toward). Returns
    /// NaN for an empty histogram.
    ///
    /// Estimation error is bounded by the containing bucket's width —
    /// see the exact [`Reservoir`] percentiles when the full sample is
    /// affordable; this is the O(buckets) answer long-lived services
    /// export.
    pub fn quantile(&self, p: f64) -> f64 {
        let total = self.total();
        if total == 0 || self.bounds.is_empty() {
            return f64::NAN;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0) * total as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let prev = cum as f64;
            cum += c;
            if c > 0 && cum as f64 >= target {
                if i >= self.bounds.len() {
                    // Overflow bucket: clamp to the last finite bound.
                    return self.bounds[self.bounds.len() - 1];
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = ((target - prev) / c as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
        }
        // All mass sits in the overflow bucket.
        self.bounds[self.bounds.len() - 1]
    }
}

/// Binary/multiclass accuracy counter.
#[derive(Debug, Clone, Default)]
pub struct Accuracy {
    correct: u64,
    total: u64,
}

impl Accuracy {
    pub fn push(&mut self, predicted: i32, actual: i32) {
        if predicted == actual {
            self.correct += 1;
        }
        self.total += 1;
    }

    pub fn value(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.correct as f64 / self.total as f64
        }
    }

    pub fn percent(&self) -> f64 {
        self.value() * 100.0
    }

    pub fn total(&self) -> u64 {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5, -3.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((o.mean() - mean).abs() < 1e-12);
        assert!((o.var() - var).abs() < 1e-12);
        assert_eq!(o.min(), -3.0);
        assert_eq!(o.max(), 16.5);
        assert_eq!(o.count(), 6);
    }

    #[test]
    fn online_merge_equals_single_pass() {
        let xs: Vec<f64> = (0..101).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Online::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Online::new();
        let mut b = Online::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.var() - whole.var()).abs() < 1e-10);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn estimator_error_bias_mse() {
        let mut e = EstimatorError::new(0.5);
        for est in [0.4, 0.6, 0.5, 0.7, 0.3] {
            e.push(est);
        }
        assert!((e.bias() - 0.0).abs() < 1e-12);
        let mse = (0.01 + 0.01 + 0.0 + 0.04 + 0.04) / 5.0;
        assert!((e.mse() - mse).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut r = Reservoir::new();
        for i in 1..=100 {
            r.push(i as f64);
        }
        assert!((r.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((r.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((r.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((r.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn percentile_single_and_empty() {
        let mut r = Reservoir::new();
        assert!(r.percentile(50.0).is_nan());
        r.push(7.0);
        assert_eq!(r.percentile(99.0), 7.0);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        static BOUNDS: [f64; 3] = [1.0, 5.0, 10.0];
        let mut h = Histogram::new(&BOUNDS);
        for x in [0.5, 1.0, 1.1, 5.0, 9.9, 10.0, 11.0, 1e9] {
            h.push(x);
        }
        // <=1: {0.5, 1.0}; <=5: {1.1, 5.0}; <=10: {9.9, 10.0}; over: 2.
        assert_eq!(h.counts(), &[2, 2, 2, 2]);
        assert_eq!(h.total(), 8);
        assert_eq!(h.bounds(), &BOUNDS);
    }

    #[test]
    fn histogram_quantiles_interpolate() {
        static BOUNDS: [f64; 4] = [1.0, 2.0, 4.0, 8.0];
        let mut h = Histogram::new(&BOUNDS);
        assert!(h.quantile(50.0).is_nan());
        // 100 observations uniform over (0, 2]: 50 in (0,1], 50 in (1,2].
        for i in 0..100 {
            h.push((i as f64 + 1.0) / 50.0);
        }
        // p50 rank = 50, exactly the full first bucket -> its upper edge.
        assert!((h.quantile(50.0) - 1.0).abs() < 1e-9);
        // p75 rank = 75: halfway through the (1, 2] bucket.
        assert!((h.quantile(75.0) - 1.5).abs() < 1e-9);
        assert!((h.quantile(100.0) - 2.0).abs() < 1e-9);
        // Overflow clamps to the last finite bound.
        let mut o = Histogram::new(&BOUNDS);
        o.push(100.0);
        assert_eq!(o.quantile(99.0), 8.0);
    }

    #[test]
    fn histogram_merge_and_with_counts() {
        static BOUNDS: [f64; 3] = [1.0, 5.0, 10.0];
        let mut a = Histogram::new(&BOUNDS);
        let mut b = Histogram::new(&BOUNDS);
        for x in [0.5, 3.0, 20.0] {
            a.push(x);
        }
        for x in [0.7, 7.0] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.counts(), &[2, 1, 1, 1]);
        assert_eq!(a.total(), 5);
        // Round-trip through exported counts (the snapshot path).
        let rebuilt = Histogram::with_counts(&BOUNDS, a.counts().to_vec());
        assert_eq!(rebuilt.counts(), a.counts());
        assert_eq!(rebuilt.quantile(50.0), a.quantile(50.0));
    }

    #[test]
    fn accuracy_counts() {
        let mut a = Accuracy::default();
        a.push(1, 1);
        a.push(2, 1);
        a.push(0, 0);
        a.push(3, 3);
        assert!((a.value() - 0.75).abs() < 1e-12);
        assert_eq!(a.total(), 4);
    }
}
