//! Foundation substrates built from scratch for the offline environment:
//! RNG + samplers, thread pool, CLI parsing, JSON, statistics, logging,
//! text tables, runtime-dispatched SIMD kernels, a mini
//! property-testing harness, and the [`sync`] concurrency facade the
//! serving stack (and the loom model checker) builds on.

pub mod cli;
pub mod fastmath;
pub mod json;
pub mod log;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod sync;
pub mod table;
