//! Tiny CLI argument parser (the vendor set has no clap).
//!
//! Supports the shapes the `minmax` binary needs:
//!
//! ```text
//! minmax <subcommand> [--flag] [--key value] [--key=value] [positional...]
//! ```
//!
//! Typed accessors parse on demand and report readable errors. Unknown
//! flags are rejected by [`Args::finish`] so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// Subcommand (first non-flag token), if any.
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    positional: Vec<String>,
    /// Keys that have been read by an accessor (for `finish`).
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Self, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an iterator of tokens.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self, CliError> {
        let mut command = None;
        let mut opts = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` ends option parsing.
                    positional.extend(it.by_ref());
                    break;
                }
                let (key, val) = if let Some(eq) = stripped.find('=') {
                    (stripped[..eq].to_string(), Some(stripped[eq + 1..].to_string()))
                } else {
                    (stripped.to_string(), None)
                };
                if key.is_empty() {
                    return Err(CliError(format!("malformed flag: {tok}")));
                }
                let val = match val {
                    Some(v) => v,
                    None => {
                        // Take the next token as the value unless it looks
                        // like another flag; then it's a boolean switch.
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                if opts.insert(key.clone(), val).is_some() {
                    return Err(CliError(format!("duplicate flag --{key}")));
                }
            } else if command.is_none() && positional.is_empty() {
                command = Some(tok);
            } else {
                positional.push(tok);
            }
        }
        Ok(Self { command, opts, positional, seen: Default::default() })
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().insert(key.to_string());
    }

    /// Raw string value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).map(|s| s.to_string()).unwrap_or_else(|| default.to_string())
    }

    /// Boolean switch: `--foo`, `--foo=true/false`.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    fn parse_as<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| CliError(format!("--{key}={v}: {e}"))),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        Ok(self.parse_as::<usize>(key)?.unwrap_or(default))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        Ok(self.parse_as::<u64>(key)?.unwrap_or(default))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        Ok(self.parse_as::<f64>(key)?.unwrap_or(default))
    }

    /// Comma-separated list of T, e.g. `--k 32,64,128`.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse::<T>().map_err(|e| CliError(format!("--{key}: '{s}': {e}"))))
                .collect(),
        }
    }

    /// Error out on any flag that no accessor ever looked at.
    pub fn finish(&self) -> Result<(), CliError> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self.opts.keys().filter(|k| !seen.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError(format!(
                "unknown flag(s): {}",
                unknown.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(", ")
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["table1", "--seed", "42", "--datasets=letters,digits", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("table1"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 42);
        assert_eq!(a.str_or("datasets", ""), "letters,digits");
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_and_space_forms_agree() {
        let a = parse(&["x", "--k=7"]);
        let b = parse(&["x", "--k", "7"]);
        assert_eq!(a.usize_or("k", 0).unwrap(), 7);
        assert_eq!(b.usize_or("k", 0).unwrap(), 7);
    }

    #[test]
    fn boolean_switch_before_flag() {
        let a = parse(&["x", "--fast", "--k", "3"]);
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("k", 0).unwrap(), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.usize_or("k", 128).unwrap(), 128);
        assert_eq!(a.f64_or("c", 1.0).unwrap(), 1.0);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["x", "--ks", "32,64, 128"]);
        assert_eq!(a.list_or::<usize>("ks", &[]).unwrap(), vec![32, 64, 128]);
        let b = parse(&["x"]);
        assert_eq!(b.list_or::<usize>("ks", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["x", "--k", "notanum"]);
        assert!(a.usize_or("k", 0).is_err());
    }

    #[test]
    fn duplicate_flag_is_error() {
        assert!(Args::parse(["x", "--k", "1", "--k", "2"].iter().map(|s| s.to_string())).is_err());
    }

    #[test]
    fn unknown_flag_detected_by_finish() {
        let a = parse(&["x", "--typo", "1"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn positional_after_double_dash() {
        let a = parse(&["run", "--k", "1", "--", "--not-a-flag", "pos2"]);
        assert_eq!(a.positional(), &["--not-a-flag".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn no_command() {
        let a = parse(&[]);
        assert!(a.command.is_none());
    }
}
