//! Data layer: matrix types, LIBSVM IO, the paper's preprocessing
//! transforms, and the synthetic dataset suite + word-vector corpus that
//! stand in for the paper's (non-redistributable, network-gated) data.

pub mod corpus;
pub mod dense;
pub mod libsvm;
pub mod scale;
pub mod sparse;
pub mod synth;

pub use dense::Dense;
pub use sparse::{Csr, CsrBuilder, SparseRow};

/// A feature matrix in either dense or sparse representation. Kernels
/// and hashers have fast paths for both; conversion is explicit.
#[derive(Debug, Clone)]
pub enum Matrix {
    Dense(Dense),
    Sparse(Csr),
}

impl Matrix {
    pub fn rows(&self) -> usize {
        match self {
            Matrix::Dense(d) => d.rows(),
            Matrix::Sparse(s) => s.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Matrix::Dense(d) => d.cols(),
            Matrix::Sparse(s) => s.cols(),
        }
    }

    pub fn to_dense(&self) -> Dense {
        match self {
            Matrix::Dense(d) => d.clone(),
            Matrix::Sparse(s) => s.to_dense(),
        }
    }

    pub fn to_csr(&self) -> Csr {
        match self {
            Matrix::Dense(d) => Csr::from_dense(d),
            Matrix::Sparse(s) => s.clone(),
        }
    }

    pub fn as_dense(&self) -> Option<&Dense> {
        match self {
            Matrix::Dense(d) => Some(d),
            _ => None,
        }
    }

    pub fn as_csr(&self) -> Option<&Csr> {
        match self {
            Matrix::Sparse(s) => Some(s),
            _ => None,
        }
    }

    /// Copy row `i` into a dense buffer of length `cols`.
    pub fn row_into(&self, i: usize, buf: &mut [f32]) {
        assert_eq!(buf.len(), self.cols());
        match self {
            Matrix::Dense(d) => buf.copy_from_slice(d.row(i)),
            Matrix::Sparse(s) => {
                buf.fill(0.0);
                let r = s.row(i);
                for (&j, &v) in r.indices.iter().zip(r.values) {
                    buf[j as usize] = v;
                }
            }
        }
    }
}

/// A classification dataset with a fixed train/test partition — the unit
/// every experiment driver consumes.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub train_x: Matrix,
    pub train_y: Vec<i32>,
    pub test_x: Matrix,
    pub test_y: Vec<i32>,
}

impl Dataset {
    pub fn n_classes(&self) -> usize {
        let m = self
            .train_y
            .iter()
            .chain(self.test_y.iter())
            .max()
            .copied()
            .unwrap_or(0);
        (m + 1) as usize
    }

    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    pub fn dim(&self) -> usize {
        self.train_x.cols()
    }

    /// Structural sanity: shapes agree, labels contiguous from 0,
    /// features nonnegative (the kernels require it).
    pub fn validate(&self) -> Result<(), String> {
        if self.train_x.rows() != self.train_y.len() {
            return Err("train rows != labels".into());
        }
        if self.test_x.rows() != self.test_y.len() {
            return Err("test rows != labels".into());
        }
        if self.train_x.cols() != self.test_x.cols() {
            return Err("train/test dim mismatch".into());
        }
        let k = self.n_classes();
        let mut seen = vec![false; k];
        for &y in self.train_y.iter().chain(self.test_y.iter()) {
            if y < 0 || y as usize >= k {
                return Err(format!("label {y} out of range"));
            }
            seen[y as usize] = true;
        }
        if !seen.iter().all(|&s| s) {
            return Err("labels not contiguous from 0".into());
        }
        let nonneg = |m: &Matrix| -> bool {
            match m {
                Matrix::Dense(d) => d.data().iter().all(|&v| v >= 0.0 && v.is_finite()),
                Matrix::Sparse(s) => (0..s.rows())
                    .all(|i| s.row(i).values.iter().all(|&v| v >= 0.0 && v.is_finite())),
            }
        };
        if !nonneg(&self.train_x) || !nonneg(&self.test_x) {
            return Err("negative or non-finite feature".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "tiny".into(),
            train_x: Matrix::Dense(Dense::from_rows(&[&[1., 0.], &[0., 1.]])),
            train_y: vec![0, 1],
            test_x: Matrix::Dense(Dense::from_rows(&[&[1., 0.1]])),
            test_y: vec![0],
        }
    }

    #[test]
    fn dataset_validates() {
        let d = tiny();
        d.validate().unwrap();
        assert_eq!(d.n_classes(), 2);
        assert_eq!(d.n_train(), 2);
        assert_eq!(d.n_test(), 1);
        assert_eq!(d.dim(), 2);
    }

    #[test]
    fn validation_catches_negatives() {
        let mut d = tiny();
        d.test_x = Matrix::Dense(Dense::from_rows(&[&[-1., 0.]]));
        assert!(d.validate().is_err());
    }

    #[test]
    fn validation_catches_label_gap() {
        let mut d = tiny();
        d.train_y = vec![0, 2];
        d.test_y = vec![0];
        assert!(d.validate().is_err());
    }

    #[test]
    fn matrix_row_into_matches() {
        let dense = Dense::from_rows(&[&[0., 1., 2.], &[3., 0., 0.]]);
        let m1 = Matrix::Dense(dense.clone());
        let m2 = Matrix::Sparse(Csr::from_dense(&dense));
        let mut b1 = vec![0.0; 3];
        let mut b2 = vec![0.0; 3];
        for i in 0..2 {
            m1.row_into(i, &mut b1);
            m2.row_into(i, &mut b2);
            assert_eq!(b1, b2);
            assert_eq!(b1, dense.row(i));
        }
    }
}
