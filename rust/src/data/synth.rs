//! Seeded synthetic dataset generators — the substitution for the paper's
//! 34 public datasets (no network access in this environment; see
//! DESIGN.md §2).
//!
//! Each generator is an *analog* of one group of the paper's datasets and
//! controls the specific property that drives the paper's Table-1 effect:
//!
//! * multi-modal class structure → nonlinear kernels ≫ linear kernel
//!   (Letter: 62.4% linear vs 96.2% min-max in the paper);
//! * heterogeneous feature magnitudes → min-max (scale-aware) vs
//!   intersection (ℓ₁-normalized, magnitude-blind) gap;
//! * noise/rotation/background image variants → the M-* difficulty
//!   ordering (M-Noise1 hardest … M-Noise6 easiest; M-RotImg worst).
//!
//! All generators are deterministic in `(name, SynthConfig)`.

use super::dense::Dense;
use super::sparse::CsrBuilder;
use super::{Dataset, Matrix};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone, Copy)]
pub struct SynthConfig {
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self { seed: 2015, n_train: 800, n_test: 1200 }
    }
}

impl SynthConfig {
    pub fn with_sizes(seed: u64, n_train: usize, n_test: usize) -> Self {
        Self { seed, n_train, n_test }
    }
}

/// Names of every generator in the suite, in Table-1 (alphabetical-ish)
/// order. `generate(name, cfg)` accepts exactly these.
pub fn all_names() -> &'static [&'static str] {
    &[
        "covertype", "ijcnn", "isolet", "letter", "m-basic", "m-image", "m-noise1", "m-noise3",
        "m-noise6", "m-rand", "m-rotate", "m-rotimg", "optdigits", "pendigits", "phoneme",
        "protein", "rcv1", "satimage", "segment", "sensit", "shuttle", "spam", "splice", "usps",
        "vowel", "webspam", "youtube",
    ]
}

/// A compact subset used by the faster drivers/benches.
pub fn core_names() -> &'static [&'static str] {
    &["letter", "m-basic", "m-rotate", "covertype", "rcv1", "satimage", "vowel", "splice"]
}

/// Generate a named dataset.
pub fn generate(name: &str, cfg: SynthConfig) -> Result<Dataset, String> {
    // Per-dataset seed derived from the experiment seed so datasets are
    // independent but the whole suite is reproducible from one number.
    let seed = cfg.seed ^ fnv(name);
    let d = match name {
        "letter" => gaussian_modes(name, cfg, seed, GaussianSpec {
            dim: 16,
            classes: 26,
            modes: 3,
            scale_spread: 1.0,
            noise: 0.50,
            proto_sparsity: 0.25,
        }),
        "vowel" => gaussian_modes(name, cfg, seed, GaussianSpec {
            dim: 10,
            classes: 11,
            modes: 2,
            scale_spread: 0.7,
            noise: 0.55,
            proto_sparsity: 0.0,
        }),
        "isolet" => gaussian_modes(name, cfg, seed, GaussianSpec {
            dim: 64,
            classes: 26,
            modes: 2,
            scale_spread: 0.5,
            noise: 0.55,
            proto_sparsity: 0.1,
        }),
        "youtube" => gaussian_modes(name, cfg, seed, GaussianSpec {
            dim: 64,
            classes: 10,
            modes: 3,
            scale_spread: 1.2,
            noise: 0.55,
            proto_sparsity: 0.45,
        }),
        "segment" => gaussian_modes(name, cfg, seed, GaussianSpec {
            dim: 19,
            classes: 7,
            modes: 2,
            scale_spread: 1.6,
            noise: 0.40,
            proto_sparsity: 0.1,
        }),
        "m-basic" => digits(name, cfg, seed, DigitSpec::basic()),
        "m-noise1" => digits(name, cfg, seed, DigitSpec::noise(1)),
        "m-noise3" => digits(name, cfg, seed, DigitSpec::noise(3)),
        "m-noise6" => digits(name, cfg, seed, DigitSpec::noise(6)),
        "m-rotate" => digits(name, cfg, seed, DigitSpec { rotate_full: true, ..DigitSpec::basic() }),
        "m-image" => digits(name, cfg, seed, DigitSpec { background: Background::Texture, ..DigitSpec::basic() }),
        "m-rand" => digits(name, cfg, seed, DigitSpec { background: Background::Random, ..DigitSpec::basic() }),
        "m-rotimg" => digits(name, cfg, seed, DigitSpec {
            rotate_full: true,
            background: Background::Texture,
            ..DigitSpec::basic()
        }),
        "usps" => digits(name, cfg, seed, DigitSpec { canvas: 12, ..DigitSpec::basic() }),
        "optdigits" => digits(name, cfg, seed, DigitSpec { canvas: 8, ..DigitSpec::basic() }),
        "pendigits" => pendigits(name, cfg, seed),
        "covertype" => covertype(name, cfg, seed),
        "shuttle" => shuttle(name, cfg, seed),
        "ijcnn" => waveform(name, cfg, seed, 2, 24, 0.35),
        "phoneme" => waveform(name, cfg, seed, 2, 33, 0.55),
        "sensit" => waveform(name, cfg, seed, 3, 50, 0.75),
        "satimage" => satimage(name, cfg, seed),
        "protein" => dirichlet(name, cfg, seed, 3, 60, 2.2),
        "rcv1" => text(name, cfg, seed, TextSpec { classes: 4, vocab: 2000, topic_words: 60, boost: 1.6, doc_len: 70 }),
        "webspam" => text(name, cfg, seed, TextSpec { classes: 2, vocab: 1500, topic_words: 40, boost: 2.2, doc_len: 90 }),
        "spam" => text(name, cfg, seed, TextSpec { classes: 2, vocab: 600, topic_words: 30, boost: 1.8, doc_len: 50 }),
        "splice" => splice(name, cfg, seed),
        other => return Err(format!("unknown synthetic dataset '{other}' (see all_names())")),
    };
    d.validate().map_err(|e| format!("{name}: generated dataset invalid: {e}"))?;
    Ok(d)
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn split(name: &str, cfg: SynthConfig, all_x: Dense, all_y: Vec<i32>) -> Dataset {
    let n = all_y.len();
    let n_train = cfg.n_train.min(n - 1);
    let idx_train: Vec<usize> = (0..n_train).collect();
    let idx_test: Vec<usize> = (n_train..n).collect();
    Dataset {
        name: name.to_string(),
        train_x: Matrix::Dense(all_x.select_rows(&idx_train)),
        train_y: idx_train.iter().map(|&i| all_y[i]).collect(),
        test_x: Matrix::Dense(all_x.select_rows(&idx_test)),
        test_y: idx_test.iter().map(|&i| all_y[i]).collect(),
    }
}

/// Draw labels round-robin then shuffle sample order, so both splits see
/// every class (paired with `split` above).
fn shuffled_labels(rng: &mut Pcg64, n: usize, classes: usize) -> Vec<i32> {
    let mut y: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
    rng.shuffle(&mut y);
    y
}

// ------------------------------------------------------ gaussian modes

struct GaussianSpec {
    dim: usize,
    classes: usize,
    /// Modes per class: >1 makes the classes non-linearly-separable.
    modes: usize,
    /// Spread of per-mode overall magnitude (lognormal σ). Nonzero makes
    /// total mass class-informative — the signal ℓ₁ normalization throws
    /// away, i.e. the min-max vs intersection gap.
    scale_spread: f64,
    /// Relative noise level around the mode prototype.
    noise: f64,
    /// Fraction of prototype entries forced to (near) zero.
    proto_sparsity: f64,
}

fn gaussian_modes(name: &str, cfg: SynthConfig, seed: u64, spec: GaussianSpec) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 1);
    let n = cfg.n_train + cfg.n_test;
    // Prototypes: classes × modes × dim, lognormal entries with a
    // per-mode magnitude factor.
    let mut protos = vec![0.0f64; spec.classes * spec.modes * spec.dim];
    let mut mode_scale = vec![1.0f64; spec.classes * spec.modes];
    for c in 0..spec.classes {
        for m in 0..spec.modes {
            let s = rng.lognormal(0.0, spec.scale_spread);
            mode_scale[c * spec.modes + m] = s;
            for d in 0..spec.dim {
                let v = if rng.uniform() < spec.proto_sparsity {
                    0.02 * rng.uniform()
                } else {
                    rng.lognormal(0.0, 0.9)
                };
                protos[(c * spec.modes + m) * spec.dim + d] = v * s;
            }
        }
    }
    let y = shuffled_labels(&mut rng, n, spec.classes);
    let mut x = Dense::zeros(n, spec.dim);
    for i in 0..n {
        let c = y[i] as usize;
        let m = rng.below(spec.modes as u64) as usize;
        let base = (c * spec.modes + m) * spec.dim;
        let row = x.row_mut(i);
        for d in 0..spec.dim {
            let p = protos[base + d];
            // Multiplicative lognormal jitter + small additive floor noise.
            let v = p * rng.lognormal(0.0, spec.noise) + 0.05 * rng.exp1() * spec.noise;
            row[d] = v.max(0.0) as f32;
        }
    }
    split(name, cfg, x, y)
}

// --------------------------------------------------------------- digits

/// 8×8 glyph templates for digits 0–9 ('#' = ink).
const GLYPHS: [&str; 10] = [
    ".####...#..#...#..#...#..#...#..#...#..#...#..#...####..", // 0 (7 rows x 8? see note)
    "...#.....##.....#.....#.....#.....#.....#....###...",     // 1
    ".####...#..#......#.....#.....#.....#....#.....####.",    // 2
    ".####..#...#.....#...###......#.#...#..#...#..####..",    // 3
    "..#.#...#.#...#..#..#..#..#####.....#.....#.....#...",    // 4
    ".#####..#.....#.....####......#......#.#...#..###...",    // 5
    "..###...#.....#.....####...#..#..#..#..#..#...##....",    // 6
    ".#####......#.....#....#....#....#.....#.....#......",    // 7
    "..###...#..#..#..#...##...#..#..#..#..#..#....##....",    // 8
    "..###...#..#..#..#...###......#.....#....#...##.....", // 9
];

/// Parse a glyph into an 8×8 intensity grid. The string art above is
/// free-form; we lay it out row-major over 8 columns and pad/truncate —
/// exact artistic fidelity is irrelevant, distinctness of the 10 classes
/// is what matters (verified by a test on pairwise template distance).
fn glyph_grid(digit: usize) -> [[f32; 8]; 8] {
    let mut g = [[0.0f32; 8]; 8];
    let chars: Vec<char> = GLYPHS[digit].chars().collect();
    for r in 0..8 {
        for c in 0..8 {
            let idx = r * 8 + c;
            if idx < chars.len() && chars[idx] == '#' {
                g[r][c] = 1.0;
            }
        }
    }
    g
}

#[derive(Clone, Copy)]
enum Background {
    None,
    /// Smooth low-frequency texture (M-Image analog).
    Texture,
    /// Per-pixel uniform noise (M-Rand analog).
    Random,
}

#[derive(Clone, Copy)]
struct DigitSpec {
    canvas: usize,
    rotate_full: bool,
    /// Additive pixel-noise amplitude.
    noise_amp: f32,
    background: Background,
}

impl DigitSpec {
    fn basic() -> Self {
        Self { canvas: 12, rotate_full: false, noise_amp: 0.22, background: Background::None }
    }

    /// M-NoiseX analog: the paper's level 1 is the *hardest* (most
    /// noise), level 6 the easiest.
    fn noise(level: usize) -> Self {
        let amp = 0.65 - 0.09 * (level as f32 - 1.0);
        Self { noise_amp: amp, ..Self::basic() }
    }
}

/// Render one digit sample with random affine jitter (+ optional full
/// rotation and background), bilinear-sampling the 8×8 glyph.
fn render_digit(rng: &mut Pcg64, digit: usize, spec: &DigitSpec) -> Vec<f32> {
    let g = glyph_grid(digit);
    let n = spec.canvas;
    let angle = if spec.rotate_full {
        rng.uniform() * std::f64::consts::TAU
    } else {
        (rng.uniform() - 0.5) * 0.55 // ±16 deg
    };
    let scale = 0.72 + 0.56 * rng.uniform();
    let dx = (rng.uniform() - 0.5) * 3.4;
    let dy = (rng.uniform() - 0.5) * 3.4;
    let (sin, cos) = angle.sin_cos();
    let cn = (n as f64 - 1.0) / 2.0;
    let cg = 3.5; // center of the 8x8 glyph
    let mut out = vec![0.0f32; n * n];
    for r in 0..n {
        for c in 0..n {
            // Output pixel -> centered coords -> inverse transform ->
            // glyph coords.
            let xo = c as f64 - cn - dx;
            let yo = r as f64 - cn - dy;
            let xi = (cos * xo + sin * yo) / scale * (8.0 / n as f64) + cg;
            let yi = (-sin * xo + cos * yo) / scale * (8.0 / n as f64) + cg;
            out[r * n + c] = bilinear(&g, xi, yi);
        }
    }
    // Background + noise, clamped to [0, 1].
    match spec.background {
        Background::None => {}
        Background::Random => {
            for v in &mut out {
                let b = rng.uniform_f32();
                *v = v.max(b * 0.9);
            }
        }
        Background::Texture => {
            // Sum of two random low-frequency plane waves.
            let (f1, f2) = (0.3 + rng.uniform(), 0.3 + rng.uniform());
            let (p1, p2) = (rng.uniform() * 6.28, rng.uniform() * 6.28);
            let (a1, a2) = (rng.uniform(), rng.uniform());
            for r in 0..n {
                for c in 0..n {
                    let t = 0.4
                        * ((f1 * r as f64 + p1).sin() * a1 + (f2 * c as f64 + p2).sin() * a2)
                            .abs() as f32;
                    let v = &mut out[r * n + c];
                    *v = v.max(t.min(0.95));
                }
            }
        }
    }
    if spec.noise_amp > 0.0 {
        for v in &mut out {
            *v = (*v + spec.noise_amp * rng.uniform_f32()).clamp(0.0, 1.0);
        }
    }
    out
}

#[inline]
fn bilinear(g: &[[f32; 8]; 8], x: f64, y: f64) -> f32 {
    if !(-1.0..8.0).contains(&x) || !(-1.0..8.0).contains(&y) {
        return 0.0;
    }
    let x0 = x.floor();
    let y0 = y.floor();
    let fx = (x - x0) as f32;
    let fy = (y - y0) as f32;
    let sample = |xx: i64, yy: i64| -> f32 {
        if (0..8).contains(&xx) && (0..8).contains(&yy) {
            g[yy as usize][xx as usize]
        } else {
            0.0
        }
    };
    let (x0, y0) = (x0 as i64, y0 as i64);
    sample(x0, y0) * (1.0 - fx) * (1.0 - fy)
        + sample(x0 + 1, y0) * fx * (1.0 - fy)
        + sample(x0, y0 + 1) * (1.0 - fx) * fy
        + sample(x0 + 1, y0 + 1) * fx * fy
}

fn digits(name: &str, cfg: SynthConfig, seed: u64, spec: DigitSpec) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 2);
    let n = cfg.n_train + cfg.n_test;
    let y = shuffled_labels(&mut rng, n, 10);
    let dim = spec.canvas * spec.canvas;
    let mut x = Dense::zeros(n, dim);
    for i in 0..n {
        let img = render_digit(&mut rng, y[i] as usize, &spec);
        x.row_mut(i).copy_from_slice(&img);
    }
    split(name, cfg, x, y)
}

/// Pendigits analog: pen trajectories — 8 (x, y) resampled points along a
/// noisy parametric curve per class.
fn pendigits(name: &str, cfg: SynthConfig, seed: u64) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 3);
    let n = cfg.n_train + cfg.n_test;
    let y = shuffled_labels(&mut rng, n, 10);
    let mut x = Dense::zeros(n, 16);
    for i in 0..n {
        let c = y[i] as usize as f64;
        let row = x.row_mut(i);
        let phase = rng.uniform() * 0.4;
        let wob = 0.25 + 0.1 * rng.uniform();
        for p in 0..8 {
            let t = p as f64 / 7.0;
            // Class-specific Lissajous-ish stroke in [0,1]^2.
            let fx = (1.0 + (c % 5.0)) * 0.9;
            let fy = (1.0 + (c / 2.0).floor() % 4.0) * 1.1;
            let px = 0.5 + 0.45 * (fx * t * 3.14 + phase + 0.7 * c).sin();
            let py = 0.5 + 0.45 * (fy * t * 3.14 + 1.3 * c).cos();
            row[2 * p] = ((px + wob * (rng.uniform() - 0.5) * 0.3).clamp(0.0, 1.0) * 100.0) as f32;
            row[2 * p + 1] =
                ((py + wob * (rng.uniform() - 0.5) * 0.3).clamp(0.0, 1.0) * 100.0) as f32;
        }
    }
    split(name, cfg, x, y)
}

// ------------------------------------------------------------ covertype

/// Covertype analog: 10 heavy-tailed quantitative features with very
/// different natural scales + 8 one-hot-ish binary indicators; 7 classes
/// with overlapping multi-modal structure.
fn covertype(name: &str, cfg: SynthConfig, seed: u64) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 4);
    let classes = 7;
    let n = cfg.n_train + cfg.n_test;
    let y = shuffled_labels(&mut rng, n, classes);
    let dim = 18;
    // Per-class, per-mode parameters for the quantitative block.
    let modes = 2;
    let scales = [2600.0, 150.0, 20.0, 300.0, 60.0, 2300.0, 220.0, 230.0, 150.0, 6200.0];
    let mut centers = vec![0.0f64; classes * modes * 10];
    for v in centers.iter_mut() {
        *v = 0.3 + rng.uniform();
    }
    let mut x = Dense::zeros(n, dim);
    for i in 0..n {
        let c = y[i] as usize;
        let m = rng.below(modes as u64) as usize;
        let row = x.row_mut(i);
        for d in 0..10 {
            let center = centers[(c * modes + m) * 10 + d];
            let v = scales[d] * center * rng.lognormal(0.0, 0.25);
            row[d] = v.max(0.0) as f32;
        }
        // Binary block: indicator pattern correlated with (class, mode).
        for d in 0..8 {
            let p = if (c + m + d) % 8 < 3 { 0.8 } else { 0.1 };
            row[10 + d] = if rng.uniform() < p { 1.0 } else { 0.0 };
        }
    }
    split(name, cfg, x, y)
}

/// Shuttle analog: 9 dims, 7 classes, heavy class imbalance (~78% class 0).
fn shuttle(name: &str, cfg: SynthConfig, seed: u64) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 5);
    let classes = 7;
    let n = cfg.n_train + cfg.n_test;
    // Imbalanced label draw, then force the first `classes` positions to
    // cover all labels so validate() sees contiguous classes in train.
    let weights = [0.78, 0.08, 0.05, 0.04, 0.02, 0.02, 0.01];
    let cdf: Vec<f64> = weights
        .iter()
        .scan(0.0, |acc, w| {
            *acc += w;
            Some(*acc)
        })
        .collect();
    let mut y: Vec<i32> = (0..n).map(|_| rng.discrete_cdf(&cdf) as i32).collect();
    rng.shuffle(&mut y);
    // Force every class into both splits (rare classes could otherwise
    // miss one side entirely under this imbalance).
    let n_train = cfg.n_train.min(n - 1);
    for c in 0..classes {
        y[c] = c as i32;
        y[(n_train + c).min(n - 1)] = c as i32;
    }
    let mut protos = vec![0.0f64; classes * 9];
    for v in protos.iter_mut() {
        *v = rng.lognormal(1.0, 0.8);
    }
    let mut x = Dense::zeros(n, 9);
    for i in 0..n {
        let c = y[i] as usize;
        let row = x.row_mut(i);
        for d in 0..9 {
            row[d] = (protos[c * 9 + d] * rng.lognormal(0.0, 0.2)).max(0.0) as f32;
        }
    }
    split(name, cfg, x, y)
}

/// Waveform analog (IJCNN / Phoneme / SensIT): class-specific harmonic
/// stacks + noise, shifted nonnegative.
fn waveform(name: &str, cfg: SynthConfig, seed: u64, classes: usize, dim: usize, noise: f64) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 6);
    let n = cfg.n_train + cfg.n_test;
    let y = shuffled_labels(&mut rng, n, classes);
    // Each class: 2 modes of (freq, phase, amplitude) triples.
    let modes = 2;
    let mut params = Vec::new();
    for _ in 0..classes * modes {
        params.push((
            0.8 + 2.0 * rng.uniform(),
            rng.uniform() * 6.28,
            0.6 + 0.8 * rng.uniform(),
            1.8 + 3.0 * rng.uniform(), // second harmonic freq
            rng.uniform() * 6.28,
        ));
    }
    let mut x = Dense::zeros(n, dim);
    for i in 0..n {
        let c = y[i] as usize;
        let m = rng.below(modes as u64) as usize;
        let (f1, p1, a1, f2, p2) = params[c * modes + m];
        let jitter = rng.uniform() * 0.5;
        let row = x.row_mut(i);
        for d in 0..dim {
            let t = d as f64 / dim as f64 * 6.28;
            let v = 1.2
                + a1 * (f1 * t + p1 + jitter).sin()
                + 0.5 * (f2 * t + p2).sin()
                + noise * rng.normal();
            row[d] = v.max(0.0) as f32;
        }
    }
    split(name, cfg, x, y)
}

/// Satimage analog: 4 spectral bands × 9 pixels; class = land type with
/// band signature; neighboring pixels correlated.
fn satimage(name: &str, cfg: SynthConfig, seed: u64) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 7);
    let classes = 6;
    let n = cfg.n_train + cfg.n_test;
    let y = shuffled_labels(&mut rng, n, classes);
    // Two modes (sub-land-types) per class: the nonlinearity that gives
    // nonlinear kernels their satimage edge in the paper.
    let modes = 2;
    let mut sig = vec![0.0f64; classes * modes * 4];
    for v in sig.iter_mut() {
        *v = 40.0 + 85.0 * rng.uniform();
    }
    let mut x = Dense::zeros(n, 36);
    for i in 0..n {
        let c = y[i] as usize;
        let m = rng.below(modes as u64) as usize;
        let row = x.row_mut(i);
        // Patch-level lighting factor (correlates all 36 dims).
        let light = rng.lognormal(0.0, 0.30);
        for band in 0..4 {
            let mu = sig[(c * modes + m) * 4 + band] * light;
            let mut px = mu + 14.0 * rng.normal();
            for pix in 0..9 {
                // AR(1) across the 3x3 patch.
                px = 0.7 * px + 0.3 * (mu + 14.0 * rng.normal());
                row[band * 9 + pix] = px.max(0.0) as f32;
            }
        }
    }
    split(name, cfg, x, y)
}

/// Protein analog: composition histograms from per-class Dirichlet
/// (sampled as normalized Gammas), heavily overlapping → low accuracy.
fn dirichlet(name: &str, cfg: SynthConfig, seed: u64, classes: usize, dim: usize, conc: f64) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 8);
    let n = cfg.n_train + cfg.n_test;
    let y = shuffled_labels(&mut rng, n, classes);
    // Class base measures.
    let mut alpha = vec![0.0f64; classes * dim];
    for v in alpha.iter_mut() {
        *v = 0.2 + rng.exp1();
    }
    let mut x = Dense::zeros(n, dim);
    for i in 0..n {
        let c = y[i] as usize;
        let row = x.row_mut(i);
        let mut total = 0.0f64;
        for d in 0..dim {
            let g = rng.gamma(conc * alpha[c * dim + d] / dim as f64 * 8.0 + 0.05);
            row[d] = g as f32;
            total += g;
        }
        // Scale to a heavy-tailed "sequence length" so magnitudes carry
        // information (min-max vs intersection separation).
        let len = rng.lognormal(4.0, 0.5);
        let f = (len / total.max(1e-9)) as f32;
        for v in row {
            *v *= f;
        }
    }
    split(name, cfg, x, y)
}

// ----------------------------------------------------------------- text

struct TextSpec {
    classes: usize,
    vocab: usize,
    topic_words: usize,
    boost: f64,
    doc_len: usize,
}

/// Sparse bag-of-words: Zipfian background + boosted class topic words.
/// Produces a sparse dataset (the RCV1/Webspam/Spam analog).
fn text(name: &str, cfg: SynthConfig, seed: u64, spec: TextSpec) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 9);
    let n = cfg.n_train + cfg.n_test;
    let y = shuffled_labels(&mut rng, n, spec.classes);
    // Topic words per class: distinct ranges plus shared noise words.
    let mut topic: Vec<Vec<u32>> = Vec::new();
    for c in 0..spec.classes {
        let mut words = Vec::with_capacity(spec.topic_words);
        for t in 0..spec.topic_words {
            // Spread topics over the vocabulary, deterministic per class.
            words.push(((c * 131 + t * 17 + 7) % spec.vocab) as u32);
        }
        topic.push(words);
    }
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n);
    for yi in y.iter().take(n) {
        let c = *yi as usize;
        let len = (spec.doc_len as f64 * (0.5 + rng.uniform())) as usize + 5;
        let mut counts: std::collections::HashMap<u32, f32> = std::collections::HashMap::new();
        for _ in 0..len {
            let w = if rng.uniform() < spec.boost / (spec.boost + 10.0) {
                // topic word
                *topic[c].as_slice().get(rng.below(spec.topic_words as u64) as usize).unwrap()
            } else {
                (rng.zipf(spec.vocab as u64, 1.15) - 1) as u32
            };
            *counts.entry(w).or_insert(0.0) += 1.0;
        }
        rows.push(counts.into_iter().collect());
    }
    let mut b = CsrBuilder::new(spec.vocab);
    for r in rows {
        b.push_row(r);
    }
    let all = b.finish();
    let n_train = cfg.n_train.min(n - 1);
    let idx_train: Vec<usize> = (0..n_train).collect();
    let idx_test: Vec<usize> = (n_train..n).collect();
    Dataset {
        name: name.to_string(),
        train_x: Matrix::Sparse(all.select_rows(&idx_train)),
        train_y: idx_train.iter().map(|&i| y[i]).collect(),
        test_x: Matrix::Sparse(all.select_rows(&idx_test)),
        test_y: idx_test.iter().map(|&i| y[i]).collect(),
    }
}

/// Splice analog: 60 DNA positions one-hot over {A,C,G,T} (240 binary
/// dims); 2 classes distinguished by noisy motifs around the center —
/// binary data, where min-max reduces to resemblance.
fn splice(name: &str, cfg: SynthConfig, seed: u64) -> Dataset {
    let mut rng = Pcg64::new_stream(seed, 10);
    let n = cfg.n_train + cfg.n_test;
    let y = shuffled_labels(&mut rng, n, 2);
    let positions = 60;
    let mut x = Dense::zeros(n, positions * 4);
    // Class motifs: preferred base per position with per-position fidelity.
    let mut motif = vec![0u8; 2 * positions];
    let mut fidelity = vec![0.25f64; 2 * positions];
    for c in 0..2 {
        for p in 0..positions {
            motif[c * positions + p] = rng.below(4) as u8;
            // Strong signal only near the "splice site" (center).
            let dist = (p as i64 - 30).unsigned_abs() as f64;
            fidelity[c * positions + p] = 0.22 + 0.34 * (-dist / 4.5).exp();
        }
    }
    for i in 0..n {
        let c = y[i] as usize;
        let row = x.row_mut(i);
        for p in 0..positions {
            let base = if rng.uniform() < fidelity[c * positions + p] {
                motif[c * positions + p]
            } else {
                rng.below(4) as u8
            };
            row[p * 4 + base as usize] = 1.0;
        }
    }
    split(name, cfg, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_named_dataset_generates_and_validates() {
        let cfg = SynthConfig { seed: 1, n_train: 60, n_test: 90 };
        for name in all_names() {
            let d = generate(name, cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(d.name, *name);
            assert!(d.n_train() > 0 && d.n_test() > 0, "{name} sizes");
            assert!(d.n_classes() >= 2, "{name} classes");
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(generate("not-a-dataset", SynthConfig::default()).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SynthConfig { seed: 9, n_train: 40, n_test: 40 };
        let a = generate("letter", cfg).unwrap();
        let b = generate("letter", cfg).unwrap();
        assert_eq!(a.train_y, b.train_y);
        assert_eq!(a.train_x.to_dense(), b.train_x.to_dense());
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate("letter", SynthConfig { seed: 1, n_train: 40, n_test: 40 }).unwrap();
        let b = generate("letter", SynthConfig { seed: 2, n_train: 40, n_test: 40 }).unwrap();
        assert_ne!(a.train_x.to_dense(), b.train_x.to_dense());
    }

    #[test]
    fn glyph_templates_are_distinct() {
        // Pairwise L1 distance between digit templates must be well away
        // from zero, otherwise the digit datasets are degenerate.
        for a in 0..10 {
            for b in (a + 1)..10 {
                let ga = glyph_grid(a);
                let gb = glyph_grid(b);
                let dist: f32 = (0..8)
                    .flat_map(|r| (0..8).map(move |c| (r, c)))
                    .map(|(r, c)| (ga[r][c] - gb[r][c]).abs())
                    .sum();
                assert!(dist >= 4.0, "glyphs {a} and {b} too similar ({dist})");
            }
        }
    }

    #[test]
    fn rotation_variant_scrambles_pixels() {
        let cfg = SynthConfig { seed: 3, n_train: 30, n_test: 30 };
        let basic = generate("m-basic", cfg).unwrap();
        let rot = generate("m-rotate", cfg).unwrap();
        // Same shapes, different content.
        assert_eq!(basic.dim(), rot.dim());
        assert_ne!(basic.train_x.to_dense(), rot.train_x.to_dense());
    }

    #[test]
    fn noise_levels_order_by_amplitude() {
        // Hardest (noise1) must have strictly more background energy than
        // easiest (noise6).
        let cfg = SynthConfig { seed: 4, n_train: 50, n_test: 10 };
        let energy = |name: &str| -> f64 {
            let d = generate(name, cfg).unwrap();
            let m = d.train_x.to_dense();
            m.data().iter().map(|&v| v as f64).sum::<f64>() / m.data().len() as f64
        };
        assert!(energy("m-noise1") > energy("m-noise6"));
    }

    #[test]
    fn text_is_sparse() {
        let d = generate("rcv1", SynthConfig { seed: 5, n_train: 50, n_test: 50 }).unwrap();
        let csr = d.train_x.as_csr().expect("text should be CSR");
        let density = csr.nnz() as f64 / (csr.rows() * csr.cols()) as f64;
        assert!(density < 0.1, "density {density}");
        csr.check_invariants().unwrap();
    }

    #[test]
    fn shuttle_is_imbalanced() {
        let d = generate("shuttle", SynthConfig { seed: 6, n_train: 400, n_test: 400 }).unwrap();
        let frac0 = d.train_y.iter().filter(|&&y| y == 0).count() as f64 / d.n_train() as f64;
        assert!(frac0 > 0.5, "class 0 fraction {frac0}");
    }

    #[test]
    fn splice_is_binary() {
        let d = generate("splice", SynthConfig { seed: 7, n_train: 30, n_test: 30 }).unwrap();
        let m = d.train_x.to_dense();
        assert!(m.data().iter().all(|&v| v == 0.0 || v == 1.0));
        // Exactly one base set per position.
        for row in m.iter_rows() {
            let ones: f32 = row.iter().sum();
            assert_eq!(ones, 60.0);
        }
    }

    #[test]
    fn all_classes_in_train_split() {
        let cfg = SynthConfig { seed: 8, n_train: 120, n_test: 120 };
        for name in ["letter", "vowel", "covertype", "shuttle"] {
            let d = generate(name, cfg).unwrap();
            let k = d.n_classes();
            let mut seen = vec![false; k];
            for &y in &d.train_y {
                seen[y as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "{name}: train split missing a class");
        }
    }
}
