//! LIBSVM text-format IO (`<label> <index>:<value> ...`, 1-based indices)
//! — the interchange format for every dataset the paper uses, so users
//! can run the pipeline on the real files when they have them.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use super::sparse::{Csr, CsrBuilder};

#[derive(Debug)]
pub struct LibsvmData {
    pub features: Csr,
    pub labels: Vec<i32>,
}

/// Parse LIBSVM text from a reader. `min_cols` lets callers force a
/// dimensionality (e.g. to align train/test); the result has
/// `cols = max(max_index, min_cols)`.
pub fn read_from<R: BufRead>(reader: R, min_cols: usize) -> Result<LibsvmData, String> {
    let mut rows: Vec<(i32, Vec<(u32, f32)>)> = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().ok_or_else(|| format!("line {}: empty", lineno + 1))?;
        // Accept "1", "+1", "-1", "2.0" style labels.
        let label = label_tok
            .trim_start_matches('+')
            .parse::<f64>()
            .map_err(|e| format!("line {}: bad label '{label_tok}': {e}", lineno + 1))?
            as i32;
        let mut entries = Vec::new();
        for tok in parts {
            let (idx_s, val_s) = tok
                .split_once(':')
                .ok_or_else(|| format!("line {}: bad pair '{tok}'", lineno + 1))?;
            let idx: usize = idx_s
                .parse()
                .map_err(|e| format!("line {}: bad index '{idx_s}': {e}", lineno + 1))?;
            if idx == 0 {
                return Err(format!("line {}: LIBSVM indices are 1-based", lineno + 1));
            }
            let val: f32 = val_s
                .parse()
                .map_err(|e| format!("line {}: bad value '{val_s}': {e}", lineno + 1))?;
            max_col = max_col.max(idx);
            entries.push(((idx - 1) as u32, val));
        }
        rows.push((label, entries));
    }
    let cols = max_col.max(min_cols);
    let mut b = CsrBuilder::new(cols.max(1));
    let mut labels = Vec::with_capacity(rows.len());
    for (label, entries) in rows {
        labels.push(label);
        b.push_row(entries);
    }
    Ok(LibsvmData { features: b.finish(), labels })
}

pub fn read_file(path: &Path, min_cols: usize) -> Result<LibsvmData, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    read_from(BufReader::new(f), min_cols)
}

/// Write rows in LIBSVM format (1-based indices, zeros omitted).
pub fn write_to<W: Write>(mut w: W, data: &Csr, labels: &[i32]) -> std::io::Result<()> {
    assert_eq!(data.rows(), labels.len());
    for i in 0..data.rows() {
        let row = data.row(i);
        write!(w, "{}", labels[i])?;
        for (&j, &v) in row.indices.iter().zip(row.values) {
            write!(w, " {}:{}", j + 1, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

pub fn write_file(path: &Path, data: &Csr, labels: &[i32]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path)?;
    write_to(BufWriter::new(f), data, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:2\n-1 2:1.5\n# comment\n\n2 1:1 2:1 3:1\n";
        let d = read_from(text.as_bytes(), 0).unwrap();
        assert_eq!(d.labels, vec![1, -1, 2]);
        assert_eq!(d.features.rows(), 3);
        assert_eq!(d.features.cols(), 3);
        assert_eq!(d.features.row(0).indices, &[0, 2]);
        assert_eq!(d.features.row(0).values, &[0.5, 2.0]);
    }

    #[test]
    fn roundtrip() {
        let text = "1 1:0.25 5:4\n3 2:1\n";
        let d = read_from(text.as_bytes(), 0).unwrap();
        let mut buf = Vec::new();
        write_to(&mut buf, &d.features, &d.labels).unwrap();
        let d2 = read_from(buf.as_slice(), d.features.cols()).unwrap();
        assert_eq!(d2.labels, d.labels);
        assert_eq!(d2.features, d.features);
    }

    #[test]
    fn min_cols_respected() {
        let d = read_from("1 1:1\n".as_bytes(), 10).unwrap();
        assert_eq!(d.features.cols(), 10);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(read_from("1 0:1\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_from("abc 1:1\n".as_bytes(), 0).is_err());
        assert!(read_from("1 nocolon\n".as_bytes(), 0).is_err());
        assert!(read_from("1 1:xyz\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("minmax_libsvm_test");
        let path = dir.join("t.svm");
        let d = read_from("1 1:1 2:2\n-1 3:3\n".as_bytes(), 0).unwrap();
        write_file(&path, &d.features, &d.labels).unwrap();
        let d2 = read_file(&path, 0).unwrap();
        assert_eq!(d2.labels, d.labels);
        assert_eq!(d2.features, d.features);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
