//! CSR sparse matrix with sorted column indices per row.
//!
//! The paper's data regime (word vectors, tf-idf text) is sparse; all
//! kernels have merge-based sparse fast paths that only touch nonzeros,
//! and the hashed one-hot features produced by 0-bit CWS are `k`
//! nonzeros per row by construction.

use super::dense::Dense;

#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

/// One sparse row: parallel (indices, values), indices strictly increasing.
#[derive(Debug, Clone, Copy)]
pub struct SparseRow<'a> {
    pub indices: &'a [u32],
    pub values: &'a [f32],
}

impl<'a> SparseRow<'a> {
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn l1_norm(&self) -> f64 {
        self.values.iter().map(|&v| v.abs() as f64).sum()
    }

    pub fn l2_norm(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().map(|&v| v as f64).sum()
    }
}

pub struct CsrBuilder {
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrBuilder {
    pub fn new(cols: usize) -> Self {
        Self { cols, indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Push a row given (index, value) pairs; they are sorted and
    /// deduplicated (last wins), zeros dropped.
    pub fn push_row(&mut self, mut entries: Vec<(u32, f32)>) {
        entries.sort_by_key(|e| e.0);
        let mut last: Option<u32> = None;
        for (i, v) in entries {
            assert!((i as usize) < self.cols, "column {i} out of bounds (cols={})", self.cols);
            if v == 0.0 {
                continue;
            }
            if last == Some(i) {
                *self.values.last_mut().unwrap() = v;
            } else {
                self.indices.push(i);
                self.values.push(v);
                last = Some(i);
            }
        }
        self.indptr.push(self.indices.len());
    }

    /// Push a row that is already sorted, strictly increasing, zero-free.
    pub fn push_sorted_row(&mut self, indices: &[u32], values: &[f32]) {
        assert_eq!(indices.len(), values.len());
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices must be sorted");
        debug_assert!(indices.iter().all(|&i| (i as usize) < self.cols));
        self.indices.extend_from_slice(indices);
        self.values.extend_from_slice(values);
        self.indptr.push(self.indices.len());
    }

    pub fn finish(self) -> Csr {
        Csr { cols: self.cols, indptr: self.indptr, indices: self.indices, values: self.values }
    }
}

impl Csr {
    pub fn rows(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    #[inline]
    pub fn row(&self, i: usize) -> SparseRow<'_> {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        SparseRow { indices: &self.indices[s..e], values: &self.values[s..e] }
    }

    pub fn iter_rows(&self) -> impl Iterator<Item = SparseRow<'_>> + '_ {
        (0..self.rows()).map(move |i| self.row(i))
    }

    pub fn from_dense(d: &Dense) -> Csr {
        let mut b = CsrBuilder::new(d.cols());
        for row in d.iter_rows() {
            let entries: Vec<(u32, f32)> = row
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(j, &v)| (j as u32, v))
                .collect();
            b.push_row(entries);
        }
        b.finish()
    }

    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.rows(), self.cols);
        for i in 0..self.rows() {
            let r = self.row(i);
            let out = d.row_mut(i);
            for (&j, &v) in r.indices.iter().zip(r.values) {
                out[j as usize] = v;
            }
        }
        d
    }

    /// Apply `f` to every stored value in place (sparsity structure is
    /// unchanged — indices and indptr stay as they are).
    pub fn map_values(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.values {
            *v = f(*v);
        }
    }

    /// Scale each row's values in place (used by normalization).
    pub fn scale_rows(&mut self, factors: &[f32]) {
        assert_eq!(factors.len(), self.rows());
        for i in 0..self.rows() {
            let (s, e) = (self.indptr[i], self.indptr[i + 1]);
            for v in &mut self.values[s..e] {
                *v *= factors[i];
            }
        }
    }

    pub fn select_rows(&self, idx: &[usize]) -> Csr {
        let mut b = CsrBuilder::new(self.cols);
        for &i in idx {
            let r = self.row(i);
            b.push_sorted_row(r.indices, r.values);
        }
        b.finish()
    }

    /// Validate structural invariants (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.indptr.first() != Some(&0) || self.indptr.last() != Some(&self.indices.len()) {
            return Err("indptr endpoints".into());
        }
        if self.indices.len() != self.values.len() {
            return Err("indices/values length".into());
        }
        if self.indptr.windows(2).any(|w| w[0] > w[1]) {
            return Err("indptr not monotone".into());
        }
        for i in 0..self.rows() {
            let r = self.row(i);
            if r.indices.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("row {i} indices not strictly increasing"));
            }
            if r.indices.iter().any(|&j| j as usize >= self.cols) {
                return Err(format!("row {i} column out of bounds"));
            }
            if r.values.iter().any(|&v| v == 0.0 || !v.is_finite()) {
                return Err(format!("row {i} has zero/non-finite stored value"));
            }
        }
        Ok(())
    }
}

/// Sparse dot product of two sorted rows (merge join).
#[inline]
pub fn dot(a: SparseRow<'_>, b: SparseRow<'_>) -> f64 {
    let mut sum = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.indices.len() && j < b.indices.len() {
        let (ia, ib) = (a.indices[i], b.indices[j]);
        if ia == ib {
            sum += a.values[i] as f64 * b.values[j] as f64;
            i += 1;
            j += 1;
        } else if ia < ib {
            i += 1;
        } else {
            j += 1;
        }
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        let mut b = CsrBuilder::new(5);
        b.push_row(vec![(0, 1.0), (3, 2.0)]);
        b.push_row(vec![]);
        b.push_row(vec![(4, 5.0), (1, 3.0)]); // unsorted on purpose
        b.finish()
    }

    #[test]
    fn build_and_access() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 5);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(0).indices, &[0, 3]);
        assert_eq!(m.row(1).nnz(), 0);
        assert_eq!(m.row(2).indices, &[1, 4]); // got sorted
        assert_eq!(m.row(2).values, &[3.0, 5.0]);
        m.check_invariants().unwrap();
    }

    #[test]
    fn zeros_dropped_dups_last_wins() {
        let mut b = CsrBuilder::new(4);
        b.push_row(vec![(1, 0.0), (2, 1.0), (2, 7.0)]);
        let m = b.finish();
        assert_eq!(m.row(0).indices, &[2]);
        assert_eq!(m.row(0).values, &[7.0]);
        m.check_invariants().unwrap();
    }

    #[test]
    fn dense_roundtrip() {
        let d = Dense::from_rows(&[&[0., 1., 0.], &[2., 0., 3.]]);
        let s = Csr::from_dense(&d);
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.to_dense(), d);
        s.check_invariants().unwrap();
    }

    #[test]
    fn sparse_dot_matches_dense() {
        let d = Dense::from_rows(&[&[0., 1., 2., 0.], &[3., 0., 4., 5.]]);
        let s = Csr::from_dense(&d);
        let dense_dot: f64 = d
            .row(0)
            .iter()
            .zip(d.row(1))
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        assert!((dot(s.row(0), s.row(1)) - dense_dot).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let mut b = CsrBuilder::new(3);
        b.push_row(vec![(0, 3.0), (1, 4.0)]);
        let m = b.finish();
        assert!((m.row(0).l2_norm() - 5.0).abs() < 1e-9);
        assert!((m.row(0).l1_norm() - 7.0).abs() < 1e-9);
        assert!((m.row(0).sum() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn scale_and_select() {
        let mut m = sample();
        m.scale_rows(&[2.0, 1.0, 0.5]);
        assert_eq!(m.row(0).values, &[2.0, 4.0]);
        assert_eq!(m.row(2).values, &[1.5, 2.5]);
        let sel = m.select_rows(&[2, 0]);
        assert_eq!(sel.rows(), 2);
        assert_eq!(sel.row(0).indices, &[1, 4]);
        sel.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn col_bounds_checked() {
        let mut b = CsrBuilder::new(2);
        b.push_row(vec![(2, 1.0)]);
    }
}
