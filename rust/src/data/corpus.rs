//! Synthetic word-occurrence corpus calibrated to Table 2 of the paper.
//!
//! The paper's 0-bit-CWS validation (Table 2, Figures 4–6) uses vectors
//! of word occurrences over 2¹⁶ documents for 13 English word pairs —
//! heavy-tailed data whose (f₁, f₂, R, MM) statistics are printed in
//! Table 2. We cannot redistribute the original corpus, but the
//! estimation study depends only on those statistics, so each pair is
//! regenerated synthetically:
//!
//! 1. choose the support overlap `a` from the target resemblance
//!    `R = a/(f₁+f₂−a)  ⇒  a = R(f₁+f₂)/(1+R)`;
//! 2. draw heavy-tailed (log-normal) counts; on shared documents the two
//!    words' counts share a common log-normal factor plus independent
//!    log-normal disagreement of magnitude σ;
//! 3. bisect on σ to hit the target min-max similarity `MM` (exactly
//!    computed by [`crate::kernels::sparse_minmax`]) — MM is strictly
//!    decreasing in σ on a fixed support, so bisection converges.

use super::sparse::{Csr, CsrBuilder};
use crate::kernels::{sparse_minmax, sparse_resemblance};
use crate::util::rng::Pcg64;

/// Number of documents in the corpus (the paper's 2^16).
pub const N_DOCS: usize = 1 << 16;

/// One row of Table 2.
#[derive(Debug, Clone)]
pub struct WordPair {
    pub word1: &'static str,
    pub word2: &'static str,
    pub f1: usize,
    pub f2: usize,
    /// Target resemblance (Table 2 "R").
    pub r: f64,
    /// Target min-max similarity (Table 2 "MM").
    pub mm: f64,
}

/// The 13 pairs of Table 2, verbatim.
pub fn table2_pairs() -> Vec<WordPair> {
    let rows: [(&str, &str, usize, usize, f64, f64); 13] = [
        ("A", "THE", 39063, 42754, 0.6444, 0.3543),
        ("ADDICT", "PRICELESS", 77, 77, 0.0065, 0.0052),
        ("AIR", "DOCTOR", 3159, 860, 0.0439, 0.0248),
        ("CREDIT", "CARD", 2999, 2697, 0.2849, 0.2091),
        ("GAMBIA", "KIRIBATI", 206, 186, 0.7118, 0.6070),
        ("HONG", "KONG", 940, 948, 0.9246, 0.8985),
        ("OF", "AND", 37339, 36289, 0.7711, 0.6084),
        ("PAPER", "REVIEW", 1944, 3197, 0.0780, 0.0502),
        ("PIPELINE", "FLUSH", 139, 118, 0.0158, 0.0143),
        ("SAN", "FRANCISCO", 3194, 1651, 0.4758, 0.2885),
        ("THIS", "TODAY", 27695, 5775, 0.1518, 0.0658),
        ("TIME", "JOB", 37339, 36289, 0.1279, 0.0794),
        ("UNITED", "STATES", 4079, 3981, 0.5913, 0.5017),
    ];
    rows.iter()
        .map(|&(word1, word2, f1, f2, r, mm)| WordPair { word1, word2, f1, f2, r, mm })
        .collect()
}

/// A generated pair of word vectors over `N_DOCS` documents, with the
/// exactly-computed similarities of the realized vectors.
#[derive(Debug, Clone)]
pub struct GeneratedPair {
    pub spec: WordPair,
    /// 2 × N_DOCS sparse matrix; row 0 = word1, row 1 = word2.
    pub vectors: Csr,
    pub realized_r: f64,
    pub realized_mm: f64,
}

impl GeneratedPair {
    pub fn u(&self) -> super::sparse::SparseRow<'_> {
        self.vectors.row(0)
    }
    pub fn v(&self) -> super::sparse::SparseRow<'_> {
        self.vectors.row(1)
    }
}

/// Generate one calibrated pair. `mm_tol` is the acceptable absolute gap
/// between the realized and target MM (the support — hence R — is matched
/// by construction up to integer rounding).
pub fn generate_pair(spec: &WordPair, seed: u64, mm_tol: f64) -> GeneratedPair {
    let overlap = ((spec.r * (spec.f1 + spec.f2) as f64) / (1.0 + spec.r)).round() as usize;
    let overlap = overlap.min(spec.f1).min(spec.f2);
    let mut rng = Pcg64::new_stream(seed ^ fnv(spec.word1) ^ fnv(spec.word2), 77);

    // Document supports: shared docs first, then exclusives. Document ids
    // are a random sample of [0, N_DOCS).
    let total_docs = spec.f1 + spec.f2 - overlap;
    assert!(total_docs <= N_DOCS, "pair does not fit the corpus");
    let mut docs = rng.sample_indices(N_DOCS, total_docs);
    docs.sort_unstable();
    rng.shuffle(&mut docs);
    let shared: Vec<usize> = docs[..overlap].to_vec();
    let only1: Vec<usize> = docs[overlap..overlap + (spec.f1 - overlap)].to_vec();
    let only2: Vec<usize> = docs[overlap + (spec.f1 - overlap)..].to_vec();

    // Base counts (heavy-tailed): shared base + per-word factors.
    let base: Vec<f64> = (0..overlap).map(|_| rng.lognormal(0.3, 1.0)).collect();
    let z1: Vec<f64> = (0..overlap).map(|_| rng.normal()).collect();
    let z2: Vec<f64> = (0..overlap).map(|_| rng.normal()).collect();
    let x1: Vec<f64> = (0..only1.len()).map(|_| rng.lognormal(0.3, 1.2)).collect();
    let x2: Vec<f64> = (0..only2.len()).map(|_| rng.lognormal(0.3, 1.2)).collect();

    let realize = |sigma: f64| -> Csr {
        // Counts are ceil()'d to integers ≥ 1 like real term counts.
        let mut e1: Vec<(u32, f32)> = Vec::with_capacity(spec.f1);
        let mut e2: Vec<(u32, f32)> = Vec::with_capacity(spec.f2);
        for i in 0..overlap {
            let c1 = (base[i] * (sigma * z1[i]).exp()).ceil().max(1.0) as f32;
            let c2 = (base[i] * (sigma * z2[i]).exp()).ceil().max(1.0) as f32;
            e1.push((shared[i] as u32, c1));
            e2.push((shared[i] as u32, c2));
        }
        for (i, &d) in only1.iter().enumerate() {
            e1.push((d as u32, x1[i].ceil().max(1.0) as f32));
        }
        for (i, &d) in only2.iter().enumerate() {
            e2.push((d as u32, x2[i].ceil().max(1.0) as f32));
        }
        let mut b = CsrBuilder::new(N_DOCS);
        b.push_row(e1);
        b.push_row(e2);
        b.finish()
    };

    // Bisection on the disagreement magnitude σ. At σ=0, shared counts
    // are identical (MM is maximal); large σ decorrelates them.
    let (mut lo, mut hi) = (0.0f64, 6.0f64);
    let mm_of = |m: &Csr| sparse_minmax(m.row(0), m.row(1));
    let mut best = realize(0.0);
    let mm_hi_limit = mm_of(&realize(hi));
    let mm_lo_limit = mm_of(&best);
    // Clamp the target into the achievable interval (support fixes both
    // endpoints; targets outside can happen for extreme pairs).
    let target = spec.mm.clamp(mm_hi_limit.min(mm_lo_limit), mm_hi_limit.max(mm_lo_limit));
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        let m = realize(mid);
        let mm = mm_of(&m);
        if (mm - target).abs() <= mm_tol {
            best = m;
            break;
        }
        if mm > target {
            lo = mid; // more disagreement needed
        } else {
            hi = mid;
        }
        best = m;
    }
    let realized_mm = mm_of(&best);
    let realized_r = sparse_resemblance(best.row(0), best.row(1));
    GeneratedPair { spec: spec.clone(), vectors: best, realized_r, realized_mm }
}

/// Generate all 13 Table-2 pairs.
pub fn generate_table2(seed: u64, mm_tol: f64) -> Vec<GeneratedPair> {
    table2_pairs().iter().map(|p| generate_pair(p, seed, mm_tol)).collect()
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairs_table_matches_paper_constants() {
        let pairs = table2_pairs();
        assert_eq!(pairs.len(), 13);
        let hk = pairs.iter().find(|p| p.word1 == "HONG").unwrap();
        assert_eq!(hk.f1, 940);
        assert!((hk.mm - 0.8985).abs() < 1e-9);
    }

    #[test]
    fn generated_pair_hits_support_targets() {
        let spec = table2_pairs()[5].clone(); // HONG-KONG
        let g = generate_pair(&spec, 42, 0.003);
        assert_eq!(g.u().nnz(), spec.f1);
        assert_eq!(g.v().nnz(), spec.f2);
        // R is fixed by the support construction (integer rounding only).
        assert!((g.realized_r - spec.r).abs() < 0.01, "R {} vs {}", g.realized_r, spec.r);
    }

    #[test]
    fn calibration_hits_mm_for_selected_pairs() {
        for idx in [2usize, 3, 5, 9, 12] {
            let spec = table2_pairs()[idx].clone();
            let g = generate_pair(&spec, 7, 0.004);
            assert!(
                (g.realized_mm - spec.mm).abs() < 0.02,
                "{}-{}: MM {} vs target {}",
                spec.word1,
                spec.word2,
                g.realized_mm,
                spec.mm
            );
        }
    }

    #[test]
    fn counts_are_positive_integers() {
        let spec = table2_pairs()[4].clone(); // GAMBIA-KIRIBATI (small)
        let g = generate_pair(&spec, 3, 0.005);
        for &v in g.u().values.iter().chain(g.v().values) {
            assert!(v >= 1.0 && v.fract() == 0.0, "count {v}");
        }
        g.vectors.check_invariants().unwrap();
    }

    #[test]
    fn deterministic() {
        let spec = table2_pairs()[8].clone();
        let a = generate_pair(&spec, 11, 0.005);
        let b = generate_pair(&spec, 11, 0.005);
        assert_eq!(a.vectors, b.vectors);
    }

    #[test]
    fn heavy_tail_present() {
        // Counts must vary dramatically (the paper stresses this regime):
        // max/min count ratio ≥ 10 for a large pair.
        let spec = table2_pairs()[0].clone(); // A-THE
        let g = generate_pair(&spec, 5, 0.01);
        let max = g.u().values.iter().cloned().fold(0.0f32, f32::max);
        assert!(max >= 10.0, "max count {max}");
    }
}
