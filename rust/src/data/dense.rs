//! Row-major dense f32 matrix — the layout the PJRT executables consume
//! directly (no copy on the way into `xla::Literal`).

#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Dense {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from row slices (all must share a length).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks(self.cols.max(1)).take(self.rows)
    }

    /// Select a subset of rows (copying).
    pub fn select_rows(&self, idx: &[usize]) -> Dense {
        let mut out = Dense::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Vertically stack two matrices with equal `cols`.
    pub fn vstack(&self, other: &Dense) -> Dense {
        assert_eq!(self.cols, other.cols);
        let mut data = Vec::with_capacity(self.data.len() + other.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Dense { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Fraction of exactly-zero entries.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64 / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Dense::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.get(0, 2), 3.0);
        assert_eq!(m.get(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn from_rows_roundtrip() {
        let m = Dense::from_rows(&[&[1., 2.], &[3., 4.], &[5., 6.]]);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(2), &[5., 6.]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rejected() {
        Dense::from_rows(&[&[1., 2.], &[3.]]);
    }

    #[test]
    fn select_and_stack() {
        let m = Dense::from_rows(&[&[1., 2.], &[3., 4.], &[5., 6.]]);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.row(0), &[5., 6.]);
        assert_eq!(s.row(1), &[1., 2.]);
        let v = s.vstack(&m);
        assert_eq!(v.rows(), 5);
        assert_eq!(v.row(4), &[5., 6.]);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let m = Dense::from_vec(1, 4, vec![0., 1., 0., 2.]);
        assert!((m.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mutate_row() {
        let mut m = Dense::zeros(2, 2);
        m.row_mut(1)[0] = 7.0;
        assert_eq!(m.get(1, 0), 7.0);
        m.set(0, 1, 3.0);
        assert_eq!(m.get(0, 1), 3.0);
    }

    #[test]
    fn iter_rows_covers_all() {
        let m = Dense::from_rows(&[&[1., 2.], &[3., 4.]]);
        let rows: Vec<&[f32]> = m.iter_rows().collect();
        assert_eq!(rows, vec![&[1.0f32, 2.][..], &[3., 4.][..]]);
    }
}
