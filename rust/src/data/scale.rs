//! Data transforms from the paper's experimental protocol (§2 notes):
//!
//! * `(z+1)/2` shift for datasets scaled to `[-1, 1]` (note ii),
//! * ℓ₁ (sum-to-one) normalization — definition of the intersection and
//!   n-min-max kernels (Eqs. 3–4),
//! * ℓ₂ (unit-length) normalization — definition of the linear kernel
//!   baseline (Eq. 5),
//! * binarization — maps to the resemblance regime (Eq. 2).
//!
//! All transforms exist for both dense and CSR matrices and preserve
//! nonnegativity.

use super::dense::Dense;
use super::sparse::Csr;

/// Map `z ∈ [-1,1]` to `(z+1)/2 ∈ [0,1]` (paper note (ii)).
pub fn shift_unit(d: &mut Dense) {
    for v in d.data_mut() {
        *v = (*v + 1.0) * 0.5;
    }
}

/// ℓ₁-normalize one dense row in place (f64 norm over the full row
/// including zeros, f32 factor, in-place f32 multiply; all-zero rows
/// untouched). The single source of the per-row arithmetic — both the
/// matrix transform below and the fused scorer's per-row mirror
/// (`serve::Scorer`) call this, so their outputs are bit-identical by
/// construction.
pub fn l1_scale_row(row: &mut [f32]) {
    let s: f64 = row.iter().map(|&x| x.abs() as f64).sum();
    if s > 0.0 {
        let inv = (1.0 / s) as f32;
        for v in row {
            *v *= inv;
        }
    }
}

/// ℓ₂-normalize one dense row in place — see [`l1_scale_row`].
pub fn l2_scale_row(row: &mut [f32]) {
    let s: f64 = row.iter().map(|&x| (x as f64) * (x as f64)).sum();
    if s > 0.0 {
        let inv = (1.0 / s.sqrt()) as f32;
        for v in row {
            *v *= inv;
        }
    }
}

/// Binarize one value — the shared kernel of [`binarize_dense`],
/// [`binarize_csr`], and the serving mirror.
#[inline]
pub fn binarize_value(v: f32) -> f32 {
    if v != 0.0 {
        1.0
    } else {
        0.0
    }
}

/// The per-row CSR scaling factor for ℓ₁ (stored values only; rows
/// with zero norm get factor 1.0). Shared by [`l1_normalize_csr`] and
/// the fused scorer's sparse mirror.
pub fn csr_row_l1_factor(row: crate::data::sparse::SparseRow<'_>) -> f32 {
    let s = row.l1_norm();
    if s > 0.0 {
        (1.0 / s) as f32
    } else {
        1.0
    }
}

/// The per-row CSR scaling factor for ℓ₂ — see [`csr_row_l1_factor`].
pub fn csr_row_l2_factor(row: crate::data::sparse::SparseRow<'_>) -> f32 {
    let s = row.l2_norm();
    if s > 0.0 {
        (1.0 / s) as f32
    } else {
        1.0
    }
}

/// Row-wise ℓ₁ normalization: each row sums to 1 (rows of all zeros are
/// left untouched).
pub fn l1_normalize_dense(d: &mut Dense) {
    for i in 0..d.rows() {
        l1_scale_row(d.row_mut(i));
    }
}

/// Row-wise ℓ₂ normalization: each row has unit Euclidean norm.
pub fn l2_normalize_dense(d: &mut Dense) {
    for i in 0..d.rows() {
        l2_scale_row(d.row_mut(i));
    }
}

pub fn l1_normalize_csr(m: &mut Csr) {
    let factors: Vec<f32> = (0..m.rows()).map(|i| csr_row_l1_factor(m.row(i))).collect();
    m.scale_rows(&factors);
}

pub fn l2_normalize_csr(m: &mut Csr) {
    let factors: Vec<f32> = (0..m.rows()).map(|i| csr_row_l2_factor(m.row(i))).collect();
    m.scale_rows(&factors);
}

/// Replace every nonzero with 1.0 (resemblance-kernel regime).
pub fn binarize_dense(d: &mut Dense) {
    for v in d.data_mut() {
        *v = binarize_value(*v);
    }
}

/// Sparse binarization: stored values become 1.0 in place — the
/// structure (and memory) is untouched, no densification.
pub fn binarize_csr(m: &mut Csr) {
    m.map_values(binarize_value);
}

/// Clamp negatives to zero (the kernels require nonnegative input).
pub fn clamp_nonneg(d: &mut Dense) {
    for v in d.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// True if every entry is nonnegative and finite.
pub fn is_nonneg(d: &Dense) -> bool {
    d.data().iter().all(|&v| v >= 0.0 && v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrBuilder;

    #[test]
    fn shift_maps_range() {
        let mut d = Dense::from_vec(1, 3, vec![-1.0, 0.0, 1.0]);
        shift_unit(&mut d);
        assert_eq!(d.data(), &[0.0, 0.5, 1.0]);
        assert!(is_nonneg(&d));
    }

    #[test]
    fn l1_rows_sum_to_one() {
        let mut d = Dense::from_rows(&[&[1., 3.], &[0., 0.], &[2., 2.]]);
        l1_normalize_dense(&mut d);
        assert!((d.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert_eq!(d.row(1), &[0., 0.]); // zero row untouched
        assert!((d.row(2).iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn l2_rows_unit_norm() {
        let mut d = Dense::from_rows(&[&[3., 4.]]);
        l2_normalize_dense(&mut d);
        let n: f32 = d.row(0).iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-6);
    }

    #[test]
    fn csr_normalization_matches_dense() {
        let dense = Dense::from_rows(&[&[0., 2., 6.], &[1., 0., 0.]]);
        let mut d1 = dense.clone();
        l1_normalize_dense(&mut d1);
        let mut s1 = Csr::from_dense(&dense);
        l1_normalize_csr(&mut s1);
        assert_eq!(s1.to_dense(), d1);

        let mut d2 = dense.clone();
        l2_normalize_dense(&mut d2);
        let mut s2 = Csr::from_dense(&dense);
        l2_normalize_csr(&mut s2);
        for i in 0..2 {
            for j in 0..3 {
                assert!((s2.to_dense().get(i, j) - d2.get(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn binarize_and_clamp() {
        let mut d = Dense::from_vec(1, 4, vec![-2.0, 0.0, 0.5, 3.0]);
        clamp_nonneg(&mut d);
        assert_eq!(d.data(), &[0.0, 0.0, 0.5, 3.0]);
        binarize_dense(&mut d);
        assert_eq!(d.data(), &[0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn csr_binarize_matches_dense_and_keeps_structure() {
        let dense = Dense::from_rows(&[&[0., 2.5, 0.25], &[7., 0., 0.]]);
        let mut d = dense.clone();
        binarize_dense(&mut d);
        let mut s = Csr::from_dense(&dense);
        let nnz_before = s.nnz();
        binarize_csr(&mut s);
        assert_eq!(s.to_dense(), d);
        assert_eq!(s.nnz(), nnz_before);
        s.check_invariants().unwrap();
    }

    #[test]
    fn csr_l1_empty_rows_ok() {
        let mut b = CsrBuilder::new(3);
        b.push_row(vec![]);
        b.push_row(vec![(1, 4.0)]);
        let mut m = b.finish();
        l1_normalize_csr(&mut m);
        assert_eq!(m.row(1).values, &[1.0]);
    }
}
