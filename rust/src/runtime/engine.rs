//! The PJRT engine: loads AOT HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes them from the coordinator's hot
//! path. Python is never involved at runtime.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifact::{ArtifactSpec, Manifest};

/// A loaded, compiled artifact set bound to one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU engine over `artifacts_dir`, compiling every
    /// manifest entry eagerly (compile once, execute many).
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        Self::load_subset_inner(manifest, None)
    }

    /// Load only the named entries (faster startup for focused tools).
    pub fn load_subset(artifacts_dir: &Path, names: &[&str]) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        Self::load_subset_inner(manifest, Some(names))
    }

    fn load_subset_inner(manifest: Manifest, names: Option<&[&str]>) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            if let Some(ns) = names {
                if !ns.contains(&entry.name.as_str()) {
                    continue;
                }
            }
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .with_context(|| format!("parsing {}", entry.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(Engine { client, manifest, executables })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name` with the given inputs; returns the tuple
    /// elements as literals. Input count and element counts are checked
    /// against the manifest before dispatch.
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.spec(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        for (lit, ts) in inputs.iter().zip(&spec.inputs) {
            let n = lit.element_count();
            if n != ts.elements() {
                return Err(anyhow!(
                    "{name}: input '{}' has {n} elements, expected {}",
                    ts.name,
                    ts.elements()
                ));
            }
        }
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True — always a tuple.
        Ok(lit.to_tuple()?)
    }

    /// Convenience: run and decode every output as the manifest dtype.
    pub fn run_decoded(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let outs = self.run(name, inputs)?;
        let spec = self.spec(name)?;
        outs.iter()
            .zip(&spec.outputs)
            .map(|(lit, ts)| Tensor::from_literal(lit, ts))
            .collect()
    }
}

/// A decoded output tensor.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    fn from_literal(lit: &xla::Literal, ts: &super::artifact::TensorSpec) -> Result<Tensor> {
        match ts.dtype.as_str() {
            "f32" => Ok(Tensor::F32 { shape: ts.shape.clone(), data: lit.to_vec::<f32>()? }),
            "s32" => Ok(Tensor::I32 { shape: ts.shape.clone(), data: lit.to_vec::<i32>()? }),
            other => Err(anyhow!("unsupported dtype {other}")),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } => shape,
            Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }
}

/// Build an f32 literal of the given shape from a flat row-major slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("literal_f32: {} elements for shape {shape:?}", data.len()));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Default artifacts directory: `$MINMAX_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var("MINMAX_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_f32_shape_mismatch() {
        assert!(literal_f32(&[1.0], &[2, 3]).is_err());
    }
}
