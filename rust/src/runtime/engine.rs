//! The PJRT engine: loads AOT HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes them from the coordinator's hot
//! path. Python is never involved at runtime.
//!
//! The XLA bindings (`xla` crate) are only present on hosts with the
//! XLA toolchain, so the whole bridge is gated behind the **`pjrt`
//! cargo feature**. Without it this module compiles a same-API stub
//! whose `Engine::load*` / [`literal_f32`] fail with a clear error —
//! callers are Result-based either way, and everything downstream
//! (service backends, examples, benches) probes [`pjrt_enabled`] or the
//! artifacts manifest before relying on it.
//!
//! Pattern (real build) follows /opt/xla-example/load_hlo.rs:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.

use std::path::Path;

use super::artifact::{ArtifactSpec, Manifest};

/// Whether this build carries the real PJRT/XLA runtime.
pub const fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

/// Error type for the runtime bridge: plain strings (the vendor set has
/// no error-handling crates), convertible into `Box<dyn Error>`.
pub type RuntimeError = String;

#[cfg(feature = "pjrt")]
pub use real::{literal_f32, Engine, Literal};

#[cfg(not(feature = "pjrt"))]
pub use stub::{literal_f32, Engine, Literal};

/// A decoded output tensor (shared by real and stub builds).
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } => shape,
            Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }
}

/// Default artifacts directory: `$MINMAX_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var("MINMAX_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod real {
    use super::*;
    use std::collections::HashMap;

    pub use xla::Literal;

    /// A loaded, compiled artifact set bound to one PJRT client.
    pub struct Engine {
        client: xla::PjRtClient,
        manifest: Manifest,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Engine {
        /// Create a CPU engine over `artifacts_dir`, compiling every
        /// manifest entry eagerly (compile once, execute many).
        pub fn load(artifacts_dir: &Path) -> Result<Engine, RuntimeError> {
            let manifest = Manifest::load(artifacts_dir)?;
            Self::load_subset_inner(manifest, None)
        }

        /// Load only the named entries (faster startup for focused tools).
        pub fn load_subset(artifacts_dir: &Path, names: &[&str]) -> Result<Engine, RuntimeError> {
            let manifest = Manifest::load(artifacts_dir)?;
            Self::load_subset_inner(manifest, Some(names))
        }

        fn load_subset_inner(
            manifest: Manifest,
            names: Option<&[&str]>,
        ) -> Result<Engine, RuntimeError> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| format!("creating PJRT CPU client: {e}"))?;
            let mut executables = HashMap::new();
            for entry in &manifest.entries {
                if let Some(ns) = names {
                    if !ns.contains(&entry.name.as_str()) {
                        continue;
                    }
                }
                let proto = xla::HloModuleProto::from_text_file(&entry.file)
                    .map_err(|e| format!("parsing {}: {e}", entry.file.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| format!("compiling {}: {e}", entry.name))?;
                executables.insert(entry.name.clone(), exe);
            }
            Ok(Engine { client, manifest, executables })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn spec(&self, name: &str) -> Result<&ArtifactSpec, RuntimeError> {
            self.manifest.get(name).ok_or_else(|| format!("unknown artifact '{name}'"))
        }

        pub fn has(&self, name: &str) -> bool {
            self.executables.contains_key(name)
        }

        /// Execute artifact `name` with the given inputs; returns the
        /// tuple elements as literals. Input count and element counts
        /// are checked against the manifest before dispatch.
        pub fn run(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>, RuntimeError> {
            let spec = self.spec(name)?;
            if inputs.len() != spec.inputs.len() {
                return Err(format!(
                    "{name}: expected {} inputs, got {}",
                    spec.inputs.len(),
                    inputs.len()
                ));
            }
            for (lit, ts) in inputs.iter().zip(&spec.inputs) {
                let n = lit.element_count();
                if n != ts.elements() {
                    return Err(format!(
                        "{name}: input '{}' has {n} elements, expected {}",
                        ts.name,
                        ts.elements()
                    ));
                }
            }
            let exe = self
                .executables
                .get(name)
                .ok_or_else(|| format!("artifact '{name}' not loaded"))?;
            let result =
                exe.execute::<Literal>(inputs).map_err(|e| format!("{name}: execute: {e}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| format!("{name}: device transfer: {e}"))?;
            // aot.py lowers with return_tuple=True — always a tuple.
            lit.to_tuple().map_err(|e| format!("{name}: untuple: {e}"))
        }

        /// Convenience: run and decode every output as the manifest dtype.
        pub fn run_decoded(
            &self,
            name: &str,
            inputs: &[Literal],
        ) -> Result<Vec<Tensor>, RuntimeError> {
            let outs = self.run(name, inputs)?;
            let spec = self.spec(name)?;
            outs.iter()
                .zip(&spec.outputs)
                .map(|(lit, ts)| tensor_from_literal(lit, ts))
                .collect()
        }
    }

    fn tensor_from_literal(
        lit: &Literal,
        ts: &crate::runtime::artifact::TensorSpec,
    ) -> Result<Tensor, RuntimeError> {
        match ts.dtype.as_str() {
            "f32" => Ok(Tensor::F32 {
                shape: ts.shape.clone(),
                data: lit.to_vec::<f32>().map_err(|e| format!("decode f32: {e}"))?,
            }),
            "s32" => Ok(Tensor::I32 {
                shape: ts.shape.clone(),
                data: lit.to_vec::<i32>().map_err(|e| format!("decode s32: {e}"))?,
            }),
            other => Err(format!("unsupported dtype {other}")),
        }
    }

    /// Build an f32 literal of the given shape from a flat row-major
    /// slice.
    pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal, RuntimeError> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(format!("literal_f32: {} elements for shape {shape:?}", data.len()));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(data).reshape(&dims).map_err(|e| format!("literal_f32 reshape: {e}"))
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::*;

    const DISABLED: &str =
        "built without the `pjrt` feature: on a host with the XLA toolchain, add the `xla` \
         dependency to rust/Cargo.toml (see its [features] note) and rebuild with \
         `--features pjrt` to use AOT artifacts";

    /// Placeholder literal so PJRT-consuming code type-checks in stub
    /// builds; no value of it can be constructed through this module's
    /// API (every constructor fails first).
    #[derive(Debug, Clone)]
    pub struct Literal(#[allow(dead_code)] ());

    /// Stub engine: same API as the real one, fails at load time.
    pub struct Engine {
        manifest: Manifest,
        never: std::convert::Infallible,
    }

    impl Engine {
        pub fn load(artifacts_dir: &Path) -> Result<Engine, RuntimeError> {
            let _ = Manifest::load(artifacts_dir)?;
            Err(DISABLED.to_string())
        }

        pub fn load_subset(artifacts_dir: &Path, names: &[&str]) -> Result<Engine, RuntimeError> {
            let _ = names;
            Self::load(artifacts_dir)
        }

        pub fn platform(&self) -> String {
            match self.never {}
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn spec(&self, _name: &str) -> Result<&ArtifactSpec, RuntimeError> {
            match self.never {}
        }

        pub fn has(&self, _name: &str) -> bool {
            match self.never {}
        }

        pub fn run(&self, _name: &str, _inputs: &[Literal]) -> Result<Vec<Literal>, RuntimeError> {
            match self.never {}
        }

        pub fn run_decoded(
            &self,
            _name: &str,
            _inputs: &[Literal],
        ) -> Result<Vec<Tensor>, RuntimeError> {
            match self.never {}
        }
    }

    pub fn literal_f32(_data: &[f32], _shape: &[usize]) -> Result<Literal, RuntimeError> {
        Err(DISABLED.to_string())
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_f32_shape_mismatch() {
        assert!(literal_f32(&[1.0], &[2, 3]).is_err());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_cleanly() {
        assert!(!pjrt_enabled());
        let err = literal_f32(&[1.0], &[1]).unwrap_err();
        assert!(err.contains("pjrt"));
    }
}
