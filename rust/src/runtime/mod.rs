//! Runtime bridge to the AOT layer: manifest-described HLO-text
//! artifacts (produced once by `make artifacts`) are compiled on the PJRT
//! CPU client and executed from rust. See DESIGN.md §3.
//!
//! The XLA bindings are gated behind the `pjrt` cargo feature; probe
//! [`pjrt_enabled`] (or just handle the `Result` from `Engine::load`)
//! before relying on artifact execution.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use engine::{
    default_artifacts_dir, literal_f32, pjrt_enabled, Engine, Literal, RuntimeError, Tensor,
};
