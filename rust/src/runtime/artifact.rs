//! Artifact manifest: the contract between `python/compile/aot.py`
//! (which writes `artifacts/manifest.json` + `*.hlo.txt`) and the rust
//! runtime that loads them.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "s32" — all the AOT graphs use.
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactSpec>,
}

fn tensor_specs(j: &Json, what: &str) -> Result<Vec<TensorSpec>, String> {
    let arr = j.as_arr().ok_or_else(|| format!("{what}: not an array"))?;
    arr.iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{what}: missing name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("{what}/{name}: missing shape"))?
                .iter()
                .map(|d| d.as_f64().map(|x| x as usize).ok_or_else(|| "bad dim".to_string()))
                .collect::<Result<Vec<_>, _>>()?;
            let dtype = t
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{what}/{name}: missing dtype"))?
                .to_string();
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Parse `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| format!("manifest.json: {e}"))?;
        let format = j.get("format").and_then(Json::as_str).unwrap_or("");
        if format != "hlo-text" {
            return Err(format!("unsupported artifact format '{format}'"));
        }
        let entries_obj = match j.get("entries") {
            Some(Json::Obj(m)) => m,
            _ => return Err("manifest.json: missing entries".into()),
        };
        let mut entries = Vec::new();
        for (name, e) in entries_obj {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{name}: missing file"))?;
            entries.push(ArtifactSpec {
                name: name.clone(),
                file: dir.join(file),
                inputs: tensor_specs(e.get("inputs").unwrap_or(&Json::Null), "inputs")?,
                outputs: tensor_specs(e.get("outputs").unwrap_or(&Json::Null), "outputs")?,
            });
        }
        entries.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "entries": {
        "cws_hash": {
          "file": "cws_hash.hlo.txt",
          "spec": {"b": 64, "d": 256, "k": 128},
          "inputs": [
            {"name": "x", "shape": [64, 256], "dtype": "f32"},
            {"name": "r", "shape": [128, 256], "dtype": "f32"}
          ],
          "outputs": [
            {"name": "i_star", "shape": [64, 128], "dtype": "s32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/artifacts"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.get("cws_hash").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![64, 256]);
        assert_eq!(e.inputs[0].elements(), 64 * 256);
        assert_eq!(e.outputs[0].dtype, "s32");
        assert!(e.file.ends_with("cws_hash.hlo.txt"));
        assert_eq!(m.names(), vec!["cws_hash"]);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_missing_entries() {
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"format":"hlo-text"}"#).is_err());
    }

    #[test]
    fn load_real_manifest_if_built() {
        // Integration hook: when `make artifacts` has run, the real
        // manifest must parse and reference existing files.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.entries.is_empty());
        for e in &m.entries {
            assert!(e.file.exists(), "{} missing", e.file.display());
        }
    }
}
