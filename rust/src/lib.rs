//! # minmax-kernels
//!
//! Production-quality reproduction of **"Min-Max Kernels" (Ping Li,
//! stat.ML 2015)**: min-max kernel machines, consistent weighted sampling
//! (CWS) with the paper's 0-bit scheme, and a three-layer
//! Rust + JAX + Pallas hashing/serving stack (AOT via XLA/PJRT).
//!
//! See `DESIGN.md` for the architecture and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! Layer map:
//! * [`util`], [`bench`] — from-scratch substrates (RNG, pool, CLI, JSON,
//!   stats, property testing, measurement harness).
//! * [`data`] — matrices, LIBSVM IO, scaling, synthetic dataset suite and
//!   word-vector corpus.
//! * [`kernels`] — min-max / n-min-max / intersection / linear /
//!   resemblance / chi² kernels + blocked kernel-matrix computation.
//! * [`cws`] — ICWS sampler (Alg. 1 of the paper) and the 0-bit/1-bit/
//!   b-bit schemes; [`features`] — one-hot hashed-feature expansion.
//! * [`svm`] — linear dual-CD SVM, logistic regression, precomputed-kernel
//!   SVM, multiclass wrappers, C-grid evaluation.
//! * [`estimate`] — the Figures 4–6 estimator-quality simulation harness.
//! * [`runtime`] — PJRT engine loading `artifacts/*.hlo.txt` (L2/L1 AOT).
//! * [`coordinator`] — the deployable hashing/serving pipeline.
//! * [`experiments`] — drivers regenerating every paper table and figure.

pub mod bench;
pub mod util;



pub mod coordinator;
pub mod cws;
pub mod data;
pub mod estimate;
pub mod experiments;
pub mod features;



pub mod kernels;
pub mod runtime;
pub mod svm;


