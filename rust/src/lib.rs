//! # minmax-kernels
//!
//! Production-quality reproduction of **"Min-Max Kernels" (Ping Li,
//! stat.ML 2015)**: min-max kernel machines, consistent weighted sampling
//! (CWS) with the paper's 0-bit scheme, and a three-layer
//! Rust + JAX + Pallas hashing/serving stack (AOT via XLA/PJRT, behind
//! the `pjrt` cargo feature).
//!
//! Start from [`prelude`]; the public API is organized around three
//! abstractions (see `DESIGN.md` for the architecture and migration
//! notes, and `EXPERIMENTS.md` for paper-vs-measured results):
//!
//! * [`sketch::Sketcher`] — anything that hashes a vector into
//!   `(i*, t*)` samples (ICWS, minwise, PJRT-backed, future GCWS);
//! * [`kernels::Kernel`] — an exact pairwise similarity plus its hashed
//!   linearization ([`kernels::KernelKind`] is the paper's concrete set);
//! * [`pipeline::Pipeline`] — `Scaling → Sketcher → Expansion → linear
//!   model` as one fit/transform/predict object.
//!
//! Layer map:
//! * [`util`], [`bench`] — from-scratch substrates (RNG, pool, CLI, JSON,
//!   stats, property testing, measurement harness).
//! * [`data`] — matrices, LIBSVM IO, scaling, synthetic dataset suite and
//!   word-vector corpus.
//! * [`kernels`] — the [`kernels::Kernel`] trait, min-max / n-min-max /
//!   intersection / linear / resemblance / chi² forms + blocked
//!   kernel-matrix computation.
//! * [`cws`] — ICWS sampler (Alg. 1 of the paper) and the 0-bit/1-bit/
//!   b-bit schemes; [`sketch`] — the [`sketch::Sketcher`] trait over
//!   every hash family; [`features`] — one-hot hashed features: the
//!   [`features::CodeMatrix`] code slab (training default) and the CSR
//!   expansion (IO/export).
//! * [`svm`] — linear dual-CD SVM, logistic regression, kernel SVM over
//!   any [`kernels::gram::GramSource`] (precomputed or on-the-fly Gram
//!   with a bounded row cache, LIBLINEAR-style shrinking), multiclass
//!   wrappers (parallel OvR/OvO), C-grid evaluation; [`svm::RowSet`]
//!   specializes the solvers over both feature representations.
//! * [`pipeline`] — the composable fit/transform/predict pipeline.
//! * [`serve`] — the fused zero-allocation serving path:
//!   [`serve::Scorer`] runs sketch → b-bit code → weight-slab gather in
//!   one pass (bit-identical to the layered predict path), with a
//!   reusable [`serve::Scratch`] arena and a chunk-parallel batch
//!   entry; `Pipeline::predict` and the coordinator's score mode ride
//!   it.
//! * [`estimate`] — the Figures 4–6 estimator-quality simulation harness.
//! * [`runtime`] — PJRT engine loading `artifacts/*.hlo.txt` (L2/L1 AOT;
//!   stubbed without the `pjrt` feature).
//! * [`coordinator`] — the deployable hashing/serving stack: open
//!   [`coordinator::SketcherBackend`] factories, the batching service,
//!   the replica router, the sharded hot-swappable serving cluster
//!   ([`coordinator::ScoreRouter`]), and the offline batch pipeline.
//! * [`experiments`] — drivers regenerating every paper table and figure.

// Unsafe hygiene (ISSUE 9): every unsafe operation needs its own
// `unsafe {}` block with a `// SAFETY:` comment even inside `unsafe
// fn` bodies — `xtask lint` checks the comments; this makes the blocks
// explicit.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod util;

pub mod sketch;

pub mod coordinator;
pub mod cws;
pub mod data;
pub mod estimate;
pub mod experiments;
pub mod features;

pub mod pipeline;
pub mod prelude;
pub mod serve;

pub mod kernels;
pub mod runtime;
pub mod svm;
