//! The estimator-quality simulation harness behind Figures 4–6.
//!
//! For a pair of vectors with known `K_MM`, repeatedly CWS-hash both with
//! fresh randomness and measure the empirical **bias** and **MSE** of the
//! collision-fraction estimator K̂ under each bit-budget [`Scheme`], as a
//! function of the number of samples k. The paper overlays the binomial
//! variance `K(1−K)/k` (the theoretical MSE of the unbiased full scheme);
//! we report it alongside.
//!
//! Implementation notes:
//! * one simulation draws `k_max` samples once; every smaller k is a
//!   prefix (exactly how the paper's plots nest), so cost is
//!   `sims × k_max × nnz` — the dominant term for the big word pairs;
//! * all schemes are evaluated on the *same* draws, making the
//!   full-vs-0-bit bias differences paired (lower variance), again like
//!   the paper's overlapping curves.

use crate::cws::{CwsHasher, Scheme};
use crate::data::sparse::SparseRow;
use crate::util::stats::EstimatorError;

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Sample counts to evaluate (ascending); `k_max = last`.
    pub ks: Vec<usize>,
    /// Number of Monte Carlo repetitions (the paper uses 10,000).
    pub sims: usize,
    pub seed: u64,
}

impl SimConfig {
    /// Log-spaced k grid 1..=k_max (the paper sweeps k = 1..1000).
    pub fn log_ks(k_max: usize) -> Vec<usize> {
        let mut ks = vec![1usize];
        let mut k = 2;
        while k <= k_max {
            ks.push(k);
            k *= 2;
        }
        if *ks.last().unwrap() != k_max {
            ks.push(k_max);
        }
        ks
    }
}

/// One (scheme, k) cell of the Figure 4–6 result grid.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub scheme: Scheme,
    pub k: usize,
    pub bias: f64,
    pub mse: f64,
    /// Binomial reference: `K(1−K)/k`.
    pub theory_var: f64,
    pub sims: usize,
}

/// Simulate all (scheme, k) cells for one vector pair with ground truth
/// `truth` (the exact K_MM, computed by the caller).
pub fn simulate_pair(
    u: SparseRow<'_>,
    v: SparseRow<'_>,
    truth: f64,
    schemes: &[Scheme],
    cfg: &SimConfig,
) -> Vec<CellResult> {
    assert!(!cfg.ks.is_empty());
    let k_max = *cfg.ks.last().unwrap();
    assert!(cfg.ks.windows(2).all(|w| w[0] < w[1]), "ks must be ascending");
    let mut acc: Vec<Vec<EstimatorError>> = schemes
        .iter()
        .map(|_| cfg.ks.iter().map(|_| EstimatorError::new(truth)).collect())
        .collect();
    let mut hits = vec![0u32; k_max];
    for sim in 0..cfg.sims {
        // Fresh randomness per simulation: distinct hasher seed.
        let sim_seed = cfg.seed.wrapping_add(0x9E37_79B9u64.wrapping_mul(1 + sim as u64));
        let hasher = CwsHasher::new(sim_seed, k_max);
        let su = hasher.hash_sparse(u);
        let sv = hasher.hash_sparse(v);
        for (si, scheme) in schemes.iter().enumerate() {
            // Prefix collision counts.
            for j in 0..k_max {
                hits[j] = (scheme.encode(&su[j]) == scheme.encode(&sv[j])) as u32;
            }
            let mut running = 0u32;
            let mut ki = 0usize;
            for (j, &h) in hits.iter().enumerate() {
                running += h;
                if ki < cfg.ks.len() && j + 1 == cfg.ks[ki] {
                    acc[si][ki].push(running as f64 / (j + 1) as f64);
                    ki += 1;
                }
            }
        }
    }
    let mut out = Vec::new();
    for (si, scheme) in schemes.iter().enumerate() {
        for (ki, &k) in cfg.ks.iter().enumerate() {
            out.push(CellResult {
                scheme: *scheme,
                k,
                bias: acc[si][ki].bias(),
                mse: acc[si][ki].mse(),
                theory_var: truth * (1.0 - truth) / k as f64,
                sims: cfg.sims,
            });
        }
    }
    out
}

/// The scheme set of Figures 4–5: full, 0-bit, 1-bit.
pub fn fig45_schemes() -> Vec<Scheme> {
    vec![Scheme::FULL, Scheme::ZERO_BIT, Scheme::ONE_BIT]
}

/// The scheme set of Figure 6: all bits of t*, only 0/1/2/4 bits of i*.
pub fn fig6_schemes() -> Vec<Scheme> {
    [0u8, 1, 2, 4]
        .iter()
        .map(|&b| Scheme { i_bits: Some(b), t_bits: None })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrBuilder;
    use crate::kernels::sparse_minmax;

    fn pair() -> crate::data::Csr {
        let mut b = CsrBuilder::new(64);
        let mut rng = crate::util::rng::Pcg64::new(5);
        let u: Vec<(u32, f32)> =
            (0..48u32).map(|i| (i, rng.lognormal(0.0, 1.0) as f32)).collect();
        let v: Vec<(u32, f32)> = u
            .iter()
            .map(|&(i, x)| {
                (
                    i + ((i % 5 == 0) as u32) * 10,
                    (x as f64 * rng.lognormal(0.0, 0.4)) as f32,
                )
            })
            .map(|(i, x)| (i.min(63), x))
            .collect();
        b.push_row(u);
        b.push_row(v);
        b.finish()
    }

    #[test]
    fn full_scheme_is_unbiased_and_matches_binomial_mse() {
        let m = pair();
        let truth = sparse_minmax(m.row(0), m.row(1));
        let cfg = SimConfig { ks: vec![1, 4, 16, 64], sims: 1500, seed: 1 };
        let res = simulate_pair(m.row(0), m.row(1), truth, &[Scheme::FULL], &cfg);
        for cell in &res {
            // Bias within ~4 standard errors of the mean estimator.
            let se = (cell.theory_var / cfg.sims as f64).sqrt();
            assert!(
                cell.bias.abs() < 4.0 * se + 5e-3,
                "k={}: bias {} (se {se})",
                cell.k,
                cell.bias
            );
            // Empirical MSE within 25% of K(1-K)/k.
            assert!(
                (cell.mse - cell.theory_var).abs() < 0.25 * cell.theory_var + 1e-4,
                "k={}: mse {} vs theory {}",
                cell.k,
                cell.mse,
                cell.theory_var
            );
        }
    }

    #[test]
    fn zero_bit_curve_overlaps_full_curve() {
        // The paper's core claim (Figures 4–5): MSE(0-bit) ≈ MSE(full).
        let m = pair();
        let truth = sparse_minmax(m.row(0), m.row(1));
        let cfg = SimConfig { ks: vec![16, 64], sims: 1200, seed: 2 };
        let res = simulate_pair(m.row(0), m.row(1), truth, &fig45_schemes(), &cfg);
        let find = |s: Scheme, k: usize| {
            res.iter().find(|c| c.scheme == s && c.k == k).unwrap().mse
        };
        for &k in &[16usize, 64] {
            let full = find(Scheme::FULL, k);
            let zero = find(Scheme::ZERO_BIT, k);
            assert!(
                (zero - full).abs() < 0.35 * full + 1e-4,
                "k={k}: zero {zero} vs full {full}"
            );
        }
    }

    #[test]
    fn mse_decreases_with_k() {
        let m = pair();
        let truth = sparse_minmax(m.row(0), m.row(1));
        let cfg = SimConfig { ks: vec![1, 8, 64], sims: 800, seed: 3 };
        let res = simulate_pair(m.row(0), m.row(1), truth, &[Scheme::ZERO_BIT], &cfg);
        assert!(res[0].mse > res[1].mse);
        assert!(res[1].mse > res[2].mse);
    }

    #[test]
    fn fig6_schemes_with_few_i_bits_are_badly_biased() {
        // Figure 6: keeping t* but few bits of i* does NOT estimate K_MM.
        let m = pair();
        let truth = sparse_minmax(m.row(0), m.row(1));
        let cfg = SimConfig { ks: vec![64], sims: 500, seed: 4 };
        let res = simulate_pair(m.row(0), m.row(1), truth, &fig6_schemes(), &cfg);
        // i_bits=0 (t* only): collisions vastly over-count -> big positive bias.
        let b0 = res.iter().find(|c| c.scheme.i_bits == Some(0)).unwrap().bias;
        assert!(b0 > 0.05, "t*-only bias {b0}");
        // More i* bits -> bias shrinks (allowing noise).
        let b4 = res.iter().find(|c| c.scheme.i_bits == Some(4)).unwrap().bias;
        assert!(b4 < b0, "bias must shrink with i* bits: {b4} vs {b0}");
    }

    #[test]
    fn log_ks_grid() {
        let ks = SimConfig::log_ks(1000);
        assert_eq!(ks[0], 1);
        assert_eq!(*ks.last().unwrap(), 1000);
        assert!(ks.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn deterministic_given_seed() {
        let m = pair();
        let truth = sparse_minmax(m.row(0), m.row(1));
        let cfg = SimConfig { ks: vec![8], sims: 50, seed: 9 };
        let a = simulate_pair(m.row(0), m.row(1), truth, &[Scheme::FULL], &cfg);
        let b = simulate_pair(m.row(0), m.row(1), truth, &[Scheme::FULL], &cfg);
        assert_eq!(a[0].bias, b[0].bias);
        assert_eq!(a[0].mse, b[0].mse);
    }
}
