//! Criterion-style measurement harness (the vendor set has no criterion).
//!
//! Each `rust/benches/*.rs` binary (built with `harness = false`) creates
//! a [`Runner`], registers benchmark closures, and the runner handles
//! warmup, adaptive iteration counts, robust statistics (median + MAD),
//! throughput reporting, and `--filter`/`--quick` CLI flags so
//! `cargo bench -- --filter cws` works as expected.

use std::time::{Duration, Instant};

use crate::util::stats::Reservoir;

#[derive(Debug, Clone)]
pub struct Config {
    /// Minimum measurement time per benchmark.
    pub measure_time: Duration,
    /// Warmup time before measuring.
    pub warmup_time: Duration,
    /// Max samples collected.
    pub max_samples: usize,
    /// Substring filter on benchmark names.
    pub filter: Option<String>,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            measure_time: Duration::from_millis(1500),
            warmup_time: Duration::from_millis(300),
            max_samples: 60,
            filter: None,
        }
    }
}

impl Config {
    /// Parse `cargo bench` style args: `--filter <substr>`, `--quick`.
    pub fn from_args() -> Self {
        let mut cfg = Self::default();
        let args: Vec<String> = std::env::args().skip(1).collect();
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--filter" if i + 1 < args.len() => {
                    cfg.filter = Some(args[i + 1].clone());
                    i += 1;
                }
                s if s.starts_with("--filter=") => {
                    cfg.filter = Some(s["--filter=".len()..].to_string());
                }
                "--quick" => {
                    cfg.measure_time = Duration::from_millis(300);
                    cfg.warmup_time = Duration::from_millis(50);
                    cfg.max_samples = 15;
                }
                // `cargo bench` passes --bench; ignore unknown flags.
                _ => {}
            }
            i += 1;
        }
        if std::env::var("MINMAX_BENCH_QUICK").is_ok() {
            cfg.measure_time = Duration::from_millis(300);
            cfg.warmup_time = Duration::from_millis(50);
            cfg.max_samples = 15;
        }
        cfg
    }
}

#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Median seconds per iteration.
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
    /// Optional work units per iteration (elements, bytes…), for
    /// throughput reporting.
    pub throughput: Option<(f64, &'static str)>,
}

impl Measurement {
    pub fn report(&self) {
        let t = fmt_time(self.median);
        let lo = fmt_time(self.p05);
        let hi = fmt_time(self.p95);
        let thr = match self.throughput {
            Some((units, label)) if self.median > 0.0 => {
                format!("  {} {label}/s", fmt_count(units / self.median))
            }
            _ => String::new(),
        };
        println!(
            "{:<48} {t:>10}  [{lo} .. {hi}]  ({} samples x {} iters){thr}",
            self.name, self.samples, self.iters_per_sample
        );
    }
}

pub struct Runner {
    cfg: Config,
    results: Vec<Measurement>,
}

impl Runner {
    pub fn new() -> Self {
        Self { cfg: Config::from_args(), results: Vec::new() }
    }

    pub fn with_config(cfg: Config) -> Self {
        Self { cfg, results: Vec::new() }
    }

    fn selected(&self, name: &str) -> bool {
        match &self.cfg.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Benchmark `f`, which performs ONE unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        self.bench_with_throughput(name, None, f)
    }

    /// Benchmark with a throughput annotation: `units` of `label` are
    /// processed per call (e.g. `(n_elems as f64, "elem")`).
    pub fn bench_with_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        throughput: Option<(f64, &'static str)>,
        mut f: F,
    ) {
        if !self.selected(name) {
            return;
        }
        // Warmup + calibrate iterations per sample so one sample takes
        // ~measure_time / max_samples.
        let warmup_end = Instant::now() + self.cfg.warmup_time;
        let mut calls = 0u64;
        let warm_start = Instant::now();
        while Instant::now() < warmup_end || calls == 0 {
            f();
            calls += 1;
        }
        let per_call = warm_start.elapsed().as_secs_f64() / calls as f64;
        let target_sample = self.cfg.measure_time.as_secs_f64() / self.cfg.max_samples as f64;
        let iters = ((target_sample / per_call.max(1e-9)).ceil() as u64).max(1);

        let mut res = Reservoir::new();
        let measure_end = Instant::now() + self.cfg.measure_time;
        let mut samples = 0usize;
        while (Instant::now() < measure_end || samples < 5) && samples < self.cfg.max_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            res.push(t0.elapsed().as_secs_f64() / iters as f64);
            samples += 1;
        }
        let m = Measurement {
            name: name.to_string(),
            median: res.percentile(50.0),
            p05: res.percentile(5.0),
            p95: res.percentile(95.0),
            samples,
            iters_per_sample: iters,
            throughput,
        };
        m.report();
        self.results.push(m);
    }

    /// Record a measured scalar statistic (not a timing) into the same
    /// JSON snapshot — e.g. the Gram benches' rows-materialized
    /// peak-memory proxy. Encoded as a measurement with `median = 1 s`
    /// so `save`'s `throughput_per_s` field carries the value verbatim
    /// under the given unit label.
    pub fn stat(&mut self, name: &str, value: f64, unit: &'static str) {
        if !self.selected(name) {
            return;
        }
        println!("{:<48} {value:>10} {unit}", name);
        self.results.push(Measurement {
            name: name.to_string(),
            median: 1.0,
            p05: 1.0,
            p95: 1.0,
            samples: 0,
            iters_per_sample: 0,
            throughput: Some((value, unit)),
        });
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write results as JSON under `results/bench/<file>.json`.
    pub fn save(&self, file: &str) {
        use crate::util::json::{write_json, Json};
        let mut arr = Vec::new();
        for m in &self.results {
            let mut o = Json::obj();
            o.set("name", m.name.as_str())
                .set("median_s", m.median)
                .set("p05_s", m.p05)
                .set("p95_s", m.p95)
                .set("samples", m.samples)
                .set("iters", m.iters_per_sample as u64);
            if let Some((units, label)) = m.throughput {
                o.set("throughput_per_s", units / m.median.max(1e-12)).set("unit", label);
            }
            arr.push(o);
        }
        let path = std::path::Path::new("results/bench").join(format!("{file}.json"));
        if let Err(e) = write_json(&path, &Json::Arr(arr)) {
            eprintln!("warning: could not save bench results: {e}");
        }
    }
}

impl Default for Runner {
    fn default() -> Self {
        Self::new()
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = Config {
            measure_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
            max_samples: 5,
            filter: None,
        };
        let mut r = Runner::with_config(cfg);
        let mut acc = 0u64;
        r.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(r.results().len(), 1);
        assert!(r.results()[0].median >= 0.0);
    }

    #[test]
    fn filter_excludes() {
        let cfg = Config {
            measure_time: Duration::from_millis(10),
            warmup_time: Duration::from_millis(2),
            max_samples: 3,
            filter: Some("match-me".to_string()),
        };
        let mut r = Runner::with_config(cfg);
        r.bench("other", || {});
        assert!(r.results().is_empty());
        r.bench("yes-match-me", || {});
        assert_eq!(r.results().len(), 1);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5).contains(" s"));
        assert_eq!(fmt_count(1500.0), "1.50K");
    }
}
