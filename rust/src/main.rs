//! `minmax` — CLI for the Min-Max Kernels reproduction.
//!
//! Experiment drivers (one per paper table/figure), dataset tooling, and
//! the serving demo. Run `minmax help` for usage.

use minmax::experiments::estimation::{run_fig4_5, run_fig6, EstimationConfig};
use minmax::experiments::perf::run_perf;
use minmax::experiments::svm_tables::{
    run_fig1_3, run_fig7_8, run_table1, HashedSvmConfig, SvmExperimentConfig,
};
use minmax::experiments::table2::run_table2;
use minmax::kernels::gram::GramSpec;
use minmax::util::cli::Args;

const USAGE: &str = "\
minmax — reproduction of 'Min-Max Kernels' (Ping Li, 2015)

USAGE: minmax <command> [flags]

EXPERIMENTS (one per paper table/figure; JSON saved under results/):
  table1    kernel SVM: linear vs min-max vs n-min-max vs intersection
            [--datasets a,b,..] [--n-train N] [--n-test N] [--c-points N]
            [--seed S] [--ablations] [--gram pre|otf] [--gram-cache N]
            (--gram otf streams kernel rows on demand behind an N-row
             LRU cache — default n/4 — instead of an n x n matrix;
             models are bit-identical)
  fig1-3    accuracy-vs-C curves for the four kernels (finer C grid)
            [same flags; default --c-points 17]
  table2    the 13 calibrated word pairs (f1, f2, R, MM)
            [--seed S]
  fig4-5    bias/MSE of full vs 0-bit vs 1-bit CWS  [--k-max N] [--sims N]
            [--full] (paper scale: all pairs, 10k sims)
  fig6      bias keeping t* and only 0/1/2/4 bits of i*  [same flags]
  fig7      linear SVM on 0-bit CWS features, b_i x k grid
            [--datasets ..] [--ks 32,64,..] [--i-bits 1,2,4,8]
  fig8      0-bit vs 2-bit t* schemes  [--ks 128,512,2048]
  perf      whole-stack performance snapshot  [--no-pjrt]

TOOLS:
  gen       generate a synthetic dataset to LIBSVM files
            --name letter --out dir/ [--n-train N] [--n-test N] [--seed S]
  hash      hash a LIBSVM file with 0-bit CWS to expanded features
            --in f.svm --out f.hashed.svm --k 256 --i-bits 8 [--seed S]
  info      list datasets, kernels, artifacts
  help      this message

Datasets are seeded synthetic analogs of the paper's public datasets
(no network in this environment); see DESIGN.md §2 for the mapping.
";

fn main() {
    minmax::util::log::init_from_env();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn svm_cfg(args: &Args) -> Result<SvmExperimentConfig, Box<dyn std::error::Error>> {
    let mut cfg = SvmExperimentConfig::default();
    if let Some(ds) = args.get("datasets") {
        cfg.datasets = ds.split(',').map(|s| s.trim().to_string()).collect();
    }
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.n_train = args.usize_or("n-train", cfg.n_train)?;
    cfg.n_test = args.usize_or("n-test", cfg.n_test)?;
    cfg.c_points = args.usize_or("c-points", cfg.c_points)?;
    if args.flag("ablations") {
        use minmax::kernels::KernelKind;
        cfg.extra_kernels = vec![KernelKind::Resemblance, KernelKind::Chi2, KernelKind::MinMaxChi2];
    }
    let gram_cache = match args.get("gram-cache") {
        Some(v) => Some(v.parse::<usize>().map_err(|e| format!("--gram-cache={v}: {e}"))?),
        None => None,
    };
    cfg.gram = match args.str_or("gram", "pre").as_str() {
        "pre" if gram_cache.is_some() => {
            // Fail loudly instead of silently materializing the full
            // n×n Gram the flag was meant to cap.
            return Err("--gram-cache only applies to --gram otf".into());
        }
        "pre" => GramSpec::Precomputed,
        "otf" => GramSpec::OnTheFly { cache_rows: gram_cache },
        other => return Err(format!("--gram must be 'pre' or 'otf', got '{other}'").into()),
    };
    Ok(cfg)
}

fn est_cfg(args: &Args) -> Result<EstimationConfig, Box<dyn std::error::Error>> {
    let mut cfg =
        if args.flag("full") { EstimationConfig::full() } else { EstimationConfig::default() };
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.k_max = args.usize_or("k-max", cfg.k_max)?;
    cfg.sims = args.usize_or("sims", cfg.sims)?;
    Ok(cfg)
}

fn run(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    match args.command.as_deref() {
        Some("table1") => {
            let cfg = svm_cfg(args)?;
            args.finish()?;
            run_table1(&cfg).print();
        }
        Some("fig1-3") | Some("fig1_3") => {
            let mut cfg = svm_cfg(args)?;
            if args.get("c-points").is_none() {
                cfg.c_points = 17;
            }
            args.finish()?;
            run_fig1_3(&cfg).print();
        }
        Some("table2") => {
            let seed = args.u64_or("seed", 2015)?;
            args.finish()?;
            run_table2(seed, 0.004).0.print();
        }
        Some("fig4-5") | Some("fig4_5") => {
            let cfg = est_cfg(args)?;
            args.finish()?;
            run_fig4_5(&cfg).print();
        }
        Some("fig6") => {
            let cfg = est_cfg(args)?;
            args.finish()?;
            run_fig6(&cfg).print();
        }
        Some("fig7") | Some("fig8") => {
            let is8 = args.command.as_deref() == Some("fig8");
            let mut cfg = HashedSvmConfig::default();
            if let Some(ds) = args.get("datasets") {
                cfg.datasets = ds.split(',').map(|s| s.trim().to_string()).collect();
            }
            cfg.seed = args.u64_or("seed", cfg.seed)?;
            cfg.n_train = args.usize_or("n-train", cfg.n_train)?;
            cfg.n_test = args.usize_or("n-test", cfg.n_test)?;
            cfg.i_bits = args.list_or("i-bits", &cfg.i_bits.clone())?;
            if is8 {
                cfg.t_bits = vec![0, 2];
                cfg.ks = vec![128, 512, 2048];
            }
            cfg.ks = args.list_or("ks", &cfg.ks.clone())?;
            args.finish()?;
            run_fig7_8(&cfg, if is8 { "fig8" } else { "fig7" }).print();
        }
        Some("perf") => {
            let with_pjrt = !args.flag("no-pjrt");
            args.finish()?;
            run_perf(with_pjrt).table.print();
        }
        Some("gen") => {
            use minmax::data::libsvm;
            use minmax::data::synth::{generate, SynthConfig};
            let name = args.str_or("name", "letter");
            let out = args.str_or("out", "data");
            let cfg = SynthConfig {
                seed: args.u64_or("seed", 2015)?,
                n_train: args.usize_or("n-train", 800)?,
                n_test: args.usize_or("n-test", 1200)?,
            };
            args.finish()?;
            let ds = generate(&name, cfg)?;
            let dir = std::path::Path::new(&out);
            libsvm::write_file(
                &dir.join(format!("{name}.train.svm")),
                &ds.train_x.to_csr(),
                &ds.train_y,
            )?;
            libsvm::write_file(
                &dir.join(format!("{name}.test.svm")),
                &ds.test_x.to_csr(),
                &ds.test_y,
            )?;
            println!(
                "wrote {}/{name}.{{train,test}}.svm  ({} train, {} test, dim {}, {} classes)",
                out,
                ds.n_train(),
                ds.n_test(),
                ds.dim(),
                ds.n_classes()
            );
        }
        Some("hash") => {
            use minmax::coordinator::{hash_dataset, PipelineConfig};
            use minmax::data::{libsvm, Dataset, Matrix};
            let input = args.get("in").ok_or("missing --in")?.to_string();
            let output = args.str_or("out", &format!("{input}.hashed"));
            let k = args.usize_or("k", 256)?;
            let i_bits = args.usize_or("i-bits", 8)? as u8;
            let seed = args.u64_or("seed", 2015)?;
            args.finish()?;
            let data = libsvm::read_file(std::path::Path::new(&input), 0)?;
            let n = data.labels.len();
            let ds = Dataset {
                name: input.clone(),
                train_x: Matrix::Sparse(data.features),
                train_y: data.labels,
                test_x: Matrix::Sparse(minmax::data::CsrBuilder::new(1).finish()),
                test_y: vec![],
            };
            let hashed = hash_dataset(&ds, &PipelineConfig::new(seed, k, i_bits))?;
            // LIBSVM IO consumes the CSR export of the one-hot codes.
            let expanded = hashed.train_csr();
            libsvm::write_file(std::path::Path::new(&output), &expanded, &ds.train_y)?;
            println!("hashed {n} rows -> {output} (dim {})", expanded.cols());
        }
        Some("info") => {
            args.finish()?;
            println!("datasets: {}", minmax::data::synth::all_names().join(", "));
            println!(
                "kernels:  linear, min-max, n-min-max, intersection, resemblance, chi2, minmax*chi2"
            );
            let dir = minmax::runtime::default_artifacts_dir();
            match minmax::runtime::Manifest::load(&dir) {
                Ok(m) => println!("artifacts ({}): {}", dir.display(), m.names().join(", ")),
                Err(e) => println!("artifacts: {e}"),
            }
        }
        Some("help") | None => print!("{USAGE}"),
        Some(other) => {
            eprintln!("unknown command '{other}'\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
