//! The ICWS sampler (Algorithm 1) with counter-based randomness.

use super::engine::SketchEngine;
use crate::data::sparse::SparseRow;


/// One CWS sample: the argmin index and its quantized offset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CwsSample {
    pub i_star: u32,
    pub t_star: i64,
}

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// SplitMix64 finalizer — the only mixing primitive; reproduced
/// bit-for-bit in `python/compile/kernels/cws.py`.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform in (0, 1] from a u64 (53-bit mantissa, never exactly 0 so it
/// is a safe `ln` argument).
#[inline]
fn to_uniform(x: u64) -> f64 {
    ((x >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The fixed per-cell random triple `(r, c, β)` for hash sample `j`,
/// dimension `i`: `r, c ~ Gamma(2,1)` (as −ln(U·U)), `β ~ U(0,1)`.
#[inline]
pub fn params_at(seed: u64, j: u32, i: u32) -> (f64, f64, f64) {
    let key = seed ^ mix64(((j as u64) << 32) | i as u64);
    let u1 = to_uniform(mix64(key.wrapping_add(GOLDEN)));
    let u2 = to_uniform(mix64(key.wrapping_add(GOLDEN.wrapping_mul(2))));
    let u3 = to_uniform(mix64(key.wrapping_add(GOLDEN.wrapping_mul(3))));
    let u4 = to_uniform(mix64(key.wrapping_add(GOLDEN.wrapping_mul(4))));
    let u5 = to_uniform(mix64(key.wrapping_add(GOLDEN.wrapping_mul(5))));
    let r = -(u1 * u2).ln();
    let c = -(u3 * u4).ln();
    // β in [0,1): u5 ∈ (0,1]; reuse 1−u5.
    (r, c, 1.0 - u5)
}

/// Materialize the `(r, c, β)` matrices for a dense PJRT batch: three
/// row-major `k × d` f32 buffers drawn from [`params_at`] — the LAYER-2
/// executable receives exactly these, so rust-native and AOT hashing run
/// on identical randomness.
pub fn materialize_params(seed: u64, d: usize, k: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = vec![0.0f32; k * d];
    let mut c = vec![0.0f32; k * d];
    let mut b = vec![0.0f32; k * d];
    for j in 0..k {
        for i in 0..d {
            let (rr, cc, bb) = params_at(seed, j as u32, i as u32);
            r[j * d + i] = rr as f32;
            c[j * d + i] = cc as f32;
            b[j * d + i] = bb as f32;
        }
    }
    (r, c, b)
}

/// The ICWS hasher: `k` independent samples per vector, seeded.
#[derive(Debug, Clone)]
pub struct CwsHasher {
    seed: u64,
    k: usize,
}

impl CwsHasher {
    pub fn new(seed: u64, k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        Self { seed, k }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Hash a sparse nonnegative vector: only nonzeros are touched
    /// (O(nnz · k)). Returns `k` samples. Panics if the vector is empty
    /// or has a non-positive value (callers filter empty rows; CWS is
    /// undefined on the zero vector).
    ///
    /// Perf: `ln(uᵢ)` is computed once per nonzero and reused across all
    /// k samples; the argmin itself runs loop-inverted through
    /// [`super::engine::sample_lazy`] (see EXPERIMENTS.md §Perf).
    pub fn hash_sparse(&self, row: SparseRow<'_>) -> Vec<CwsSample> {
        assert!(row.nnz() > 0, "CWS is undefined on the all-zero vector");
        let ln_u: Vec<f64> = row.values.iter().map(|&v| (v as f64).ln()).collect();
        super::engine::sample_lazy(self.seed, self.k, row.indices, &ln_u)
    }

    /// Hash a dense nonnegative vector (zeros skipped).
    pub fn hash_dense(&self, u: &[f32]) -> Vec<CwsSample> {
        // Gather nonzeros once: index list + cached ln(u).
        let mut indices: Vec<u32> = Vec::with_capacity(u.len());
        let mut ln_u: Vec<f64> = Vec::with_capacity(u.len());
        for (i, &ui) in u.iter().enumerate() {
            if ui > 0.0 {
                indices.push(i as u32);
                ln_u.push((ui as f64).ln());
            }
        }
        assert!(!indices.is_empty(), "CWS is undefined on the all-zero vector");
        super::engine::sample_lazy(self.seed, self.k, &indices, &ln_u)
    }

    /// Build a [`DenseBatchHasher`] for repeated hashing of vectors of
    /// one fixed dimension: the `(r, c, β)` slabs are materialized ONCE
    /// (in the engine's transposed layout) and shared across rows,
    /// removing the ~6 mix64 and 2 ln per cell of parameter derivation
    /// from the per-row cost (EXPERIMENTS.md §Perf). Output is
    /// bit-identical to [`hash_dense`](CwsHasher::hash_dense) in the
    /// default exact mode; `MINMAX_FAST_MATH=1` opts the materialized
    /// engine into `util::fastmath` (≥99.5% sample agreement), while
    /// `CwsHasher`'s own paths always stay exact.
    pub fn dense_batch(&self, dim: usize) -> DenseBatchHasher {
        DenseBatchHasher::new(self.seed, self.k, dim)
    }
}

/// Amortized hasher for one fixed `(seed, k, D)`: a thin facade over the
/// materialized [`SketchEngine`] (transposed `[i*k + j]` slabs, ~24
/// bytes/cell exact mode — 6.3 MB at D=1024, k=256 — plus two derived
/// slabs when fast math is on), traded for a large per-row speedup when
/// many rows share one configuration. This is the service hot path.
pub struct DenseBatchHasher {
    engine: SketchEngine,
}

impl DenseBatchHasher {
    pub fn new(seed: u64, k: usize, dim: usize) -> Self {
        Self { engine: SketchEngine::new(seed, k, dim) }
    }

    pub fn k(&self) -> usize {
        self.engine.k()
    }

    pub fn seed(&self) -> u64 {
        self.engine.seed()
    }

    pub fn dim(&self) -> usize {
        self.engine.dim()
    }

    /// The execution core (parameter slabs + batch entry points).
    pub fn engine(&self) -> &SketchEngine {
        &self.engine
    }

    /// Hash one dense row — identical output to `CwsHasher::hash_dense`
    /// in the default exact mode (see
    /// [`dense_batch`](CwsHasher::dense_batch) for the fastmath caveat).
    pub fn hash(&self, u: &[f32]) -> Vec<CwsSample> {
        self.engine.sketch_dense(u)
    }

    /// Hash a sparse row against the materialized slabs — identical
    /// output to `CwsHasher::hash_sparse` (exact mode) for indices
    /// below `dim` (bounds are validated once per row, not per cell).
    pub fn hash_sparse(&self, row: crate::data::sparse::SparseRow<'_>) -> Vec<CwsSample> {
        self.engine.sketch_sparse(row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::Dense;
    use crate::data::sparse::Csr;
    use crate::kernels::dense_minmax;
    use crate::util::rng::Pcg64;

    #[test]
    fn params_deterministic_and_distributed() {
        let (r1, c1, b1) = params_at(42, 3, 7);
        let (r2, c2, b2) = params_at(42, 3, 7);
        assert_eq!((r1, c1, b1), (r2, c2, b2));
        // Gamma(2,1) has mean 2; beta uniform mean 0.5.
        let n = 50_000u32;
        let (mut sr, mut sc, mut sb) = (0.0, 0.0, 0.0);
        for i in 0..n {
            let (r, c, b) = params_at(1, i % 64, i);
            sr += r;
            sc += c;
            sb += b;
            assert!(r > 0.0 && c > 0.0 && (0.0..1.0).contains(&b));
        }
        assert!((sr / n as f64 - 2.0).abs() < 0.05, "r mean {}", sr / n as f64);
        assert!((sc / n as f64 - 2.0).abs() < 0.05);
        assert!((sb / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn dense_and_sparse_agree() {
        let mut rng = Pcg64::new(5);
        for _ in 0..20 {
            let dim = 1 + rng.below(50) as usize;
            let u: Vec<f32> = (0..dim)
                .map(|_| if rng.uniform() < 0.4 { 0.0 } else { rng.lognormal(0.0, 1.0) as f32 })
                .collect();
            if u.iter().all(|&x| x == 0.0) {
                continue;
            }
            let d = Dense::from_rows(&[&u]);
            let s = Csr::from_dense(&d);
            let h = CwsHasher::new(99, 16);
            assert_eq!(h.hash_dense(&u), h.hash_sparse(s.row(0)));
        }
    }

    #[test]
    fn identical_vectors_always_collide() {
        let u = [0.5f32, 2.0, 0.0, 7.0];
        let h = CwsHasher::new(7, 64);
        assert_eq!(h.hash_dense(&u), h.hash_dense(&u));
    }

    #[test]
    fn scale_invariance_of_i_star() {
        // K_MM(u, λu) < 1 for λ≠1, but i* SHOULD often still match;
        // more fundamentally, hashing is consistent: the sample of λu is
        // determined (uniqueness of CWS). We check the weaker, exact
        // property that the full sample stream is deterministic per seed
        // and differs across seeds.
        let u = [0.5f32, 2.0, 1.0];
        let a = CwsHasher::new(1, 32).hash_dense(&u);
        let b = CwsHasher::new(2, 32).hash_dense(&u);
        assert_ne!(a, b);
    }

    #[test]
    fn collision_probability_matches_minmax() {
        // The core theorem (Eq. 7): Pr[(i*,t*) match] == K_MM. Empirical
        // check on a handful of vector pairs with k = 4000.
        let pairs: Vec<(Vec<f32>, Vec<f32>)> = vec![
            (vec![1.0, 2.0, 0.0, 4.0], vec![2.0, 1.0, 1.0, 4.0]),
            (vec![5.0, 0.0, 1.0, 0.5, 3.0], vec![5.0, 0.0, 1.0, 0.5, 3.0]),
            (vec![1.0, 0.0, 0.0, 0.0], vec![0.0, 1.0, 0.0, 0.0]),
            (vec![0.3, 0.3, 0.3, 0.1], vec![0.1, 0.3, 0.5, 0.1]),
        ];
        let k = 4000;
        let h = CwsHasher::new(2015, k);
        for (u, v) in pairs {
            let want = dense_minmax(&u, &v);
            let su = h.hash_dense(&u);
            let sv = h.hash_dense(&v);
            let got = su.iter().zip(&sv).filter(|(a, b)| a == b).count() as f64 / k as f64;
            // 3σ binomial tolerance.
            let tol = 3.0 * (want * (1.0 - want) / k as f64).sqrt() + 1e-9;
            assert!(
                (got - want).abs() <= tol.max(0.02),
                "K_MM {want} vs collision {got} (tol {tol})"
            );
        }
    }

    #[test]
    fn zero_bit_collision_also_matches_minmax() {
        // Eq. (8): Pr[i* match] ≈ K_MM — the paper's 0-bit claim. The
        // approximation error shrinks with dimensionality; the paper
        // validates on D = 2^16 word vectors. We use D = 64 heavy-tailed
        // vectors and a modest tolerance (the bias at this D is ~1e-3).
        let mut rng = Pcg64::new(31);
        let d = 64;
        let u: Vec<f32> = (0..d).map(|_| rng.lognormal(0.0, 1.0) as f32).collect();
        let v: Vec<f32> =
            u.iter().map(|&x| (x as f64 * rng.lognormal(0.0, 0.6)) as f32).collect();
        let k = 4000;
        let h = CwsHasher::new(7, k);
        let want = dense_minmax(&u, &v);
        let su = h.hash_dense(&u);
        let sv = h.hash_dense(&v);
        let got =
            su.iter().zip(&sv).filter(|(a, b)| a.i_star == b.i_star).count() as f64 / k as f64;
        let tol = 4.0 * (want * (1.0 - want) / k as f64).sqrt();
        assert!((got - want).abs() <= tol.max(0.025), "K_MM {want} vs 0-bit collision {got}");
    }

    #[test]
    fn zero_bit_bias_is_positive_and_small_d_visible() {
        // On a TINY dimension with extreme weights, Pr[i* match] exceeds
        // K_MM noticeably — the 0-bit scheme is genuinely an
        // approximation (the paper's own caveat, §3.4: biases exist but
        // vanish in realistic regimes). Documented here as a test.
        let u = [10.0f32, 1.0, 1.0];
        let v = [1.0f32, 10.0, 1.0];
        let k = 6000;
        let h = CwsHasher::new(5, k);
        let (su, sv) = (h.hash_dense(&u), h.hash_dense(&v));
        let want = dense_minmax(&u, &v); // 1/7
        let full =
            su.iter().zip(&sv).filter(|(a, b)| a == b).count() as f64 / k as f64;
        let zero =
            su.iter().zip(&sv).filter(|(a, b)| a.i_star == b.i_star).count() as f64 / k as f64;
        assert!((full - want).abs() < 0.02, "full {full} vs {want}");
        assert!(zero >= full - 1e-12, "0-bit can only add collisions");
    }

    #[test]
    fn binary_input_matches_resemblance() {
        let u = [1.0f32, 1.0, 0.0, 1.0, 0.0, 0.0];
        let v = [1.0f32, 0.0, 1.0, 1.0, 0.0, 1.0];
        let want = crate::kernels::dense_resemblance(&u, &v); // 2/5
        let k = 4000;
        let h = CwsHasher::new(3, k);
        let su = h.hash_dense(&u);
        let sv = h.hash_dense(&v);
        let got = su.iter().zip(&sv).filter(|(a, b)| a == b).count() as f64 / k as f64;
        assert!((got - want).abs() < 0.03, "R {want} vs {got}");
    }

    #[test]
    fn dense_batch_hasher_matches_per_row_hasher() {
        if crate::cws::engine::fast_math_requested() {
            eprintln!("skipped: bit parity is only claimed without MINMAX_FAST_MATH");
            return;
        }
        let mut rng = Pcg64::new(21);
        let h = CwsHasher::new(77, 24);
        let batch = h.dense_batch(40);
        for _ in 0..25 {
            let mut u: Vec<f32> = (0..40)
                .map(|_| if rng.uniform() < 0.4 { 0.0 } else { rng.lognormal(0.0, 1.0) as f32 })
                .collect();
            if !u.iter().any(|&x| x > 0.0) {
                u[0] = 1.0;
            }
            assert_eq!(batch.hash(&u), h.hash_dense(&u));
        }
        assert_eq!(batch.k(), 24);
        assert_eq!(batch.dim(), 40);
    }

    #[test]
    fn golden_params_cross_language() {
        // Shared golden vectors with python/compile/params.py — both
        // implementations are pinned to the same specification.
        let cases: [(u64, u32, u32, f64, f64, f64); 4] = [
            (42, 0, 0, 2.1321342897249402, 2.34453352747202, 0.9619698314597537),
            (42, 3, 7, 0.9596960229776987, 1.5230354601677472, 0.4030703586081501),
            (2015, 127, 255, 2.5218182169423575, 2.662209577473352, 0.642316614160663),
            (
                123456789,
                65535,
                4095,
                0.822830793014408,
                1.7835555440010344,
                0.3710858790607353,
            ),
        ];
        for (seed, j, i, er, ec, eb) in cases {
            let (r, c, b) = params_at(seed, j, i);
            assert_eq!(r, er, "r({seed},{j},{i})");
            assert_eq!(c, ec, "c({seed},{j},{i})");
            assert_eq!(b, eb, "beta({seed},{j},{i})");
        }
    }

    #[test]
    fn materialized_params_match_lazy() {
        let (r, c, b) = materialize_params(11, 5, 3);
        for j in 0..3u32 {
            for i in 0..5u32 {
                let (rr, cc, bb) = params_at(11, j, i);
                assert_eq!(r[(j * 5 + i) as usize], rr as f32);
                assert_eq!(c[(j * 5 + i) as usize], cc as f32);
                assert_eq!(b[(j * 5 + i) as usize], bb as f32);
            }
        }
    }

    #[test]
    #[should_panic(expected = "undefined on the all-zero")]
    fn zero_vector_panics() {
        CwsHasher::new(1, 4).hash_dense(&[0.0, 0.0]);
    }

    #[test]
    fn sketch_matrix_handles_empty_rows() {
        // `hash_matrix` was removed — `Sketcher::sketch_matrix` is the
        // one whole-matrix entry (same semantics: empty rows → None).
        use crate::sketch::Sketcher;
        let mut b = crate::data::sparse::CsrBuilder::new(4);
        b.push_row(vec![(1, 2.0)]);
        b.push_row(vec![]);
        let m = crate::data::Matrix::Sparse(b.finish());
        let hs = CwsHasher::new(1, 8).sketch_matrix(&m);
        assert!(hs[0].is_some());
        assert!(hs[1].is_none());
    }

    #[test]
    fn weights_matter_not_just_support() {
        // Same support, very different weights ⇒ 0-bit collision tracks
        // K_MM, NOT the resemblance (which is 1.0 here). This is the
        // "0-bit CWS is not minwise hashing" point of §3.4. D = 64 so
        // the 0-bit approximation is in its valid regime.
        let mut rng = Pcg64::new(41);
        let d = 64;
        let u: Vec<f32> = (0..d).map(|_| rng.lognormal(0.0, 1.2) as f32).collect();
        let v: Vec<f32> =
            u.iter().map(|&x| (x as f64 * rng.lognormal(0.0, 1.2)) as f32).collect();
        let want = dense_minmax(&u, &v);
        let resem = crate::kernels::dense_resemblance(&u, &v); // 1.0
        assert!((resem - 1.0).abs() < 1e-12);
        let k = 6000;
        let h = CwsHasher::new(5, k);
        let su = h.hash_dense(&u);
        let sv = h.hash_dense(&v);
        let got =
            su.iter().zip(&sv).filter(|(a, b)| a.i_star == b.i_star).count() as f64 / k as f64;
        assert!((got - want).abs() < 0.04, "K_MM {want} vs {got}");
        assert!((got - resem).abs() > 0.2, "0-bit must not estimate resemblance");
    }
}
