//! Consistent Weighted Sampling (CWS) — Algorithm 1 of the paper — and
//! the paper's contribution: the **0-bit scheme** (discard `t*`) plus the
//! general b-bit encodings of `(i*, t*)` studied in Figures 4–8.
//!
//! The sampler follows Ioffe's ICWS exactly:
//!
//! ```text
//! for i with uᵢ > 0:
//!     rᵢ, cᵢ ~ Gamma(2,1),  βᵢ ~ Uniform(0,1)          (fixed per (sample j, dim i))
//!     tᵢ = ⌊ln uᵢ / rᵢ + βᵢ⌋
//!     yᵢ = exp(rᵢ (tᵢ − βᵢ))
//!     aᵢ = cᵢ / (yᵢ exp(rᵢ))
//! (i*, t*) = (argminᵢ aᵢ, t_{i*})
//! Pr[(i*ᵤ, t*ᵤ) = (i*ᵥ, t*ᵥ)] = K_MM(u, v)            (Eq. 7)
//! ```
//!
//! The random triples `(rᵢⱼ, cᵢⱼ, βᵢⱼ)` are **counter-based**: derived
//! deterministically from `(seed, j, i)` via a SplitMix64 finalizer, so
//!
//! * sparse vectors only pay for their nonzeros (no D×k materialization),
//! * the dense PJRT path and the rust-native path draw *identical*
//!   randomness (the L2 executable receives matrices materialized from
//!   the same function — see [`materialize_params`]), and
//! * two processes hashing the same data with the same seed agree.
//!
//! On binary input CWS degenerates to minwise hashing and the collision
//! probability is the resemblance (Eq. 2) — that is the sense in which
//! min-max generalizes resemblance, and it is how the b-bit-minwise
//! baseline is obtained here (binarize, then hash).

//! Both hashers implement [`crate::sketch::Sketcher`], the crate-wide
//! hashing abstraction the coordinator and [`crate::pipeline`] consume;
//! construct them directly (as here) or via
//! [`crate::kernels::Kernel::sketcher`]. Since the loop-inversion
//! refactor they are thin facades over [`engine::SketchEngine`], the
//! shared execution core (transposed parameter slabs, branchless argmin,
//! optional fast math, chunked parallel batches) — see `engine` for the
//! performance story and DESIGN.md §2.1 for ownership.

pub mod engine;
pub mod lsh;
pub mod minwise;
pub mod sampler;
pub mod schemes;

pub use engine::{SketchEngine, SketchScratch};
pub use lsh::{
    KnnClassifier, LshConfig, LshError, LshIndex, PackedLshIndex, QueryParams, QueryScratch, Vote,
};
pub use minwise::MinwiseHasher;
pub use sampler::{materialize_params, CwsHasher, CwsSample, DenseBatchHasher};
pub use schemes::{collision_fraction, Scheme};
