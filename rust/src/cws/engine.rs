//! The [`SketchEngine`] execution core: one loop-inverted, cache-aware
//! ICWS sampling kernel behind every sketching layer in the crate.
//!
//! The naive sampler (the original `CwsHasher::sample_one` and its three
//! near-copies in `DenseBatchHasher`) ran `for j in 0..k { for i in
//! nonzeros }` with strided `[j*dim + i]` parameter reads: every nonzero
//! touched k cache lines per sample stream, and the argmin carried a
//! branch per cell. This engine is the inverse:
//!
//! * **Transposed structure-of-arrays slabs.** `(r, c, β)` are stored
//!   `[i*k + j]`, so all k parameters of one dimension are contiguous —
//!   the inner loop streams three slabs linearly per nonzero.
//! * **Loop inversion.** Outer over nonzeros, inner over all k samples,
//!   accumulating into `best_a`/`best_i`/`best_t` slabs with branchless
//!   select updates (strict `<`, so the first winner of an exact tie is
//!   kept — identical tie-breaking to the scalar loop, hence bit-for-bit
//!   identical output; pinned by `rust/tests/engine_parity.rs`).
//! * **SIMD-chunked argmin (PR 7).** The inner loop is element-wise
//!   across the k slots, so [`crate::util::simd`] dispatch splits it
//!   into chunks of [`crate::util::simd::CHUNK`] staged through fixed
//!   lane arrays — same arithmetic, same candidate order, same strict
//!   `<`, hence bit-identical to the scalar fallback that
//!   `MINMAX_SIMD=off` forces (pinned by the lanes-vs-scalar module
//!   tests). The exact path keeps libm `exp` as scalar calls; the
//!   fast-math path vectorizes end to end because [`fast_exp`] is pure
//!   float arithmetic.
//! * **`util::fastmath` behind an accuracy-checked toggle.** With
//!   `MINMAX_FAST_MATH=1` (or [`SketchEngine::with_fast_math`]) the
//!   engine precomputes the derived slabs `1/r` and `r·β − r`, replaces
//!   the per-cell division with a multiply, and routes `ln`/`exp`
//!   through [`crate::util::fastmath`]. The toggle only engages after a
//!   runtime probe of the fastmath kernels against libm over the
//!   sampler's operating range (see [`fastmath_accuracy_ok`]); the
//!   default mode is exact libm math and byte-identical output.
//! * **Chunked parallel batch entry.** [`SketchEngine::sketch_rows`]
//!   shards row chunks across [`crate::util::pool::par_claim`] scoped
//!   threads (`MINMAX_THREADS` controls the default; batches below a
//!   minimum work size stay sequential); results are independent of the
//!   thread count by construction (disjoint output chunks, per-row
//!   determinism).
//!
//! [`crate::cws::CwsHasher`] (lazy parameters) and
//! [`crate::cws::DenseBatchHasher`] (materialized slabs) are thin
//! facades over this module — see EXPERIMENTS.md §Perf for measured
//! before/after throughput (`rust/benches/bench_sketch.rs`).

use std::sync::Mutex;

use super::sampler::{params_at, CwsSample};
use crate::data::sparse::{Csr, SparseRow};
use crate::util::fastmath::{fast_exp, fast_ln};
use crate::util::pool;
use crate::util::rng::Pcg64;
use crate::util::simd;

/// Placeholder sample used to prefill batch output slabs; every live row
/// overwrites its slots before they are read.
const EMPTY_SAMPLE: CwsSample = CwsSample { i_star: u32::MAX, t_star: 0 };

/// `true` when the environment requests fast math
/// (`MINMAX_FAST_MATH=1|true|on`).
pub fn fast_math_requested() -> bool {
    matches!(
        std::env::var("MINMAX_FAST_MATH").ok().as_deref(),
        Some("1") | Some("true") | Some("on")
    )
}

/// Runtime accuracy gate for the fastmath toggle: probe
/// [`fast_ln`]/[`fast_exp`] against libm over the exact composites the
/// sampler evaluates (`ln(u₁·u₂)` for uniforms; `exp` of arguments in
/// the argmin exponent range). The toggle only engages when every probe
/// is within 1e-9 relative error — far below the ≤2e-11 the kernels are
/// designed for, so a miscompiled or platform-odd build falls back to
/// exact math instead of silently degrading sketch quality.
pub fn fastmath_accuracy_ok() -> bool {
    let mut rng = Pcg64::new(0xFA57_AC);
    for _ in 0..512 {
        let u = rng.uniform_pos() * rng.uniform_pos();
        if (fast_ln(u) - u.ln()).abs() > 1e-9 * u.ln().abs().max(1.0) {
            return false;
        }
        let x = rng.range_f64(-80.0, 10.0);
        if (fast_exp(x) / x.exp() - 1.0).abs() > 1e-9 {
            return false;
        }
    }
    true
}

/// Branchless k-wide argmin accumulators — the one inner loop every
/// sketching path in the crate now runs. `best_a` carries the running
/// minima, `best_i`/`best_t` the argmin payloads; updates are
/// conditional selects the compiler can vectorize, not branches.
#[derive(Default)]
struct Argmin {
    best_a: Vec<f64>,
    best_i: Vec<u32>,
    best_t: Vec<f64>,
}

impl Argmin {
    /// Re-arm the accumulators for a fresh row of `k` samples. `clear` +
    /// `resize` reuses the existing capacity, so a long-lived `Argmin`
    /// (inside a [`SketchScratch`]) allocates only on its first use.
    fn reset(&mut self, k: usize) {
        self.best_a.clear();
        self.best_a.resize(k, f64::INFINITY);
        self.best_i.clear();
        self.best_i.resize(k, u32::MAX);
        self.best_t.clear();
        self.best_t.resize(k, 0.0);
    }

    /// Exact-math update for one nonzero, dispatched once per call on
    /// the cached [`simd::wide`] decision: the chunked kernel when SIMD
    /// is enabled, the verbatim scalar loop under `MINMAX_SIMD=off`.
    /// Both variants perform the same per-slot arithmetic in the same
    /// candidate order with the same strict `<`, so the dispatch is
    /// bit-invisible (pinned by the module tests below and
    /// `rust/tests/engine_parity.rs`).
    #[inline]
    fn update_exact(&mut self, i: u32, lnu: f64, r: &[f64], c: &[f64], beta: &[f64]) {
        if simd::wide() {
            self.update_exact_lanes(i, lnu, r, c, beta);
        } else {
            self.update_exact_scalar(i, lnu, r, c, beta);
        }
    }

    /// Byte-identical arithmetic to the original scalar sampler
    /// (`t = ⌊ln u / r + β⌋`, `a = c·exp(−r(t−β) − r)`), visited in the
    /// same per-sample candidate order, compared with the same strict
    /// `<`.
    ///
    /// Indexed loop on purpose: six equal-length slabs walked in
    /// lockstep with no bounds checks after the `[..k]` narrowing — the
    /// shape LLVM vectorizes.
    #[inline]
    #[allow(clippy::needless_range_loop)]
    fn update_exact_scalar(&mut self, i: u32, lnu: f64, r: &[f64], c: &[f64], beta: &[f64]) {
        let k = self.best_a.len();
        let (r, c, beta) = (&r[..k], &c[..k], &beta[..k]);
        let ba = &mut self.best_a[..k];
        let bi = &mut self.best_i[..k];
        let bt = &mut self.best_t[..k];
        for j in 0..k {
            let t = (lnu / r[j] + beta[j]).floor();
            let a = c[j] * (-(r[j] * (t - beta[j])) - r[j]).exp();
            let better = a < ba[j];
            ba[j] = if better { a } else { ba[j] };
            bi[j] = if better { i } else { bi[j] };
            bt[j] = if better { t } else { bt[j] };
        }
    }

    /// Chunked exact update: stage `t` and `a` for [`simd::CHUNK`]
    /// slots into fixed arrays (the divide/floor/select phases
    /// vectorize; `exp` stays a scalar libm call per slot, so the
    /// arithmetic is identical to [`Self::update_exact_scalar`] — only
    /// instruction scheduling changes), then run the branchless selects
    /// lane-wise. The tail reuses the scalar body verbatim.
    #[inline]
    #[allow(clippy::needless_range_loop)]
    fn update_exact_lanes(&mut self, i: u32, lnu: f64, r: &[f64], c: &[f64], beta: &[f64]) {
        const L: usize = simd::CHUNK;
        let k = self.best_a.len();
        let (r, c, beta) = (&r[..k], &c[..k], &beta[..k]);
        let ba = &mut self.best_a[..k];
        let bi = &mut self.best_i[..k];
        let bt = &mut self.best_t[..k];
        let mut j = 0;
        while j + L <= k {
            let mut t = [0.0f64; L];
            let mut a = [0.0f64; L];
            for l in 0..L {
                t[l] = (lnu / r[j + l] + beta[j + l]).floor();
            }
            for l in 0..L {
                a[l] = c[j + l] * (-(r[j + l] * (t[l] - beta[j + l])) - r[j + l]).exp();
            }
            for l in 0..L {
                let better = a[l] < ba[j + l];
                ba[j + l] = if better { a[l] } else { ba[j + l] };
                bi[j + l] = if better { i } else { bi[j + l] };
                bt[j + l] = if better { t[l] } else { bt[j + l] };
            }
            j += L;
        }
        while j < k {
            let t = (lnu / r[j] + beta[j]).floor();
            let a = c[j] * (-(r[j] * (t - beta[j])) - r[j]).exp();
            let better = a < ba[j];
            ba[j] = if better { a } else { ba[j] };
            bi[j] = if better { i } else { bi[j] };
            bt[j] = if better { t } else { bt[j] };
            j += 1;
        }
    }

    /// Fast-math update, dispatched like [`Self::update_exact`]: the
    /// division becomes a multiply by the precomputed `1/r`, the
    /// exponent folds the precomputed `r·β − r`
    /// (`−r(t−β) − r = (r·β − r) − r·t`), and `exp` is [`fast_exp`].
    /// Not bit-pinned against libm — gated by [`fastmath_accuracy_ok`]
    /// and the agreement tests in `rust/tests/engine_parity.rs` — but
    /// the lanes/scalar pair is still bit-identical to each other.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn update_fast(
        &mut self,
        i: u32,
        lnu: f64,
        r: &[f64],
        c: &[f64],
        beta: &[f64],
        inv_r: &[f64],
        shift: &[f64],
    ) {
        if simd::wide() {
            self.update_fast_lanes(i, lnu, r, c, beta, inv_r, shift);
        } else {
            self.update_fast_scalar(i, lnu, r, c, beta, inv_r, shift);
        }
    }

    #[inline]
    #[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
    fn update_fast_scalar(
        &mut self,
        i: u32,
        lnu: f64,
        r: &[f64],
        c: &[f64],
        beta: &[f64],
        inv_r: &[f64],
        shift: &[f64],
    ) {
        let k = self.best_a.len();
        let (r, c, beta, inv_r, shift) = (&r[..k], &c[..k], &beta[..k], &inv_r[..k], &shift[..k]);
        let ba = &mut self.best_a[..k];
        let bi = &mut self.best_i[..k];
        let bt = &mut self.best_t[..k];
        for j in 0..k {
            let t = (lnu * inv_r[j] + beta[j]).floor();
            let a = c[j] * fast_exp(shift[j] - r[j] * t);
            let better = a < ba[j];
            ba[j] = if better { a } else { ba[j] };
            bi[j] = if better { i } else { bi[j] };
            bt[j] = if better { t } else { bt[j] };
        }
    }

    /// Chunked fast-math update. Unlike the exact path, *everything*
    /// here vectorizes — [`fast_exp`] is pure float arithmetic with no
    /// libm call, so the whole chunk lowers to straight-line vector
    /// code. Same per-slot arithmetic and select order as
    /// [`Self::update_fast_scalar`], hence bit-identical to it.
    #[inline]
    #[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
    fn update_fast_lanes(
        &mut self,
        i: u32,
        lnu: f64,
        r: &[f64],
        c: &[f64],
        beta: &[f64],
        inv_r: &[f64],
        shift: &[f64],
    ) {
        const L: usize = simd::CHUNK;
        let k = self.best_a.len();
        let (r, c, beta, inv_r, shift) = (&r[..k], &c[..k], &beta[..k], &inv_r[..k], &shift[..k]);
        let ba = &mut self.best_a[..k];
        let bi = &mut self.best_i[..k];
        let bt = &mut self.best_t[..k];
        let mut j = 0;
        while j + L <= k {
            let mut t = [0.0f64; L];
            let mut a = [0.0f64; L];
            for l in 0..L {
                t[l] = (lnu * inv_r[j + l] + beta[j + l]).floor();
            }
            for l in 0..L {
                a[l] = c[j + l] * fast_exp(shift[j + l] - r[j + l] * t[l]);
            }
            for l in 0..L {
                let better = a[l] < ba[j + l];
                ba[j + l] = if better { a[l] } else { ba[j + l] };
                bi[j + l] = if better { i } else { bi[j + l] };
                bt[j + l] = if better { t[l] } else { bt[j + l] };
            }
            j += L;
        }
        while j < k {
            let t = (lnu * inv_r[j] + beta[j]).floor();
            let a = c[j] * fast_exp(shift[j] - r[j] * t);
            let better = a < ba[j];
            ba[j] = if better { a } else { ba[j] };
            bi[j] = if better { i } else { bi[j] };
            bt[j] = if better { t } else { bt[j] };
            j += 1;
        }
    }

    fn write(&self, out: &mut [CwsSample]) {
        for (slot, ((&a, &i), &t)) in
            out.iter_mut().zip(self.best_a.iter().zip(&self.best_i).zip(&self.best_t))
        {
            debug_assert!(a.is_finite() && i != u32::MAX, "argmin never updated");
            *slot = CwsSample { i_star: i, t_star: t as i64 };
        }
    }
}

/// Reusable per-row sketching scratch: the nonzero gather buffers
/// (`indices`, `ln_u`), the [`Argmin`] accumulators, and the lazy
/// path's per-dimension parameter buffers. One `SketchScratch` held by
/// a caller (a serving thread, a batch chunk worker) makes every
/// subsequent `*_with` sketch call allocation-free in steady state —
/// the buffers only grow, never shrink, and `clear`/`resize` reuse
/// capacity. The scratch carries no row state between calls: using a
/// shared scratch is bit-identical to a fresh one per row (pinned by
/// the engine tests and `rust/tests/serve_parity.rs`).
#[derive(Default)]
pub struct SketchScratch {
    indices: Vec<u32>,
    ln_u: Vec<f64>,
    acc: Argmin,
    /// Lazy-path per-dimension parameter buffers (k-wide).
    r: Vec<f64>,
    c: Vec<f64>,
    beta: Vec<f64>,
}

impl SketchScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Loop-inverted lazy sampling: parameters derived on the fly from
/// `(seed, j, i)` (no materialization, any index range), accumulated
/// through the same [`Argmin`] kernel as the materialized paths. This is
/// what [`crate::cws::CwsHasher`] runs; output is bit-identical to the
/// pre-refactor per-sample loop.
pub fn sample_lazy_into(seed: u64, k: usize, indices: &[u32], ln_u: &[f64], out: &mut [CwsSample]) {
    let mut scratch = SketchScratch::new();
    let SketchScratch { acc, r, c, beta, .. } = &mut scratch;
    sample_lazy_core(seed, k, indices, ln_u, acc, r, c, beta, out);
}

/// Lazy-sample a sparse row with caller-owned scratch: `ln(v)` is
/// cached into the scratch (exact libm math — the lazy path never uses
/// fastmath) and the argmin / parameter buffers are reused across rows
/// instead of allocated per call.
pub fn sample_lazy_sparse_with(
    seed: u64,
    k: usize,
    row: SparseRow<'_>,
    s: &mut SketchScratch,
    out: &mut [CwsSample],
) {
    assert!(row.nnz() > 0, "CWS is undefined on the all-zero vector");
    s.ln_u.clear();
    s.ln_u.extend(row.values.iter().map(|&v| (v as f64).ln()));
    // Field-disjoint borrows: ln_u is read, acc/r/c/beta are written.
    let SketchScratch { ln_u, acc, r, c, beta, .. } = s;
    sample_lazy_core(seed, k, row.indices, ln_u, acc, r, c, beta, out);
}

/// The shared lazy-sampling body: per-dimension parameter scratch
/// (`r`, `c`, `beta`) refilled for each nonzero — the derivation cost
/// (6 mix64 + 2 ln per cell) is identical to the lazy loop it replaced;
/// only the accumulation order changed.
#[allow(clippy::too_many_arguments)]
fn sample_lazy_core(
    seed: u64,
    k: usize,
    indices: &[u32],
    ln_u: &[f64],
    acc: &mut Argmin,
    r: &mut Vec<f64>,
    c: &mut Vec<f64>,
    beta: &mut Vec<f64>,
    out: &mut [CwsSample],
) {
    assert_eq!(indices.len(), ln_u.len(), "indices/ln_u length mismatch");
    assert!(!indices.is_empty(), "CWS is undefined on the all-zero vector");
    assert_eq!(out.len(), k, "output slot must hold k samples");
    acc.reset(k);
    r.clear();
    r.resize(k, 0.0);
    c.clear();
    c.resize(k, 0.0);
    beta.clear();
    beta.resize(k, 0.0);
    for (&i, &lnu) in indices.iter().zip(ln_u) {
        for (j, ((rj, cj), bj)) in r.iter_mut().zip(c.iter_mut()).zip(beta.iter_mut()).enumerate()
        {
            let (rr, cc, bb) = params_at(seed, j as u32, i);
            *rj = rr;
            *cj = cc;
            *bj = bb;
        }
        acc.update_exact(i, lnu, r, c, beta);
    }
    acc.write(out);
}

/// Allocating convenience over [`sample_lazy_into`].
pub fn sample_lazy(seed: u64, k: usize, indices: &[u32], ln_u: &[f64]) -> Vec<CwsSample> {
    let mut out = vec![EMPTY_SAMPLE; k];
    sample_lazy_into(seed, k, indices, ln_u, &mut out);
    out
}

/// The materialized ICWS execution core. Owns the `(r, c, β)` parameter
/// slabs for one `(seed, k, dim)` in transposed `[i*k + j]` layout
/// (plus the `1/r` and `r·β − r` derived slabs when fast math is on)
/// and runs every row through the shared loop-inverted [`Argmin`]
/// kernel. Construct once per configuration and reuse across rows —
/// facades: [`crate::cws::CwsHasher::dense_batch`],
/// [`crate::cws::DenseBatchHasher`]. `Clone` duplicates the slabs so
/// service replicas can each own one engine.
#[derive(Clone)]
pub struct SketchEngine {
    seed: u64,
    k: usize,
    dim: usize,
    /// `r` in `[i*k + j]` transposed layout.
    r: Vec<f64>,
    /// `c`, same layout.
    c: Vec<f64>,
    /// `β`, same layout.
    beta: Vec<f64>,
    /// `1/r`, same layout; empty unless fast math is enabled.
    inv_r: Vec<f64>,
    /// `r·β − r`, same layout; empty unless fast math is enabled.
    shift: Vec<f64>,
    fast: bool,
}

impl SketchEngine {
    /// Materialize the parameter slabs for `(seed, k, dim)`. Fast math
    /// engages only if `MINMAX_FAST_MATH` requests it AND
    /// [`fastmath_accuracy_ok`] passes; the default is exact libm math,
    /// bit-identical to the lazy sampler.
    pub fn new(seed: u64, k: usize, dim: usize) -> Self {
        assert!(k > 0, "k must be positive");
        let n = k * dim;
        let mut r = Vec::with_capacity(n);
        let mut c = Vec::with_capacity(n);
        let mut beta = Vec::with_capacity(n);
        for i in 0..dim as u32 {
            for j in 0..k as u32 {
                let (rr, cc, bb) = params_at(seed, j, i);
                r.push(rr);
                c.push(cc);
                beta.push(bb);
            }
        }
        let mut engine =
            Self { seed, k, dim, r, c, beta, inv_r: Vec::new(), shift: Vec::new(), fast: false };
        if fast_math_requested() {
            engine = engine.with_fast_math(true);
        }
        engine
    }

    /// Enable/disable the fastmath path explicitly. Enabling runs the
    /// accuracy gate; if the probe fails the engine stays exact (the
    /// toggle is a request, not a promise). Disabling drops the derived
    /// slabs.
    pub fn with_fast_math(mut self, fast: bool) -> Self {
        if fast && fastmath_accuracy_ok() {
            if self.inv_r.is_empty() {
                self.inv_r = self.r.iter().map(|&r| 1.0 / r).collect();
                self.shift = self.r.iter().zip(&self.beta).map(|(&r, &b)| r * b - r).collect();
            }
            self.fast = true;
        } else {
            self.fast = false;
            self.inv_r = Vec::new();
            self.shift = Vec::new();
        }
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Whether the fastmath path is active.
    pub fn fast_math(&self) -> bool {
        self.fast
    }

    /// The `(r, c, β)` slab for dimension `i` — k contiguous values
    /// each. Exposed for the golden engine-vs-`params_at` tests.
    pub fn params_slab(&self, i: usize) -> (&[f64], &[f64], &[f64]) {
        assert!(i < self.dim, "dimension {i} out of range for dim {}", self.dim);
        let base = i * self.k;
        (
            &self.r[base..base + self.k],
            &self.c[base..base + self.k],
            &self.beta[base..base + self.k],
        )
    }

    #[inline]
    fn ln(&self, x: f64) -> f64 {
        if self.fast {
            fast_ln(x)
        } else {
            x.ln()
        }
    }

    /// Core entry: sketch one row given its nonzero `indices` (each
    /// `< dim`) and cached `ln(uᵢ)` values, writing k samples into
    /// `out`. Outer loop over nonzeros, inner loop over samples.
    pub fn sketch_indices_into(&self, indices: &[u32], ln_u: &[f64], out: &mut [CwsSample]) {
        let mut acc = Argmin::default();
        self.sketch_indices_core(indices, ln_u, &mut acc, out);
    }

    /// The one argmin loop, against a caller-owned accumulator.
    fn sketch_indices_core(
        &self,
        indices: &[u32],
        ln_u: &[f64],
        acc: &mut Argmin,
        out: &mut [CwsSample],
    ) {
        assert_eq!(indices.len(), ln_u.len(), "indices/ln_u length mismatch");
        assert!(!indices.is_empty(), "CWS is undefined on the all-zero vector");
        assert_eq!(out.len(), self.k, "output slot must hold k samples");
        let k = self.k;
        acc.reset(k);
        for (&i, &lnu) in indices.iter().zip(ln_u) {
            let base = i as usize * k;
            if self.fast {
                acc.update_fast(
                    i,
                    lnu,
                    &self.r[base..base + k],
                    &self.c[base..base + k],
                    &self.beta[base..base + k],
                    &self.inv_r[base..base + k],
                    &self.shift[base..base + k],
                );
            } else {
                acc.update_exact(
                    i,
                    lnu,
                    &self.r[base..base + k],
                    &self.c[base..base + k],
                    &self.beta[base..base + k],
                );
            }
        }
        acc.write(out);
    }

    /// Sketch a sparse row. Index bounds are validated ONCE per row
    /// (single pass over the nonzeros), not per `(sample, nonzero)` cell
    /// inside the hot loop.
    pub fn sketch_sparse_into(&self, row: SparseRow<'_>, out: &mut [CwsSample]) {
        let mut scratch = SketchScratch::new();
        self.sketch_sparse_with(row, &mut scratch, out);
    }

    /// [`SketchEngine::sketch_sparse_into`] against caller-owned
    /// scratch: the `ln(v)` cache and argmin accumulators live in the
    /// [`SketchScratch`], so a caller that holds one (serving threads,
    /// batch chunk workers) sketches with zero per-row allocations.
    /// Output is bit-identical to the allocating entry.
    pub fn sketch_sparse_with(
        &self,
        row: SparseRow<'_>,
        s: &mut SketchScratch,
        out: &mut [CwsSample],
    ) {
        assert!(row.nnz() > 0, "CWS is undefined on the all-zero vector");
        let max = row.indices.iter().copied().max().expect("nonempty row");
        assert!((max as usize) < self.dim, "index {max} out of range for dim {}", self.dim);
        s.ln_u.clear();
        s.ln_u.extend(row.values.iter().map(|&v| self.ln(v as f64)));
        let SketchScratch { ln_u, acc, .. } = s;
        self.sketch_indices_core(row.indices, ln_u, acc, out);
    }

    pub fn sketch_sparse(&self, row: SparseRow<'_>) -> Vec<CwsSample> {
        let mut out = vec![EMPTY_SAMPLE; self.k];
        self.sketch_sparse_into(row, &mut out);
        out
    }

    /// Sketch a dense row (zeros skipped; panics if no positive entry).
    pub fn sketch_dense_into(&self, u: &[f32], out: &mut [CwsSample]) {
        let mut scratch = SketchScratch::new();
        self.sketch_dense_with(u, &mut scratch, out);
    }

    /// [`SketchEngine::sketch_dense_into`] against caller-owned scratch
    /// (the nonzero gather, `ln(u)` cache, and argmin accumulators all
    /// reuse the [`SketchScratch`] buffers) — the zero-allocation entry
    /// the fused serving scorer and the batch chunk loops ride. Output
    /// is bit-identical to the allocating entry.
    pub fn sketch_dense_with(&self, u: &[f32], s: &mut SketchScratch, out: &mut [CwsSample]) {
        assert_eq!(u.len(), self.dim, "dimension mismatch");
        s.indices.clear();
        s.ln_u.clear();
        for (i, &ui) in u.iter().enumerate() {
            if ui > 0.0 {
                s.indices.push(i as u32);
                s.ln_u.push(self.ln(ui as f64));
            }
        }
        assert!(!s.indices.is_empty(), "CWS is undefined on the all-zero vector");
        let SketchScratch { indices, ln_u, acc, .. } = s;
        self.sketch_indices_core(indices, ln_u, acc, out);
    }

    pub fn sketch_dense(&self, u: &[f32]) -> Vec<CwsSample> {
        let mut out = vec![EMPTY_SAMPLE; self.k];
        self.sketch_dense_into(u, &mut out);
        out
    }

    /// The chunked batch entry the coordinator and pipeline ride: sketch
    /// many dense rows, sharding contiguous row chunks across
    /// [`pool::par_claim`] scoped threads (sequential below a minimum
    /// work size — thread spawns would dominate tiny service batches).
    /// Every row must have a positive entry (callers filter empty rows;
    /// see [`crate::sketch::Sketcher::sketch_matrix`]). Results are
    /// identical for every thread count.
    pub fn sketch_rows(&self, rows: &[&[f32]]) -> Vec<Vec<CwsSample>> {
        self.sketch_rows_with_threads(rows, batch_threads(rows.len(), self.k))
    }

    /// [`SketchEngine::sketch_rows`] with an explicit thread count
    /// (honored as given — no work-size clamp — so tests and callers
    /// with better knowledge can force either path). Each chunk worker
    /// owns one [`SketchScratch`], so the per-row gather/argmin buffers
    /// are reused across the chunk instead of allocated per row.
    pub fn sketch_rows_with_threads(&self, rows: &[&[f32]], threads: usize) -> Vec<Vec<CwsSample>> {
        let mut out: Vec<Vec<CwsSample>> =
            rows.iter().map(|_| vec![EMPTY_SAMPLE; self.k]).collect();
        par_fill_chunks_ctx(&mut out, threads, SketchScratch::new, |i, slot, scratch| {
            self.sketch_dense_with(rows[i], scratch, slot);
        });
        out
    }
}

/// Below this many output sample slots (`rows × k`) a batch runs
/// sequentially: scoped-thread spawn/join costs tens of microseconds,
/// which dwarfs the sketching work of the small dynamic-batcher flushes
/// the service produces under light load.
const PAR_MIN_SLOTS: usize = 2048;

/// Default thread count for a `rows × k` batch:
/// [`pool::default_threads`] (`MINMAX_THREADS`), clamped to sequential
/// below the minimum work size. The batch entry points the coordinator
/// and `Sketcher` overrides ride use this; the `*_with_threads` APIs
/// honor their argument verbatim.
pub fn batch_threads(rows: usize, k: usize) -> usize {
    if rows.saturating_mul(k) < PAR_MIN_SLOTS {
        1
    } else {
        pool::default_threads()
    }
}

/// Shard the per-row fill `fill(row_index, &mut slot, &mut ctx)` over
/// contiguous chunks of the output, with one `mk_ctx()` context (e.g. a
/// [`SketchScratch`] or a serving scratch arena) per claimed chunk so
/// per-row buffers amortize across the chunk. Each chunk's `&mut`
/// slice is handed out exactly once to whichever [`pool::par_claim`]
/// worker steals it, so the closure writes disjoint memory (the final
/// per-row `Vec`s directly — no second copy pass) without locks in the
/// inner loop. ~4 chunks per thread, claimed one at a time, balances
/// ragged row costs without a static partition. The context must not
/// carry row state between calls (every scratch type here resets per
/// row), which is what keeps results identical at any thread count.
pub(crate) fn par_fill_chunks_ctx<T, C, M, F>(out: &mut [T], threads: usize, mk_ctx: M, fill: F)
where
    T: Send,
    M: Fn() -> C + Sync,
    F: Fn(usize, &mut T, &mut C) + Sync,
{
    let n = out.len();
    let threads = threads.max(1);
    if threads <= 1 || n <= 1 {
        let mut ctx = mk_ctx();
        for (i, slot) in out.iter_mut().enumerate() {
            fill(i, slot, &mut ctx);
        }
        return;
    }
    let chunk_rows = n.div_ceil(threads * 4).max(1);
    let nchunks = n.div_ceil(chunk_rows);
    let slots: Vec<Mutex<Option<&mut [T]>>> =
        out.chunks_mut(chunk_rows).map(|c| Mutex::new(Some(c))).collect();
    pool::par_claim(nchunks, threads, |ci| {
        let slab = slots[ci].lock().unwrap().take().expect("chunk claimed twice");
        let mut ctx = mk_ctx();
        for (off, slot) in slab.iter_mut().enumerate() {
            fill(ci * chunk_rows + off, slot, &mut ctx);
        }
    });
}

/// Parallel sketch over a CSR matrix: rows with no nonzeros yield `None`
/// (hashing is undefined there), everything else is sketched by `f` into
/// its k-wide slot, with a per-chunk [`SketchScratch`] so the `ln(v)` /
/// argmin buffers are reused across each chunk's rows. The shared
/// batching substrate behind the
/// [`crate::sketch::Sketcher::sketch_matrix`] impls of both ICWS
/// facades (lazy `f` for [`crate::cws::CwsHasher`], engine `f` for
/// [`crate::cws::DenseBatchHasher`]).
pub fn sketch_csr_with<F>(m: &Csr, k: usize, threads: usize, f: F) -> Vec<Option<Vec<CwsSample>>>
where
    F: Fn(SparseRow<'_>, &mut SketchScratch, &mut [CwsSample]) + Sync,
{
    let mut out: Vec<Option<Vec<CwsSample>>> = (0..m.rows())
        .map(|i| if m.row(i).nnz() == 0 { None } else { Some(vec![EMPTY_SAMPLE; k]) })
        .collect();
    par_fill_chunks_ctx(&mut out, threads, SketchScratch::new, |i, slot, scratch| {
        if let Some(samples) = slot {
            f(m.row(i), scratch, samples);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::Dense;
    use crate::data::sparse::Csr;

    fn random_row(rng: &mut Pcg64, dim: usize, zero_frac: f64) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim)
            .map(|_| if rng.uniform() < zero_frac { 0.0 } else { rng.lognormal(0.0, 1.0) as f32 })
            .collect();
        if !v.iter().any(|&x| x > 0.0) {
            v[0] = 1.0;
        }
        v
    }

    #[test]
    fn slabs_match_params_at() {
        let e = SketchEngine::new(42, 8, 16);
        for i in 0..16u32 {
            let (r, c, b) = e.params_slab(i as usize);
            for j in 0..8u32 {
                let (rr, cc, bb) = params_at(42, j, i);
                assert_eq!(r[j as usize], rr);
                assert_eq!(c[j as usize], cc);
                assert_eq!(b[j as usize], bb);
            }
        }
    }

    #[test]
    fn engine_matches_lazy_sampler_bit_for_bit() {
        let mut rng = Pcg64::new(17);
        // Pin exact mode: bit parity is only claimed there (a test run
        // under MINMAX_FAST_MATH=1 must not flip this engine).
        let e = SketchEngine::new(9, 24, 48).with_fast_math(false);
        for _ in 0..25 {
            let v = random_row(&mut rng, 48, 0.4);
            let d = Dense::from_rows(&[&v]);
            let s = Csr::from_dense(&d);
            let row = s.row(0);
            let ln_u: Vec<f64> = row.values.iter().map(|&x| (x as f64).ln()).collect();
            let lazy = sample_lazy(9, 24, row.indices, &ln_u);
            assert_eq!(e.sketch_dense(&v), lazy);
            assert_eq!(e.sketch_sparse(row), lazy);
        }
    }

    #[test]
    fn lanes_argmin_is_bit_identical_to_scalar() {
        // The SIMD dispatch contract: chunked and scalar argmin updates
        // compute the same bits for every k (full chunks, ragged tails,
        // k below one chunk), in both exact and fast math.
        let mut rng = Pcg64::new(0x1A9E);
        for &k in &[1usize, 3, 7, 8, 9, 16, 23, 64] {
            let exact = SketchEngine::new(77, k, 40).with_fast_math(false);
            let fast = SketchEngine::new(77, k, 40).with_fast_math(true);
            let mut scalar = Argmin::default();
            let mut lanes = Argmin::default();
            let mut scalar_f = Argmin::default();
            let mut lanes_f = Argmin::default();
            scalar.reset(k);
            lanes.reset(k);
            scalar_f.reset(k);
            lanes_f.reset(k);
            for i in 0..40u32 {
                let lnu = rng.range_f64(-6.0, 2.0);
                let (r, c, beta) = exact.params_slab(i as usize);
                scalar.update_exact_scalar(i, lnu, r, c, beta);
                lanes.update_exact_lanes(i, lnu, r, c, beta);
                let base = i as usize * k;
                let (inv_r, shift) =
                    (&fast.inv_r[base..base + k], &fast.shift[base..base + k]);
                scalar_f.update_fast_scalar(i, lnu, r, c, beta, inv_r, shift);
                lanes_f.update_fast_lanes(i, lnu, r, c, beta, inv_r, shift);
            }
            for (s, l) in [(&scalar, &lanes), (&scalar_f, &lanes_f)] {
                let a_same =
                    s.best_a.iter().zip(&l.best_a).all(|(x, y)| x.to_bits() == y.to_bits());
                let t_same =
                    s.best_t.iter().zip(&l.best_t).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(a_same, "best_a diverged at k={k}");
                assert!(t_same, "best_t diverged at k={k}");
                assert_eq!(s.best_i, l.best_i, "best_i diverged at k={k}");
            }
        }
    }

    #[test]
    fn sketch_rows_is_thread_count_invariant() {
        let mut rng = Pcg64::new(5);
        let rows: Vec<Vec<f32>> = (0..33).map(|_| random_row(&mut rng, 40, 0.5)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let e = SketchEngine::new(3, 16, 40);
        let one = e.sketch_rows_with_threads(&refs, 1);
        for threads in [2usize, 3, 4, 8] {
            assert_eq!(one, e.sketch_rows_with_threads(&refs, threads), "threads={threads}");
        }
        assert_eq!(one.len(), 33);
        assert!(one.iter().all(|s| s.len() == 16));
        for (row, samples) in refs.iter().zip(&one) {
            assert_eq!(*samples, e.sketch_dense(row));
        }
    }

    #[test]
    fn sketch_csr_marks_empty_rows_and_parallelizes() {
        let mut b = crate::data::sparse::CsrBuilder::new(6);
        b.push_row(vec![(1, 2.0)]);
        b.push_row(vec![]);
        b.push_row(vec![(0, 0.5), (5, 3.0)]);
        let m = b.finish();
        let e = SketchEngine::new(1, 8, 6);
        for threads in [1usize, 4] {
            let out = sketch_csr_with(&m, 8, threads, |row, scratch, slot| {
                e.sketch_sparse_with(row, scratch, slot);
            });
            assert_eq!(out.len(), 3);
            assert_eq!(out[0], Some(e.sketch_sparse(m.row(0))));
            assert_eq!(out[1], None);
            assert_eq!(out[2], Some(e.sketch_sparse(m.row(2))));
        }
    }

    #[test]
    fn fast_math_gate_and_agreement() {
        assert!(fastmath_accuracy_ok());
        let mut rng = Pcg64::new(11);
        let exact = SketchEngine::new(7, 64, 64).with_fast_math(false);
        let fast = SketchEngine::new(7, 64, 64).with_fast_math(true);
        assert!(fast.fast_math());
        assert!(!exact.fast_math());
        let (mut same, mut total) = (0usize, 0usize);
        for _ in 0..100 {
            let v = random_row(&mut rng, 64, 0.3);
            let a = exact.sketch_dense(&v);
            let b = fast.sketch_dense(&v);
            total += a.len();
            same += a.iter().zip(&b).filter(|(x, y)| x == y).count();
        }
        // ≤1e-10 relative math error flips an argmin only on near-exact
        // ties; anything below 99.5% agreement is a real defect.
        assert!(same as f64 >= 0.995 * total as f64, "fastmath agreement {same}/{total}");
    }

    #[test]
    fn disabling_fast_math_drops_derived_slabs() {
        let e = SketchEngine::new(1, 4, 8).with_fast_math(true).with_fast_math(false);
        assert!(!e.fast_math());
        let v = [1.0f32, 0.0, 2.0, 0.0, 0.5, 0.0, 0.0, 3.0];
        let ln_u: Vec<f64> = [1.0f64, 2.0, 0.5, 3.0].iter().map(|x| x.ln()).collect();
        assert_eq!(e.sketch_dense(&v), sample_lazy(1, 4, &[0, 2, 4, 7], &ln_u));
    }

    #[test]
    fn shared_scratch_is_bit_identical_to_fresh_scratch() {
        // The zero-allocation contract: a SketchScratch reused across
        // many rows (dense and sparse, exact and fast math, mixed nnz)
        // must produce exactly what per-row fresh buffers produce.
        let mut rng = Pcg64::new(23);
        for fast in [false, true] {
            let e = SketchEngine::new(13, 24, 32).with_fast_math(fast);
            let mut shared = SketchScratch::new();
            let mut lazy_shared = SketchScratch::new();
            for _ in 0..20 {
                let v = random_row(&mut rng, 32, rng.uniform());
                let d = Dense::from_rows(&[&v]);
                let csr = Csr::from_dense(&d);
                let mut got = vec![EMPTY_SAMPLE; 24];
                e.sketch_dense_with(&v, &mut shared, &mut got);
                assert_eq!(got, e.sketch_dense(&v));
                e.sketch_sparse_with(csr.row(0), &mut shared, &mut got);
                assert_eq!(got, e.sketch_sparse(csr.row(0)));
                if !fast {
                    // Lazy scratch path too (always exact math).
                    sample_lazy_sparse_with(13, 24, csr.row(0), &mut lazy_shared, &mut got);
                    let ln_u: Vec<f64> =
                        csr.row(0).values.iter().map(|&x| (x as f64).ln()).collect();
                    assert_eq!(got, sample_lazy(13, 24, csr.row(0).indices, &ln_u));
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_index_is_caught_per_row() {
        let e = SketchEngine::new(1, 4, 4);
        let indices = [9u32];
        let values = [1.0f32];
        e.sketch_sparse(SparseRow { indices: &indices, values: &values });
    }

    #[test]
    #[should_panic(expected = "undefined on the all-zero")]
    fn zero_vector_panics() {
        SketchEngine::new(1, 4, 2).sketch_dense(&[0.0, 0.0]);
    }
}
