//! Sub-linear retrieval over 0-bit CWS sketches — the banded b-bit LSH
//! engine behind the crate's search workload.
//!
//! Standard banding: `k = bands × rows_per_band` samples per vector; a
//! band's `rows_per_band` sample values concatenate into one bucket
//! key. Two vectors with min-max similarity `s` share a specific band
//! with probability `s^r`, hence collide in ≥1 of `b` bands with
//! probability `1 − (1 − s^r)^b` — the classic S-curve, tuned by
//! (bands, rows_per_band). Candidates are exactly re-ranked with the
//! sparse min-max kernel.
//!
//! Two index layouts share that machinery:
//!
//! * [`LshIndex`] — the legacy sample-keyed index, kept for parity: each
//!   band key is an FNV hash of the band's full `i*` tuple. Retrieval
//!   quality matches exact-tuple banding; memory is the bucket tables
//!   only (samples are discarded after the build).
//! * [`PackedLshIndex`] — the production layout: the corpus is sketched
//!   once through the chunked-parallel engine entry, truncated to b-bit
//!   codes (arXiv:1105.4385), and stored as one contiguous `[n × words]`
//!   u64 slab ([`PackedCodes`]). Band `t`'s key is bits
//!   `[t·r·b, (t+1)·r·b)` *sliced straight out of the packed row* — no
//!   re-hash, no per-row `Vec`. Lookup supports **multi-probe** (flip
//!   the lowest-confidence band positions to reach `T` extra buckets per
//!   band, recovering recall at fewer bands) and an optional packed-code
//!   Hamming prefilter through [`crate::util::simd::packed_mismatch`]
//!   before the exact re-rank.
//!
//! Both indexes replace the old `HashMap<u64, Vec<u32>>`-per-band
//! storage with [`BandTable`]: an open-addressed, power-of-two-sized
//! slot array over one contiguous postings arena (load factor ≤ 0.5,
//! linear probing on `mix64(key)`), built by sorting `(key, row)` pairs
//! once — no per-bucket allocations, postings ascending within a
//! bucket, and lookups touch two cache lines in the common case.
//!
//! Queries run through a caller-owned [`QueryScratch`]; after warm-up
//! `candidates_with` / `query_with` perform **zero heap allocations per
//! call** (measured by the counting allocator in `bench_lsh.rs`).
//! [`KnnClassifier`] layers majority / similarity-weighted voting over
//! the top-k, and `coordinator::cluster::QueryRouter` exposes the whole
//! thing as the cluster's `query` service mode.

use std::sync::Arc;

use crate::data::sparse::{Csr, SparseRow};
use crate::features::{Expansion, ExpansionError, PackedCodes};
use crate::kernels::sparse_minmax;
use crate::util::simd;

use super::engine::{self, SketchScratch};
use super::sampler::{mix64, CwsSample};

/// Typed construction/validation errors for the LSH layer — the
/// `Expansion::checked` pattern applied to index builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LshError {
    /// `bands == 0`: every vector would hash to zero bands and nothing
    /// is ever retrieved (previously accepted silently).
    ZeroBands,
    /// `rows_per_band == 0`: every band key degenerates to the hash of
    /// the empty tuple, so all rows collide in every band.
    ZeroRowsPerBand,
    /// b-bit width without a supported packing (`b` must divide 64 and
    /// lie in 1..=16 — see [`PackedCodes::supported_bits`]).
    UnsupportedBits(u8),
    /// `rows_per_band · bits > 64`: a band key must fit one u64 so it
    /// can be sliced from the packed row without re-hashing.
    BandTooWide { rows_per_band: usize, bits: u8 },
    /// The (k, bits) pair overflows the one-hot code space.
    CodeSpace(ExpansionError),
    /// `KnnClassifier` label vector length ≠ corpus rows.
    LabelMismatch { labels: usize, rows: usize },
}

impl std::fmt::Display for LshError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LshError::ZeroBands => write!(f, "bands must be >= 1"),
            LshError::ZeroRowsPerBand => write!(f, "rows_per_band must be >= 1"),
            LshError::UnsupportedBits(b) => {
                write!(f, "unsupported b-bit width {b} (need b in {{1,2,4,8,16}})")
            }
            LshError::BandTooWide { rows_per_band, bits } => write!(
                f,
                "band key {rows_per_band}x{bits} bits exceeds one u64 word"
            ),
            LshError::CodeSpace(e) => write!(f, "code space: {e}"),
            LshError::LabelMismatch { labels, rows } => {
                write!(f, "label vector length {labels} != corpus rows {rows}")
            }
        }
    }
}

impl std::error::Error for LshError {}

#[derive(Debug, Clone, Copy)]
pub struct LshConfig {
    pub bands: usize,
    pub rows_per_band: usize,
    pub seed: u64,
}

impl LshConfig {
    /// Validated construction — the only path that guards the
    /// `bands == 0` / `rows_per_band == 0` degeneracies (struct-literal
    /// construction stays possible for backwards compatibility, but
    /// every index build re-validates).
    pub fn checked(bands: usize, rows_per_band: usize, seed: u64) -> Result<Self, LshError> {
        let cfg = Self { bands, rows_per_band, seed };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The degeneracy check shared by [`Self::checked`] and the index
    /// builds.
    pub(crate) fn validate(&self) -> Result<(), LshError> {
        if self.bands == 0 {
            return Err(LshError::ZeroBands);
        }
        if self.rows_per_band == 0 {
            return Err(LshError::ZeroRowsPerBand);
        }
        Ok(())
    }

    pub fn k(&self) -> usize {
        self.bands * self.rows_per_band
    }

    /// Probability that a pair at similarity `s` becomes a candidate.
    pub fn candidate_probability(&self, s: f64) -> f64 {
        1.0 - (1.0 - s.powi(self.rows_per_band as i32)).powi(self.bands as i32)
    }
}

impl Default for LshConfig {
    fn default() -> Self {
        Self { bands: 16, rows_per_band: 4, seed: 2015 }
    }
}

/// Lookup knobs for the packed index (the legacy index ignores them —
/// its keys hash full tuples, so probing has no bit-level handle).
///
/// Defaults are the exact configuration: no extra probes, no Hamming
/// prefilter — every candidate is re-ranked with the exact kernel, so
/// parity tests run against `QueryParams::default()`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct QueryParams {
    /// Extra buckets probed per band. Probe `p` flips band position
    /// `order[p mod r]` (positions ordered by ascending query
    /// confidence) by the nonzero code delta `(1 + p/r) mod 2^b` —
    /// deterministic, and the probe sequence for `T` is a prefix of the
    /// sequence for `T' > T`, so candidate sets are superset-monotone
    /// in `probes`.
    pub probes: usize,
    /// Minimum fraction of agreeing packed code positions a candidate
    /// needs to reach the exact re-rank (`0.0` disables the prefilter).
    /// Computed with [`simd::packed_mismatch`] on the u64 slab — a few
    /// XOR/popcount words per candidate instead of an O(nnz) kernel.
    pub min_agreement: f32,
}

/// Reusable per-query workspace: sketch scratch, the query's packed
/// words, probe ordering, candidate/result arenas. After the first few
/// queries every buffer has reached steady-state capacity and
/// `candidates_with` / `query_with` / `classify_with` allocate nothing
/// (verified by the counting allocator in `bench_lsh.rs`). A scratch
/// carries no state between calls: reusing one is bit-identical to a
/// fresh scratch per query.
#[derive(Default)]
pub struct QueryScratch {
    sketch: SketchScratch,
    samples: Vec<CwsSample>,
    qcodes: Vec<u32>,
    qwords: Vec<u64>,
    conf: Vec<f32>,
    order: Vec<u32>,
    cands: Vec<u32>,
    scored: Vec<(u32, f64)>,
    votes: Vec<(i32, f64)>,
}

impl QueryScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One band's bucket directory: open-addressed slots (power-of-two
/// count, ≤ 50% load, linear probing on `mix64(key)`) over a single
/// contiguous postings arena. `lens[slot] == 0` marks an empty slot —
/// valid because a real bucket always holds ≥ 1 row. Built once by
/// sorting the band's `(key, row)` pairs, so postings within a bucket
/// are ascending row ids and iteration order is deterministic.
struct BandTable {
    keys: Vec<u64>,
    offsets: Vec<u32>,
    lens: Vec<u32>,
    postings: Vec<u32>,
}

impl BandTable {
    fn build(mut entries: Vec<(u64, u32)>) -> BandTable {
        entries.sort_unstable();
        let mut distinct = 0usize;
        let mut prev = None;
        for &(key, _) in &entries {
            if prev != Some(key) {
                distinct += 1;
                prev = Some(key);
            }
        }
        let slots = (distinct.max(1) * 2).next_power_of_two();
        let mask = slots - 1;
        let mut keys = vec![0u64; slots];
        let mut offsets = vec![0u32; slots];
        let mut lens = vec![0u32; slots];
        let mut postings = Vec::with_capacity(entries.len());
        let mut i = 0usize;
        while i < entries.len() {
            let key = entries[i].0;
            let start = postings.len() as u32;
            let mut j = i;
            while j < entries.len() && entries[j].0 == key {
                postings.push(entries[j].1);
                j += 1;
            }
            let mut slot = (mix64(key) as usize) & mask;
            while lens[slot] != 0 {
                slot = (slot + 1) & mask;
            }
            keys[slot] = key;
            offsets[slot] = start;
            lens[slot] = (j - i) as u32;
            i = j;
        }
        BandTable { keys, offsets, lens, postings }
    }

    /// The rows bucketed under `key` (empty slice when absent). Probing
    /// terminates because load ≤ 0.5 guarantees an empty slot.
    #[inline]
    fn bucket(&self, key: u64) -> &[u32] {
        if self.postings.is_empty() {
            return &[];
        }
        let mask = self.keys.len() - 1;
        let mut slot = (mix64(key) as usize) & mask;
        loop {
            if self.lens[slot] == 0 {
                return &[];
            }
            if self.keys[slot] == key {
                let o = self.offsets[slot] as usize;
                return &self.postings[o..o + self.lens[slot] as usize];
            }
            slot = (slot + 1) & mask;
        }
    }

    fn occupied(&self) -> usize {
        self.lens.iter().filter(|&&l| l != 0).count()
    }
}

/// Slice `len_bits` bits starting at absolute bit `start_bit` out of a
/// packed row — the band-key slicing contract: band `t`'s key is bits
/// `[t·r·b, (t+1)·r·b)` of the row's little-endian u64 words, which is
/// exactly the concatenation of its `r` truncated codes because
/// [`PackedCodes`] stores slot `j` at bit `(j mod 64/b)·b` of word
/// `j/(64/b)`.
#[inline]
fn band_key_bits(words: &[u64], start_bit: usize, len_bits: usize) -> u64 {
    let w = start_bit >> 6;
    let off = start_bit & 63;
    let mut key = words[w] >> off;
    if off != 0 && off + len_bits > 64 {
        key |= words[w + 1] << (64 - off);
    }
    if len_bits < 64 {
        key &= (1u64 << len_bits) - 1;
    }
    key
}

/// Sketch a query into `s.samples` via the engine's lazy sparse entry
/// (bit-identical to `CwsHasher::hash_sparse` — the pinned engine
/// contract). Returns `false` for an empty query, which can never match
/// anything (CWS is undefined on the zero vector).
fn sketch_query(seed: u64, k: usize, query: SparseRow<'_>, s: &mut QueryScratch) -> bool {
    if query.nnz() == 0 {
        return false;
    }
    s.samples.clear();
    s.samples.resize(k, CwsSample { i_star: 0, t_star: 0 });
    engine::sample_lazy_sparse_with(seed, k, query, &mut s.sketch, &mut s.samples);
    true
}

/// The query's weight at coordinate `i` (0 when absent — cannot happen
/// for an `i*` drawn from the query's own support, but stays total).
#[inline]
fn weight_at(row: SparseRow<'_>, i: u32) -> f32 {
    match row.indices.binary_search(&i) {
        Ok(p) => row.values[p],
        Err(_) => 0.0,
    }
}

/// Descending similarity, ascending row id on ties; truncate to `n`.
/// `total_cmp` gives the same order as `partial_cmp` for the finite
/// nonnegative similarities the kernel produces, without the unwrap.
fn rank_and_truncate(scored: &mut Vec<(u32, f64)>, n: usize) {
    scored.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    scored.truncate(n);
}

/// Merge-dedup the candidate arena in place (`sort_unstable` + `dedup`
/// are allocation-free), leaving ascending unique row ids.
fn dedup_candidates(cands: &mut Vec<u32>) {
    cands.sort_unstable();
    cands.dedup();
}

/// The legacy sample-keyed LSH index: band keys are FNV-1a hashes of
/// the band's full `(i*…)` tuple. Kept as the parity baseline for
/// [`PackedLshIndex`] (at `b = 16` and `dim ≤ 65536` truncation is
/// lossless, so both indexes induce identical candidate sets).
pub struct LshIndex {
    cfg: LshConfig,
    /// One open-addressed bucket directory per band.
    tables: Vec<BandTable>,
    corpus: Arc<Csr>,
}

impl LshIndex {
    /// Build over all rows of `corpus` (rows with no nonzeros are
    /// skipped — they can never be retrieved). The corpus is shared via
    /// `Arc` so the coordinator's shards reference one copy.
    ///
    /// The whole corpus is sketched through the engine's chunked
    /// parallel batch entry (bit-identical to per-row
    /// [`super::sampler::CwsHasher::hash_sparse`] at any
    /// `MINMAX_THREADS`); bucket assembly sorts `(key, row)` pairs, so
    /// bucket contents are deterministic and ascending.
    pub fn try_build(corpus: Arc<Csr>, cfg: LshConfig) -> Result<LshIndex, LshError> {
        cfg.validate()?;
        let k = cfg.k();
        let threads = engine::batch_threads(corpus.rows(), k);
        let sketched = engine::sketch_csr_with(&corpus, k, threads, |row, s, out| {
            engine::sample_lazy_sparse_with(cfg.seed, k, row, s, out)
        });
        let mut entries: Vec<Vec<(u64, u32)>> = vec![Vec::new(); cfg.bands];
        for (row_id, samples) in sketched.iter().enumerate() {
            let Some(samples) = samples else { continue };
            for (band, key) in band_keys(samples, cfg.rows_per_band).enumerate() {
                entries[band].push((key, row_id as u32));
            }
        }
        let tables = entries.into_iter().map(BandTable::build).collect();
        Ok(LshIndex { cfg, tables, corpus })
    }

    /// Corpus-owning build, kept for source compatibility.
    #[deprecated(
        since = "0.8.0",
        note = "use `LshIndex::try_build(Arc<Csr>, cfg)` — shares the corpus without \
                cloning and surfaces config errors instead of accepting degenerate \
                bands/rows_per_band"
    )]
    pub fn build(corpus: Csr, cfg: LshConfig) -> LshIndex {
        Self::try_build(Arc::new(corpus), cfg).expect("invalid LshConfig")
    }

    pub fn config(&self) -> &LshConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.corpus.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.corpus.rows() == 0
    }

    pub fn corpus(&self) -> &Arc<Csr> {
        &self.corpus
    }

    /// Sketch the query and collect band postings into `s.cands`
    /// (sorted, deduplicated). Returns `false` for an empty query.
    fn fill_candidates(&self, query: SparseRow<'_>, s: &mut QueryScratch) -> bool {
        s.cands.clear();
        if !sketch_query(self.cfg.seed, self.cfg.k(), query, s) {
            return false;
        }
        for (band, key) in band_keys(&s.samples, self.cfg.rows_per_band).enumerate() {
            s.cands.extend_from_slice(self.tables[band].bucket(key));
        }
        dedup_candidates(&mut s.cands);
        true
    }

    /// Candidate row ids: deduplicated, ascending — identical input
    /// always produces identical output. Zero-alloc once `s` is warm.
    pub fn candidates_with<'s>(&self, query: SparseRow<'_>, s: &'s mut QueryScratch) -> &'s [u32] {
        self.fill_candidates(query, s);
        &s.cands
    }

    /// Allocating convenience wrapper around [`Self::candidates_with`].
    pub fn candidates(&self, query: SparseRow<'_>) -> Vec<u32> {
        let mut s = QueryScratch::new();
        self.candidates_with(query, &mut s).to_vec()
    }

    /// Fill `s.scored` with the ranked top-`n` over the candidates.
    fn fill_topk(&self, query: SparseRow<'_>, n: usize, s: &mut QueryScratch) {
        let ok = self.fill_candidates(query, s);
        let QueryScratch { cands, scored, .. } = s;
        scored.clear();
        if ok {
            scored.extend(
                cands
                    .iter()
                    .map(|&id| (id, sparse_minmax(query, self.corpus.row(id as usize)))),
            );
            rank_and_truncate(scored, n);
        }
    }

    /// Top-`n` most similar corpus rows by exact min-max similarity,
    /// re-ranked over the LSH candidates. Returns `(row_id, similarity)`
    /// descending (ties broken by ascending id). Zero-alloc once `s` is
    /// warm; an empty query yields an empty slice.
    pub fn query_with<'s>(
        &self,
        query: SparseRow<'_>,
        n: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [(u32, f64)] {
        self.fill_topk(query, n, s);
        &s.scored
    }

    /// Allocating convenience wrapper around [`Self::query_with`].
    pub fn query(&self, query: SparseRow<'_>, n: usize) -> Vec<(u32, f64)> {
        let mut s = QueryScratch::new();
        self.query_with(query, n, &mut s).to_vec()
    }

    /// Average bucket occupancy per band (diagnostics / tests).
    pub fn mean_bucket_size(&self) -> f64 {
        mean_bucket_size(&self.tables)
    }
}

fn mean_bucket_size(tables: &[BandTable]) -> f64 {
    let total: usize = tables.iter().map(|t| t.postings.len()).sum();
    let buckets: usize = tables.iter().map(BandTable::occupied).sum();
    if buckets == 0 {
        0.0
    } else {
        total as f64 / buckets as f64
    }
}

/// The production index: b-bit truncated codes in one contiguous
/// `[n × words]` u64 slab, band keys sliced straight from the packed
/// words, open-addressed bucket tables, multi-probe lookup, and an
/// optional SWAR Hamming prefilter ahead of the exact re-rank.
///
/// Memory per row is `⌈k·b/64⌉ · 8` bytes (6 words at k=48, b=8 —
/// versus ~16 bytes *per sample* for the `Vec<CwsSample>` layout the
/// legacy index sketches through), so a million-row corpus indexes in
/// tens of megabytes plus the postings arenas.
pub struct PackedLshIndex {
    cfg: LshConfig,
    bits: u8,
    /// Bits per band key: `rows_per_band · bits` (validated ≤ 64).
    band_bits: usize,
    codes: PackedCodes,
    tables: Vec<BandTable>,
    corpus: Arc<Csr>,
}

impl PackedLshIndex {
    /// Sketch `corpus` once through the parallel engine entry, truncate
    /// each sample to its low `bits` bits, pack into the u64 slab, and
    /// build one bucket table per band from word-sliced keys.
    ///
    /// Validates the config (typed errors instead of the old silent
    /// acceptance), the b-bit width (`bits ∈ {1,2,4,8,16}` so codes
    /// never straddle words), the band width (`rows_per_band · bits ≤
    /// 64`), and the code space (`Expansion::checked`).
    pub fn build(corpus: Arc<Csr>, cfg: LshConfig, bits: u8) -> Result<PackedLshIndex, LshError> {
        cfg.validate()?;
        if bits == 0 || bits > 16 || PackedCodes::supported_bits(1usize << bits) != Some(bits) {
            return Err(LshError::UnsupportedBits(bits));
        }
        let band_bits = cfg.rows_per_band * bits as usize;
        if band_bits > 64 {
            return Err(LshError::BandTooWide { rows_per_band: cfg.rows_per_band, bits });
        }
        let k = cfg.k();
        Expansion::checked(k, bits, 0).map_err(LshError::CodeSpace)?;

        let threads = engine::batch_threads(corpus.rows(), k);
        let sketched = engine::sketch_csr_with(&corpus, k, threads, |row, s, out| {
            engine::sample_lazy_sparse_with(cfg.seed, k, row, s, out)
        });
        let codes = PackedCodes::from_samples(&sketched, k, bits)
            .expect("bits validated against supported_bits");
        // Free the per-row sample vectors before building the postings
        // arenas — at a million rows the samples dominate peak memory.
        drop(sketched);

        let mut tables = Vec::with_capacity(cfg.bands);
        for t in 0..cfg.bands {
            let mut entries = Vec::with_capacity(codes.rows());
            for i in 0..codes.rows() {
                if codes.is_empty_row(i) {
                    continue;
                }
                let key = band_key_bits(codes.word_row(i), t * band_bits, band_bits);
                entries.push((key, i as u32));
            }
            tables.push(BandTable::build(entries));
        }
        Ok(PackedLshIndex { cfg, bits, band_bits, codes, tables, corpus })
    }

    pub fn config(&self) -> &LshConfig {
        &self.cfg
    }

    /// Bits per truncated code.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn len(&self) -> usize {
        self.corpus.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.corpus.rows() == 0
    }

    pub fn corpus(&self) -> &Arc<Csr> {
        &self.corpus
    }

    /// The packed code slab (diagnostics / tests).
    pub fn codes(&self) -> &PackedCodes {
        &self.codes
    }

    pub fn mean_bucket_size(&self) -> f64 {
        mean_bucket_size(&self.tables)
    }

    /// Sketch + pack the query, collect base and probe buckets per
    /// band into `s.cands` (sorted, deduplicated). Returns `false` for
    /// an empty query.
    fn fill_candidates(
        &self,
        query: SparseRow<'_>,
        params: QueryParams,
        s: &mut QueryScratch,
    ) -> bool {
        s.cands.clear();
        let k = self.cfg.k();
        if !sketch_query(self.cfg.seed, k, query, s) {
            return false;
        }
        // Pack the query exactly as the build packed corpus rows:
        // rel = i_star mod 2^b per slot (pack_row_into masks for us).
        s.qcodes.clear();
        s.qcodes.extend(s.samples.iter().map(|smp| smp.i_star));
        PackedCodes::pack_row_into(&s.qcodes, 1usize << self.bits, self.bits, &mut s.qwords);
        if params.probes > 0 {
            // Per-sample confidence: the query's weight at the argmin
            // coordinate. A heavy i* dominates its exponential race, so
            // its code is stable under resampling; light coordinates
            // are the likeliest to differ on a true neighbor — flip
            // those first.
            s.conf.clear();
            s.conf.extend(s.samples.iter().map(|smp| weight_at(query, smp.i_star)));
        }

        let r = self.cfg.rows_per_band;
        let code_mask = (1u64 << self.bits) - 1;
        let QueryScratch { qwords, conf, order, cands, .. } = s;
        for (t, table) in self.tables.iter().enumerate() {
            let base = band_key_bits(qwords, t * self.band_bits, self.band_bits);
            cands.extend_from_slice(table.bucket(base));
            if params.probes == 0 {
                continue;
            }
            // Band-local positions, least-confident first (ties by
            // position for determinism).
            order.clear();
            order.extend(0..r as u32);
            order.sort_unstable_by(|&a, &b| {
                conf[t * r + a as usize]
                    .total_cmp(&conf[t * r + b as usize])
                    .then(a.cmp(&b))
            });
            for p in 0..params.probes {
                let pos = order[p % r] as usize;
                let delta = ((1 + p / r) as u64) & code_mask;
                if delta == 0 {
                    continue; // wrapped to the identity — nothing new
                }
                let probe = base ^ (delta << (pos * self.bits as usize));
                cands.extend_from_slice(table.bucket(probe));
            }
        }
        dedup_candidates(cands);
        true
    }

    /// Candidate row ids under `params`: deduplicated, ascending,
    /// superset-monotone in `params.probes`. Zero-alloc once `s` is
    /// warm.
    pub fn candidates_with<'s>(
        &self,
        query: SparseRow<'_>,
        params: QueryParams,
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        self.fill_candidates(query, params, s);
        &s.cands
    }

    /// Allocating convenience wrapper around [`Self::candidates_with`].
    pub fn candidates(&self, query: SparseRow<'_>, params: QueryParams) -> Vec<u32> {
        let mut s = QueryScratch::new();
        self.candidates_with(query, params, &mut s).to_vec()
    }

    /// Fill `s.scored` with the ranked top-`n`: candidates, optional
    /// packed-Hamming prefilter, exact `sparse_minmax` on survivors.
    fn fill_topk(&self, query: SparseRow<'_>, n: usize, params: QueryParams, s: &mut QueryScratch) {
        let ok = self.fill_candidates(query, params, s);
        let k = self.cfg.k() as u32;
        let floor = (params.min_agreement.clamp(0.0, 1.0) * k as f32).ceil() as u32;
        let QueryScratch { cands, scored, qwords, .. } = s;
        scored.clear();
        if !ok {
            return;
        }
        for &id in cands.iter() {
            if floor > 0 {
                let mism =
                    simd::packed_mismatch(qwords, self.codes.word_row(id as usize), self.bits);
                if k - mism < floor {
                    continue;
                }
            }
            scored.push((id, sparse_minmax(query, self.corpus.row(id as usize))));
        }
        rank_and_truncate(scored, n);
    }

    /// Top-`n` most similar corpus rows under `params`: `(row_id,
    /// similarity)` descending, ties by ascending id. With default
    /// params this is the exact re-rank of every candidate; a nonzero
    /// `min_agreement` short-circuits low-agreement candidates with a
    /// few XOR/popcount words each. Zero-alloc once `s` is warm.
    pub fn query_with<'s>(
        &self,
        query: SparseRow<'_>,
        n: usize,
        params: QueryParams,
        s: &'s mut QueryScratch,
    ) -> &'s [(u32, f64)] {
        self.fill_topk(query, n, params, s);
        &s.scored
    }

    /// Allocating convenience wrapper: default params, fresh scratch.
    pub fn query(&self, query: SparseRow<'_>, n: usize) -> Vec<(u32, f64)> {
        let mut s = QueryScratch::new();
        self.query_with(query, n, QueryParams::default(), &mut s).to_vec()
    }
}

/// Vote aggregation for [`KnnClassifier`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vote {
    /// Each of the top-k neighbors contributes one vote.
    Majority,
    /// Each neighbor contributes its min-max similarity.
    Weighted,
}

/// KNN classification over a [`PackedLshIndex`]: retrieve the top-k
/// neighbors, vote their labels (majority or similarity-weighted), tie
/// break by the smaller label. `classify_with` is zero-alloc once the
/// scratch is warm.
pub struct KnnClassifier {
    index: PackedLshIndex,
    labels: Vec<i32>,
    neighbors: usize,
    params: QueryParams,
    vote: Vote,
}

impl KnnClassifier {
    pub fn new(
        index: PackedLshIndex,
        labels: Vec<i32>,
        neighbors: usize,
    ) -> Result<KnnClassifier, LshError> {
        if labels.len() != index.len() {
            return Err(LshError::LabelMismatch { labels: labels.len(), rows: index.len() });
        }
        Ok(KnnClassifier {
            index,
            labels,
            neighbors: neighbors.max(1),
            params: QueryParams::default(),
            vote: Vote::Majority,
        })
    }

    pub fn with_vote(mut self, vote: Vote) -> Self {
        self.vote = vote;
        self
    }

    pub fn with_params(mut self, params: QueryParams) -> Self {
        self.params = params;
        self
    }

    pub fn index(&self) -> &PackedLshIndex {
        &self.index
    }

    pub fn labels(&self) -> &[i32] {
        &self.labels
    }

    /// Predict the label for `query`, or `None` when retrieval finds no
    /// candidates (empty query, or nothing collides in any band).
    pub fn classify_with(&self, query: SparseRow<'_>, s: &mut QueryScratch) -> Option<i32> {
        self.index.fill_topk(query, self.neighbors, self.params, s);
        let QueryScratch { scored, votes, .. } = s;
        votes.clear();
        for &(id, sim) in scored.iter() {
            let label = self.labels[id as usize];
            let w = match self.vote {
                Vote::Majority => 1.0,
                Vote::Weighted => sim,
            };
            match votes.iter_mut().find(|(l, _)| *l == label) {
                Some((_, acc)) => *acc += w,
                None => votes.push((label, w)),
            }
        }
        let mut best: Option<(i32, f64)> = None;
        for &(label, w) in votes.iter() {
            let better = match best {
                None => true,
                Some((bl, bw)) => w > bw || (w == bw && label < bl),
            };
            if better {
                best = Some((label, w));
            }
        }
        best.map(|(label, _)| label)
    }

    /// Allocating convenience wrapper around [`Self::classify_with`].
    pub fn classify(&self, query: SparseRow<'_>) -> Option<i32> {
        let mut s = QueryScratch::new();
        self.classify_with(query, &mut s)
    }
}

/// Iterate the legacy band keys of a sample vector: each band FNV-1a
/// hashes its `rows_per_band` `i*` values (0-bit: `t*` ignored) into
/// one u64. Unchanged from the HashMap-era index so bucket membership
/// is bit-compatible across the rebuild.
fn band_keys<'a>(
    samples: &'a [CwsSample],
    rows_per_band: usize,
) -> impl Iterator<Item = u64> + 'a {
    samples.chunks(rows_per_band).map(|chunk| {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for s in chunk {
            h ^= s.i_star as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::sampler::CwsHasher;
    use crate::data::sparse::CsrBuilder;
    use crate::util::rng::Pcg64;

    /// Corpus of `groups` clusters: `per_group` near-duplicates each.
    fn corpus(groups: usize, per_group: usize, dim: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut b = CsrBuilder::new(dim);
        for _g in 0..groups {
            let proto: Vec<f32> = (0..dim)
                .map(|_| if rng.uniform() < 0.5 { 0.0 } else { rng.lognormal(0.0, 1.0) as f32 })
                .collect();
            for _ in 0..per_group {
                let row: Vec<(u32, f32)> = proto
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v > 0.0)
                    .map(|(i, &v)| (i as u32, (v as f64 * rng.lognormal(0.0, 0.12)) as f32))
                    .collect();
                b.push_row(row);
            }
        }
        b.finish()
    }

    fn shared(c: &Csr) -> Arc<Csr> {
        Arc::new(c.clone())
    }

    #[test]
    fn config_validation_rejects_degenerate_shapes() {
        assert_eq!(LshConfig::checked(0, 4, 1).unwrap_err(), LshError::ZeroBands);
        assert_eq!(LshConfig::checked(4, 0, 1).unwrap_err(), LshError::ZeroRowsPerBand);
        assert!(LshConfig::checked(4, 4, 1).is_ok());
        // Builds re-validate even for struct-literal configs.
        let c = corpus(2, 2, 16, 5);
        let bad = LshConfig { bands: 0, rows_per_band: 3, seed: 1 };
        assert_eq!(LshIndex::try_build(shared(&c), bad).err(), Some(LshError::ZeroBands));
        assert_eq!(
            PackedLshIndex::build(shared(&c), bad, 8).err(),
            Some(LshError::ZeroBands)
        );
    }

    #[test]
    fn packed_build_rejects_bad_widths() {
        let c = corpus(2, 2, 16, 5);
        let cfg = LshConfig { bands: 4, rows_per_band: 3, seed: 1 };
        for bits in [0u8, 3, 6, 17] {
            assert_eq!(
                PackedLshIndex::build(shared(&c), cfg, bits).err(),
                Some(LshError::UnsupportedBits(bits)),
                "bits={bits}"
            );
        }
        // 5 codes × 16 bits = 80 > 64: band key can't fit one word.
        let wide = LshConfig { bands: 4, rows_per_band: 5, seed: 1 };
        assert_eq!(
            PackedLshIndex::build(shared(&c), wide, 16).err(),
            Some(LshError::BandTooWide { rows_per_band: 5, bits: 16 })
        );
    }

    #[test]
    fn near_duplicates_are_retrieved() {
        let per = 4;
        let c = corpus(12, per, 64, 1);
        let cfg = LshConfig { bands: 24, rows_per_band: 3, seed: 9 };
        let idx = LshIndex::try_build(shared(&c), cfg).unwrap();
        // Query with each row; its group mates must dominate the top-k.
        let mut hits = 0usize;
        let mut total = 0usize;
        for q in 0..c.rows() {
            let group = q / per;
            let top = idx.query(c.row(q), per);
            for (id, sim) in &top {
                total += 1;
                if (*id as usize) / per == group {
                    hits += 1;
                }
                assert!((0.0..=1.0).contains(sim));
            }
        }
        assert!(hits as f64 / total as f64 > 0.9, "group precision {hits}/{total}");
    }

    #[test]
    fn self_query_returns_self_first() {
        let c = corpus(6, 3, 48, 2);
        let idx = LshIndex::try_build(shared(&c), LshConfig::default()).unwrap();
        let pidx = PackedLshIndex::build(shared(&c), LshConfig::default(), 8).unwrap();
        for q in [0usize, 5, 11] {
            let top = idx.query(c.row(q), 1);
            assert_eq!(top[0].0 as usize, q);
            assert!((top[0].1 - 1.0).abs() < 1e-9);
            let ptop = pidx.query(c.row(q), 1);
            assert_eq!(ptop[0].0 as usize, q);
            assert!((ptop[0].1 - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn s_curve_is_monotone() {
        let cfg = LshConfig { bands: 16, rows_per_band: 4, seed: 0 };
        let probs: Vec<f64> =
            (0..=10).map(|i| cfg.candidate_probability(i as f64 / 10.0)).collect();
        assert!(probs.windows(2).all(|w| w[1] >= w[0] - 1e-12));
        assert!(probs[0] < 1e-6);
        assert!((probs[10] - 1.0).abs() < 1e-9);
        // Threshold behavior: far below (1/b)^(1/r) → tiny.
        assert!(cfg.candidate_probability(0.2) < 0.1);
        assert!(cfg.candidate_probability(0.9) > 0.99);
    }

    #[test]
    fn dissimilar_vectors_rarely_candidates() {
        // Disjoint supports → similarity 0 → never candidates (band keys
        // derive from i*, which lives in disjoint index sets).
        let mut b = CsrBuilder::new(1000);
        b.push_row((0..50).map(|i| (i as u32, 1.0)).collect());
        b.push_row((500..550).map(|i| (i as u32, 1.0)).collect());
        let c = b.finish();
        let idx = LshIndex::try_build(shared(&c), LshConfig::default()).unwrap();
        let cands = idx.candidates(c.row(1));
        assert!(!cands.contains(&0), "disjoint vectors must not collide");
    }

    #[test]
    fn empty_rows_skipped_not_panicking() {
        let mut b = CsrBuilder::new(8);
        b.push_row(vec![(1, 1.0)]);
        b.push_row(vec![]);
        let c = b.finish();
        let idx = LshIndex::try_build(shared(&c), LshConfig::default()).unwrap();
        assert_eq!(idx.len(), 2);
        let mut q = CsrBuilder::new(8);
        q.push_row(vec![(1, 1.0)]);
        let qm = q.finish();
        let top = idx.query(qm.row(0), 2);
        assert_eq!(top[0].0, 0);
        assert_eq!(top.len(), 1); // the empty row is unreachable

        // An empty *query* returns empty instead of panicking.
        assert!(idx.query(c.row(1), 2).is_empty());
        assert!(idx.candidates(c.row(1)).is_empty());
        let pidx = PackedLshIndex::build(shared(&c), LshConfig::default(), 8).unwrap();
        assert!(pidx.query(c.row(1), 2).is_empty());
    }

    #[test]
    fn candidates_are_sorted_and_deterministic() {
        let c = corpus(8, 4, 48, 7);
        let cfg = LshConfig { bands: 20, rows_per_band: 2, seed: 3 };
        let idx = LshIndex::try_build(shared(&c), cfg).unwrap();
        let pidx = PackedLshIndex::build(shared(&c), cfg, 8).unwrap();
        let mut s = QueryScratch::new();
        for q in 0..c.rows() {
            let a = idx.candidates(c.row(q));
            assert!(!a.is_empty(), "row {q} must at least find itself");
            assert!(a.windows(2).all(|w| w[0] < w[1]), "unsorted/duplicated candidates: {a:?}");
            assert_eq!(a, idx.candidates(c.row(q)), "row {q} output must be stable");
            // The reusable-scratch entry is bit-identical to the
            // allocating wrapper.
            assert_eq!(a, idx.candidates_with(c.row(q), &mut s));
            let p = pidx.candidates(c.row(q), QueryParams::default());
            assert!(p.contains(&(q as u32)), "packed row {q} must find itself");
            assert!(p.windows(2).all(|w| w[0] < w[1]), "packed candidates unsorted: {p:?}");
            assert_eq!(
                p,
                pidx.candidates_with(c.row(q), QueryParams::default(), &mut s)
            );
        }
    }

    #[test]
    fn batched_build_matches_per_row_sketching() {
        // The engine-batched build must bucket exactly as per-row
        // hashing would: querying a corpus row always finds itself
        // (identical samples ⇒ identical band keys in every band).
        let c = corpus(5, 3, 32, 9);
        let cfg = LshConfig { bands: 6, rows_per_band: 3, seed: 11 };
        let idx = LshIndex::try_build(shared(&c), cfg).unwrap();
        let hasher = CwsHasher::new(cfg.seed, cfg.k());
        for q in 0..c.rows() {
            let cands = idx.candidates(c.row(q));
            assert!(cands.contains(&(q as u32)), "row {q} missing from its own buckets");
            // Band keys from a fresh per-row hash agree with the index's.
            let samples = hasher.hash_sparse(c.row(q));
            for (band, key) in band_keys(&samples, cfg.rows_per_band).enumerate() {
                assert!(
                    idx.tables[band].bucket(key).contains(&(q as u32)),
                    "row {q} not bucketed under its own key in band {band}"
                );
            }
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_build_matches_try_build() {
        let c = corpus(4, 3, 32, 13);
        let cfg = LshConfig { bands: 8, rows_per_band: 2, seed: 21 };
        let old = LshIndex::build(c.clone(), cfg);
        let new = LshIndex::try_build(shared(&c), cfg).unwrap();
        for q in 0..c.rows() {
            assert_eq!(old.candidates(c.row(q)), new.candidates(c.row(q)));
            assert_eq!(old.query(c.row(q), 3), new.query(c.row(q), 3));
        }
    }

    #[test]
    fn band_table_bucket_roundtrip() {
        // Adversarial key set: sequential, duplicated, and colliding
        // patterns; every inserted (key → ids) group must come back
        // exactly, absent keys must return empty.
        let mut entries = Vec::new();
        for key in [0u64, 1, 2, u64::MAX, 0xdead_beef, 1 << 63, 42] {
            for id in 0..(key % 5 + 1) as u32 {
                entries.push((key, id * 10));
            }
        }
        let t = BandTable::build(entries.clone());
        for key in [0u64, 1, 2, u64::MAX, 0xdead_beef, 1 << 63, 42] {
            let want: Vec<u32> = {
                let mut v: Vec<u32> =
                    entries.iter().filter(|(k, _)| *k == key).map(|&(_, id)| id).collect();
                v.sort_unstable();
                v
            };
            assert_eq!(t.bucket(key), &want[..], "key {key}");
        }
        for absent in [3u64, 7, 12345, u64::MAX - 1] {
            assert!(t.bucket(absent).is_empty(), "key {absent} should be absent");
        }
        assert!(BandTable::build(Vec::new()).bucket(0).is_empty());
    }

    #[test]
    fn band_key_slicing_matches_truncated_tuples() {
        // The word-sliced band key must equal the little-endian
        // concatenation of the band's truncated codes — the §2.7
        // slicing contract, checked against a per-row sketch.
        let c = corpus(3, 2, 40, 17);
        let cfg = LshConfig { bands: 10, rows_per_band: 3, seed: 23 };
        for bits in [1u8, 2, 4, 8, 16] {
            let idx = PackedLshIndex::build(shared(&c), cfg, bits).unwrap();
            let hasher = CwsHasher::new(cfg.seed, cfg.k());
            let band_bits = cfg.rows_per_band * bits as usize;
            for q in 0..c.rows() {
                let samples = hasher.hash_sparse(c.row(q));
                for t in 0..cfg.bands {
                    let mut want = 0u64;
                    for j in 0..cfg.rows_per_band {
                        let rel =
                            samples[t * cfg.rows_per_band + j].i_star as u64 & ((1 << bits) - 1);
                        want |= rel << (j * bits as usize);
                    }
                    let got =
                        band_key_bits(idx.codes.word_row(q), t * band_bits, band_bits);
                    assert_eq!(got, want, "row {q} band {t} bits {bits}");
                }
            }
        }
    }

    #[test]
    fn packed_matches_legacy_topk_at_lossless_bits() {
        // At b=16 with dim ≤ 65536 truncation is the identity, so the
        // packed index's band equality classes coincide with exact
        // tuple equality — which is what the FNV keys hash. Top-k must
        // agree exactly.
        let c = corpus(8, 3, 96, 29);
        let cfg = LshConfig { bands: 12, rows_per_band: 3, seed: 31 };
        let legacy = LshIndex::try_build(shared(&c), cfg).unwrap();
        let packed = PackedLshIndex::build(shared(&c), cfg, 16).unwrap();
        let mut s = QueryScratch::new();
        for q in 0..c.rows() {
            assert_eq!(
                legacy.candidates(c.row(q)),
                packed.candidates(c.row(q), QueryParams::default()),
                "row {q} candidate sets diverged"
            );
            assert_eq!(
                legacy.query(c.row(q), 5),
                packed.query_with(c.row(q), 5, QueryParams::default(), &mut s).to_vec(),
                "row {q} top-k diverged"
            );
        }
    }

    #[test]
    fn multi_probe_is_superset_monotone() {
        let c = corpus(6, 4, 64, 37);
        let cfg = LshConfig { bands: 8, rows_per_band: 4, seed: 41 };
        let idx = PackedLshIndex::build(shared(&c), cfg, 4).unwrap();
        for q in 0..c.rows() {
            let mut prev: Vec<u32> = Vec::new();
            for probes in [0usize, 1, 2, 4, 8, 16] {
                let cur = idx.candidates(c.row(q), QueryParams { probes, min_agreement: 0.0 });
                assert!(
                    prev.iter().all(|id| cur.binary_search(id).is_ok()),
                    "row {q}: probes={probes} dropped a candidate from a smaller T"
                );
                prev = cur;
            }
        }
    }

    #[test]
    fn hamming_prefilter_keeps_exact_matches() {
        // min_agreement = 1.0 demands every packed position agree — a
        // self-query survives (agreement k), so it still returns self.
        let c = corpus(5, 3, 48, 43);
        let cfg = LshConfig { bands: 10, rows_per_band: 3, seed: 47 };
        let idx = PackedLshIndex::build(shared(&c), cfg, 8).unwrap();
        let mut s = QueryScratch::new();
        for q in 0..c.rows() {
            let strict = QueryParams { probes: 0, min_agreement: 1.0 };
            let top = idx.query_with(c.row(q), 1, strict, &mut s);
            assert_eq!(top[0].0 as usize, q, "self must survive the strictest prefilter");
            // And the filtered result set is a subset of the unfiltered.
            let loose: Vec<u32> = idx.query(c.row(q), 16).iter().map(|&(id, _)| id).collect();
            let tight = idx.query_with(c.row(q), 16, strict, &mut s);
            assert!(tight.iter().all(|&(id, _)| loose.contains(&id)));
        }
    }

    #[test]
    fn knn_classifier_recovers_group_labels() {
        let per = 5;
        let groups = 8;
        let c = corpus(groups, per, 64, 53);
        let labels: Vec<i32> = (0..c.rows()).map(|i| (i / per) as i32).collect();
        let cfg = LshConfig { bands: 16, rows_per_band: 3, seed: 59 };
        let idx = PackedLshIndex::build(shared(&c), cfg, 8).unwrap();
        for vote in [Vote::Majority, Vote::Weighted] {
            let idx2 = PackedLshIndex::build(shared(&c), cfg, 8).unwrap();
            let knn = KnnClassifier::new(idx2, labels.clone(), per).unwrap().with_vote(vote);
            let mut s = QueryScratch::new();
            let mut correct = 0usize;
            for q in 0..c.rows() {
                if knn.classify_with(c.row(q), &mut s) == Some(labels[q]) {
                    correct += 1;
                }
            }
            assert!(
                correct as f64 / c.rows() as f64 > 0.9,
                "{vote:?}: {correct}/{} correct",
                c.rows()
            );
        }
        // Label-length mismatch is a typed error, not a panic.
        assert_eq!(
            KnnClassifier::new(idx, vec![0; 3], per).err(),
            Some(LshError::LabelMismatch { labels: 3, rows: c.rows() })
        );
    }

    #[test]
    fn bucket_stats_reasonable() {
        let c = corpus(10, 3, 64, 3);
        let idx =
            LshIndex::try_build(shared(&c), LshConfig { bands: 8, rows_per_band: 2, seed: 4 })
                .unwrap();
        let m = idx.mean_bucket_size();
        assert!(m >= 1.0 && m <= 30.0, "mean bucket size {m}");
        let pidx =
            PackedLshIndex::build(shared(&c), LshConfig { bands: 8, rows_per_band: 2, seed: 4 }, 8)
                .unwrap();
        let pm = pidx.mean_bucket_size();
        assert!(pm >= 1.0 && pm <= 30.0, "packed mean bucket size {pm}");
    }
}
