//! LSH index over 0-bit CWS samples — similarity search in min-max
//! space, the retrieval use-case the paper's lineage (near-duplicate
//! detection, nearest-neighbor caching [4, 5, 13, 26]) motivates.
//!
//! Standard banding: `k = bands × rows_per_band` samples per vector; a
//! band's `rows_per_band` sample values are concatenated into one bucket
//! key. Two vectors with min-max similarity `s` share a specific band
//! with probability `s^r`, hence collide in ≥1 of `b` bands with
//! probability `1 − (1 − s^r)^b` — the classic S-curve, tuned by
//! (bands, rows_per_band). Candidates are exactly re-ranked with the
//! sparse min-max kernel.

use std::collections::HashMap;

use crate::data::sparse::{Csr, SparseRow};
use crate::data::Matrix;
use crate::kernels::sparse_minmax;
use crate::sketch::Sketcher;

use super::sampler::{CwsHasher, CwsSample};

#[derive(Debug, Clone, Copy)]
pub struct LshConfig {
    pub bands: usize,
    pub rows_per_band: usize,
    pub seed: u64,
}

impl LshConfig {
    pub fn k(&self) -> usize {
        self.bands * self.rows_per_band
    }

    /// Probability that a pair at similarity `s` becomes a candidate.
    pub fn candidate_probability(&self, s: f64) -> f64 {
        1.0 - (1.0 - s.powi(self.rows_per_band as i32)).powi(self.bands as i32)
    }
}

impl Default for LshConfig {
    fn default() -> Self {
        Self { bands: 16, rows_per_band: 4, seed: 2015 }
    }
}

/// An LSH index over the 0-bit CWS samples of a corpus.
pub struct LshIndex {
    cfg: LshConfig,
    hasher: CwsHasher,
    /// One bucket map per band: band key -> row ids.
    tables: Vec<HashMap<u64, Vec<u32>>>,
    /// Stored samples (for optional sample-level re-rank) and the corpus.
    corpus: Csr,
}

impl LshIndex {
    /// Build over all rows of `corpus` (rows with no nonzeros are
    /// skipped — they can never be retrieved).
    ///
    /// The whole corpus is sketched through the engine's chunked
    /// parallel batch entry ([`Sketcher::sketch_matrix`] — bit-identical
    /// to per-row [`CwsHasher::hash_sparse`] at any `MINMAX_THREADS`);
    /// bucket insertion stays sequential in ascending row order so
    /// bucket contents are deterministic.
    pub fn build(corpus: Csr, cfg: LshConfig) -> LshIndex {
        let hasher = CwsHasher::new(cfg.seed, cfg.k());
        let mut tables: Vec<HashMap<u64, Vec<u32>>> = vec![HashMap::new(); cfg.bands];
        let m = Matrix::Sparse(corpus);
        let sketched = Sketcher::sketch_matrix(&hasher, &m);
        let Matrix::Sparse(corpus) = m else { unreachable!("built as sparse") };
        for (row_id, samples) in sketched.iter().enumerate() {
            let Some(samples) = samples else { continue };
            for (band, key) in band_keys(samples, cfg.rows_per_band).enumerate() {
                tables[band].entry(key).or_default().push(row_id as u32);
            }
        }
        LshIndex { cfg, hasher, tables, corpus }
    }

    pub fn config(&self) -> &LshConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.corpus.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.corpus.rows() == 0
    }

    /// Candidate row ids for a query: deduplicated and returned in
    /// ascending row order, so identical input always produces
    /// identical output (a raw `HashSet` iteration leaked
    /// nondeterministic ordering run to run).
    pub fn candidates(&self, query: SparseRow<'_>) -> Vec<u32> {
        let samples = self.hasher.hash_sparse(query);
        let mut seen = std::collections::HashSet::new();
        for (band, key) in band_keys(&samples, self.cfg.rows_per_band).enumerate() {
            if let Some(ids) = self.tables[band].get(&key) {
                seen.extend(ids.iter().copied());
            }
        }
        let mut out: Vec<u32> = seen.into_iter().collect();
        out.sort_unstable();
        out
    }

    /// Top-`n` most similar corpus rows by exact min-max similarity,
    /// re-ranked over the LSH candidates. Returns (row_id, similarity),
    /// descending.
    pub fn query(&self, query: SparseRow<'_>, n: usize) -> Vec<(u32, f64)> {
        let mut scored: Vec<(u32, f64)> = self
            .candidates(query)
            .into_iter()
            .map(|id| (id, sparse_minmax(query, self.corpus.row(id as usize))))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        scored.truncate(n);
        scored
    }

    /// Average bucket occupancy per band (diagnostics / tests).
    pub fn mean_bucket_size(&self) -> f64 {
        let (mut total, mut buckets) = (0usize, 0usize);
        for t in &self.tables {
            for ids in t.values() {
                total += ids.len();
                buckets += 1;
            }
        }
        if buckets == 0 {
            0.0
        } else {
            total as f64 / buckets as f64
        }
    }
}

/// Iterate the band keys of a sample vector: each band hashes its
/// `rows_per_band` `i*` values (0-bit: `t*` ignored) into one u64.
fn band_keys<'a>(
    samples: &'a [CwsSample],
    rows_per_band: usize,
) -> impl Iterator<Item = u64> + 'a {
    samples.chunks(rows_per_band).map(|chunk| {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for s in chunk {
            h ^= s.i_star as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrBuilder;
    use crate::util::rng::Pcg64;

    /// Corpus of `groups` clusters: `per_group` near-duplicates each.
    fn corpus(groups: usize, per_group: usize, dim: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut b = CsrBuilder::new(dim);
        for _g in 0..groups {
            let proto: Vec<f32> = (0..dim)
                .map(|_| if rng.uniform() < 0.5 { 0.0 } else { rng.lognormal(0.0, 1.0) as f32 })
                .collect();
            for _ in 0..per_group {
                let row: Vec<(u32, f32)> = proto
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v > 0.0)
                    .map(|(i, &v)| (i as u32, (v as f64 * rng.lognormal(0.0, 0.12)) as f32))
                    .collect();
                b.push_row(row);
            }
        }
        b.finish()
    }

    #[test]
    fn near_duplicates_are_retrieved() {
        let per = 4;
        let c = corpus(12, per, 64, 1);
        let idx = LshIndex::build(c.clone(), LshConfig { bands: 24, rows_per_band: 3, seed: 9 });
        // Query with each row; its group mates must dominate the top-k.
        let mut hits = 0usize;
        let mut total = 0usize;
        for q in 0..c.rows() {
            let group = q / per;
            let top = idx.query(c.row(q), per);
            for (id, sim) in &top {
                total += 1;
                if (*id as usize) / per == group {
                    hits += 1;
                }
                assert!((0.0..=1.0).contains(sim));
            }
        }
        assert!(hits as f64 / total as f64 > 0.9, "group precision {hits}/{total}");
    }

    #[test]
    fn self_query_returns_self_first() {
        let c = corpus(6, 3, 48, 2);
        let idx = LshIndex::build(c.clone(), LshConfig::default());
        for q in [0usize, 5, 11] {
            let top = idx.query(c.row(q), 1);
            assert_eq!(top[0].0 as usize, q);
            assert!((top[0].1 - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn s_curve_is_monotone() {
        let cfg = LshConfig { bands: 16, rows_per_band: 4, seed: 0 };
        let probs: Vec<f64> =
            (0..=10).map(|i| cfg.candidate_probability(i as f64 / 10.0)).collect();
        assert!(probs.windows(2).all(|w| w[1] >= w[0] - 1e-12));
        assert!(probs[0] < 1e-6);
        assert!((probs[10] - 1.0).abs() < 1e-9);
        // Threshold behavior: far below (1/b)^(1/r) → tiny.
        assert!(cfg.candidate_probability(0.2) < 0.1);
        assert!(cfg.candidate_probability(0.9) > 0.99);
    }

    #[test]
    fn dissimilar_vectors_rarely_candidates() {
        // Disjoint supports → similarity 0 → never candidates (band keys
        // derive from i*, which lives in disjoint index sets).
        let mut b = CsrBuilder::new(1000);
        b.push_row((0..50).map(|i| (i as u32, 1.0)).collect());
        b.push_row((500..550).map(|i| (i as u32, 1.0)).collect());
        let c = b.finish();
        let idx = LshIndex::build(c.clone(), LshConfig::default());
        let cands = idx.candidates(c.row(1));
        assert!(!cands.contains(&0), "disjoint vectors must not collide");
    }

    #[test]
    fn empty_rows_skipped_not_panicking() {
        let mut b = CsrBuilder::new(8);
        b.push_row(vec![(1, 1.0)]);
        b.push_row(vec![]);
        let idx = LshIndex::build(b.finish(), LshConfig::default());
        assert_eq!(idx.len(), 2);
        let mut q = CsrBuilder::new(8);
        q.push_row(vec![(1, 1.0)]);
        let qm = q.finish();
        let top = idx.query(qm.row(0), 2);
        assert_eq!(top[0].0, 0);
        assert_eq!(top.len(), 1); // the empty row is unreachable
    }

    #[test]
    fn candidates_are_sorted_and_deterministic() {
        let c = corpus(8, 4, 48, 7);
        let idx = LshIndex::build(c.clone(), LshConfig { bands: 20, rows_per_band: 2, seed: 3 });
        for q in 0..c.rows() {
            let a = idx.candidates(c.row(q));
            assert!(!a.is_empty(), "row {q} must at least find itself");
            assert!(a.windows(2).all(|w| w[0] < w[1]), "unsorted/duplicated candidates: {a:?}");
            assert_eq!(a, idx.candidates(c.row(q)), "row {q} output must be stable");
        }
    }

    #[test]
    fn batched_build_matches_per_row_sketching() {
        // The engine-batched build must bucket exactly as per-row
        // hashing would: querying a corpus row always finds itself
        // (identical samples ⇒ identical band keys in every band).
        let c = corpus(5, 3, 32, 9);
        let cfg = LshConfig { bands: 6, rows_per_band: 3, seed: 11 };
        let idx = LshIndex::build(c.clone(), cfg);
        let hasher = CwsHasher::new(cfg.seed, cfg.k());
        for q in 0..c.rows() {
            let cands = idx.candidates(c.row(q));
            assert!(cands.contains(&(q as u32)), "row {q} missing from its own buckets");
            // Band keys from a fresh per-row hash agree with the index's.
            let samples = hasher.hash_sparse(c.row(q));
            for (band, key) in band_keys(&samples, cfg.rows_per_band).enumerate() {
                assert!(
                    idx.tables[band].get(&key).is_some_and(|ids| ids.contains(&(q as u32))),
                    "row {q} not bucketed under its own key in band {band}"
                );
            }
        }
    }

    #[test]
    fn bucket_stats_reasonable() {
        let c = corpus(10, 3, 64, 3);
        let idx = LshIndex::build(c, LshConfig { bands: 8, rows_per_band: 2, seed: 4 });
        let m = idx.mean_bucket_size();
        assert!(m >= 1.0 && m <= 30.0, "mean bucket size {m}");
    }
}
