//! Classical minwise hashing — the binary-data baseline the paper
//! generalizes (§1: "the resemblance kernel has been widely used in
//! practice on binary (or binarized) data [4, 5, …]", and [20]'s b-bit
//! minwise hashing).
//!
//! For a binary set S ⊆ {0..D−1} and a random hash π_j,
//! `h_j(S) = min_{i∈S} π_j(i)` and `Pr[h_j(S) = h_j(T)] = R(S,T)`
//! (the resemblance, Eq. 2). The b-bit variant stores only the lowest
//! b bits of the min-hash; [20] shows collisions then estimate
//! `C + (1−C)·R` with `C ≈ 2^{−b}` for sparse data — we expose the
//! unbiased corrected estimator.
//!
//! This exists (a) as the baseline CWS must beat on *weighted* data
//! (0-bit CWS estimates K_MM, minwise only ever sees the support) and
//! (b) to validate that CWS on binarized input matches minwise-estimated
//! resemblance — two very different samplers, one statistic.

use crate::data::sparse::SparseRow;

use super::sampler::mix64;

/// Minwise hasher: `k` independent permutations approximated by 64-bit
/// universal hashing (collision-free in practice for D ≤ 2^32).
#[derive(Debug, Clone)]
pub struct MinwiseHasher {
    seed: u64,
    k: usize,
}

impl MinwiseHasher {
    pub fn new(seed: u64, k: usize) -> Self {
        assert!(k > 0);
        Self { seed, k }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Hash the support of a sparse row: `k` min-hash values.
    pub fn hash(&self, row: SparseRow<'_>) -> Vec<u64> {
        assert!(row.nnz() > 0, "minwise hashing is undefined on the empty set");
        (0..self.k as u64)
            .map(|j| {
                row.indices
                    .iter()
                    .map(|&i| mix64(self.seed ^ (j << 32) ^ mix64(i as u64 + 1)))
                    .min()
                    .unwrap()
            })
            .collect()
    }

    /// b-bit codes of the min-hashes ([20]).
    pub fn hash_b_bits(&self, row: SparseRow<'_>, b: u8) -> Vec<u64> {
        assert!(b >= 1 && b <= 63);
        let mask = (1u64 << b) - 1;
        self.hash(row).into_iter().map(|h| h & mask).collect()
    }
}

/// Plain collision-fraction estimator of the resemblance.
pub fn estimate_resemblance(a: &[u64], b: &[u64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
}

/// The b-bit-minwise corrected estimator of [20]:
/// `R̂ = (P̂ − C) / (1 − C)` with `C = 2^{−b}` (the accidental-collision
/// rate for b-bit codes under near-uniform min-hash values).
pub fn estimate_resemblance_b_bits(a: &[u64], b: &[u64], bits: u8) -> f64 {
    let p = estimate_resemblance(a, b);
    let c = 0.5f64.powi(bits as i32);
    ((p - c) / (1.0 - c)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::CwsHasher;
    use crate::data::sparse::CsrBuilder;
    use crate::kernels::sparse_resemblance;
    use crate::util::rng::Pcg64;

    /// Two binary rows with controlled overlap.
    fn binary_pair(d: usize, f1: usize, f2: usize, shared: usize, seed: u64) -> crate::data::Csr {
        let mut rng = Pcg64::new(seed);
        let idx = rng.sample_indices(d, f1 + f2 - shared);
        let u: Vec<(u32, f32)> = idx[..f1].iter().map(|&i| (i as u32, 1.0)).collect();
        let v: Vec<(u32, f32)> =
            idx[f1 - shared..].iter().map(|&i| (i as u32, 1.0)).collect();
        let mut b = CsrBuilder::new(d);
        b.push_row(u);
        b.push_row(v);
        b.finish()
    }

    #[test]
    fn collision_rate_estimates_resemblance() {
        let m = binary_pair(10_000, 300, 200, 100, 1);
        let truth = sparse_resemblance(m.row(0), m.row(1));
        let h = MinwiseHasher::new(7, 4000);
        let est = estimate_resemblance(&h.hash(m.row(0)), &h.hash(m.row(1)));
        let tol = 4.0 * (truth * (1.0 - truth) / 4000.0).sqrt();
        assert!((est - truth).abs() < tol.max(0.02), "{est} vs {truth}");
    }

    #[test]
    fn b_bit_corrected_estimator_tracks_truth() {
        let m = binary_pair(10_000, 400, 400, 240, 2);
        let truth = sparse_resemblance(m.row(0), m.row(1));
        let h = MinwiseHasher::new(11, 6000);
        for bits in [1u8, 2, 4, 8] {
            let a = h.hash_b_bits(m.row(0), bits);
            let b = h.hash_b_bits(m.row(1), bits);
            let est = estimate_resemblance_b_bits(&a, &b, bits);
            // Fewer bits → noisier but still unbiased-ish.
            let tol = 0.04 + 0.06 / bits as f64;
            assert!((est - truth).abs() < tol, "b={bits}: {est} vs {truth}");
        }
    }

    #[test]
    fn raw_b_bit_collisions_exceed_resemblance() {
        // Without the correction, accidental collisions inflate P.
        let m = binary_pair(10_000, 300, 300, 30, 3);
        let truth = sparse_resemblance(m.row(0), m.row(1));
        let h = MinwiseHasher::new(3, 4000);
        let a = h.hash_b_bits(m.row(0), 1);
        let b = h.hash_b_bits(m.row(1), 1);
        let raw = estimate_resemblance(&a, &b);
        assert!(raw > truth + 0.1, "raw {raw} should exceed R {truth}");
    }

    #[test]
    fn cws_on_binary_matches_minwise_statistic() {
        // Two different samplers, one estimand: CWS collisions on binary
        // data and minwise collisions both estimate the resemblance.
        let m = binary_pair(5_000, 250, 220, 110, 4);
        let truth = sparse_resemblance(m.row(0), m.row(1));
        let k = 4000;
        let mh = MinwiseHasher::new(5, k);
        let ch = CwsHasher::new(5, k);
        let minwise = estimate_resemblance(&mh.hash(m.row(0)), &mh.hash(m.row(1)));
        let su = ch.hash_sparse(m.row(0));
        let sv = ch.hash_sparse(m.row(1));
        let cws = su.iter().zip(&sv).filter(|(a, b)| a == b).count() as f64 / k as f64;
        assert!((minwise - truth).abs() < 0.03);
        assert!((cws - truth).abs() < 0.03);
    }

    #[test]
    fn deterministic_per_seed() {
        let m = binary_pair(1000, 50, 50, 25, 6);
        let h = MinwiseHasher::new(9, 32);
        assert_eq!(h.hash(m.row(0)), h.hash(m.row(0)));
        let h2 = MinwiseHasher::new(10, 32);
        assert_ne!(h.hash(m.row(0)), h2.hash(m.row(0)));
    }

    #[test]
    #[should_panic(expected = "undefined on the empty set")]
    fn empty_set_panics() {
        let mut b = CsrBuilder::new(4);
        b.push_row(vec![]);
        let m = b.finish();
        MinwiseHasher::new(1, 4).hash(m.row(0));
    }
}
