//! Bit-budget encodings of CWS samples `(i*, t*)` — the design space the
//! paper explores in §3.3–§4 and Figures 4–8.
//!
//! A [`Scheme`] chooses how many bits of `i*` and of `t*` survive:
//!
//! * the paper's proposal is `t_bits = Some(0)` (**0-bit CWS**);
//! * the original ("full") scheme is `t_bits = None` (keep everything);
//! * Figures 4–5 add the 1-bit scheme (`t*` parity);
//! * Figure 6 inverts the question (`i_bits ∈ {0,1,2,4}` with full `t*`);
//! * Figures 7–8 use `i_bits ∈ {1,2,4,8}` with `t_bits ∈ {0, 2}`.
//!
//! `b`-bit truncation of a sample component keeps its value mod `2^b`
//! (for `t*`, on the euclidean remainder so negative offsets behave).

use super::sampler::CwsSample;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Scheme {
    /// Bits kept of `i*`; `None` = all.
    pub i_bits: Option<u8>,
    /// Bits kept of `t*`; `None` = all, `Some(0)` = the 0-bit scheme.
    pub t_bits: Option<u8>,
}

impl Scheme {
    /// The original CWS scheme: keep everything.
    pub const FULL: Scheme = Scheme { i_bits: None, t_bits: None };
    /// The paper's 0-bit scheme: `i*` only.
    pub const ZERO_BIT: Scheme = Scheme { i_bits: None, t_bits: Some(0) };
    /// The 1-bit scheme of Figures 4–5: `i*` plus the parity of `t*`.
    pub const ONE_BIT: Scheme = Scheme { i_bits: None, t_bits: Some(1) };

    pub fn with_i_bits(b: u8) -> Scheme {
        Scheme { i_bits: Some(b), t_bits: Some(0) }
    }

    pub fn name(&self) -> String {
        let i = match self.i_bits {
            None => "i:full".to_string(),
            Some(b) => format!("i:{b}b"),
        };
        let t = match self.t_bits {
            None => "t:full".to_string(),
            Some(b) => format!("t:{b}b"),
        };
        format!("{i}/{t}")
    }

    /// Encode one sample under this scheme. Equality of codes is the
    /// collision event whose probability estimates `K_MM`.
    ///
    /// `b`-bit truncation keeps the component mod `2^b`. For `t*` the
    /// mask is applied to the two's-complement u64 reinterpretation,
    /// which equals the euclidean remainder for every `b < 64` (for
    /// negative `t`, `t as u64 = t + 2^64 ≡ t (mod 2^b)` since
    /// `2^b | 2^64`) — and, unlike the old `rem_euclid(1i64 << b)`,
    /// stays correct at `b = 63`, where the i64 shift overflows into
    /// the sign bit and hands `rem_euclid` a negative modulus.
    #[inline]
    pub fn encode(&self, s: &CwsSample) -> u128 {
        let i_part: u64 = match self.i_bits {
            None => s.i_star as u64,
            Some(b) if b >= 32 => s.i_star as u64,
            Some(b) => (s.i_star as u64) & ((1u64 << b) - 1),
        };
        let t_part: u64 = match self.t_bits {
            None => s.t_star as u64, // bijective i64→u64 reinterpretation
            Some(b) if b >= 64 => s.t_star as u64,
            Some(b) => (s.t_star as u64) & ((1u64 << b) - 1),
        };
        ((i_part as u128) << 64) | t_part as u128
    }
}

/// Fraction of positions where the two sample streams collide under the
/// scheme — the estimator K̂_MM plotted in Figures 4–6.
pub fn collision_fraction(scheme: Scheme, a: &[CwsSample], b: &[CwsSample]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let hits = a
        .iter()
        .zip(b)
        .filter(|(x, y)| scheme.encode(x) == scheme.encode(y))
        .count();
    hits as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::sampler::CwsHasher;
    use crate::kernels::dense_minmax;

    fn s(i: u32, t: i64) -> CwsSample {
        CwsSample { i_star: i, t_star: t }
    }

    #[test]
    fn full_scheme_is_exact_equality() {
        let sch = Scheme::FULL;
        assert_eq!(sch.encode(&s(5, -3)), sch.encode(&s(5, -3)));
        assert_ne!(sch.encode(&s(5, -3)), sch.encode(&s(5, -2)));
        assert_ne!(sch.encode(&s(4, -3)), sch.encode(&s(5, -3)));
    }

    #[test]
    fn zero_bit_ignores_t() {
        let sch = Scheme::ZERO_BIT;
        assert_eq!(sch.encode(&s(5, -3)), sch.encode(&s(5, 999)));
        assert_ne!(sch.encode(&s(5, 0)), sch.encode(&s(6, 0)));
    }

    #[test]
    fn one_bit_keeps_parity() {
        let sch = Scheme::ONE_BIT;
        assert_eq!(sch.encode(&s(5, 2)), sch.encode(&s(5, 4)));
        assert_ne!(sch.encode(&s(5, 2)), sch.encode(&s(5, 3)));
        // negative t: -1 and 1 are both odd
        assert_eq!(sch.encode(&s(5, -1)), sch.encode(&s(5, 1)));
        assert_eq!(sch.encode(&s(5, -2)), sch.encode(&s(5, 0)));
    }

    #[test]
    fn i_bit_truncation() {
        let sch = Scheme::with_i_bits(2);
        assert_eq!(sch.encode(&s(0b100, 1)), sch.encode(&s(0b000, 7)));
        assert_ne!(sch.encode(&s(0b101, 1)), sch.encode(&s(0b100, 1)));
        let sch8 = Scheme::with_i_bits(8);
        assert_eq!(sch8.encode(&s(256, 0)), sch8.encode(&s(0, 0)));
        assert_ne!(sch8.encode(&s(255, 0)), sch8.encode(&s(0, 0)));
    }

    #[test]
    fn wide_bit_requests_saturate() {
        let sch = Scheme { i_bits: Some(32), t_bits: Some(64) };
        assert_eq!(sch.encode(&s(7, -9)), Scheme::FULL.encode(&s(7, -9)));
    }

    #[test]
    fn i_bit_truncation_boundaries_31_32() {
        let i31 = Scheme { i_bits: Some(31), t_bits: Some(0) };
        // Bit 31 is dropped at 31 bits…
        assert_eq!(i31.encode(&s(1u32 << 31, 0)), i31.encode(&s(0, 0)));
        assert_ne!(i31.encode(&s((1u32 << 31) - 1, 0)), i31.encode(&s(0, 0)));
        // …and kept at 32 (full width for a u32 index).
        let i32b = Scheme { i_bits: Some(32), t_bits: Some(0) };
        assert_ne!(i32b.encode(&s(1u32 << 31, 0)), i32b.encode(&s(0, 0)));
        assert_eq!(i32b.encode(&s(u32::MAX, 0)), Scheme::ZERO_BIT.encode(&s(u32::MAX, 0)));
    }

    #[test]
    fn t_bit_truncation_boundaries_62_63_64() {
        let t62 = Scheme { i_bits: None, t_bits: Some(62) };
        let t63 = Scheme { i_bits: None, t_bits: Some(63) };
        let t64 = Scheme { i_bits: None, t_bits: Some(64) };
        // 2^62 ≡ 0 under 62 kept bits, distinct under 63.
        assert_eq!(t62.encode(&s(5, 1i64 << 62)), t62.encode(&s(5, 0)));
        assert_ne!(t63.encode(&s(5, 1i64 << 62)), t63.encode(&s(5, 0)));
        // 63 bits: the old `1i64 << 63` shifted into the sign bit and
        // produced a negative modulus. −2^63 ≡ 0 (mod 2^63); −1 maps to
        // the euclidean remainder 2^63 − 1.
        assert_eq!(t63.encode(&s(5, i64::MIN)), t63.encode(&s(5, 0)));
        assert_ne!(t63.encode(&s(5, -1)), t63.encode(&s(5, 0)));
        assert_eq!(t63.encode(&s(5, -1)) as u64, (1u64 << 63) - 1);
        // 64 bits keeps everything (= the full scheme).
        assert_ne!(t64.encode(&s(5, i64::MIN)), t64.encode(&s(5, 0)));
        assert_eq!(t64.encode(&s(5, -9)), Scheme::FULL.encode(&s(5, -9)));
    }

    #[test]
    fn mask_truncation_matches_euclidean_remainder() {
        // For b ≤ 62 (where the old shift was sound) the new mask path
        // must agree with rem_euclid exactly, negatives included.
        for b in [1u8, 2, 7, 31, 32, 33, 62] {
            let sch = Scheme { i_bits: None, t_bits: Some(b) };
            for t in [-3i64, -1, 0, 1, 5, -(1i64 << 40), (1i64 << 40) + 9, i64::MAX, i64::MIN] {
                let want = t.rem_euclid(1i64 << b) as u64;
                assert_eq!(sch.encode(&s(9, t)) as u64, want, "b={b} t={t}");
            }
        }
    }

    #[test]
    fn collision_fraction_counts() {
        let a = vec![s(1, 0), s(2, 5), s(3, 1)];
        let b = vec![s(1, 0), s(2, 6), s(9, 1)];
        assert!((collision_fraction(Scheme::FULL, &a, &b) - 1.0 / 3.0).abs() < 1e-12);
        assert!((collision_fraction(Scheme::ZERO_BIT, &a, &b) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn truncation_orders_collision_rates() {
        // Fewer bits kept ⇒ collision fraction can only grow.
        let u = [1.0f32, 3.0, 0.5, 2.0, 0.0, 1.0, 4.0, 0.25];
        let v = [2.0f32, 1.0, 0.5, 1.0, 1.0, 0.0, 4.0, 0.25];
        let h = CwsHasher::new(2024, 2000);
        let (su, sv) = (h.hash_dense(&u), h.hash_dense(&v));
        let full = collision_fraction(Scheme::FULL, &su, &sv);
        let one = collision_fraction(Scheme::ONE_BIT, &su, &sv);
        let zero = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
        let i2 = collision_fraction(Scheme::with_i_bits(2), &su, &sv);
        assert!(full <= one + 1e-12);
        assert!(one <= zero + 1e-12);
        assert!(zero <= i2 + 1e-12);
    }

    #[test]
    fn zero_bit_estimates_minmax_closely() {
        // The paper's empirical core: 0-bit ≈ full ≈ K_MM, in a
        // realistic-dimension regime (D = 96, heavy-tailed, sparse).
        let mut rng = crate::util::rng::Pcg64::new(77);
        let d = 96;
        let u: Vec<f32> = (0..d)
            .map(|_| if rng.uniform() < 0.3 { 0.0 } else { rng.lognormal(0.0, 1.0) as f32 })
            .collect();
        let v: Vec<f32> = u
            .iter()
            .map(|&x| {
                if rng.uniform() < 0.1 {
                    rng.lognormal(0.0, 1.0) as f32
                } else {
                    (x as f64 * rng.lognormal(0.0, 0.5)) as f32
                }
            })
            .collect();
        let truth = dense_minmax(&u, &v);
        let h = CwsHasher::new(5150, 8000);
        let (su, sv) = (h.hash_dense(&u), h.hash_dense(&v));
        let full = collision_fraction(Scheme::FULL, &su, &sv);
        let zero = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
        assert!((full - truth).abs() < 0.025, "full {full} vs {truth}");
        assert!((zero - truth).abs() < 0.025, "zero {zero} vs {truth}");
        assert!((zero - full).abs() < 0.02);
    }

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::FULL.name(), "i:full/t:full");
        assert_eq!(Scheme::ZERO_BIT.name(), "i:full/t:0b");
        assert_eq!(Scheme::with_i_bits(8).name(), "i:8b/t:0b");
    }
}
