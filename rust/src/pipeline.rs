//! The composable [`Pipeline`]: `Scaling → Sketcher → Expansion →
//! linear model` as one fit/transform/predict object — the §4 recipe
//! ("hash, expand, train a linear SVM, serve") packaged behind the
//! crate's trait surface.
//!
//! ```no_run
//! use minmax::prelude::*;
//!
//! # fn demo(train_x: Matrix, train_y: Vec<i32>, test_x: Matrix, test_y: Vec<i32>)
//! #     -> Result<(), PipelineError> {
//! let mut pipe = Pipeline::builder()
//!     .seed(2015)
//!     .samples(256)       // k hash samples per vector
//!     .i_bits(8)          // 0-bit CWS, 8 bits of i* per sample
//!     .scaling(Scaling::None)
//!     .cost(1.0)          // linear-SVM C
//!     .build()?;
//! pipe.fit(&train_x, &train_y)?;
//! let acc = pipe.accuracy(&test_x, &test_y)?;
//! # let _ = acc; Ok(())
//! # }
//! ```
//!
//! Every stage is swappable: [`PipelineBuilder::sketcher`] accepts any
//! [`Sketcher`] (ICWS, minwise, PJRT-backed, future GCWS families), and
//! [`PipelineBuilder::for_kernel`] wires the stage stack from a
//! [`Kernel`]'s own linearization + required normalization.

use crate::cws::CwsSample;
use crate::data::{scale, Csr, Matrix};
use crate::features::{CodeMatrix, Expansion, ExpansionError};
use crate::kernels::{Kernel, Normalization};
use crate::serve::{ServeError, Scorer};
use crate::sketch::Sketcher;
use crate::svm::{LinearOvR, LinearSvmParams, RowSet};

/// Row preprocessing applied before sketching — the paper's §2 protocol
/// transforms as an explicit pipeline stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Scaling {
    /// Use features as-is (min-max kernel regime).
    #[default]
    None,
    /// Row-wise ℓ₁ normalization (n-min-max / intersection regime).
    L1,
    /// Row-wise ℓ₂ normalization (linear-kernel regime).
    L2,
    /// Replace nonzeros with 1.0 (resemblance regime).
    Binarize,
}

impl Scaling {
    /// The scaling a kernel's evaluation protocol requires.
    pub fn for_normalization(n: Normalization) -> Scaling {
        match n {
            Normalization::None => Scaling::None,
            Normalization::L1 => Scaling::L1,
            Normalization::L2 => Scaling::L2,
        }
    }

    /// Apply to a matrix, preserving the representation.
    pub fn apply(&self, m: &Matrix) -> Matrix {
        match (self, m) {
            (Scaling::None, m) => m.clone(),
            (Scaling::L1, Matrix::Dense(d)) => {
                let mut d = d.clone();
                scale::l1_normalize_dense(&mut d);
                Matrix::Dense(d)
            }
            (Scaling::L1, Matrix::Sparse(s)) => {
                let mut s = s.clone();
                scale::l1_normalize_csr(&mut s);
                Matrix::Sparse(s)
            }
            (Scaling::L2, Matrix::Dense(d)) => {
                let mut d = d.clone();
                scale::l2_normalize_dense(&mut d);
                Matrix::Dense(d)
            }
            (Scaling::L2, Matrix::Sparse(s)) => {
                let mut s = s.clone();
                scale::l2_normalize_csr(&mut s);
                Matrix::Sparse(s)
            }
            (Scaling::Binarize, Matrix::Dense(d)) => {
                let mut d = d.clone();
                scale::binarize_dense(&mut d);
                Matrix::Dense(d)
            }
            // Sparse stays sparse: values become 1.0 in place.
            (Scaling::Binarize, Matrix::Sparse(s)) => {
                let mut s = s.clone();
                scale::binarize_csr(&mut s);
                Matrix::Sparse(s)
            }
        }
    }
}

/// Errors from pipeline construction and use.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PipelineError {
    /// The feature-expansion bit budget is invalid.
    Expansion(ExpansionError),
    /// An explicit sketcher's `k()` disagrees with an explicit
    /// [`PipelineBuilder::samples`] request.
    SketcherMismatch { sketcher_k: usize, expansion_k: usize },
    /// The chosen kernel has no known hashed linearization.
    NotLinearizable(&'static str),
    /// `predict`/`accuracy` before `fit`.
    NotFitted,
    /// Label/row count disagreement in `fit`.
    ShapeMismatch { rows: usize, labels: usize },
    /// [`Pipeline::scorer`] on a sketcher family the fused scorer
    /// cannot replay (only the native ICWS families ride the
    /// `SketchEngine` parameter slabs).
    UnsupportedSketcher(&'static str),
    /// Weight-slab validation failed while building a scorer.
    Serve(ServeError),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::Expansion(e) => write!(f, "expansion: {e}"),
            PipelineError::SketcherMismatch { sketcher_k, expansion_k } => write!(
                f,
                "sketcher produces k={sketcher_k} samples but samples({expansion_k}) was requested"
            ),
            PipelineError::NotLinearizable(name) => {
                write!(f, "kernel '{name}' has no hashed linearization")
            }
            PipelineError::NotFitted => write!(f, "pipeline used before fit()"),
            PipelineError::ShapeMismatch { rows, labels } => {
                write!(f, "{rows} feature rows vs {labels} labels")
            }
            PipelineError::UnsupportedSketcher(name) => {
                write!(f, "sketcher '{name}' has no fused serving scorer")
            }
            PipelineError::Serve(e) => write!(f, "scorer: {e}"),
        }
    }
}

impl std::error::Error for PipelineError {}

impl From<ExpansionError> for PipelineError {
    fn from(e: ExpansionError) -> Self {
        PipelineError::Expansion(e)
    }
}

impl From<ServeError> for PipelineError {
    fn from(e: ServeError) -> Self {
        PipelineError::Serve(e)
    }
}

/// Builder for [`Pipeline`]. Defaults: seed 2015, k = 128, 8 bits of
/// i*, 0 bits of t*, no scaling, C = 1.0, ICWS sketcher.
pub struct PipelineBuilder {
    seed: u64,
    /// `None` until [`PipelineBuilder::samples`] is called; the default
    /// k only applies when no explicit sketcher fixes it.
    samples: Option<usize>,
    i_bits: u8,
    t_bits: u8,
    scaling: Scaling,
    c: f64,
    sketcher: Option<Box<dyn Sketcher>>,
    /// Deferred kernel linearization: (kernel name, factory). Resolved
    /// at `build()` with the FINAL seed/k so `.for_kernel(..).seed(..)`
    /// composes in any order.
    from_kernel: Option<(&'static str, KernelSketcherFactory)>,
}

type KernelSketcherFactory = Box<dyn FnOnce(u64, usize) -> Option<Box<dyn Sketcher>>>;

/// Default hash samples per vector when neither [`PipelineBuilder::samples`]
/// nor an explicit sketcher specifies k.
pub const DEFAULT_SAMPLES: usize = 128;

impl Default for PipelineBuilder {
    fn default() -> Self {
        Self {
            seed: 2015,
            samples: None,
            i_bits: 8,
            t_bits: 0,
            scaling: Scaling::None,
            c: 1.0,
            sketcher: None,
            from_kernel: None,
        }
    }
}

impl PipelineBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed for the sketcher's counter-based randomness. Ignored when an
    /// explicit [`PipelineBuilder::sketcher`] is supplied.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Hash samples per vector (k). When combined with an explicit
    /// [`PipelineBuilder::sketcher`] whose own k disagrees, `build`
    /// fails with [`PipelineError::SketcherMismatch`].
    pub fn samples(mut self, k: usize) -> Self {
        self.samples = Some(k);
        self
    }

    fn effective_k(&self) -> usize {
        self.samples.unwrap_or(DEFAULT_SAMPLES)
    }

    /// Bits of `i*` kept per sample (the b-bit expansion of §4).
    pub fn i_bits(mut self, b: u8) -> Self {
        self.i_bits = b;
        self
    }

    /// Bits of `t*` kept per sample (Figure 8's variant; 0 = the
    /// paper's 0-bit scheme).
    pub fn t_bits(mut self, b: u8) -> Self {
        self.t_bits = b;
        self
    }

    /// Row preprocessing before sketching.
    pub fn scaling(mut self, s: Scaling) -> Self {
        self.scaling = s;
        self
    }

    /// Linear-SVM regularization parameter C.
    pub fn cost(mut self, c: f64) -> Self {
        self.c = c;
        self
    }

    /// Use an explicit sketcher (any [`Sketcher`] impl) instead of the
    /// default ICWS family. Overrides a previous `for_kernel` choice.
    pub fn sketcher(mut self, s: Box<dyn Sketcher>) -> Self {
        self.sketcher = Some(s);
        self.from_kernel = None;
        self
    }

    /// Wire scaling + sketcher from a [`Kernel`]'s own linearization:
    /// the pipeline then trains a linear model approximating that
    /// kernel's SVM. Errors for kernels with no known linearization.
    /// The sketcher itself is constructed at `build()` with the final
    /// seed/k, so `.for_kernel(..).seed(..).samples(..)` composes in
    /// any order.
    pub fn for_kernel<K: Kernel + 'static>(mut self, kernel: K) -> Result<Self, PipelineError> {
        // Probe linearizability eagerly so the error points at this call.
        if kernel.sketcher(0, 1).is_none() {
            return Err(PipelineError::NotLinearizable(kernel.name()));
        }
        self.scaling = Scaling::for_normalization(kernel.required_normalization());
        let name = kernel.name();
        self.from_kernel = Some((name, Box::new(move |seed, k| kernel.sketcher(seed, k))));
        self.sketcher = None;
        Ok(self)
    }

    /// Validate and assemble the pipeline.
    pub fn build(self) -> Result<Pipeline, PipelineError> {
        let k = self.effective_k();
        let sketcher: Box<dyn Sketcher> = match (self.sketcher, self.from_kernel) {
            (Some(s), _) => s,
            (None, Some((name, factory))) => {
                factory(self.seed, k).ok_or(PipelineError::NotLinearizable(name))?
            }
            (None, None) => Box::new(crate::cws::CwsHasher::new(self.seed, k)),
        };
        // An explicit sketcher AND an explicit samples() that disagree
        // is a configuration bug, not something to silently resolve.
        if let Some(k) = self.samples {
            if sketcher.k() != k {
                return Err(PipelineError::SketcherMismatch {
                    sketcher_k: sketcher.k(),
                    expansion_k: k,
                });
            }
        }
        let expansion = Expansion::checked(sketcher.k(), self.i_bits, self.t_bits)?;
        Ok(Pipeline {
            scaling: self.scaling,
            sketcher,
            expansion,
            c: self.c,
            model: None,
            n_classes: 0,
            scorer_cache: None,
        })
    }
}

/// The fitted (or fittable) hashing pipeline:
/// `Scaling → Sketcher → Expansion → LinearOvR`.
pub struct Pipeline {
    scaling: Scaling,
    sketcher: Box<dyn Sketcher>,
    expansion: Expansion,
    c: f64,
    model: Option<LinearOvR>,
    n_classes: usize,
    /// Fused serving scorer built once at `fit` (for the training
    /// dimensionality) so repeated `predict` calls don't re-materialize
    /// the parameter and weight slabs; `None` for sketchers without a
    /// fused path.
    scorer_cache: Option<Scorer>,
}

impl Pipeline {
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder::new()
    }

    /// Scale (if configured) and sketch every row — the shared front
    /// half of [`Pipeline::transform`] and [`Pipeline::transform_codes`].
    fn sketch(&self, x: &Matrix) -> Vec<Option<Vec<CwsSample>>> {
        // Scaling::None borrows the input directly — no matrix copy on
        // the default (min-max regime) path.
        match self.scaling {
            Scaling::None => self.sketcher.sketch_matrix(x),
            _ => self.sketcher.sketch_matrix(&self.scaling.apply(x)),
        }
    }

    /// The feature map alone: scale, sketch, expand to the legacy CSR
    /// representation (compatibility/IO path — fit/predict ride
    /// [`Pipeline::transform_codes`]). Rows with no positive entry
    /// become all-zero feature rows. Deterministic per (sketcher,
    /// expansion) — train/test/serving all agree.
    ///
    /// Sketching goes through [`Sketcher::sketch_matrix`], so the
    /// default ICWS sketchers shard rows across `MINMAX_THREADS` scoped
    /// threads via the `cws::SketchEngine` batch entry; the output is
    /// identical at any thread count, so fit/transform stay
    /// reproducible.
    pub fn transform(&self, x: &Matrix) -> Csr {
        self.expansion.expand(&self.sketch(x))
    }

    /// The feature map as a one-hot [`CodeMatrix`] — what fit/predict
    /// train and score on: same columns as [`Pipeline::transform`]
    /// (`transform_codes(x).to_csr() == transform(x)`), ~3× less memory
    /// traffic, and gather-only downstream inner products.
    pub fn transform_codes(&self, x: &Matrix) -> CodeMatrix {
        self.expansion.encode(&self.sketch(x))
    }

    /// Fit the linear model on hashed features (the one-hot code-matrix
    /// fast path; OvR classes train across `MINMAX_THREADS`). Also
    /// builds the fused serving scorer for the training dimensionality,
    /// so subsequent `predict` calls score without re-materializing the
    /// parameter/weight slabs.
    pub fn fit(&mut self, x: &Matrix, y: &[i32]) -> Result<&mut Self, PipelineError> {
        if x.rows() != y.len() {
            return Err(PipelineError::ShapeMismatch { rows: x.rows(), labels: y.len() });
        }
        let n_classes = y.iter().copied().max().unwrap_or(0).max(0) as usize + 1;
        let features = self.transform_codes(x);
        let params = LinearSvmParams { c: self.c, ..Default::default() };
        self.model = Some(LinearOvR::train(&features, y, n_classes, &params));
        self.n_classes = n_classes;
        self.scorer_cache = match self.scorer(x.cols()) {
            Ok(s) => Some(s),
            Err(PipelineError::UnsupportedSketcher(_)) => None,
            Err(e) => return Err(e),
        };
        Ok(self)
    }

    /// Predict class labels for a feature matrix. ICWS-backed pipelines
    /// ride the fused [`Scorer`] batch path (sketch → code → gather in
    /// one pass, no `CodeMatrix` materialization, rows sharded across
    /// `MINMAX_THREADS`); its predictions are bit-identical to the
    /// layered `transform_codes → predict_on` path, which remains the
    /// fallback for non-ICWS sketchers (minwise, PJRT).
    pub fn predict(&self, x: &Matrix) -> Result<Vec<i32>, PipelineError> {
        let model = self.model.as_ref().ok_or(PipelineError::NotFitted)?;
        // Fit-time cache when the dimensionality matches; otherwise a
        // fresh scorer for this matrix's width. Only a sketcher with no
        // fused path falls back to the layered route — any other scorer
        // error is a real fault and propagates.
        if let Some(scorer) = self.scorer_cache.as_ref().filter(|s| s.dim() == x.cols()) {
            return Ok(scorer.predict_batch(x));
        }
        match self.scorer(x.cols()) {
            Ok(scorer) => return Ok(scorer.predict_batch(x)),
            Err(PipelineError::UnsupportedSketcher(_)) => {}
            Err(e) => return Err(e),
        }
        let features = self.transform_codes(x);
        Ok((0..features.rows()).map(|i| model.predict_on(&features, i)).collect())
    }

    /// Build the fused serving [`Scorer`] for this fitted pipeline:
    /// the model's weights are transposed into the class-minor
    /// `[K, 2^bits, C]` slab at full f64 precision, the pipeline's
    /// scaling stage is carried over, and the ICWS parameter slabs are
    /// materialized for raw input dimensionality `dim`. Only the
    /// native ICWS sketcher families are supported (`icws` pins exact
    /// math — its batch path always sketches exact — while
    /// `icws-materialized` follows `MINMAX_FAST_MATH` like the engine
    /// it wraps); other sketchers yield
    /// [`PipelineError::UnsupportedSketcher`].
    pub fn scorer(&self, dim: usize) -> Result<Scorer, PipelineError> {
        let model = self.model.as_ref().ok_or(PipelineError::NotFitted)?;
        let pin_exact = match self.sketcher.name() {
            "icws" => true,
            "icws-materialized" => false,
            name => return Err(PipelineError::UnsupportedSketcher(name)),
        };
        let mut scorer = Scorer::from_model(self.sketcher.seed(), dim, self.expansion, model)?
            .with_scaling(self.scaling);
        if pin_exact {
            scorer = scorer.with_fast_math(false);
        }
        Ok(scorer)
    }

    /// Stand up a sharded serving cluster
    /// ([`crate::coordinator::cluster::ScoreRouter`]) for this fitted
    /// pipeline: builds the fused [`Scorer`] for raw dimensionality
    /// `dim` and starts `cfg.shards` workers behind bounded queues.
    /// Subsequent retrains can be pushed with
    /// [`crate::coordinator::cluster::ScoreRouter::publish`] without
    /// restarting the cluster. Errors are stringly typed to match the
    /// coordinator layer's start functions.
    pub fn cluster(
        &self,
        dim: usize,
        cfg: crate::coordinator::ClusterConfig,
    ) -> Result<crate::coordinator::ScoreRouter, String> {
        let scorer = self.scorer(dim).map_err(|e| e.to_string())?;
        crate::coordinator::ScoreRouter::start(scorer, cfg)
    }

    /// Per-class decision values for one already-transformed row set —
    /// a [`CodeMatrix`] from [`Pipeline::transform_codes`] or a legacy
    /// CSR from [`Pipeline::transform`].
    pub fn decisions<X: RowSet + ?Sized>(
        &self,
        features: &X,
        row: usize,
    ) -> Result<Vec<f64>, PipelineError> {
        let model = self.model.as_ref().ok_or(PipelineError::NotFitted)?;
        Ok(model.decisions_on(features, row))
    }

    /// [`Pipeline::decisions`] into a caller-owned buffer
    /// (`len == n_classes`) — no per-row allocation.
    pub fn decisions_into<X: RowSet + ?Sized>(
        &self,
        features: &X,
        row: usize,
        out: &mut [f64],
    ) -> Result<(), PipelineError> {
        let model = self.model.as_ref().ok_or(PipelineError::NotFitted)?;
        model.decisions_into(features, row, out);
        Ok(())
    }

    /// Test accuracy against ground-truth labels.
    pub fn accuracy(&self, x: &Matrix, y: &[i32]) -> Result<f64, PipelineError> {
        if x.rows() != y.len() {
            return Err(PipelineError::ShapeMismatch { rows: x.rows(), labels: y.len() });
        }
        let preds = self.predict(x)?;
        let hits = preds.iter().zip(y).filter(|(p, t)| p == t).count();
        Ok(hits as f64 / y.len().max(1) as f64)
    }

    /// Export the fitted model's weights in the `[K, 2^bits, C]` serving
    /// layout (see `coordinator::export_scorer_weights`); `None` before
    /// `fit`.
    pub fn export_weights(&self) -> Option<Vec<f32>> {
        match self.export_weights_with(crate::serve::SlabPrecision::F32)? {
            crate::serve::ExportedWeights::F32(w) => Some(w),
            _ => unreachable!("an F32 export always carries an F32 slab"),
        }
    }

    /// [`Pipeline::export_weights`] at a chosen slab precision: the
    /// f64 master, the historical f32 bytes, or the gated per-class
    /// affine int8 triple (see `svm::LinearOvR::export_scorer_weights`
    /// for the layout and quantization contract). Feed the result to
    /// [`Scorer::from_exported_slab`] to serve without training
    /// structs; `None` before `fit`.
    pub fn export_weights_with(
        &self,
        precision: crate::serve::SlabPrecision,
    ) -> Option<crate::serve::ExportedWeights> {
        let model = self.model.as_ref()?;
        Some(model.export_scorer_weights(&self.expansion, precision))
    }

    pub fn expansion(&self) -> &Expansion {
        &self.expansion
    }

    pub fn scaling(&self) -> Scaling {
        self.scaling
    }

    pub fn sketcher(&self) -> &dyn Sketcher {
        self.sketcher.as_ref()
    }

    pub fn model(&self) -> Option<&LinearOvR> {
        self.model.as_ref()
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn is_fitted(&self) -> bool {
        self.model.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::kernels::KernelKind;
    use crate::sketch::MinwiseSketcher;

    fn letter() -> crate::data::Dataset {
        generate("letter", SynthConfig { seed: 3, n_train: 150, n_test: 150 }).unwrap()
    }

    #[test]
    fn builder_validates_bit_budget() {
        assert!(matches!(
            Pipeline::builder().i_bits(0).build(),
            Err(PipelineError::Expansion(_))
        ));
        assert!(matches!(
            Pipeline::builder().i_bits(16).t_bits(16).build(),
            Err(PipelineError::Expansion(_))
        ));
        assert!(Pipeline::builder().i_bits(8).t_bits(2).build().is_ok());
    }

    #[test]
    fn unfitted_pipeline_errors_cleanly() {
        let ds = letter();
        let pipe = Pipeline::builder().build().unwrap();
        assert!(!pipe.is_fitted());
        assert_eq!(pipe.predict(&ds.test_x), Err(PipelineError::NotFitted));
        assert!(pipe.export_weights().is_none());
    }

    #[test]
    fn fit_predict_beats_chance_and_matches_free_functions() {
        let ds = letter();
        let mut pipe =
            Pipeline::builder().seed(5).samples(128).i_bits(8).cost(1.0).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let acc = pipe.accuracy(&ds.test_x, &ds.test_y).unwrap();
        assert!(acc > 2.0 / ds.n_classes() as f64, "accuracy {acc}");

        // The object API reproduces the manual transform + train + eval
        // composition exactly (same class count, same solver seed).
        let tr = pipe.transform(&ds.train_x);
        let te = pipe.transform(&ds.test_x);
        let want = crate::svm::linear_svm_accuracy(
            &tr,
            &ds.train_y,
            &te,
            &ds.test_y,
            pipe.n_classes(),
            1.0,
        );
        assert!((acc - want).abs() < 1e-12, "pipeline {acc} vs free fn {want}");
    }

    #[test]
    fn transform_is_deterministic_and_k_hot() {
        let ds = letter();
        let pipe = Pipeline::builder().seed(9).samples(32).i_bits(4).build().unwrap();
        let a = pipe.transform(&ds.train_x);
        let b = pipe.transform(&ds.train_x);
        assert_eq!(a, b);
        assert_eq!(a.cols(), pipe.expansion().dim());
        for i in 0..a.rows() {
            assert_eq!(a.row(i).nnz(), 32);
        }
    }

    #[test]
    fn transform_codes_roundtrips_to_transform() {
        let ds = letter();
        let pipe = Pipeline::builder().seed(9).samples(32).i_bits(4).build().unwrap();
        let codes = pipe.transform_codes(&ds.train_x);
        codes.check_invariants().unwrap();
        assert_eq!(codes.to_csr(), pipe.transform(&ds.train_x));
        assert_eq!(codes.cols(), pipe.expansion().dim());
    }

    #[test]
    fn decisions_agree_between_codes_and_csr_features() {
        let ds = letter();
        let mut pipe = Pipeline::builder().seed(4).samples(16).i_bits(4).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let codes = pipe.transform_codes(&ds.test_x);
        let csr = pipe.transform(&ds.test_x);
        for i in 0..codes.rows().min(10) {
            assert_eq!(pipe.decisions(&codes, i).unwrap(), pipe.decisions(&csr, i).unwrap());
        }
    }

    #[test]
    fn for_kernel_wires_scaling_and_sketcher() {
        let p = Pipeline::builder().for_kernel(KernelKind::NMinMax).unwrap().build().unwrap();
        assert_eq!(p.scaling(), Scaling::L1);
        assert_eq!(p.sketcher().name(), "icws");

        let p = Pipeline::builder().for_kernel(KernelKind::Resemblance).unwrap().build().unwrap();
        assert_eq!(p.sketcher().name(), "minwise");

        assert!(matches!(
            Pipeline::builder().for_kernel(KernelKind::Linear),
            Err(PipelineError::NotLinearizable("linear"))
        ));
    }

    #[test]
    fn for_kernel_composes_with_later_seed_and_samples() {
        // The linearization is constructed at build() with the FINAL
        // configuration, whichever order the builder calls come in.
        let p = Pipeline::builder()
            .for_kernel(KernelKind::MinMax)
            .unwrap()
            .seed(42)
            .samples(16)
            .build()
            .unwrap();
        assert_eq!(p.sketcher().seed(), 42);
        assert_eq!(p.sketcher().k(), 16);
        let q = Pipeline::builder().seed(42).samples(16).build().unwrap();
        let ds = letter();
        assert_eq!(p.transform(&ds.train_x), q.transform(&ds.train_x));
    }

    #[test]
    fn conflicting_samples_and_sketcher_is_an_error() {
        let err = Pipeline::builder()
            .sketcher(Box::new(MinwiseSketcher::new(1, 64)))
            .samples(128)
            .build()
            .err()
            .expect("mismatch must error");
        assert_eq!(err, PipelineError::SketcherMismatch { sketcher_k: 64, expansion_k: 128 });
        // Agreeing values are fine.
        assert!(Pipeline::builder()
            .sketcher(Box::new(MinwiseSketcher::new(1, 64)))
            .samples(64)
            .build()
            .is_ok());
    }

    #[test]
    fn custom_sketcher_slots_in() {
        let ds = letter();
        let mut pipe = Pipeline::builder()
            .sketcher(Box::new(MinwiseSketcher::new(7, 64)))
            .i_bits(8)
            .build()
            .unwrap();
        assert_eq!(pipe.sketcher().k(), 64);
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        // Minwise only sees the support, which is nearly constant on this
        // dense dataset — this checks the plumbing, not model quality.
        let acc = pipe.accuracy(&ds.test_x, &ds.test_y).unwrap();
        assert!(acc >= 0.5 / ds.n_classes() as f64, "accuracy {acc}");
    }

    #[test]
    fn fit_shape_mismatch_is_an_error() {
        let ds = letter();
        let mut pipe = Pipeline::builder().build().unwrap();
        let short = vec![0i32; 3];
        assert!(matches!(
            pipe.fit(&ds.train_x, &short),
            Err(PipelineError::ShapeMismatch { .. })
        ));
    }

    #[test]
    fn export_weights_match_coordinator_export() {
        let ds = letter();
        let mut pipe = Pipeline::builder().seed(5).samples(16).i_bits(4).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let w = pipe.export_weights().unwrap();
        let features = pipe.transform(&ds.train_x);
        let want = crate::coordinator::export_scorer_weights(
            &features,
            &ds.train_y,
            pipe.n_classes(),
            pipe.expansion(),
            1.0,
        );
        assert_eq!(w.len(), want.len());
        for (a, b) in w.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn predict_rides_the_fused_scorer_bit_identically() {
        // The serving invariant at the pipeline level: the fused path
        // `predict` now rides equals the layered codes path exactly.
        let ds = letter();
        let mut pipe = Pipeline::builder().seed(8).samples(24).i_bits(5).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let via_scorer = pipe.predict(&ds.test_x).unwrap();
        let codes = pipe.transform_codes(&ds.test_x);
        let model = pipe.model().unwrap();
        let layered: Vec<i32> =
            (0..codes.rows()).map(|i| model.predict_on(&codes, i)).collect();
        assert_eq!(via_scorer, layered);
        // Sparse representation of the same data agrees too.
        let sparse = Matrix::Sparse(ds.test_x.to_csr());
        assert_eq!(pipe.predict(&sparse).unwrap(), layered);
    }

    #[test]
    fn scorer_requires_fit_and_icws() {
        let ds = letter();
        let pipe = Pipeline::builder().build().unwrap();
        assert!(matches!(pipe.scorer(ds.dim()), Err(PipelineError::NotFitted)));
        let mut mw = Pipeline::builder()
            .sketcher(Box::new(MinwiseSketcher::new(1, 16)))
            .i_bits(4)
            .build()
            .unwrap();
        mw.fit(&ds.train_x, &ds.train_y).unwrap();
        assert!(matches!(
            mw.scorer(ds.dim()),
            Err(PipelineError::UnsupportedSketcher("minwise"))
        ));
        // The minwise pipeline still predicts via the layered fallback.
        assert_eq!(mw.predict(&ds.test_x).unwrap().len(), ds.n_test());
    }

    #[test]
    fn decisions_into_matches_decisions() {
        let ds = letter();
        let mut pipe = Pipeline::builder().seed(4).samples(16).i_bits(4).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let codes = pipe.transform_codes(&ds.test_x);
        let mut buf = vec![0.0f64; pipe.n_classes()];
        for i in 0..codes.rows().min(10) {
            pipe.decisions_into(&codes, i, &mut buf).unwrap();
            assert_eq!(buf, pipe.decisions(&codes, i).unwrap());
        }
    }

    #[test]
    fn scaling_binarize_collapses_weights() {
        // Binarized input: ICWS degenerates to minwise statistics, so
        // two scaling-binarize transforms of weight-jittered copies of
        // the same support are identical.
        let d = crate::data::Dense::from_rows(&[&[0.5f32, 0.0, 2.0], &[3.0f32, 0.0, 0.1]]);
        let m = Matrix::Dense(d);
        let pipe = Pipeline::builder()
            .scaling(Scaling::Binarize)
            .samples(16)
            .i_bits(4)
            .build()
            .unwrap();
        let t = pipe.transform(&m);
        assert_eq!(t.row(0).indices, t.row(1).indices);
    }
}
