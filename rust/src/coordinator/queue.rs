//! Generic MPMC shard-queue machinery plus the versioned hot-swap
//! cell — extracted from `cluster.rs` so the loom models
//! (`rust/tests/loom_models.rs`) can exhaustively check the *actual*
//! production primitives rather than a re-implementation.
//!
//! The `score` and `query` service modes differ only in what a worker
//! does with a dequeued request, so they share this one implementation
//! (and one set of backpressure/shedding/drain semantics). Everything
//! here is `#[doc(hidden)] pub`: public enough for the integration-test
//! harness to drive, but not part of the crate's supported API — the
//! supported surface is [`super::cluster`] and [`super::service`].
//!
//! ## Invariants the loom models pin (DESIGN.md §2.8)
//!
//! * **Queue close:** every pushed request is popped exactly once
//!   before [`Pop::Closed`] is reported; a push after [`close`]
//!   returns [`PushError::Closed`] with the request handed back.
//! * **Backpressure vs. shed:** under the depth checks in [`push`],
//!   accept/[`PushError::Full`]/[`PushError::Shed`] outcomes are
//!   mutually exclusive per submit and consistent with the depth the
//!   submitter observed (the mutex serializes depth reads).
//! * **Swap:** [`SwapCell::get`] returns a fully-initialized value at
//!   a monotonically non-decreasing version; in-flight holders keep
//!   their `Arc` alive across an [`SwapCell::update`].
//! * **Drain:** the close-then-[`steal_any`]-sweep shutdown protocol
//!   serves every accepted request exactly once.
//!
//! [`push`]: ShardQueue::push
//! [`close`]: ShardQueue::close

use std::collections::VecDeque;
use std::time::Duration;

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{self, Arc, Condvar, Mutex, RwLock};

struct QueueInner<R> {
    queue: VecDeque<R>,
    closed: bool,
}

/// One bounded MPMC queue: submitters push from any thread, the owning
/// worker pops, idle siblings steal. `push` never blocks — flow
/// control is rejection, not waiting, so a submitter can fail over to
/// another shard immediately.
pub struct ShardQueue<R> {
    inner: Mutex<QueueInner<R>>,
    ready: Condvar,
}

#[derive(Debug, PartialEq, Eq)]
pub enum PushError {
    Full,
    Shed { depth: usize, watermark: usize },
    Closed,
}

pub enum Pop<R> {
    Req(Box<R>),
    /// Timed out with nothing queued (steal opportunity).
    Empty,
    /// Closed AND drained — the worker's own queue is finished.
    Closed,
}

impl<R> Default for ShardQueue<R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<R> ShardQueue<R> {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(QueueInner { queue: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }

    /// Rejections hand the request back so the submitter can fail
    /// over to another shard without cloning the row.
    pub fn push(&self, req: R, cap: usize, watermark: Option<usize>) -> Result<(), (PushError, R)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((PushError::Closed, req));
        }
        let depth = g.queue.len();
        if depth >= cap {
            return Err((PushError::Full, req));
        }
        if let Some(w) = watermark {
            if depth >= w {
                return Err((PushError::Shed { depth, watermark: w }, req));
            }
        }
        g.queue.push_back(req);
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Pop, waiting up to `timeout`. Items are always drained before
    /// `Closed` is reported, so closing never strands queued work.
    ///
    /// Under loom the facade's `wait_timeout` reports every wakeup as
    /// a timeout (no time model) — sound here because the timeout arm
    /// re-checks the queue and the closed flag rather than trusting
    /// the clock.
    pub fn pop_wait(&self, timeout: Duration) -> Pop<R> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.queue.pop_front() {
                return Pop::Req(Box::new(r));
            }
            if g.closed {
                return Pop::Closed;
            }
            let (g2, timed_out) = sync::wait_timeout(&self.ready, g, timeout);
            g = g2;
            if timed_out {
                return match g.queue.pop_front() {
                    Some(r) => Pop::Req(Box::new(r)),
                    None if g.closed => Pop::Closed,
                    None => Pop::Empty,
                };
            }
        }
    }

    /// Non-blocking pop (the steal path).
    pub fn try_pop(&self) -> Option<Box<R>> {
        self.inner.lock().unwrap().queue.pop_front().map(Box::new)
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }
}

/// How long an idle worker blocks on its own queue before scanning
/// siblings for stealable work.
pub const STEAL_POLL: Duration = Duration::from_millis(1);

/// Scan sibling queues (not our own — it was just found empty).
pub fn steal<R>(me: usize, queues: &[ShardQueue<R>]) -> Option<Box<R>> {
    let n = queues.len();
    (1..n).find_map(|off| queues[(me + off) % n].try_pop())
}

/// Scan every queue, own first (the shutdown-drain sweep).
pub fn steal_any<R>(me: usize, queues: &[ShardQueue<R>]) -> Option<Box<R>> {
    let n = queues.len();
    (0..n).find_map(|off| queues[(me + off) % n].try_pop())
}

/// Least-deep shard with a rotating round-robin tie-break start, so
/// equal-depth shards share arrivals instead of all landing on 0.
pub fn pick_least_deep<R>(queues: &[ShardQueue<R>], rr: &AtomicU64) -> usize {
    let n = queues.len();
    // relaxed-ok: rotating tie-break hint only — any interleaving of
    // the counter yields a valid start shard; no data is synchronized.
    let start = (rr.fetch_add(1, Ordering::Relaxed) as usize) % n;
    let mut best = start;
    let mut best_depth = usize::MAX;
    for off in 0..n {
        let i = (start + off) % n;
        let d = queues[i].depth();
        if d < best_depth {
            best_depth = d;
            best = i;
        }
    }
    best
}

/// The versioned hot-swap slot: readers take a shared lock just long
/// enough to clone the `Arc`; [`update`](SwapCell::update) swaps the
/// pointer under the write lock. In-flight holders keep the old value
/// alive until their last clone drops — the drain half of the swap
/// protocol (module docs, "Swap" invariant).
pub struct SwapCell<T> {
    slot: RwLock<Arc<T>>,
}

impl<T> SwapCell<T> {
    pub fn new(value: T) -> Self {
        Self { slot: RwLock::new(Arc::new(value)) }
    }

    /// Clone the current `Arc` (what workers do at every dequeue).
    pub fn get(&self) -> Arc<T> {
        self.slot.read().unwrap().clone()
    }

    /// Compute the replacement from the current value under the write
    /// lock and swap it in atomically; returns the closure's second
    /// output (e.g. the new version number). Validation that must be
    /// serialized against concurrent publishes belongs inside `f`.
    pub fn update<U>(&self, f: impl FnOnce(&T) -> (T, U)) -> U {
        let mut g = self.slot.write().unwrap();
        let (next, out) = f(&g);
        *g = Arc::new(next);
        out
    }
}

// Loom's Mutex/Condvar/RwLock are !Sync-transparent in the same way
// std's are, so no manual Send/Sync impls are needed in either cfg.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_fifo_and_close() {
        let q: ShardQueue<u32> = ShardQueue::new();
        q.push(1, 4, None).unwrap();
        q.push(2, 4, None).unwrap();
        assert_eq!(q.depth(), 2);
        assert!(matches!(q.pop_wait(Duration::from_millis(1)), Pop::Req(b) if *b == 1));
        assert_eq!(q.try_pop().as_deref(), Some(&2));
        assert!(matches!(q.pop_wait(Duration::from_millis(1)), Pop::Empty));
        q.close();
        assert_eq!(q.push(3, 4, None).unwrap_err().0, PushError::Closed);
        assert!(matches!(q.pop_wait(Duration::from_millis(1)), Pop::Closed));
    }

    #[test]
    fn cap_and_watermark_reject_with_handback() {
        let q: ShardQueue<u32> = ShardQueue::new();
        q.push(1, 2, Some(2)).unwrap();
        q.push(2, 2, Some(2)).unwrap();
        let (e, req) = q.push(3, 2, Some(2)).unwrap_err();
        assert_eq!(e, PushError::Full);
        assert_eq!(req, 3);
        let (e, _) = q.push(3, 4, Some(2)).unwrap_err();
        assert_eq!(e, PushError::Shed { depth: 2, watermark: 2 });
    }

    #[test]
    fn steal_order_skips_own_queue() {
        let qs: Vec<ShardQueue<u32>> = (0..3).map(|_| ShardQueue::new()).collect();
        qs[0].push(10, 8, None).unwrap();
        qs[2].push(30, 8, None).unwrap();
        // steal() from shard 0 must not see shard 0's own item.
        assert_eq!(steal(0, &qs).as_deref(), Some(&30));
        assert_eq!(steal(0, &qs), None);
        // steal_any() sweeps own-first.
        assert_eq!(steal_any(0, &qs).as_deref(), Some(&10));
    }

    #[test]
    fn swap_cell_versions_are_monotone() {
        let cell = SwapCell::new((1u64, "a"));
        let held = cell.get();
        let v = cell.update(|cur| ((cur.0 + 1, "b"), cur.0 + 1));
        assert_eq!(v, 2);
        assert_eq!(cell.get().1, "b");
        // In-flight holder still sees the version it dequeued with.
        assert_eq!(*held, (1, "a"));
    }
}
