//! The hashing/scoring service: the deployable L3 piece the paper's §5
//! pitch implies ("a tool for feature engineering … extremely efficient
//! and scalable linear methods").
//!
//! Shape: callers submit single nonnegative vectors and receive either
//! their CWS samples (**hash mode**) or per-class decisions + argmax
//! label (**score mode** — the fused `serve::Scorer` runs
//! sketch→code→score in one pass on the worker). Internally:
//!
//! ```text
//! submit()/submit_score() ─► bounded queue (backpressure)
//!   ─► dynamic batcher (max batch size OR deadline)
//!   ─► hash mode:  Box<dyn Sketcher> built on the worker thread by
//!                  the SketcherBackend factory
//!      score mode: serve::Scorer + one reusable Scratch arena
//!                  (zero per-request sketch/code/decision allocation
//!                  on the worker — only the response Vec leaves)
//!   ─► per-request responses (mpsc)
//! ```
//!
//! The built-in backends draw the same counter-based randomness, so
//! which one a deployment uses is a pure throughput/operational choice
//! (validated by `rust/tests/pipeline_integration.rs`). A score-mode
//! service answers plain hash submits too, from the scorer's own
//! parameter slabs.
//!
//! The batch loop is **panic-isolated**: request computation runs
//! inside `catch_unwind`, so a poisoned vector (or a buggy third-party
//! backend) answers its own request(s) with the typed
//! [`SubmitError::WorkerPanicked`] and the worker keeps serving — it
//! never takes the whole service down with it. The sharded cluster
//! layer ([`super::cluster`]) extends the same contract with worker
//! supervision and deadlines.
//!
//! Retrieval (top-k similar rows rather than a class label) is the
//! third service mode and lives one layer up: see
//! [`super::cluster::QueryRouter`], which shards an LSH index the same
//! way [`super::cluster::ScoreRouter`] shards scorers.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{mpsc, spawn_named, thread, Arc};

use crate::cws::{CwsSample, SketchScratch};
use crate::serve::{argmax, Scorer, Scratch};
use crate::sketch::Sketcher;

use super::backend::SketcherBackend;
use super::faults::panic_message;
use super::metrics::Metrics;

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub seed: u64,
    /// Samples per vector (k). For the PJRT backend this must match the
    /// artifact's K.
    pub k: usize,
    /// Input dimensionality. For PJRT must match the artifact's D.
    pub dim: usize,
    /// Dynamic batcher: flush at this many requests…
    pub max_batch: usize,
    /// …or after this long since the first queued request.
    pub max_wait: Duration,
    /// Bounded queue capacity (backpressure): submits fail fast beyond it.
    pub queue_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            seed: 2015,
            k: 64,
            dim: 64,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

pub struct HashResponse {
    pub id: u64,
    pub samples: Vec<CwsSample>,
    /// Total time from submit to completion.
    pub latency: Duration,
}

/// Score-mode response: per-class decision values and the argmax label
/// the fused scorer computed — what a classification frontend needs,
/// with no `CwsSample` stream on the wire.
pub struct ScoreResponse {
    pub id: u64,
    /// Per-class decision values (`len == n_classes`).
    pub decisions: Vec<f64>,
    /// `argmax(decisions)` with `LinearOvR::predict_on` semantics.
    pub label: i32,
    /// Total time from submit to completion.
    pub latency: Duration,
}

/// Where a request's answer goes: hash submits want samples, score
/// submits want decisions. One queue carries both so the batcher and
/// backpressure logic stay single-path. The payload is a `Result` so a
/// request whose computation panicked still gets its exactly-one
/// response — as the typed [`SubmitError::WorkerPanicked`] — instead
/// of a dropped channel the client cannot tell from shutdown.
enum Responder {
    Hash(mpsc::Sender<Result<HashResponse, SubmitError>>),
    Score(mpsc::Sender<Result<ScoreResponse, SubmitError>>),
}

struct Request {
    id: u64,
    vector: Vec<f32>,
    submitted: Instant,
    resp: Responder,
}

enum Msg {
    Req(Request),
    Flush,
}

/// Score-mode worker state: the fused scorer plus its long-lived
/// scratch arenas — the "pooled" buffers that make steady-state
/// per-request work allocation-free on the worker.
struct ScoreExec {
    scorer: Scorer,
    scratch: Scratch,
    /// Decision staging reused across requests; each response copies it
    /// into its own (n_classes-sized) Vec.
    staging: Vec<f64>,
    /// Sketch scratch + sample staging for hash submits served from
    /// the scorer's engine.
    sketch: SketchScratch,
    samples: Vec<CwsSample>,
}

/// What the worker thread executes: a backend-built sketcher (hash
/// mode) or the fused scorer state (score mode).
enum WorkerExec {
    Hash(Box<dyn Sketcher>),
    Score(Box<ScoreExec>),
}

/// Handle to the running service.
///
/// ## Shutdown contract (graceful drain)
///
/// [`HashService::shutdown`] (and `Drop`) closes the queue by dropping
/// the sender — NOT by racing a control message past queued work. The
/// worker keeps receiving until the channel reports disconnection,
/// which by mpsc semantics only happens after every buffered message
/// has been delivered; it then flushes its final partial batch and
/// exits. Consequence: **every request a submit accepted gets exactly
/// one response** — accepted-then-dropped requests cannot happen, and
/// submits that lose the race to shutdown fail with the typed
/// [`SubmitError::ShuttingDown`] instead. Pinned by
/// `shutdown_drains_accepted_requests` below.
pub struct HashService {
    /// `None` once shutdown began — dropping the sender is what closes
    /// the queue and lets the worker drain it.
    tx: Option<mpsc::SyncSender<Msg>>,
    worker: Option<thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    stopping: Arc<AtomicBool>,
    cfg: ServiceConfig,
    /// `Some(n_classes)` when started in score mode.
    scoring: Option<usize>,
    /// The serving plan when started in score mode: which weight slab
    /// the worker's scorer streams and whether it packs codes —
    /// deployment observability, mirroring the cluster's publish-time
    /// invariants.
    score_plan: Option<(crate::serve::SlabPrecision, bool)>,
}

#[derive(Debug)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
    BadInput(String),
    /// `submit_score` on a service started in hash mode.
    NotScoring,
    /// The worker's computation panicked serving this request. The
    /// panic was caught at the batch loop's unwind boundary: the worker
    /// (and every other queued request) keeps going, and this request's
    /// response channel carries the typed error with the captured panic
    /// message instead of silently disconnecting.
    WorkerPanicked { message: String },
    /// A bounded wait ([`super::Routed::wait_timeout`]) elapsed before
    /// the response arrived. The request is still in flight — it was
    /// not cancelled, and its response may still be received later.
    WaitTimeout,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full (backpressure)"),
            SubmitError::ShuttingDown => write!(f, "service shutting down"),
            SubmitError::BadInput(s) => write!(f, "bad input: {s}"),
            SubmitError::NotScoring => write!(f, "service has no scorer (hash mode)"),
            SubmitError::WorkerPanicked { message } => {
                write!(f, "worker panicked serving this request: {message}")
            }
            SubmitError::WaitTimeout => {
                write!(f, "timed out waiting for the response (request may still complete)")
            }
        }
    }
}
impl std::error::Error for SubmitError {}

impl HashService {
    /// Start the service over any [`SketcherBackend`]. The factory runs
    /// on the worker thread (PJRT clients are thread-bound); `start`
    /// blocks until it reports readiness, so backend misconfiguration
    /// (missing artifacts, D/K mismatch, `pjrt` feature absent) surfaces
    /// here instead of hanging every submit.
    pub fn start(cfg: ServiceConfig, backend: impl SketcherBackend) -> Result<HashService, String> {
        let label = backend.label();
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_cap);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let metrics = Arc::new(Metrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let m2 = Arc::clone(&metrics);
        let cfg2 = cfg.clone();
        let boxed: Box<dyn SketcherBackend> = Box::new(backend);
        let worker = spawn_named("minmax-hash-service".into(), move || {
            let sketcher = match boxed.build(&cfg2) {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            run_worker(cfg2, WorkerExec::Hash(sketcher), rx, m2);
        })
        .map_err(|e| format!("spawn service worker: {e}"))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(format!("{label} backend failed to start: {e}"));
            }
            Err(_) => {
                let _ = worker.join();
                return Err(format!("{label} backend worker died during startup"));
            }
        }
        Ok(HashService {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            stopping,
            cfg,
            scoring: None,
            score_plan: None,
        })
    }

    /// Start in **score mode**: the worker owns the fused
    /// [`Scorer`] (and one long-lived scratch arena) and answers
    /// `submit_score` with per-class decisions + argmax label. Plain
    /// `submit` hashing requests are served from the scorer's own
    /// parameter slabs. The scorer's `(seed, k, dim)` must match the
    /// service configuration — a mismatched deployment fails here, not
    /// per request.
    pub fn start_scoring(cfg: ServiceConfig, scorer: Scorer) -> Result<HashService, String> {
        if scorer.k() != cfg.k {
            return Err(format!("scorer k {} != service k {}", scorer.k(), cfg.k));
        }
        if scorer.dim() != cfg.dim {
            return Err(format!("scorer dim {} != service dim {}", scorer.dim(), cfg.dim));
        }
        if scorer.seed() != cfg.seed {
            return Err(format!("scorer seed {} != service seed {}", scorer.seed(), cfg.seed));
        }
        let n_classes = scorer.n_classes();
        let score_plan = Some((scorer.precision(), scorer.packed_codes()));
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_cap);
        let metrics = Arc::new(Metrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let m2 = Arc::clone(&metrics);
        let cfg2 = cfg.clone();
        let worker = spawn_named("minmax-score-service".into(), move || {
            let scratch = scorer.scratch();
            let staging = vec![0.0f64; scorer.n_classes()];
            let samples = vec![CwsSample { i_star: u32::MAX, t_star: 0 }; scorer.k()];
            let exec = WorkerExec::Score(Box::new(ScoreExec {
                scorer,
                scratch,
                staging,
                sketch: SketchScratch::new(),
                samples,
            }));
            run_worker(cfg2, exec, rx, m2);
        })
        .map_err(|e| format!("spawn score worker: {e}"))?;
        Ok(HashService {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            stopping,
            cfg,
            scoring: Some(n_classes),
            score_plan,
        })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// `Some(n_classes)` when this service was started in score mode.
    pub fn n_classes(&self) -> Option<usize> {
        self.scoring
    }

    /// `Some((slab precision, packed codes))` when this service was
    /// started in score mode — the serving plan the worker's scorer
    /// executes (see `serve::SlabPrecision` and
    /// `serve::Scorer::with_packed_codes`).
    pub fn score_plan(&self) -> Option<(crate::serve::SlabPrecision, bool)> {
        self.score_plan
    }

    fn validate(&self, vector: &[f32]) -> Result<(), SubmitError> {
        // Acquire pairs with the Release store in `stop_and_drain`,
        // matching the cluster routers' documented stopping protocol.
        // This was `Relaxed` through PR 8 — an inconsistency the first
        // concurrency audit flagged (ISSUE 9): a Relaxed read here is
        // not ordered against the queue teardown that follows the
        // store, so a submitter could in principle observe the closed
        // channel before the flag and return the wrong error variant.
        if self.stopping.load(Ordering::Acquire) {
            return Err(SubmitError::ShuttingDown);
        }
        if vector.len() != self.cfg.dim {
            return Err(SubmitError::BadInput(format!(
                "dim {} != {}",
                vector.len(),
                self.cfg.dim
            )));
        }
        if !vector.iter().any(|&v| v > 0.0) {
            return Err(SubmitError::BadInput("all-zero vector".into()));
        }
        if vector.iter().any(|&v| v < 0.0 || !v.is_finite()) {
            return Err(SubmitError::BadInput("negative or non-finite entry".into()));
        }
        Ok(())
    }

    fn enqueue(&self, req: Request) -> Result<(), SubmitError> {
        let tx = self.tx.as_ref().ok_or(SubmitError::ShuttingDown)?;
        self.metrics.record_request();
        match tx.try_send(Msg::Req(req)) {
            Ok(()) => Ok(()),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(SubmitError::QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Submit one vector for hashing; the response arrives on the
    /// returned channel (an `Err(WorkerPanicked)` payload if the
    /// computation panicked — the request still gets exactly one
    /// answer). Fails fast with `QueueFull` under backpressure.
    pub fn submit(
        &self,
        id: u64,
        vector: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<HashResponse, SubmitError>>, SubmitError> {
        self.validate(&vector)?;
        let (rtx, rrx) = mpsc::channel();
        self.enqueue(Request {
            id,
            vector,
            submitted: Instant::now(),
            resp: Responder::Hash(rtx),
        })?;
        Ok(rrx)
    }

    /// Submit one vector for fused scoring (score-mode services only):
    /// the response carries per-class decisions and the argmax label.
    pub fn submit_score(
        &self,
        id: u64,
        vector: &[f32],
    ) -> Result<mpsc::Receiver<Result<ScoreResponse, SubmitError>>, SubmitError> {
        if self.scoring.is_none() {
            return Err(SubmitError::NotScoring);
        }
        self.validate(vector)?;
        let (rtx, rrx) = mpsc::channel();
        self.enqueue(Request {
            id,
            vector: vector.to_vec(),
            submitted: Instant::now(),
            resp: Responder::Score(rtx),
        })?;
        Ok(rrx)
    }

    /// Blocking convenience: submit for hashing and wait. Borrows the
    /// vector — the one owned copy is made here, not by every caller.
    pub fn hash_blocking(&self, id: u64, vector: &[f32]) -> Result<HashResponse, SubmitError> {
        let rx = self.submit(id, vector.to_vec())?;
        rx.recv().map_err(|_| SubmitError::ShuttingDown)?
    }

    /// Blocking convenience: submit for scoring and wait.
    pub fn score_blocking(&self, id: u64, vector: &[f32]) -> Result<ScoreResponse, SubmitError> {
        let rx = self.submit_score(id, vector)?;
        rx.recv().map_err(|_| SubmitError::ShuttingDown)?
    }

    /// Blocking classification: submit for scoring, return only the
    /// argmax label.
    pub fn classify_blocking(&self, id: u64, vector: &[f32]) -> Result<i32, SubmitError> {
        Ok(self.score_blocking(id, vector)?.label)
    }

    /// Ask the batcher to flush a partial batch immediately.
    pub fn flush(&self) {
        if let Some(tx) = self.tx.as_ref() {
            let _ = tx.try_send(Msg::Flush);
        }
    }

    /// Graceful shutdown: refuse new submits, close the queue, and
    /// block until the worker has drained and answered every request
    /// that was already accepted (see the type-level shutdown
    /// contract).
    pub fn shutdown(mut self) {
        self.stop_and_drain();
    }

    fn stop_and_drain(&mut self) {
        self.stopping.store(true, Ordering::Release);
        // Dropping the sender closes the queue; buffered requests stay
        // receivable, so the worker serves them all before exiting.
        drop(self.tx.take());
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HashService {
    fn drop(&mut self) {
        self.stop_and_drain();
    }
}

/// The batching loop. Hash mode is backend-agnostic: whatever the
/// factory built, the worker only sees `dyn Sketcher` — batched
/// backends override `sketch_dense_batch` (the native engine shards the
/// batch across `MINMAX_THREADS` scoped threads; the PJRT impl
/// pads/chunks to its fixed B internally). Score mode runs the fused
/// scorer per request against the worker's long-lived scratch arena —
/// no sketch/code/decision allocation per request; only the response's
/// own decisions `Vec` is fresh.
///
/// Shutdown is signaled by sender disconnection, which mpsc reports
/// only after every buffered message has been received — so the loop
/// naturally drains the queue, answers everything, and only then
/// exits (the service's exactly-one-response guarantee).
fn run_worker(
    cfg: ServiceConfig,
    mut exec: WorkerExec,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    loop {
        // Wait for the first request (or control message)…
        let first_deadline = if pending.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => {
                    pending.push(r);
                    Instant::now() + cfg.max_wait
                }
                Ok(Msg::Flush) => continue,
                // Disconnected with nothing buffered: fully drained.
                Err(_) => break,
            }
        } else {
            Instant::now() + cfg.max_wait
        };
        // …then fill the batch until size or deadline.
        let mut flush_now = false;
        let mut shutdown = false;
        while pending.len() < cfg.max_batch && !flush_now {
            let left = first_deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Flush) => flush_now = true,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if !pending.is_empty() {
            let batch: Vec<Request> = pending.drain(..).collect();
            metrics.record_batch(batch.len(), cfg.max_batch);
            for r in &batch {
                metrics.record_queue_wait_ms(r.submitted.elapsed().as_secs_f64() * 1e3);
            }
            run_batch(&mut exec, &batch, &metrics);
        }
        if shutdown {
            break;
        }
    }
}

fn run_batch(exec: &mut WorkerExec, batch: &[Request], metrics: &Metrics) {
    match exec {
        WorkerExec::Hash(sketcher) => {
            let rows: Vec<&[f32]> = batch.iter().map(|r| r.vector.as_slice()).collect();
            // Unwind boundary, per batch: hash backends compute the
            // whole batch in one call, so a panic inside poisons every
            // request in it — each gets the typed error — but never
            // the worker, which keeps serving the next batch. No lock
            // is held across the boundary (nothing here to poison).
            let sketched = catch_unwind(AssertUnwindSafe(|| {
                let sketched = sketcher.sketch_dense_batch(&rows);
                // Hard contract on third-party backends: one output per
                // request. A silent zip truncation would drop responses.
                assert_eq!(
                    sketched.len(),
                    batch.len(),
                    "sketcher '{}' returned {} sample streams for {} requests",
                    sketcher.name(),
                    sketched.len(),
                    batch.len()
                );
                sketched
            }));
            match sketched {
                Ok(sketched) => {
                    for (req, samples) in batch.iter().zip(sketched) {
                        match &req.resp {
                            Responder::Hash(_) => respond_hash(req, samples, metrics),
                            // submit_score is rejected on hash-mode services.
                            Responder::Score(_) => unreachable!("score request on hash worker"),
                        }
                    }
                }
                Err(payload) => {
                    let message = panic_message(payload.as_ref());
                    for req in batch {
                        metrics.record_panicked();
                        match &req.resp {
                            Responder::Hash(tx) => {
                                let _ = tx.send(Err(SubmitError::WorkerPanicked {
                                    message: message.clone(),
                                }));
                            }
                            Responder::Score(_) => unreachable!("score request on hash worker"),
                        }
                    }
                }
            }
        }
        WorkerExec::Score(state) => {
            let ScoreExec { scorer, scratch, staging, sketch, samples } = &mut **state;
            for req in batch {
                match &req.resp {
                    Responder::Score(tx) => {
                        // Unwind boundary, per request: one poisoned
                        // vector answers with the typed error; the
                        // batch's other requests still complete.
                        let computed = catch_unwind(AssertUnwindSafe(|| {
                            scorer.score_dense_into(&req.vector, scratch, staging);
                            (staging.clone(), argmax(staging))
                        }));
                        match computed {
                            Ok((decisions, label)) => {
                                let latency = req.submitted.elapsed();
                                metrics.record_latency_ms(latency.as_secs_f64() * 1e3);
                                let _ = tx.send(Ok(ScoreResponse {
                                    id: req.id,
                                    decisions,
                                    label,
                                    latency,
                                }));
                            }
                            Err(payload) => {
                                metrics.record_panicked();
                                reset_score_state(scorer, scratch, sketch, samples);
                                let _ = tx.send(Err(SubmitError::WorkerPanicked {
                                    message: panic_message(payload.as_ref()),
                                }));
                            }
                        }
                    }
                    // Hash submits on a score-mode service ride the
                    // scorer's own parameter slabs (note: the scorer
                    // hashes the RAW vector — its scaling stage applies
                    // to scoring only).
                    Responder::Hash(tx) => {
                        let computed = catch_unwind(AssertUnwindSafe(|| {
                            scorer.engine().sketch_dense_with(&req.vector, sketch, samples);
                            samples.clone()
                        }));
                        match computed {
                            Ok(s) => respond_hash(req, s, metrics),
                            Err(payload) => {
                                metrics.record_panicked();
                                reset_score_state(scorer, scratch, sketch, samples);
                                let _ = tx.send(Err(SubmitError::WorkerPanicked {
                                    message: panic_message(payload.as_ref()),
                                }));
                            }
                        }
                    }
                }
            }
        }
    }
}

/// After a caught panic the long-lived scratch arenas may hold
/// partially-written state; rebuild them so the next request starts
/// from the same clean slate a fresh worker would.
fn reset_score_state(
    scorer: &Scorer,
    scratch: &mut Scratch,
    sketch: &mut SketchScratch,
    samples: &mut Vec<CwsSample>,
) {
    *scratch = scorer.scratch();
    *sketch = SketchScratch::new();
    *samples = vec![CwsSample { i_star: u32::MAX, t_star: 0 }; scorer.k()];
}

fn respond_hash(req: &Request, samples: Vec<CwsSample>, metrics: &Metrics) {
    let latency = req.submitted.elapsed();
    metrics.record_latency_ms(latency.as_secs_f64() * 1e3);
    let tx = match &req.resp {
        Responder::Hash(tx) => tx,
        Responder::Score(_) => unreachable!("hash response to score responder"),
    };
    let _ = tx.send(Ok(HashResponse { id: req.id, samples, latency }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::cws::CwsHasher;

    fn cfg(k: usize, dim: usize) -> ServiceConfig {
        ServiceConfig {
            k,
            dim,
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        }
    }

    fn vecs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.lognormal(0.0, 1.0) as f32).collect())
            .collect()
    }

    #[test]
    fn native_service_matches_direct_hasher() {
        if crate::cws::engine::fast_math_requested() {
            eprintln!("skipped: bit parity is only claimed without MINMAX_FAST_MATH");
            return;
        }
        let c = cfg(16, 24);
        let seed = c.seed;
        let svc = HashService::start(c, NativeBackend).unwrap();
        let inputs = vecs(20, 24, 3);
        let mut rxs = Vec::new();
        for (i, v) in inputs.iter().enumerate() {
            rxs.push(svc.submit(i as u64, v.clone()).unwrap());
        }
        let hasher = CwsHasher::new(seed, 16);
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.samples, hasher.hash_dense(&inputs[i]));
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.requests, 20);
        assert!(snap.batches >= 1);
        svc.shutdown();
    }

    #[test]
    fn custom_backend_serves_through_the_trait() {
        // A third-party Sketcher (minwise) behind the same service, via
        // the closure impl of SketcherBackend — no coordinator changes.
        let c = cfg(8, 16);
        let seed = c.seed;
        let factory = |cfg: &ServiceConfig| -> Result<Box<dyn crate::sketch::Sketcher>, String> {
            Ok(Box::new(crate::sketch::MinwiseSketcher::new(cfg.seed, cfg.k)))
        };
        let svc = HashService::start(c, factory).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let resp = svc.hash_blocking(1, &v).unwrap();
        let want = crate::sketch::Sketcher::sketch_dense(
            &crate::sketch::MinwiseSketcher::new(seed, 8),
            &v,
        );
        assert_eq!(resp.samples, want);
        svc.shutdown();
    }

    #[test]
    fn failing_backend_surfaces_at_start() {
        let factory = |_cfg: &ServiceConfig| -> Result<Box<dyn crate::sketch::Sketcher>, String> {
            Err("boom".into())
        };
        let err = HashService::start(cfg(4, 8), factory).unwrap_err();
        assert!(err.contains("boom"), "{err}");
    }

    #[test]
    fn rejects_bad_vectors() {
        let svc = HashService::start(cfg(4, 8), NativeBackend).unwrap();
        assert!(matches!(
            svc.submit(0, vec![0.0; 8]),
            Err(SubmitError::BadInput(_))
        ));
        assert!(matches!(
            svc.submit(0, vec![1.0; 4]),
            Err(SubmitError::BadInput(_))
        ));
        assert!(matches!(
            svc.submit(0, vec![-1.0; 8]),
            Err(SubmitError::BadInput(_))
        ));
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue and a slow drain: rapid submits must hit QueueFull.
        let c = ServiceConfig {
            k: 256,
            dim: 512,
            max_batch: 2,
            max_wait: Duration::from_millis(50),
            queue_cap: 2,
            ..Default::default()
        };
        let svc = HashService::start(c, NativeBackend).unwrap();
        let v: Vec<f32> = (0..512).map(|i| (i + 1) as f32).collect();
        let mut full = 0;
        let mut rxs = Vec::new();
        for i in 0..200 {
            match svc.submit(i, v.clone()) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::QueueFull) => full += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(full > 0, "expected backpressure rejections");
        assert!(svc.metrics().snapshot().rejected > 0);
        for rx in rxs {
            let _ = rx.recv().unwrap().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn hash_blocking_roundtrip() {
        let svc = HashService::start(cfg(8, 8), NativeBackend).unwrap();
        let resp = svc.hash_blocking(7, &[1.0; 8]).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.samples.len(), 8);
        assert!(resp.latency.as_secs_f64() >= 0.0);
        // Hash-mode services have no scorer.
        assert!(svc.n_classes().is_none());
        assert!(matches!(svc.submit_score(1, &[1.0; 8]), Err(SubmitError::NotScoring)));
        svc.shutdown();
    }

    fn demo_scorer(seed: u64, k: usize, dim: usize) -> crate::serve::Scorer {
        use crate::data::synth::{generate, SynthConfig};
        use crate::prelude::Pipeline;
        let ds = generate("letter", SynthConfig { seed: 2, n_train: 90, n_test: 30 }).unwrap();
        assert_eq!(ds.dim(), dim, "demo scorer is sized for the letter synth dims");
        let mut pipe =
            Pipeline::builder().seed(seed).samples(k).i_bits(4).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        pipe.scorer(dim).unwrap()
    }

    #[test]
    fn score_mode_matches_direct_scorer() {
        let c = cfg(16, 16);
        let seed = c.seed;
        let scorer = demo_scorer(seed, 16, 16);
        let direct = scorer.clone();
        let svc = HashService::start_scoring(c, scorer).unwrap();
        assert_eq!(svc.n_classes(), Some(direct.n_classes()));
        let inputs = vecs(12, 16, 9);
        let mut scratch = direct.scratch();
        let mut want = vec![0.0f64; direct.n_classes()];
        for (i, v) in inputs.iter().enumerate() {
            let resp = svc.score_blocking(i as u64, v).unwrap();
            direct.score_dense_into(v, &mut scratch, &mut want);
            assert_eq!(resp.decisions, want, "request {i}");
            assert_eq!(resp.label, crate::serve::argmax(&want));
            assert_eq!(svc.classify_blocking(100 + i as u64, v).unwrap(), resp.label);
        }
        // Hash submits are served from the scorer's own slabs.
        let hashed = svc.hash_blocking(1000, &inputs[0]).unwrap();
        assert_eq!(hashed.samples, direct.engine().sketch_dense(&inputs[0]));
        assert!(svc.metrics().snapshot().requests > 0);
        svc.shutdown();
    }

    #[test]
    fn score_mode_serves_quantized_packed_plans() {
        use crate::serve::SlabPrecision;
        let c = cfg(16, 16);
        let seed = c.seed;
        let scorer = demo_scorer(seed, 16, 16)
            .with_precision(SlabPrecision::Int8)
            .with_packed_codes(true);
        assert_eq!(scorer.precision(), SlabPrecision::Int8);
        assert!(scorer.packed_codes());
        let direct = scorer.clone();
        let svc = HashService::start_scoring(c, scorer).unwrap();
        assert_eq!(svc.score_plan(), Some((SlabPrecision::Int8, true)));
        let inputs = vecs(8, 16, 21);
        let mut scratch = direct.scratch();
        let mut want = vec![0.0f64; direct.n_classes()];
        for (i, v) in inputs.iter().enumerate() {
            let resp = svc.score_blocking(i as u64, v).unwrap();
            direct.score_dense_into(v, &mut scratch, &mut want);
            assert_eq!(resp.decisions, want, "request {i}");
        }
        svc.shutdown();
        // Hash mode carries no plan.
        let hash_svc = HashService::start(cfg(8, 8), NativeBackend).unwrap();
        assert!(hash_svc.score_plan().is_none());
        hash_svc.shutdown();
    }

    #[test]
    fn score_mode_validates_scorer_shape() {
        let scorer = demo_scorer(11, 16, 16);
        let err = HashService::start_scoring(cfg(8, 16), scorer).unwrap_err();
        assert!(err.contains("scorer k"), "{err}");
        let scorer = demo_scorer(11, 16, 16);
        let bad_seed = ServiceConfig { seed: 999, ..cfg(16, 16) };
        let err = HashService::start_scoring(bad_seed, scorer).unwrap_err();
        assert!(err.contains("seed"), "{err}");
    }

    #[test]
    fn shutdown_drains_accepted_requests() {
        // Fill the queue deep, then shut down immediately: every
        // accepted submit must still receive exactly one response —
        // drained, not dropped (the graceful-shutdown contract).
        let c = ServiceConfig {
            k: 64,
            dim: 64,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 512,
            ..Default::default()
        };
        let svc = HashService::start(c, NativeBackend).unwrap();
        let v: Vec<f32> = (1..=64).map(|i| i as f32).collect();
        let mut rxs = Vec::new();
        let mut rejected = 0u32;
        for i in 0..200u64 {
            match svc.submit(i, v.clone()) {
                Ok(rx) => rxs.push((i, rx)),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        let accepted = rxs.len() as u32;
        svc.shutdown();
        // After shutdown returns every accepted response is buffered.
        for (i, rx) in rxs {
            let resp =
                rx.recv().expect("accepted request dropped at shutdown").expect("request failed");
            assert_eq!(resp.id, i);
            // Exactly one: a second recv must see the closed channel.
            assert!(rx.try_recv().is_err(), "duplicate response for {i}");
        }
        assert_eq!(accepted + rejected, 200);
    }

    /// A sketcher that panics on a marker vector — stands in for any
    /// buggy computation so the unwind boundary can be pinned.
    struct PoisonSketcher(crate::sketch::MinwiseSketcher);

    impl crate::sketch::Sketcher for PoisonSketcher {
        fn k(&self) -> usize {
            crate::sketch::Sketcher::k(&self.0)
        }
        fn seed(&self) -> u64 {
            crate::sketch::Sketcher::seed(&self.0)
        }
        fn name(&self) -> &'static str {
            "poison"
        }
        fn sketch_sparse(&self, row: crate::data::SparseRow<'_>) -> Vec<CwsSample> {
            self.0.sketch_sparse(row)
        }
        fn sketch_dense(&self, u: &[f32]) -> Vec<CwsSample> {
            assert!(u[0] != 666.0, "poison vector exploded");
            crate::sketch::Sketcher::sketch_dense(&self.0, u)
        }
    }

    #[test]
    fn worker_panic_yields_typed_error_and_worker_survives() {
        let factory = |cfg: &ServiceConfig| -> Result<Box<dyn crate::sketch::Sketcher>, String> {
            Ok(Box::new(PoisonSketcher(crate::sketch::MinwiseSketcher::new(cfg.seed, cfg.k))))
        };
        let svc = HashService::start(cfg(8, 16), factory).unwrap();
        let good: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let mut poison = good.clone();
        poison[0] = 666.0;
        assert!(svc.hash_blocking(0, &good).is_ok());
        match svc.hash_blocking(1, &poison) {
            Err(SubmitError::WorkerPanicked { message }) => {
                assert!(message.contains("poison vector exploded"), "{message}");
            }
            Ok(_) => panic!("poison request must fail"),
            Err(e) => panic!("wrong error: {e}"),
        }
        // The worker survived the panic and keeps serving; the panic
        // is visible in the metrics.
        let resp = svc.hash_blocking(2, &good).unwrap();
        assert_eq!(resp.samples.len(), 8);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.panicked, 1);
        assert_eq!(snap.requests, 3);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submitters() {
        let svc = std::sync::Arc::new(
            HashService::start(ServiceConfig { queue_cap: 4096, ..cfg(8, 16) }, NativeBackend)
                .unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let svc = std::sync::Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let inputs = vecs(25, 16, 100 + t);
                for (i, v) in inputs.into_iter().enumerate() {
                    let resp = svc.hash_blocking(t * 1000 + i as u64, &v).unwrap();
                    assert_eq!(resp.samples.len(), 8);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.metrics().snapshot().requests, 100);
    }
}
