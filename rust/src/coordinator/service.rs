//! The hashing service: the deployable L3 piece the paper's §5 pitch
//! implies ("a tool for feature engineering … extremely efficient and
//! scalable linear methods").
//!
//! Shape: callers submit single nonnegative vectors and receive their
//! CWS samples asynchronously. Internally:
//!
//! ```text
//! submit() ─► bounded queue (backpressure) ─► dynamic batcher
//!             (max batch size OR deadline) ─► Box<dyn Sketcher>
//!                 built on the worker thread by the SketcherBackend
//!                 factory (NativeBackend, PjrtBackend, or any custom
//!                 impl — the coordinator never enumerates backends)
//!             ─► per-request responses (mpsc)
//! ```
//!
//! The built-in backends draw the same counter-based randomness, so
//! which one a deployment uses is a pure throughput/operational choice
//! (validated by `rust/tests/pipeline_integration.rs`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cws::CwsSample;
use crate::sketch::Sketcher;

use super::backend::SketcherBackend;
use super::metrics::Metrics;

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub seed: u64,
    /// Samples per vector (k). For the PJRT backend this must match the
    /// artifact's K.
    pub k: usize,
    /// Input dimensionality. For PJRT must match the artifact's D.
    pub dim: usize,
    /// Dynamic batcher: flush at this many requests…
    pub max_batch: usize,
    /// …or after this long since the first queued request.
    pub max_wait: Duration,
    /// Bounded queue capacity (backpressure): submits fail fast beyond it.
    pub queue_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            seed: 2015,
            k: 64,
            dim: 64,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

pub struct HashResponse {
    pub id: u64,
    pub samples: Vec<CwsSample>,
    /// Total time from submit to completion.
    pub latency: Duration,
}

struct Request {
    id: u64,
    vector: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<HashResponse>,
}

enum Msg {
    Req(Request),
    Flush,
    Shutdown,
}

/// Handle to the running service.
pub struct HashService {
    tx: mpsc::SyncSender<Msg>,
    worker: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    stopping: Arc<AtomicBool>,
    cfg: ServiceConfig,
}

#[derive(Debug)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
    BadInput(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full (backpressure)"),
            SubmitError::ShuttingDown => write!(f, "service shutting down"),
            SubmitError::BadInput(s) => write!(f, "bad input: {s}"),
        }
    }
}
impl std::error::Error for SubmitError {}

impl HashService {
    /// Start the service over any [`SketcherBackend`]. The factory runs
    /// on the worker thread (PJRT clients are thread-bound); `start`
    /// blocks until it reports readiness, so backend misconfiguration
    /// (missing artifacts, D/K mismatch, `pjrt` feature absent) surfaces
    /// here instead of hanging every submit.
    pub fn start(cfg: ServiceConfig, backend: impl SketcherBackend) -> Result<HashService, String> {
        let label = backend.label();
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_cap);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let metrics = Arc::new(Metrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let m2 = Arc::clone(&metrics);
        let cfg2 = cfg.clone();
        let boxed: Box<dyn SketcherBackend> = Box::new(backend);
        let worker = std::thread::Builder::new()
            .name("minmax-hash-service".into())
            .spawn(move || {
                let sketcher = match boxed.build(&cfg2) {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                run_worker(cfg2, sketcher, rx, m2);
            })
            .map_err(|e| format!("spawn service worker: {e}"))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(format!("{label} backend failed to start: {e}"));
            }
            Err(_) => {
                let _ = worker.join();
                return Err(format!("{label} backend worker died during startup"));
            }
        }
        Ok(HashService { tx, worker: Some(worker), metrics, stopping, cfg })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Submit one vector; the response arrives on the returned channel.
    /// Fails fast with `QueueFull` under backpressure.
    pub fn submit(
        &self,
        id: u64,
        vector: Vec<f32>,
    ) -> Result<mpsc::Receiver<HashResponse>, SubmitError> {
        if self.stopping.load(Ordering::Relaxed) {
            return Err(SubmitError::ShuttingDown);
        }
        if vector.len() != self.cfg.dim {
            return Err(SubmitError::BadInput(format!(
                "dim {} != {}",
                vector.len(),
                self.cfg.dim
            )));
        }
        if !vector.iter().any(|&v| v > 0.0) {
            return Err(SubmitError::BadInput("all-zero vector".into()));
        }
        if vector.iter().any(|&v| v < 0.0 || !v.is_finite()) {
            return Err(SubmitError::BadInput("negative or non-finite entry".into()));
        }
        let (rtx, rrx) = mpsc::channel();
        let req = Request { id, vector, submitted: Instant::now(), resp: rtx };
        self.metrics.record_request();
        match self.tx.try_send(Msg::Req(req)) {
            Ok(()) => Ok(rrx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(SubmitError::QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Blocking convenience: submit and wait.
    pub fn hash_blocking(&self, id: u64, vector: Vec<f32>) -> Result<HashResponse, SubmitError> {
        let rx = self.submit(id, vector)?;
        rx.recv().map_err(|_| SubmitError::ShuttingDown)
    }

    /// Ask the batcher to flush a partial batch immediately.
    pub fn flush(&self) {
        let _ = self.tx.try_send(Msg::Flush);
    }

    pub fn shutdown(mut self) {
        self.stopping.store(true, Ordering::Relaxed);
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HashService {
    fn drop(&mut self) {
        self.stopping.store(true, Ordering::Relaxed);
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// The batching loop. Backend-agnostic: whatever the factory built, the
/// worker only sees `dyn Sketcher` — batched backends override
/// `sketch_dense_batch` (the native engine shards the batch across
/// `MINMAX_THREADS` scoped threads; the PJRT impl pads/chunks to its
/// fixed B internally).
fn run_worker(
    cfg: ServiceConfig,
    sketcher: Box<dyn Sketcher>,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    loop {
        // Wait for the first request (or control message)…
        let first_deadline = if pending.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => {
                    pending.push(r);
                    Instant::now() + cfg.max_wait
                }
                Ok(Msg::Flush) => continue,
                Ok(Msg::Shutdown) | Err(_) => break,
            }
        } else {
            Instant::now() + cfg.max_wait
        };
        // …then fill the batch until size or deadline.
        let mut flush_now = false;
        let mut shutdown = false;
        while pending.len() < cfg.max_batch && !flush_now {
            let left = first_deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Flush) => flush_now = true,
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if !pending.is_empty() {
            let batch: Vec<Request> = pending.drain(..).collect();
            metrics.record_batch(batch.len(), cfg.max_batch);
            for r in &batch {
                metrics.record_queue_wait_ms(r.submitted.elapsed().as_secs_f64() * 1e3);
            }
            let rows: Vec<&[f32]> = batch.iter().map(|r| r.vector.as_slice()).collect();
            let sketched = sketcher.sketch_dense_batch(&rows);
            // Hard contract on third-party backends: one output per
            // request. A silent zip truncation would drop responses.
            assert_eq!(
                sketched.len(),
                batch.len(),
                "sketcher '{}' returned {} sample streams for {} requests",
                sketcher.name(),
                sketched.len(),
                batch.len()
            );
            for (req, samples) in batch.iter().zip(sketched) {
                respond(req, samples, &metrics);
            }
        }
        if shutdown {
            break;
        }
    }
}

fn respond(req: &Request, samples: Vec<CwsSample>, metrics: &Metrics) {
    let latency = req.submitted.elapsed();
    metrics.record_latency_ms(latency.as_secs_f64() * 1e3);
    let _ = req.resp.send(HashResponse { id: req.id, samples, latency });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::cws::CwsHasher;

    fn cfg(k: usize, dim: usize) -> ServiceConfig {
        ServiceConfig { k, dim, max_batch: 8, max_wait: Duration::from_millis(1), ..Default::default() }
    }

    fn vecs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.lognormal(0.0, 1.0) as f32).collect())
            .collect()
    }

    #[test]
    fn native_service_matches_direct_hasher() {
        if crate::cws::engine::fast_math_requested() {
            eprintln!("skipped: bit parity is only claimed without MINMAX_FAST_MATH");
            return;
        }
        let c = cfg(16, 24);
        let seed = c.seed;
        let svc = HashService::start(c, NativeBackend).unwrap();
        let inputs = vecs(20, 24, 3);
        let mut rxs = Vec::new();
        for (i, v) in inputs.iter().enumerate() {
            rxs.push(svc.submit(i as u64, v.clone()).unwrap());
        }
        let hasher = CwsHasher::new(seed, 16);
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.samples, hasher.hash_dense(&inputs[i]));
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.requests, 20);
        assert!(snap.batches >= 1);
        svc.shutdown();
    }

    #[test]
    fn custom_backend_serves_through_the_trait() {
        // A third-party Sketcher (minwise) behind the same service, via
        // the closure impl of SketcherBackend — no coordinator changes.
        let c = cfg(8, 16);
        let seed = c.seed;
        let factory = |cfg: &ServiceConfig| -> Result<Box<dyn crate::sketch::Sketcher>, String> {
            Ok(Box::new(crate::sketch::MinwiseSketcher::new(cfg.seed, cfg.k)))
        };
        let svc = HashService::start(c, factory).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let resp = svc.hash_blocking(1, v.clone()).unwrap();
        let want = crate::sketch::Sketcher::sketch_dense(
            &crate::sketch::MinwiseSketcher::new(seed, 8),
            &v,
        );
        assert_eq!(resp.samples, want);
        svc.shutdown();
    }

    #[test]
    fn failing_backend_surfaces_at_start() {
        let factory = |_cfg: &ServiceConfig| -> Result<Box<dyn crate::sketch::Sketcher>, String> {
            Err("boom".into())
        };
        let err = HashService::start(cfg(4, 8), factory).unwrap_err();
        assert!(err.contains("boom"), "{err}");
    }

    #[test]
    fn rejects_bad_vectors() {
        let svc = HashService::start(cfg(4, 8), NativeBackend).unwrap();
        assert!(matches!(
            svc.submit(0, vec![0.0; 8]),
            Err(SubmitError::BadInput(_))
        ));
        assert!(matches!(
            svc.submit(0, vec![1.0; 4]),
            Err(SubmitError::BadInput(_))
        ));
        assert!(matches!(
            svc.submit(0, vec![-1.0; 8]),
            Err(SubmitError::BadInput(_))
        ));
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue and a slow drain: rapid submits must hit QueueFull.
        let c = ServiceConfig {
            k: 256,
            dim: 512,
            max_batch: 2,
            max_wait: Duration::from_millis(50),
            queue_cap: 2,
            ..Default::default()
        };
        let svc = HashService::start(c, NativeBackend).unwrap();
        let v: Vec<f32> = (0..512).map(|i| (i + 1) as f32).collect();
        let mut full = 0;
        let mut rxs = Vec::new();
        for i in 0..200 {
            match svc.submit(i, v.clone()) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::QueueFull) => full += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(full > 0, "expected backpressure rejections");
        assert!(svc.metrics().snapshot().rejected > 0);
        for rx in rxs {
            let _ = rx.recv().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn hash_blocking_roundtrip() {
        let svc = HashService::start(cfg(8, 8), NativeBackend).unwrap();
        let resp = svc.hash_blocking(7, vec![1.0; 8]).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.samples.len(), 8);
        assert!(resp.latency.as_secs_f64() >= 0.0);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submitters() {
        let svc = std::sync::Arc::new(
            HashService::start(ServiceConfig { queue_cap: 4096, ..cfg(8, 16) }, NativeBackend)
                .unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let svc = std::sync::Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let inputs = vecs(25, 16, 100 + t);
                for (i, v) in inputs.into_iter().enumerate() {
                    let resp = svc.hash_blocking(t * 1000 + i as u64, v).unwrap();
                    assert_eq!(resp.samples.len(), 8);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.metrics().snapshot().requests, 100);
    }
}
