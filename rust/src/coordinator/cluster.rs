//! The sharded, replicated, hot-swappable serving cluster — the layer
//! the paper's "industrial applications with massive data" pitch (§1,
//! §5) actually needs above the per-row fused scorer.
//!
//! [`super::service::HashService`] made one worker allocation-free;
//! [`ScoreRouter`] puts N of them behind bounded queues:
//!
//! ```text
//!            submit(id, &row) ── validate ── pick least-deep shard
//!                │                               │ (failover on full)
//!                ▼                               ▼
//!   ┌── shard 0: bounded MPMC queue ──► worker 0 (Scorer slabs + Scratch)
//!   ├── shard 1: bounded MPMC queue ──► worker 1        │
//!   ├── …                 ▲    │                        │ idle workers
//!   └── shard N-1 ────────┘    └──── work stealing ◄────┘
//!                │
//!                ▼
//!      SwapCell<Versioned> ──── publish() swaps the model Arc;
//!      workers re-read it at every dequeue (hot swap, zero downtime)
//! ```
//!
//! ## Queue / backpressure contract
//!
//! Every shard queue is bounded by `queue_cap` (**backpressure**:
//! submits fail fast with [`ClusterError::QueueFull`] once every shard
//! is full — the router fails over full shards first) and optionally
//! **load-shed** above `shed_watermark`: a submit finding the
//! *least-loaded* shard at or beyond the watermark is rejected with
//! [`ClusterError::Shed`] and counted in the snapshot — the knob that
//! keeps p99 finite under sustained overload instead of letting every
//! queue fill to the hard cap. Queues are MPMC: any submitter can feed
//! any shard, and an idle worker steals from a sibling's queue before
//! sleeping again, so one hot shard cannot strand work while others
//! idle.
//!
//! ## Version-swap protocol
//!
//! The current model lives in one [`SwapCell`] (an `RwLock<Arc<_>>`
//! underneath — see `super::queue`).
//! [`ScoreRouter::publish`] validates the new [`Scorer`]'s shape
//! (`k`/`dim`/`seed` must match — replicas must stay interchangeable —
//! and so must the serving plan: slab precision and code packing,
//! since a swap that silently changed them would change the fleet's
//! latency and accuracy characteristics), bumps the version, and swaps
//! the `Arc` under the write lock — a
//! pointer swap, no worker pause. Workers clone the `Arc` at every
//! dequeue, so requests already dequeued **drain against the version
//! they started with** while the next dequeue picks up the new slab;
//! the old model is freed when its last in-flight request drops its
//! handle. No request is lost or re-scored during a swap (pinned by
//! `rust/tests/cluster_parity.rs`), and every response carries the
//! version that scored it, tallied per version in the snapshot.
//!
//! ## Shutdown contract
//!
//! [`ScoreRouter::shutdown`] closes every queue (new submits fail with
//! the typed [`ClusterError::ShuttingDown`]), then workers drain every
//! queued request — their own queue first, then stealing siblings' —
//! and answer each exactly once before exiting. Same guarantee as the
//! single service: accepted-then-dropped cannot happen.
//!
//! ## Query mode
//!
//! [`QueryRouter`] is the second service mode: the same queues,
//! backpressure, shedding, stealing, versioned hot swap, metrics, and
//! shutdown drain (all shared machinery — the queue and snapshot code
//! is generic over the request type), but the workers answer **top-k
//! retrieval** against a shared [`PackedLshIndex`] instead of scoring
//! against per-worker slabs. The index is large (the packed code slab
//! plus bucket tables over the whole corpus) and read-only, so unlike
//! score mode nothing is replicated per shard: every worker clones the
//! version `Arc` at dequeue and probes the same tables; per-worker
//! state is one reusable [`QueryScratch`]. `publish` swaps in an index
//! built over a *new corpus snapshot* — the banding, seed, bit width,
//! and feature dim must match (replicas must mean the same thing by
//! "similar"), while the row count is free to change, which is the
//! whole point of the swap. Responses are bit-identical to a direct
//! [`PackedLshIndex::query_with`] call on the serving version,
//! regardless of shard count, stealing, or concurrent swaps (pinned by
//! `rust/tests/lsh_parity.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::cws::{PackedLshIndex, QueryParams, QueryScratch};
use crate::data::sparse::SparseRow;
use crate::data::Matrix;
use crate::serve::{argmax, Scorer, Scratch, SlabPrecision};
use crate::util::stats::Histogram;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{mpsc, spawn_named, thread, Arc, Mutex};

use super::metrics::{Metrics, Snapshot, LATENCY_BUCKETS_MS};
use super::queue::{
    pick_least_deep, steal, steal_any, Pop, PushError, ShardQueue, SwapCell, STEAL_POLL,
};

/// Cluster shape and flow-control knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Worker/shard count — each shard owns a bounded queue, a scratch
    /// arena, and its own metrics.
    pub shards: usize,
    /// Per-shard queue bound (hard backpressure).
    pub queue_cap: usize,
    /// Load-shedding watermark: a submit that finds the least-loaded
    /// shard at or beyond this depth is rejected with
    /// [`ClusterError::Shed`]. `None` disables shedding (only the hard
    /// cap rejects).
    pub shed_watermark: Option<usize>,
    /// Let idle workers steal from sibling queues (default on). Off
    /// pins each request to the shard that accepted it — useful when
    /// benchmarking routing policies.
    pub steal: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { shards: 2, queue_cap: 1024, shed_watermark: None, steal: true }
    }
}

/// Typed submit/publish errors — the cluster never fails silently.
#[derive(Debug)]
pub enum ClusterError {
    /// Every shard's queue is at `queue_cap` (hard backpressure).
    QueueFull,
    /// Queue depth crossed the load-shedding watermark.
    Shed { depth: usize, watermark: usize },
    /// Cluster is shutting down (or a worker died).
    ShuttingDown,
    BadInput(String),
    /// `publish` with a scorer whose `k`/`dim`/`seed`/slab precision/
    /// code packing disagree with the cluster's.
    ShapeMismatch(String),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::QueueFull => write!(f, "every shard queue is full (backpressure)"),
            ClusterError::Shed { depth, watermark } => {
                write!(f, "load shed: queue depth {depth} >= watermark {watermark}")
            }
            ClusterError::ShuttingDown => write!(f, "cluster shutting down"),
            ClusterError::BadInput(s) => write!(f, "bad input: {s}"),
            ClusterError::ShapeMismatch(s) => write!(f, "scorer shape mismatch: {s}"),
        }
    }
}
impl std::error::Error for ClusterError {}

/// One scored request: decisions + label like the service's
/// `ScoreResponse`, plus WHICH model version and shard answered —
/// the observability a hot-swapping deployment needs.
pub struct ClusterScoreResponse {
    pub id: u64,
    /// Per-class decision values (`len == n_classes` of the scoring
    /// version).
    pub decisions: Vec<f64>,
    /// `argmax(decisions)` with `LinearOvR::predict_on` semantics.
    pub label: i32,
    /// Model version that scored this request.
    pub version: u64,
    /// Shard whose worker served it (≠ accepting shard when stolen).
    pub shard: usize,
    /// Total time from submit to completion.
    pub latency: Duration,
}

struct ClusterRequest {
    id: u64,
    vector: Vec<f32>,
    submitted: Instant,
    tx: mpsc::Sender<ClusterScoreResponse>,
}

/// A versioned model: the immutable unit the `Arc` swap publishes.
struct Versioned {
    version: u64,
    scorer: Scorer,
}

// ------------------------------------------------------------ shared
//
// The queue/steal machinery lives in `super::queue` (generic over the
// request type — the `score` and `query` service modes differ only in
// what a worker does with a dequeued request), where the loom models
// in `rust/tests/loom_models.rs` can exercise it directly.

/// Per-shard `version → completed` tally map.
type VersionTally = Mutex<BTreeMap<u64, u64>>;

struct Shared {
    queues: Vec<ShardQueue<ClusterRequest>>,
    /// The hot-swap slot. Read (cheap: shared lock + `Arc` clone) at
    /// every dequeue; written only by `publish`.
    model: SwapCell<Versioned>,
    shard_metrics: Vec<Metrics>,
    /// Per-shard `version → completed` tallies (shard-local so the
    /// serve hot path never contends across shards); merged by
    /// `snapshot()`.
    shard_versions: Vec<VersionTally>,
    steal: bool,
}

/// Merge per-shard metrics, histograms, and version tallies into the
/// cluster-wide view — shared by both router modes.
fn assemble_snapshot<R>(
    shard_metrics: &[Metrics],
    shard_versions: &[VersionTally],
    queues: &[ShardQueue<R>],
    started: Instant,
    current_version: u64,
) -> ClusterSnapshot {
    let shards: Vec<Snapshot> = shard_metrics.iter().map(|m| m.snapshot()).collect();
    let mut merged = Histogram::new(&LATENCY_BUCKETS_MS);
    for s in &shards {
        merged.merge(&Histogram::with_counts(&LATENCY_BUCKETS_MS, s.latency_hist.clone()));
    }
    let mut version_counts: BTreeMap<u64, u64> = BTreeMap::new();
    for vm in shard_versions {
        for (&v, &c) in vm.lock().unwrap().iter() {
            *version_counts.entry(v).or_insert(0) += c;
        }
    }
    let elapsed = started.elapsed().as_secs_f64();
    let completed: u64 = shards.iter().map(|s| s.completed).sum();
    ClusterSnapshot {
        requests: shards.iter().map(|s| s.requests).sum(),
        completed,
        rejected: shards.iter().map(|s| s.rejected).sum(),
        shed: shards.iter().map(|s| s.shed).sum(),
        queue_depths: queues.iter().map(|q| q.depth()).collect(),
        elapsed_s: elapsed,
        throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
        latency_p50_ms: merged.quantile(50.0),
        latency_p90_ms: merged.quantile(90.0),
        latency_p99_ms: merged.quantile(99.0),
        current_version,
        version_counts: version_counts.into_iter().collect(),
        shards,
    }
}

/// The start-time config checks shared by both router modes.
fn validate_config(cfg: &ClusterConfig) -> Result<(), String> {
    if cfg.shards == 0 {
        return Err("cluster needs at least one shard".into());
    }
    if cfg.queue_cap == 0 {
        return Err("queue_cap must be positive".into());
    }
    if let Some(w) = cfg.shed_watermark {
        if w == 0 || w > cfg.queue_cap {
            return Err(format!(
                "shed watermark {w} must be in 1..=queue_cap ({})",
                cfg.queue_cap
            ));
        }
    }
    Ok(())
}

fn worker_loop(shard: usize, shared: &Shared) {
    // One long-lived arena per worker. `k`/`dim` are invariant across
    // published versions, so the scratch survives hot swaps; only the
    // decision staging is (cheaply) resized per request.
    let mut scratch: Option<Scratch> = None;
    let mut staging: Vec<f64> = Vec::new();
    loop {
        match shared.queues[shard].pop_wait(STEAL_POLL) {
            Pop::Req(req) => serve(shard, shared, &req, &mut scratch, &mut staging),
            Pop::Empty => {
                if shared.steal {
                    if let Some(req) = steal(shard, &shared.queues) {
                        serve(shard, shared, &req, &mut scratch, &mut staging);
                    }
                }
            }
            Pop::Closed => {
                // Shutdown drain: the own queue is empty+closed; help
                // finish whatever is still queued anywhere, then exit.
                // Queues reject pushes once closed, so this terminates.
                while let Some(req) = steal_any(shard, &shared.queues) {
                    serve(shard, shared, &req, &mut scratch, &mut staging);
                }
                return;
            }
        }
    }
}

fn serve(
    shard: usize,
    shared: &Shared,
    req: &ClusterRequest,
    scratch: &mut Option<Scratch>,
    staging: &mut Vec<f64>,
) {
    let metrics = &shared.shard_metrics[shard];
    metrics.record_queue_wait_ms(req.submitted.elapsed().as_secs_f64() * 1e3);
    // Pick up the current version; in-flight work keeps this Arc alive
    // through a concurrent publish (the drain half of the swap
    // protocol).
    let model: Arc<Versioned> = shared.model.get();
    let scorer = &model.scorer;
    let s = scratch.get_or_insert_with(|| scorer.scratch());
    staging.clear();
    staging.resize(scorer.n_classes(), 0.0);
    scorer.score_dense_into(&req.vector, s, staging);
    let label = argmax(staging);
    let latency = req.submitted.elapsed();
    metrics.record_latency_ms(latency.as_secs_f64() * 1e3);
    *shared.shard_versions[shard].lock().unwrap().entry(model.version).or_insert(0) += 1;
    let _ = req.tx.send(ClusterScoreResponse {
        id: req.id,
        decisions: staging.clone(),
        label,
        version: model.version,
        shard,
        latency,
    });
}

// ------------------------------------------------------------ router

/// The sharded scoring front door. See the module docs for the queue,
/// swap, and shutdown contracts.
pub struct ScoreRouter {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    stopping: AtomicBool,
    rr: AtomicU64,
    cfg: ClusterConfig,
    started: Instant,
    // Invariant shape every published version must match.
    k: usize,
    dim: usize,
    seed: u64,
    // Serving-plan invariants (PR 7): replicas must stream the same
    // slab precision and code packing, or a hot swap silently changes
    // latency/accuracy characteristics mid-fleet.
    precision: SlabPrecision,
    packed: bool,
}

/// An accepted submission: the response handle plus which shard's
/// queue took it.
pub struct Submitted {
    rx: mpsc::Receiver<ClusterScoreResponse>,
    shard: usize,
}

impl Submitted {
    /// Shard whose queue accepted the request (a stealing worker may
    /// still serve it — the response's `shard` field is authoritative).
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block for the response. `ShuttingDown` here means a worker died
    /// abnormally — graceful shutdown answers every accepted request.
    pub fn wait(self) -> Result<ClusterScoreResponse, ClusterError> {
        self.rx.recv().map_err(|_| ClusterError::ShuttingDown)
    }
}

impl ScoreRouter {
    /// Start `cfg.shards` workers serving `scorer` as version 1. The
    /// scorer is NOT cloned per shard — workers share one slab behind
    /// the version `Arc` (replication is of execution state: scratch
    /// arenas and queues, which is what actually needs to be
    /// per-worker).
    pub fn start(scorer: Scorer, cfg: ClusterConfig) -> Result<ScoreRouter, String> {
        validate_config(&cfg)?;
        let (k, dim, seed) = (scorer.k(), scorer.dim(), scorer.seed());
        let (precision, packed) = (scorer.precision(), scorer.packed_codes());
        let shared = Arc::new(Shared {
            queues: (0..cfg.shards).map(|_| ShardQueue::new()).collect(),
            model: SwapCell::new(Versioned { version: 1, scorer }),
            shard_metrics: (0..cfg.shards).map(|_| Metrics::new()).collect(),
            shard_versions: (0..cfg.shards).map(|_| Mutex::new(BTreeMap::new())).collect(),
            steal: cfg.steal,
        });
        let mut workers = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let sh = Arc::clone(&shared);
            let h = spawn_named(format!("minmax-cluster-w{i}"), move || worker_loop(i, &sh))
                .map_err(|e| format!("spawn cluster worker {i}: {e}"))?;
            workers.push(h);
        }
        Ok(ScoreRouter {
            shared,
            workers,
            stopping: AtomicBool::new(false),
            rr: AtomicU64::new(0),
            cfg,
            started: Instant::now(),
            k,
            dim,
            seed,
            precision,
            packed,
        })
    }

    pub fn shards(&self) -> usize {
        self.cfg.shards
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Version currently being published to workers.
    pub fn current_version(&self) -> u64 {
        self.shared.model.get().version
    }

    /// Class count of the current version.
    pub fn n_classes(&self) -> usize {
        self.shared.model.get().scorer.n_classes()
    }

    /// Per-shard metrics handle (tests / scraping).
    pub fn metrics(&self, shard: usize) -> &Metrics {
        &self.shared.shard_metrics[shard]
    }

    /// Publish a new model version: validate shape, swap the `Arc`.
    /// Returns the new version number. Zero downtime — requests
    /// dequeued before the swap drain against the old version (their
    /// workers hold its `Arc`); every later dequeue scores with the
    /// new slab. The class count MAY change between versions; each
    /// response reports the version that produced it.
    pub fn publish(&self, scorer: Scorer) -> Result<u64, ClusterError> {
        if scorer.k() != self.k {
            return Err(ClusterError::ShapeMismatch(format!(
                "k {} != cluster k {}",
                scorer.k(),
                self.k
            )));
        }
        if scorer.dim() != self.dim {
            return Err(ClusterError::ShapeMismatch(format!(
                "dim {} != cluster dim {}",
                scorer.dim(),
                self.dim
            )));
        }
        if scorer.seed() != self.seed {
            return Err(ClusterError::ShapeMismatch(format!(
                "seed {} != cluster seed {}",
                scorer.seed(),
                self.seed
            )));
        }
        if scorer.precision() != self.precision {
            return Err(ClusterError::ShapeMismatch(format!(
                "slab precision {} != cluster precision {}",
                scorer.precision(),
                self.precision
            )));
        }
        if scorer.packed_codes() != self.packed {
            return Err(ClusterError::ShapeMismatch(format!(
                "packed codes {} != cluster packing {}",
                scorer.packed_codes(),
                self.packed
            )));
        }
        let version = self.shared.model.update(|cur| {
            let version = cur.version + 1;
            (Versioned { version, scorer }, version)
        });
        Ok(version)
    }

    fn validate(&self, vector: &[f32]) -> Result<(), ClusterError> {
        if self.stopping.load(Ordering::Acquire) {
            return Err(ClusterError::ShuttingDown);
        }
        if vector.len() != self.dim {
            return Err(ClusterError::BadInput(format!("dim {} != {}", vector.len(), self.dim)));
        }
        if vector.iter().any(|&v| v < 0.0 || !v.is_finite()) {
            return Err(ClusterError::BadInput("negative or non-finite entry".into()));
        }
        // NOTE: all-zero rows are accepted (they score `bias + 0` per
        // class), matching `Pipeline::predict` over a matrix with empty
        // rows — the cluster must be prediction-compatible with the
        // offline path, which the single service's stricter validation
        // is not.
        Ok(())
    }

    /// Least-deep shard with a rotating round-robin tie-break start, so
    /// equal-depth shards share arrivals instead of all landing on 0.
    fn pick(&self) -> usize {
        pick_least_deep(&self.shared.queues, &self.rr)
    }

    /// Submit one dense row for scoring. Fail-fast flow control: `Shed`
    /// past the watermark (evaluated on the least-loaded shard, so it
    /// reflects cluster-wide pressure), `QueueFull` only when every
    /// shard is at the hard cap.
    pub fn submit(&self, id: u64, vector: &[f32]) -> Result<Submitted, ClusterError> {
        self.validate(vector)?;
        let first = self.pick();
        let n = self.cfg.shards;
        let (rtx, rrx) = mpsc::channel();
        let mut req = ClusterRequest {
            id,
            vector: vector.to_vec(),
            submitted: Instant::now(),
            tx: rtx,
        };
        for off in 0..n {
            let i = (first + off) % n;
            match self.shared.queues[i].push(req, self.cfg.queue_cap, self.cfg.shed_watermark) {
                Ok(()) => {
                    self.shared.shard_metrics[i].record_request();
                    return Ok(Submitted { rx: rrx, shard: i });
                }
                Err((PushError::Shed { depth, watermark }, _)) => {
                    // Terminal: `first` was the least-loaded shard, so
                    // the whole cluster is past the watermark.
                    self.shared.shard_metrics[i].record_shed();
                    return Err(ClusterError::Shed { depth, watermark });
                }
                Err((PushError::Closed, _)) => return Err(ClusterError::ShuttingDown),
                Err((PushError::Full, back)) => {
                    // Reclaim the request and fail over to the next
                    // shard.
                    req = back;
                }
            }
        }
        self.shared.shard_metrics[first].record_rejected();
        Err(ClusterError::QueueFull)
    }

    /// Blocking submit-and-wait.
    pub fn score_blocking(
        &self,
        id: u64,
        vector: &[f32],
    ) -> Result<ClusterScoreResponse, ClusterError> {
        self.submit(id, vector)?.wait()
    }

    /// Blocking classification: label only.
    pub fn classify_blocking(&self, id: u64, vector: &[f32]) -> Result<i32, ClusterError> {
        Ok(self.score_blocking(id, vector)?.label)
    }

    /// Score a whole matrix through the cluster, in row order — the
    /// batch entry the saturation bench and parity tests drive. A
    /// backpressure-aware closed-loop client: submissions race ahead
    /// until a queue rejects, then the oldest outstanding response is
    /// reaped before retrying (shed rejections are retried too — this
    /// client wants every row answered).
    pub fn score_batch_blocking(&self, x: &Matrix) -> Result<Vec<i32>, ClusterError> {
        let dense = x.to_dense();
        let n = dense.rows();
        let mut out = vec![0i32; n];
        let mut pending: VecDeque<(usize, Submitted)> = VecDeque::new();
        for i in 0..n {
            loop {
                match self.submit(i as u64, dense.row(i)) {
                    Ok(s) => {
                        pending.push_back((i, s));
                        break;
                    }
                    Err(ClusterError::QueueFull) | Err(ClusterError::Shed { .. }) => {
                        match pending.pop_front() {
                            Some((j, s)) => out[j] = s.wait()?.label,
                            // Another client owns the queue space; let
                            // the workers drain and retry.
                            None => thread::yield_now(),
                        }
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        for (j, s) in pending {
            out[j] = s.wait()?.label;
        }
        Ok(out)
    }

    /// Cluster-wide snapshot: per-shard metrics plus merged totals,
    /// fleet latency quantiles from the merged histograms, queue
    /// depths, and per-version completion tallies.
    pub fn snapshot(&self) -> ClusterSnapshot {
        assemble_snapshot(
            &self.shared.shard_metrics,
            &self.shared.shard_versions,
            &self.shared.queues,
            self.started,
            self.current_version(),
        )
    }

    /// Graceful shutdown: close every queue (typed rejections from
    /// here on), then block until the workers have drained and
    /// answered every accepted request.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stopping.store(true, Ordering::Release);
        for q in &self.shared.queues {
            q.close();
        }
        for h in std::mem::take(&mut self.workers) {
            let _ = h.join();
        }
    }
}

impl Drop for ScoreRouter {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Aggregated cluster state. Semantics differ from the single-service
/// [`Snapshot`] in one deliberate way: cluster `requests` counts
/// ACCEPTED submissions (rejections are only in `rejected`/`shed`), so
/// at quiescence `requests == completed` exactly — the reconciliation
/// `cluster_parity.rs` pins. Per-shard `requests` vs `completed` may
/// differ when work stealing moved a request between shards; the
/// cluster-wide sums always reconcile.
#[derive(Debug, Clone)]
pub struct ClusterSnapshot {
    pub shards: Vec<Snapshot>,
    /// Accepted submissions, cluster-wide.
    pub requests: u64,
    pub completed: u64,
    /// Hard-cap backpressure rejections.
    pub rejected: u64,
    /// Watermark load-shed rejections.
    pub shed: u64,
    pub queue_depths: Vec<usize>,
    pub elapsed_s: f64,
    /// Completions per second since the cluster started.
    pub throughput_rps: f64,
    /// Fleet latency quantiles estimated from the merged per-shard
    /// histograms (exact per-shard reservoir percentiles live in
    /// `shards`).
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub current_version: u64,
    /// `(version, completed)` tallies, ascending by version.
    pub version_counts: Vec<(u64, u64)>,
}

impl ClusterSnapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("requests", self.requests)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("shed", self.shed)
            .set("elapsed_s", self.elapsed_s)
            .set("throughput_rps", self.throughput_rps)
            .set("latency_p50_ms", self.latency_p50_ms)
            .set("latency_p90_ms", self.latency_p90_ms)
            .set("latency_p99_ms", self.latency_p99_ms)
            .set("current_version", self.current_version);
        j.set(
            "queue_depths",
            Json::Arr(self.queue_depths.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        j.set(
            "version_counts",
            Json::Arr(
                self.version_counts
                    .iter()
                    .map(|&(v, c)| Json::Arr(vec![Json::Num(v as f64), Json::Num(c as f64)]))
                    .collect(),
            ),
        );
        j.set("shards", Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()));
        j
    }

    pub fn render(&self) -> String {
        format!(
            "v{} requests={} completed={} rejected={} shed={} rps={:.1} p50={:.2}ms p90={:.2}ms p99={:.2}ms depths={:?}",
            self.current_version,
            self.requests,
            self.completed,
            self.rejected,
            self.shed,
            self.throughput_rps,
            self.latency_p50_ms,
            self.latency_p90_ms,
            self.latency_p99_ms,
            self.queue_depths
        )
    }
}

// ------------------------------------------------------- query mode

/// One answered retrieval request — the `query` analog of
/// [`ClusterScoreResponse`]: ranked hits plus which index version and
/// shard served it.
pub struct ClusterQueryResponse {
    pub id: u64,
    /// `(row_id, min-max similarity)` descending, ties by ascending id —
    /// exactly `PackedLshIndex::query_with(query, top, params)` on the
    /// serving version.
    pub hits: Vec<(u32, f64)>,
    /// Index version that answered this request.
    pub version: u64,
    /// Shard whose worker served it (≠ accepting shard when stolen).
    pub shard: usize,
    /// Total time from submit to completion.
    pub latency: Duration,
}

struct QueryRequest {
    id: u64,
    indices: Vec<u32>,
    values: Vec<f32>,
    top: usize,
    submitted: Instant,
    tx: mpsc::Sender<ClusterQueryResponse>,
}

/// A versioned index: the immutable unit the query-mode `Arc` swap
/// publishes. The index itself is behind its own `Arc` so a caller can
/// keep a handle for direct comparison (and so republish is cheap).
struct VersionedIndex {
    version: u64,
    index: Arc<PackedLshIndex>,
}

struct QueryShared {
    queues: Vec<ShardQueue<QueryRequest>>,
    /// The hot-swap slot, same protocol as score mode: read (shared
    /// lock + `Arc` clone) at every dequeue, written only by `publish`.
    index: SwapCell<VersionedIndex>,
    shard_metrics: Vec<Metrics>,
    shard_versions: Vec<VersionTally>,
    steal: bool,
    /// Lookup knobs, fixed at start: every replica must probe and
    /// prefilter identically or responses would depend on which worker
    /// served them.
    params: QueryParams,
}

fn query_worker_loop(shard: usize, shared: &QueryShared) {
    // One long-lived retrieval scratch per worker: after warm-up the
    // serve path is allocation-free except for the response hits Vec.
    let mut scratch = QueryScratch::new();
    loop {
        match shared.queues[shard].pop_wait(STEAL_POLL) {
            Pop::Req(req) => serve_query(shard, shared, &req, &mut scratch),
            Pop::Empty => {
                if shared.steal {
                    if let Some(req) = steal(shard, &shared.queues) {
                        serve_query(shard, shared, &req, &mut scratch);
                    }
                }
            }
            Pop::Closed => {
                while let Some(req) = steal_any(shard, &shared.queues) {
                    serve_query(shard, shared, &req, &mut scratch);
                }
                return;
            }
        }
    }
}

fn serve_query(
    shard: usize,
    shared: &QueryShared,
    req: &QueryRequest,
    scratch: &mut QueryScratch,
) {
    let metrics = &shared.shard_metrics[shard];
    metrics.record_queue_wait_ms(req.submitted.elapsed().as_secs_f64() * 1e3);
    // Pin the version for this request; a concurrent publish cannot
    // free the index under us (same drain rule as score mode).
    let model: Arc<VersionedIndex> = shared.index.get();
    let row = SparseRow { indices: &req.indices, values: &req.values };
    let hits = model.index.query_with(row, req.top, shared.params, scratch).to_vec();
    let latency = req.submitted.elapsed();
    metrics.record_latency_ms(latency.as_secs_f64() * 1e3);
    *shared.shard_versions[shard].lock().unwrap().entry(model.version).or_insert(0) += 1;
    let _ = req.tx.send(ClusterQueryResponse {
        id: req.id,
        hits,
        version: model.version,
        shard,
        latency,
    });
}

/// An accepted query submission (see [`Submitted`]).
pub struct SubmittedQuery {
    rx: mpsc::Receiver<ClusterQueryResponse>,
    shard: usize,
}

impl SubmittedQuery {
    /// Shard whose queue accepted the request (a stealing worker may
    /// still serve it — the response's `shard` field is authoritative).
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block for the response. `ShuttingDown` here means a worker died
    /// abnormally — graceful shutdown answers every accepted request.
    pub fn wait(self) -> Result<ClusterQueryResponse, ClusterError> {
        self.rx.recv().map_err(|_| ClusterError::ShuttingDown)
    }
}

/// The sharded retrieval front door — the `query` service mode next to
/// [`ScoreRouter`]'s `score`. Same queues, backpressure, shedding,
/// stealing, versioned hot swap, metrics, and shutdown drain; workers
/// own a [`QueryScratch`] each and answer top-k retrieval against a
/// shared [`PackedLshIndex`] behind the version `Arc`.
///
/// Responses are bit-identical to calling
/// [`PackedLshIndex::query_with`] directly with the router's params —
/// sharding, stealing, and hot swaps never change results, only which
/// version answers (pinned by `rust/tests/lsh_parity.rs`).
pub struct QueryRouter {
    shared: Arc<QueryShared>,
    workers: Vec<thread::JoinHandle<()>>,
    stopping: AtomicBool,
    rr: AtomicU64,
    cfg: ClusterConfig,
    started: Instant,
    // Invariant shape every published index must match: a swap that
    // changed the banding, seed, truncation width, or feature space
    // would silently change what "similar" means mid-fleet. The corpus
    // ROW COUNT may change — that is the point of a hot swap (fresh
    // corpus snapshots).
    bands: usize,
    rows_per_band: usize,
    seed: u64,
    bits: u8,
    cols: usize,
}

impl QueryRouter {
    /// Start `cfg.shards` workers serving `index` as version 1. The
    /// index is NOT cloned per shard — workers share the slab and
    /// bucket tables behind the version `Arc`; per-worker state is the
    /// retrieval scratch.
    pub fn start(
        index: Arc<PackedLshIndex>,
        params: QueryParams,
        cfg: ClusterConfig,
    ) -> Result<QueryRouter, String> {
        validate_config(&cfg)?;
        let c = *index.config();
        let (bits, cols) = (index.bits(), index.corpus().cols());
        let shared = Arc::new(QueryShared {
            queues: (0..cfg.shards).map(|_| ShardQueue::new()).collect(),
            index: SwapCell::new(VersionedIndex { version: 1, index }),
            shard_metrics: (0..cfg.shards).map(|_| Metrics::new()).collect(),
            shard_versions: (0..cfg.shards).map(|_| Mutex::new(BTreeMap::new())).collect(),
            steal: cfg.steal,
            params,
        });
        let mut workers = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let sh = Arc::clone(&shared);
            let h = spawn_named(format!("minmax-query-w{i}"), move || query_worker_loop(i, &sh))
                .map_err(|e| format!("spawn query worker {i}: {e}"))?;
            workers.push(h);
        }
        Ok(QueryRouter {
            shared,
            workers,
            stopping: AtomicBool::new(false),
            rr: AtomicU64::new(0),
            cfg,
            started: Instant::now(),
            bands: c.bands,
            rows_per_band: c.rows_per_band,
            seed: c.seed,
            bits,
            cols,
        })
    }

    pub fn shards(&self) -> usize {
        self.cfg.shards
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Lookup knobs every worker serves with.
    pub fn params(&self) -> QueryParams {
        self.shared.params
    }

    /// Version currently being published to workers.
    pub fn current_version(&self) -> u64 {
        self.shared.index.get().version
    }

    /// Corpus rows of the current version.
    pub fn corpus_len(&self) -> usize {
        self.shared.index.get().index.len()
    }

    /// Per-shard metrics handle (tests / scraping).
    pub fn metrics(&self, shard: usize) -> &Metrics {
        &self.shared.shard_metrics[shard]
    }

    /// Publish a new index version: validate the shape invariants
    /// (banding, seed, bits, feature dim — the corpus row count may
    /// change), swap the `Arc`. Zero downtime, same drain protocol as
    /// score mode; every response carries the version that answered it.
    pub fn publish(&self, index: Arc<PackedLshIndex>) -> Result<u64, ClusterError> {
        let c = index.config();
        if c.bands != self.bands || c.rows_per_band != self.rows_per_band {
            return Err(ClusterError::ShapeMismatch(format!(
                "banding {}x{} != cluster banding {}x{}",
                c.bands, c.rows_per_band, self.bands, self.rows_per_band
            )));
        }
        if c.seed != self.seed {
            return Err(ClusterError::ShapeMismatch(format!(
                "seed {} != cluster seed {}",
                c.seed, self.seed
            )));
        }
        if index.bits() != self.bits {
            return Err(ClusterError::ShapeMismatch(format!(
                "bits {} != cluster bits {}",
                index.bits(),
                self.bits
            )));
        }
        if index.corpus().cols() != self.cols {
            return Err(ClusterError::ShapeMismatch(format!(
                "feature dim {} != cluster dim {}",
                index.corpus().cols(),
                self.cols
            )));
        }
        let version = self.shared.index.update(|cur| {
            let version = cur.version + 1;
            (VersionedIndex { version, index }, version)
        });
        Ok(version)
    }

    fn validate(&self, query: SparseRow<'_>) -> Result<(), ClusterError> {
        if self.stopping.load(Ordering::Acquire) {
            return Err(ClusterError::ShuttingDown);
        }
        if query.indices.len() != query.values.len() {
            return Err(ClusterError::BadInput(format!(
                "indices/values length mismatch: {} != {}",
                query.indices.len(),
                query.values.len()
            )));
        }
        // Unlike score mode, all-zero input is REJECTED: CWS is
        // undefined on the empty vector, so there is no meaningful
        // "similar rows" answer (a direct query returns the empty set;
        // a service caller almost certainly sent a bug).
        if query.nnz() == 0 {
            return Err(ClusterError::BadInput("empty query (no nonzeros)".into()));
        }
        if !query.indices.windows(2).all(|w| w[0] < w[1]) {
            return Err(ClusterError::BadInput("indices not strictly increasing".into()));
        }
        if query.indices[query.indices.len() - 1] as usize >= self.cols {
            return Err(ClusterError::BadInput(format!(
                "index {} out of range for dim {}",
                query.indices[query.indices.len() - 1],
                self.cols
            )));
        }
        if query.values.iter().any(|&v| !v.is_finite() || v <= 0.0) {
            return Err(ClusterError::BadInput("non-finite or non-positive value".into()));
        }
        Ok(())
    }

    /// Submit one sparse query for top-`top` retrieval. Identical
    /// flow-control contract to [`ScoreRouter::submit`]: `Shed` past
    /// the watermark, `QueueFull` only when every shard is at the hard
    /// cap, failover over full shards first.
    pub fn submit(
        &self,
        id: u64,
        query: SparseRow<'_>,
        top: usize,
    ) -> Result<SubmittedQuery, ClusterError> {
        self.validate(query)?;
        let first = pick_least_deep(&self.shared.queues, &self.rr);
        let n = self.cfg.shards;
        let (rtx, rrx) = mpsc::channel();
        let mut req = QueryRequest {
            id,
            indices: query.indices.to_vec(),
            values: query.values.to_vec(),
            top,
            submitted: Instant::now(),
            tx: rtx,
        };
        for off in 0..n {
            let i = (first + off) % n;
            match self.shared.queues[i].push(req, self.cfg.queue_cap, self.cfg.shed_watermark) {
                Ok(()) => {
                    self.shared.shard_metrics[i].record_request();
                    return Ok(SubmittedQuery { rx: rrx, shard: i });
                }
                Err((PushError::Shed { depth, watermark }, _)) => {
                    self.shared.shard_metrics[i].record_shed();
                    return Err(ClusterError::Shed { depth, watermark });
                }
                Err((PushError::Closed, _)) => return Err(ClusterError::ShuttingDown),
                Err((PushError::Full, back)) => {
                    req = back;
                }
            }
        }
        self.shared.shard_metrics[first].record_rejected();
        Err(ClusterError::QueueFull)
    }

    /// Blocking submit-and-wait.
    pub fn query_blocking(
        &self,
        id: u64,
        query: SparseRow<'_>,
        top: usize,
    ) -> Result<ClusterQueryResponse, ClusterError> {
        self.submit(id, query, top)?.wait()
    }

    /// Cluster-wide snapshot — same shape and reconciliation contract
    /// as [`ScoreRouter::snapshot`].
    pub fn snapshot(&self) -> ClusterSnapshot {
        assemble_snapshot(
            &self.shared.shard_metrics,
            &self.shared.shard_versions,
            &self.shared.queues,
            self.started,
            self.current_version(),
        )
    }

    /// Graceful shutdown: close every queue, drain, join.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stopping.store(true, Ordering::Release);
        for q in &self.shared.queues {
            q.close();
        }
        for h in std::mem::take(&mut self.workers) {
            let _ = h.join();
        }
    }
}

impl Drop for QueryRouter {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::prelude::Pipeline;

    fn demo_scorer(seed: u64, k: usize, data_seed: u64) -> (Scorer, crate::data::Dataset) {
        let ds =
            generate("letter", SynthConfig { seed: data_seed, n_train: 90, n_test: 40 }).unwrap();
        let mut pipe = Pipeline::builder().seed(seed).samples(k).i_bits(4).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let scorer = pipe.scorer(ds.dim()).unwrap();
        (scorer, ds)
    }

    fn cfg(shards: usize) -> ClusterConfig {
        ClusterConfig { shards, queue_cap: 64, shed_watermark: None, steal: true }
    }

    #[test]
    fn cluster_matches_direct_scorer() {
        let (scorer, ds) = demo_scorer(9, 16, 2);
        let direct = scorer.clone();
        let cluster = ScoreRouter::start(scorer, cfg(2)).unwrap();
        assert_eq!(cluster.shards(), 2);
        assert_eq!(cluster.current_version(), 1);
        let test = ds.test_x.to_dense();
        let mut scratch = direct.scratch();
        let mut want = vec![0.0f64; direct.n_classes()];
        for i in 0..test.rows() {
            let resp = cluster.score_blocking(i as u64, test.row(i)).unwrap();
            direct.score_dense_into(test.row(i), &mut scratch, &mut want);
            assert_eq!(resp.decisions, want, "row {i}");
            assert_eq!(resp.label, argmax(&want));
            assert_eq!(resp.version, 1);
            assert!(resp.shard < 2);
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.requests, test.rows() as u64);
        assert_eq!(snap.completed, snap.requests);
        assert_eq!(snap.version_counts, vec![(1, snap.completed)]);
        cluster.shutdown();
    }

    #[test]
    fn batch_matches_predict_batch() {
        let (scorer, ds) = demo_scorer(5, 16, 3);
        let direct = scorer.clone();
        let cluster = ScoreRouter::start(scorer, ClusterConfig { queue_cap: 8, ..cfg(3) }).unwrap();
        let want = direct.predict_batch(&ds.test_x);
        let got = cluster.score_batch_blocking(&ds.test_x).unwrap();
        assert_eq!(got, want);
        cluster.shutdown();
    }

    #[test]
    fn publish_swaps_version_and_validates_shape() {
        let (scorer, ds) = demo_scorer(9, 16, 2);
        // Same seed/k/dim, different training data → different weights.
        let (next, _) = demo_scorer(9, 16, 7);
        let next_direct = next.clone();
        let cluster = ScoreRouter::start(scorer, cfg(2)).unwrap();
        let test = ds.test_x.to_dense();
        let before = cluster.score_blocking(0, test.row(0)).unwrap();
        assert_eq!(before.version, 1);

        let v = cluster.publish(next).unwrap();
        assert_eq!(v, 2);
        assert_eq!(cluster.current_version(), 2);
        let mut scratch = next_direct.scratch();
        let mut want = vec![0.0f64; next_direct.n_classes()];
        for i in 0..test.rows() {
            let resp = cluster.score_blocking(i as u64, test.row(i)).unwrap();
            assert_eq!(resp.version, 2, "row {i} must score on the new version");
            next_direct.score_dense_into(test.row(i), &mut scratch, &mut want);
            assert_eq!(resp.decisions, want, "row {i}");
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.version_counts.len(), 2);
        assert_eq!(snap.version_counts[0].0, 1);
        assert_eq!(snap.version_counts[1].0, 2);

        // Wrong shape is a typed error, not a swap.
        let (wrong_k, _) = demo_scorer(9, 8, 2);
        assert!(matches!(cluster.publish(wrong_k), Err(ClusterError::ShapeMismatch(_))));
        let (wrong_seed, _) = demo_scorer(10, 16, 2);
        assert!(matches!(cluster.publish(wrong_seed), Err(ClusterError::ShapeMismatch(_))));
        assert_eq!(cluster.current_version(), 2);
        cluster.shutdown();
    }

    #[test]
    fn publish_rejects_precision_and_packing_mismatches() {
        let (scorer, ds) = demo_scorer(9, 16, 2);
        let cluster = ScoreRouter::start(scorer.clone(), cfg(2)).unwrap();
        // Same k/dim/seed but a different serving plan must not swap in.
        let f32_variant = scorer.clone().with_precision(SlabPrecision::F32);
        assert!(matches!(
            cluster.publish(f32_variant),
            Err(ClusterError::ShapeMismatch(_))
        ));
        let packed_variant = scorer.clone().with_packed_codes(true);
        assert!(packed_variant.packed_codes());
        assert!(matches!(
            cluster.publish(packed_variant),
            Err(ClusterError::ShapeMismatch(_))
        ));
        assert_eq!(cluster.current_version(), 1, "rejected publishes must not bump the version");
        cluster.shutdown();

        // A cluster serving a quantized, packed plan accepts a matching
        // publish and rejects the plain one — and still scores in
        // agreement with its direct twin.
        let quant = scorer.clone().with_precision(SlabPrecision::Int8).with_packed_codes(true);
        assert_eq!(quant.precision(), SlabPrecision::Int8);
        assert!(quant.packed_codes());
        let direct = quant.clone();
        let qcluster = ScoreRouter::start(quant, cfg(2)).unwrap();
        assert!(matches!(qcluster.publish(scorer), Err(ClusterError::ShapeMismatch(_))));
        let (retrain, _) = demo_scorer(9, 16, 7);
        let retrain = retrain.with_precision(SlabPrecision::Int8).with_packed_codes(true);
        assert_eq!(qcluster.publish(retrain).unwrap(), 2);
        let test = ds.test_x.to_dense();
        let mut scratch = direct.scratch();
        let mut want = vec![0.0f64; direct.n_classes()];
        direct.score_dense_into(test.row(0), &mut scratch, &mut want);
        // Version 2 has different weights; republish v1's twin to compare.
        let again = direct.clone();
        assert_eq!(qcluster.publish(again).unwrap(), 3);
        let resp = qcluster.score_blocking(0, test.row(0)).unwrap();
        assert_eq!(resp.decisions, want);
        qcluster.shutdown();
    }

    #[test]
    fn shed_and_backpressure_are_counted_and_typed() {
        let (scorer, ds) = demo_scorer(9, 256, 2);
        // One shard, tiny queue, low watermark: a burst must shed.
        let cluster = ScoreRouter::start(
            scorer,
            ClusterConfig { shards: 1, queue_cap: 4, shed_watermark: Some(2), steal: false },
        )
        .unwrap();
        let test = ds.test_x.to_dense();
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for i in 0..400u64 {
            match cluster.submit(i, test.row((i as usize) % test.rows())) {
                Ok(s) => accepted.push(s),
                Err(ClusterError::Shed { depth, watermark }) => {
                    assert!(depth >= watermark);
                    shed += 1;
                }
                Err(ClusterError::QueueFull) => {
                    unreachable!("watermark (2) trips before the hard cap (4)")
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(shed > 0, "burst against a 2-deep watermark must shed");
        let n_accepted = accepted.len() as u64;
        for s in accepted {
            s.wait().unwrap();
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.shed, shed);
        assert_eq!(snap.requests, n_accepted);
        assert_eq!(snap.completed, n_accepted);
        cluster.shutdown();
    }

    #[test]
    fn shutdown_drains_every_accepted_request() {
        let (scorer, ds) = demo_scorer(9, 128, 2);
        let cluster = ScoreRouter::start(
            scorer,
            ClusterConfig { shards: 2, queue_cap: 256, shed_watermark: None, steal: true },
        )
        .unwrap();
        let test = ds.test_x.to_dense();
        let mut accepted = Vec::new();
        for i in 0..300u64 {
            match cluster.submit(i, test.row((i as usize) % test.rows())) {
                Ok(s) => accepted.push((i, s)),
                Err(ClusterError::QueueFull) => {}
                Err(e) => panic!("unexpected {e}"),
            }
        }
        let n = accepted.len() as u64;
        cluster.shutdown();
        for (i, s) in accepted {
            let resp = s.wait().expect("accepted request dropped at shutdown");
            assert_eq!(resp.id, i);
        }
        assert!(n > 0);
    }

    #[test]
    fn rejects_bad_vectors_and_bad_configs() {
        let (scorer, _) = demo_scorer(9, 16, 2);
        let cluster = ScoreRouter::start(scorer.clone(), cfg(1)).unwrap();
        assert!(matches!(cluster.submit(0, &[1.0; 3]), Err(ClusterError::BadInput(_))));
        assert!(matches!(cluster.submit(0, &[-1.0; 16]), Err(ClusterError::BadInput(_))));
        // All-zero rows are VALID here (empty-row parity with
        // Pipeline::predict).
        assert!(cluster.submit(0, &[0.0; 16]).is_ok());
        cluster.shutdown();
        assert!(ScoreRouter::start(scorer.clone(), ClusterConfig { shards: 0, ..cfg(1) }).is_err());
        assert!(ScoreRouter::start(
            scorer,
            ClusterConfig { shed_watermark: Some(9999), queue_cap: 8, ..cfg(1) }
        )
        .is_err());
    }

    // --------------------------------------------------- query mode

    /// Planted near-duplicate corpus + a packed index over it.
    fn demo_index(rows: usize, dim: usize, data_seed: u64) -> Arc<PackedLshIndex> {
        use crate::data::sparse::CsrBuilder;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(data_seed);
        let mut b = CsrBuilder::new(dim);
        for _ in 0..rows {
            let mut row: Vec<(u32, f32)> = Vec::new();
            for i in 0..dim {
                if rng.uniform() < 0.25 {
                    row.push((i as u32, rng.lognormal(0.0, 1.0) as f32));
                }
            }
            b.push_row(if row.is_empty() { vec![(0, 1.0)] } else { row });
        }
        let cfg = crate::cws::LshConfig { bands: 8, rows_per_band: 2, seed: 77 };
        Arc::new(PackedLshIndex::build(Arc::new(b.finish()), cfg, 8).unwrap())
    }

    #[test]
    fn query_cluster_matches_direct_index() {
        let index = demo_index(120, 48, 11);
        let params = QueryParams { probes: 2, min_agreement: 0.0 };
        let mut scratch = QueryScratch::new();
        for shards in [1usize, 4] {
            let cluster = QueryRouter::start(Arc::clone(&index), params, cfg(shards)).unwrap();
            assert_eq!(cluster.shards(), shards);
            assert_eq!(cluster.current_version(), 1);
            assert_eq!(cluster.corpus_len(), 120);
            let corpus = Arc::clone(index.corpus());
            for i in 0..corpus.rows() {
                let q = corpus.row(i);
                let resp = cluster.query_blocking(i as u64, q, 5).unwrap();
                let want = index.query_with(q, 5, params, &mut scratch);
                assert_eq!(resp.hits, want, "row {i} at {shards} shards");
                assert_eq!(resp.version, 1);
                assert!(resp.shard < shards);
                // The index never misses its own row as the top hit.
                assert_eq!(resp.hits[0].0, i as u32);
            }
            let snap = cluster.snapshot();
            assert_eq!(snap.requests, corpus.rows() as u64);
            assert_eq!(snap.completed, snap.requests);
            assert_eq!(snap.version_counts, vec![(1, snap.completed)]);
            cluster.shutdown();
        }
    }

    #[test]
    fn query_publish_hot_swap_and_validation() {
        let index = demo_index(100, 48, 11);
        let params = QueryParams::default();
        let cluster = QueryRouter::start(Arc::clone(&index), params, cfg(2)).unwrap();
        let probe = index.corpus().row(3);
        assert_eq!(cluster.query_blocking(0, probe, 3).unwrap().version, 1);

        // Same banding/seed/bits/dim over a LARGER corpus snapshot:
        // the legitimate hot-swap case.
        let next = demo_index(160, 48, 12);
        assert_eq!(cluster.publish(Arc::clone(&next)).unwrap(), 2);
        assert_eq!(cluster.current_version(), 2);
        assert_eq!(cluster.corpus_len(), 160);
        let mut scratch = QueryScratch::new();
        for i in 0..20 {
            let q = next.corpus().row(i);
            let resp = cluster.query_blocking(i as u64, q, 5).unwrap();
            assert_eq!(resp.version, 2, "row {i} must serve on the new version");
            assert_eq!(resp.hits, next.query_with(q, 5, params, &mut scratch));
        }

        // Shape mismatches are typed errors, not silent meaning drift.
        let corpus = Arc::clone(next.corpus());
        let rebuilt = |bands, rpb, seed, bits| {
            let c = crate::cws::LshConfig { bands, rows_per_band: rpb, seed };
            Arc::new(PackedLshIndex::build(Arc::clone(&corpus), c, bits).unwrap())
        };
        for bad in [
            rebuilt(4, 2, 77, 8),  // bands
            rebuilt(8, 4, 77, 8),  // rows_per_band
            rebuilt(8, 2, 78, 8),  // seed
            rebuilt(8, 2, 77, 4),  // bits
            demo_index(50, 64, 13), // feature dim
        ] {
            assert!(matches!(cluster.publish(bad), Err(ClusterError::ShapeMismatch(_))));
        }
        assert_eq!(cluster.current_version(), 2, "rejected publishes must not bump");

        // Input validation: typed BadInput, never a worker panic.
        let bad_input = |ix: &[u32], vs: &[f32]| {
            let r = cluster.submit(0, SparseRow { indices: ix, values: vs }, 3);
            assert!(matches!(r, Err(ClusterError::BadInput(_))), "{ix:?}/{vs:?}");
        };
        bad_input(&[], &[]); // empty query
        bad_input(&[2, 1], &[1.0, 1.0]); // unsorted
        bad_input(&[1, 1], &[1.0, 1.0]); // duplicate
        bad_input(&[1], &[1.0, 2.0]); // length mismatch
        bad_input(&[48], &[1.0]); // out of range for dim 48
        bad_input(&[1], &[-1.0]); // negative
        bad_input(&[1], &[f32::NAN]); // non-finite
        bad_input(&[1], &[0.0]); // explicit zero ⇒ empty support

        let snap = cluster.snapshot();
        assert_eq!(snap.completed, snap.requests);
        assert_eq!(snap.version_counts.len(), 2);
        cluster.shutdown();
    }
}
