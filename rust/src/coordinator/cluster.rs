//! The sharded, replicated, hot-swappable serving cluster — the layer
//! the paper's "industrial applications with massive data" pitch (§1,
//! §5) actually needs above the per-row fused scorer.
//!
//! [`super::service::HashService`] made one worker allocation-free;
//! [`ScoreRouter`] puts N of them behind bounded queues:
//!
//! ```text
//!            submit(id, &row) ── validate ── pick least-deep shard
//!                │                               │ (failover on full)
//!                ▼                               ▼
//!   ┌── shard 0: bounded MPMC queue ──► worker 0 (Scorer slabs + Scratch)
//!   ├── shard 1: bounded MPMC queue ──► worker 1        │
//!   ├── …                 ▲    │                        │ idle workers
//!   └── shard N-1 ────────┘    └──── work stealing ◄────┘
//!                │                        ▲
//!                ▼                        │ respawn on death
//!      SwapCell<Versioned>           supervisor thread
//!      (hot swap, zero downtime)
//! ```
//!
//! ## Queue / backpressure contract
//!
//! Every shard queue is bounded by `queue_cap` (**backpressure**:
//! submits fail fast with [`ClusterError::QueueFull`] once every shard
//! is full — the router fails over full shards first) and optionally
//! **load-shed** above `shed_watermark`: a submit finding the
//! *least-loaded* shard at or beyond the watermark is rejected with
//! [`ClusterError::Shed`] and counted in the snapshot — the knob that
//! keeps p99 finite under sustained overload instead of letting every
//! queue fill to the hard cap. Queues are MPMC: any submitter can feed
//! any shard, and an idle worker steals from a sibling's queue before
//! sleeping again, so one hot shard cannot strand work while others
//! idle.
//!
//! ## Fault-tolerance contract (see DESIGN.md §2.9)
//!
//! * **Panic isolation.** Each request is served inside
//!   `catch_unwind`: a panic in scoring/retrieval answers *that*
//!   request with a typed [`ClusterError::WorkerPanicked`] (message
//!   captured) instead of killing the shard. No lock is held across
//!   the unwind boundary, so a panic can never poison the version
//!   tallies or metrics.
//! * **Supervision.** Workers are owned by a supervisor thread that
//!   probes for dead shards (a panic that *does* escape the worker
//!   loop — impossible from request code, possible from injected
//!   worker deaths or future bugs) and respawns them against the
//!   current [`SwapCell`] version. Respawns are counted per shard and
//!   exported as `restarts` in [`ClusterSnapshot`].
//! * **Deadlines.** A request submitted with
//!   [`ScoreRouter::submit_with_deadline`] is checked at dequeue:
//!   expired work is answered immediately with
//!   [`ClusterError::DeadlineExceeded`] (no compute spent) and
//!   accounted in `deadline_expired`, next to `shed`.
//! * **Bounded waits.** [`Submitted::wait_timeout`] never blocks past
//!   its budget: a lost response surfaces as
//!   [`ClusterError::WaitTimeout`] instead of a hung client.
//! * **Backoff, not spin.** The batch clients retry rejected submits
//!   under a seeded [`RetryPolicy`] (jittered exponential backoff);
//!   retries and exhausted budgets are exported as
//!   `retried`/`degraded`.
//! * **Fault injection.** `ClusterConfig::faults` (or, in debug builds
//!   only, `MINMAX_FAULT_RATE`/`MINMAX_FAULT_SEED`) arms the seeded
//!   [`FaultPlan`] harness from [`super::faults`]; the chaos tests in
//!   `rust/tests/chaos_recovery.rs` drive it to pin the exactly-once
//!   guarantee across panic → respawn → hot-swap sequences.
//!
//! ### Accounting
//!
//! `requests` counts every **validated** submit. The outcome counters
//! partition it exactly:
//!
//! ```text
//! requests == completed + rejected + shed + deadline_expired + panicked
//! ```
//!
//! ([`ClusterSnapshot::reconciles`]). `accepted()` (= `requests -
//! rejected - shed`) is the number of requests the cluster owes a
//! response, and every one of them gets **exactly one**: `Ok`,
//! `WorkerPanicked`, or `DeadlineExceeded` — `answered() ==
//! completed + panicked + deadline_expired`.
//!
//! ## Version-swap protocol
//!
//! The current model lives in one [`SwapCell`] (an `RwLock<Arc<_>>`
//! underneath — see `super::queue`).
//! [`ScoreRouter::publish`] validates the new [`Scorer`]'s shape
//! (`k`/`dim`/`seed` must match — replicas must stay interchangeable —
//! and so must the serving plan: slab precision and code packing,
//! since a swap that silently changed them would change the fleet's
//! latency and accuracy characteristics), bumps the version, and swaps
//! the `Arc` under the write lock — a
//! pointer swap, no worker pause. Workers clone the `Arc` at every
//! dequeue, so requests already dequeued **drain against the version
//! they started with** while the next dequeue picks up the new slab;
//! the old model is freed when its last in-flight request drops its
//! handle. No request is lost or re-scored during a swap (pinned by
//! `rust/tests/cluster_parity.rs`), and every response carries the
//! version that scored it, tallied per version in the snapshot.
//!
//! ## Shutdown contract
//!
//! [`ScoreRouter::shutdown`] closes every queue (new submits fail with
//! the typed [`ClusterError::ShuttingDown`]), then workers drain every
//! queued request — their own queue first, then stealing siblings' —
//! and answer each exactly once before exiting; the supervisor joins
//! them and finally sweeps any requests stranded by a worker that died
//! mid-drain. Same guarantee as the single service:
//! accepted-then-dropped cannot happen, even with fault injection
//! armed.
//!
//! ## Query mode
//!
//! [`QueryRouter`] is the second service mode: the same queues,
//! backpressure, shedding, stealing, versioned hot swap, metrics,
//! supervision, and shutdown drain (all shared machinery — the
//! supervised worker core is generic over the [`ServeMode`]), but the
//! workers answer **top-k retrieval** against a shared
//! [`PackedLshIndex`] instead of scoring against per-worker slabs. The
//! index is large (the packed code slab plus bucket tables over the
//! whole corpus) and read-only, so unlike score mode nothing is
//! replicated per shard: every worker clones the version `Arc` at
//! dequeue and probes the same tables; per-worker state is one
//! reusable [`QueryScratch`]. `publish` swaps in an index built over a
//! *new corpus snapshot* — the banding, seed, bit width, and feature
//! dim must match (replicas must mean the same thing by "similar"),
//! while the row count is free to change, which is the whole point of
//! the swap. Responses are bit-identical to a direct
//! [`PackedLshIndex::query_with`] call on the serving version,
//! regardless of shard count, stealing, respawns, or concurrent swaps
//! (pinned by `rust/tests/lsh_parity.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use crate::cws::{PackedLshIndex, QueryParams, QueryScratch};
use crate::data::sparse::{Csr, SparseRow};
use crate::data::Matrix;
use crate::serve::{argmax, Scorer, Scratch, SlabPrecision};
use crate::util::rng::Pcg64;
use crate::util::stats::Histogram;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{is_finished, mpsc, spawn_named, thread, Arc, Mutex};

use super::faults::{panic_message, FaultPlan, FaultStream, PostFault, INJECTED};
use super::metrics::{Metrics, Snapshot, LATENCY_BUCKETS_MS};
use super::queue::{
    pick_least_deep, steal, steal_any, Pop, PushError, ShardQueue, SwapCell, STEAL_POLL,
};

/// How often the supervisor probes worker liveness. Deaths are rare;
/// 1ms keeps respawn latency far below any sane request deadline while
/// costing nothing measurable when everything is healthy.
const SUPERVISOR_POLL: Duration = Duration::from_millis(1);

/// Cluster shape and flow-control knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Worker/shard count — each shard owns a bounded queue, a scratch
    /// arena, and its own metrics.
    pub shards: usize,
    /// Per-shard queue bound (hard backpressure).
    pub queue_cap: usize,
    /// Load-shedding watermark: a submit that finds the least-loaded
    /// shard at or beyond this depth is rejected with
    /// [`ClusterError::Shed`]. `None` disables shedding (only the hard
    /// cap rejects).
    pub shed_watermark: Option<usize>,
    /// Let idle workers steal from sibling queues (default on). Off
    /// pins each request to the shard that accepted it — useful when
    /// benchmarking routing policies.
    pub steal: bool,
    /// Seeded fault injection (chaos testing / resilience benches).
    /// `None` additionally consults `MINMAX_FAULT_RATE` in debug
    /// builds — see [`FaultPlan::from_env`]; release builds ignore the
    /// environment entirely.
    pub faults: Option<FaultPlan>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { shards: 2, queue_cap: 1024, shed_watermark: None, steal: true, faults: None }
    }
}

/// Typed submit/publish/wait errors — the cluster never fails silently.
#[derive(Debug)]
pub enum ClusterError {
    /// Every shard's queue is at `queue_cap` (hard backpressure).
    QueueFull,
    /// Queue depth crossed the load-shedding watermark.
    Shed { depth: usize, watermark: usize },
    /// Cluster is shutting down.
    ShuttingDown,
    BadInput(String),
    /// `publish` with a scorer whose `k`/`dim`/`seed`/slab precision/
    /// code packing disagree with the cluster's.
    ShapeMismatch(String),
    /// The worker panicked while serving THIS request. The shard
    /// survived (the panic was caught at the request boundary); the
    /// captured panic message is the observability payload.
    WorkerPanicked { message: String },
    /// The request's deadline expired before a worker began it.
    DeadlineExceeded,
    /// `wait_timeout` elapsed without a response. The request may
    /// still complete — a later wait on the same handle can pick the
    /// response up.
    WaitTimeout,
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::QueueFull => write!(f, "every shard queue is full (backpressure)"),
            ClusterError::Shed { depth, watermark } => {
                write!(f, "load shed: queue depth {depth} >= watermark {watermark}")
            }
            ClusterError::ShuttingDown => write!(f, "cluster shutting down"),
            ClusterError::BadInput(s) => write!(f, "bad input: {s}"),
            ClusterError::ShapeMismatch(s) => write!(f, "scorer shape mismatch: {s}"),
            ClusterError::WorkerPanicked { message } => {
                write!(f, "worker panicked serving this request: {message}")
            }
            ClusterError::DeadlineExceeded => {
                write!(f, "request deadline expired before work began")
            }
            ClusterError::WaitTimeout => {
                write!(f, "timed out waiting for the response (request may still complete)")
            }
        }
    }
}
impl std::error::Error for ClusterError {}

/// What travels back over a request's response channel: exactly one of
/// these per accepted request, no matter what happened to the worker.
enum Reply<T> {
    Ok(T),
    /// The serve closure panicked; the shard survived.
    Panicked { message: String },
    /// The deadline expired at dequeue; no compute was spent.
    DeadlineExceeded,
}

impl<T> Reply<T> {
    fn into_result(self) -> Result<T, ClusterError> {
        match self {
            Reply::Ok(t) => Ok(t),
            Reply::Panicked { message } => Err(ClusterError::WorkerPanicked { message }),
            Reply::DeadlineExceeded => Err(ClusterError::DeadlineExceeded),
        }
    }
}

/// One scored request: decisions + label like the service's
/// `ScoreResponse`, plus WHICH model version and shard answered —
/// the observability a hot-swapping deployment needs.
pub struct ClusterScoreResponse {
    pub id: u64,
    /// Per-class decision values (`len == n_classes` of the scoring
    /// version).
    pub decisions: Vec<f64>,
    /// `argmax(decisions)` with `LinearOvR::predict_on` semantics.
    pub label: i32,
    /// Model version that scored this request.
    pub version: u64,
    /// Shard whose worker served it (≠ accepting shard when stolen).
    pub shard: usize,
    /// Total time from submit to completion.
    pub latency: Duration,
}

struct ClusterRequest {
    id: u64,
    vector: Vec<f32>,
    submitted: Instant,
    /// Absolute deadline; checked at dequeue.
    expires: Option<Instant>,
    tx: mpsc::Sender<Reply<ClusterScoreResponse>>,
}

/// A versioned model: the immutable unit the `Arc` swap publishes.
struct Versioned {
    version: u64,
    scorer: Scorer,
}

// ------------------------------------------------- supervised core
//
// The queue/steal machinery lives in `super::queue` (generic over the
// request type), where the loom models in `rust/tests/loom_models.rs`
// can exercise it directly. The supervised worker core below is
// generic over the service mode: `score` and `query` differ only in
// what a worker computes for a dequeued request, so panic isolation,
// deadlines, supervision, and the shutdown sweep are written once.

/// Per-shard `version → completed` tally map.
type VersionTally = Mutex<BTreeMap<u64, u64>>;

/// Everything the supervised worker core needs, independent of what
/// the workers compute: queues, per-shard metrics and version tallies,
/// flow-control flags, the armed fault plan, and the worker slots the
/// supervisor owns.
struct Core<R> {
    queues: Vec<ShardQueue<R>>,
    shard_metrics: Vec<Metrics>,
    /// Per-shard `version → completed` tallies (shard-local so the
    /// serve hot path never contends across shards); merged by
    /// `snapshot()`. Locked only OUTSIDE the unwind boundary, so a
    /// request panic can never poison a tally.
    shard_versions: Vec<VersionTally>,
    steal: bool,
    stopping: AtomicBool,
    /// Batch-client submits retried after QueueFull/Shed.
    retried: AtomicU64,
    /// Batch-client requests whose retry budget was exhausted
    /// (degraded mode: the client keeps waiting at the cap instead of
    /// failing the batch).
    degraded: AtomicU64,
    faults: Option<FaultPlan>,
    /// One slot per shard, owned by the supervisor. `None` means the
    /// last (re)spawn failed and will be retried at the next probe.
    workers: Mutex<Vec<Option<thread::JoinHandle<()>>>>,
}

impl<R> Core<R> {
    fn new(cfg: &ClusterConfig) -> Core<R> {
        Core {
            queues: (0..cfg.shards).map(|_| ShardQueue::new()).collect(),
            shard_metrics: (0..cfg.shards).map(|_| Metrics::new()).collect(),
            shard_versions: (0..cfg.shards).map(|_| Mutex::new(BTreeMap::new())).collect(),
            steal: cfg.steal,
            stopping: AtomicBool::new(false),
            retried: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            faults: cfg.faults.clone().or_else(FaultPlan::from_env),
            workers: Mutex::new((0..cfg.shards).map(|_| None).collect()),
        }
    }

    /// Graceful shutdown: close every queue (typed rejections from
    /// here on), then join the supervisor — which joins every worker
    /// and sweeps anything left in the queues (see
    /// [`supervisor_loop`]).
    fn stop_and_join(&self, supervisor: &mut Option<thread::JoinHandle<()>>) {
        self.stopping.store(true, Ordering::Release);
        for q in &self.queues {
            q.close();
        }
        if let Some(h) = supervisor.take() {
            let _ = h.join();
        }
    }
}

/// What every queued request must expose to the supervised core.
trait RequestEnvelope {
    type Resp: Send + 'static;
    fn submitted(&self) -> Instant;
    fn expires(&self) -> Option<Instant>;
    fn reply_to(&self) -> &mpsc::Sender<Reply<Self::Resp>>;
}

/// A service mode: the state a worker carries and the computation it
/// runs per request. Implemented by the score and query shared states.
trait ServeMode: Send + Sync + Sized + 'static {
    /// Thread-name infix: workers are `minmax-{NAME}-w{shard}`.
    const NAME: &'static str;
    type Req: RequestEnvelope + Send + 'static;
    type State: Send;
    fn core(&self) -> &Core<Self::Req>;
    /// A fresh per-worker state (scratch arenas).
    fn fresh_state(&self) -> Self::State;
    /// Discard state that a panic may have left mid-mutation. Called
    /// after the unwind boundary catches; the next request re-warms.
    fn reset(&self, state: &mut Self::State);
    /// The actual work. Runs INSIDE the unwind boundary; must not
    /// acquire any lock shared with non-panicking code paths.
    fn compute(
        &self,
        shard: usize,
        req: &Self::Req,
        state: &mut Self::State,
    ) -> (<Self::Req as RequestEnvelope>::Resp, u64);
}

/// Serve one dequeued request: queue-wait accounting, deadline check,
/// fault-decision draw, the `catch_unwind` boundary around the
/// compute, and exactly one `Reply` send on every path. Returns the
/// post-answer fault (if any) for the worker loop to execute — faults
/// that kill or stall the worker run strictly AFTER the request is
/// answered, so a worker death can never hold a request hostage.
fn handle<M: ServeMode>(
    shared: &M,
    shard: usize,
    req: M::Req,
    state: &mut M::State,
    faults: Option<&mut FaultStream>,
) -> Option<PostFault> {
    let core = shared.core();
    let metrics = &core.shard_metrics[shard];
    metrics.record_queue_wait_ms(req.submitted().elapsed().as_secs_f64() * 1e3);
    if let Some(deadline) = req.expires() {
        if Instant::now() >= deadline {
            metrics.record_deadline();
            let _ = req.reply_to().send(Reply::DeadlineExceeded);
            return None;
        }
    }
    let decision = match faults {
        Some(stream) => stream.next(),
        None => Default::default(),
    };
    // The unwind boundary. Nothing in here touches a Mutex the
    // non-panicking paths share (version tallies and metrics are
    // updated after the catch), so a panic cannot poison shared state;
    // the worker's own scratch is reset below.
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if let Some(d) = decision.slow {
            thread::sleep(d);
        }
        if decision.panic {
            panic!("{INJECTED}: injected request panic (shard {shard})");
        }
        shared.compute(shard, &req, state)
    }));
    match outcome {
        Ok((resp, version)) => {
            metrics.record_latency_ms(req.submitted().elapsed().as_secs_f64() * 1e3);
            *core.shard_versions[shard].lock().unwrap().entry(version).or_insert(0) += 1;
            let _ = req.reply_to().send(Reply::Ok(resp));
        }
        Err(payload) => {
            metrics.record_panicked();
            shared.reset(state);
            let _ = req
                .reply_to()
                .send(Reply::Panicked { message: panic_message(payload.as_ref()) });
        }
    }
    decision.post
}

fn worker_loop<M: ServeMode>(shard: usize, shared: &Arc<M>, incarnation: u64) {
    let core = shared.core();
    // One long-lived arena per worker incarnation; survives hot swaps
    // (the shape invariants guarantee it stays valid across versions).
    let mut state = shared.fresh_state();
    let mut faults = core.faults.as_ref().map(|p| p.stream(shard, incarnation));
    loop {
        let post = match core.queues[shard].pop_wait(STEAL_POLL) {
            Pop::Req(req) => handle(&**shared, shard, req, &mut state, faults.as_mut()),
            Pop::Empty => {
                if core.steal {
                    match steal(shard, &core.queues) {
                        Some(req) => handle(&**shared, shard, req, &mut state, faults.as_mut()),
                        None => None,
                    }
                } else {
                    None
                }
            }
            Pop::Closed => {
                // Shutdown drain: the own queue is empty+closed; help
                // finish whatever is still queued anywhere, then exit.
                // Queues reject pushes once closed, so this
                // terminates. Post faults are ignored during the drain
                // (dying here would only slow shutdown down; in-work
                // faults inside `handle` still fire).
                while let Some(req) = steal_any(shard, &core.queues) {
                    let _ = handle(&**shared, shard, req, &mut state, faults.as_mut());
                }
                return;
            }
        };
        match post {
            Some(PostFault::Die) => {
                panic!("{INJECTED}: injected worker death (shard {shard})")
            }
            Some(PostFault::Stall(d)) => thread::sleep(d),
            None => {}
        }
    }
}

fn spawn_worker<M: ServeMode>(
    shared: &Arc<M>,
    shard: usize,
    incarnation: u64,
) -> std::io::Result<thread::JoinHandle<()>> {
    let name = if incarnation == 0 {
        format!("minmax-{}-w{shard}", M::NAME)
    } else {
        format!("minmax-{}-w{shard}-r{incarnation}", M::NAME)
    };
    let sh = Arc::clone(shared);
    spawn_named(name, move || worker_loop(shard, &sh, incarnation))
}

/// The supervisor: probes worker liveness, joins corpses, respawns
/// them (counted per shard as `restarts`), and at shutdown joins every
/// worker then sweeps requests a mid-drain death left behind.
///
/// A worker that exits NORMALLY (its `join()` is `Ok`) finished the
/// shutdown drain — that only happens after the queues close, so it is
/// never respawned. A worker whose join reports a panic died
/// abnormally; its queue still holds requests (deaths never hold one —
/// see [`handle`]), which the respawned incarnation, stealing
/// siblings, or the final sweep will answer.
fn supervisor_loop<M: ServeMode>(shared: &Arc<M>) {
    let core = shared.core();
    let n = core.queues.len();
    let mut incarnations = vec![0u64; n];
    while !core.stopping.load(Ordering::Acquire) {
        for shard in 0..n {
            let needs_respawn = {
                let mut slots = core.workers.lock().unwrap();
                let dead = matches!(&slots[shard], Some(h) if is_finished(h));
                if dead {
                    slots[shard].take().expect("probed Some").join().is_err()
                } else {
                    // A `None` slot means a previous (re)spawn failed;
                    // keep trying.
                    slots[shard].is_none()
                }
            };
            // Re-check stopping so a shutdown racing a death does not
            // spawn a worker nobody will need (harmless if it slips
            // through — the new worker sees closed queues, drains, and
            // exits into the final join below).
            if needs_respawn && !core.stopping.load(Ordering::Acquire) {
                incarnations[shard] += 1;
                core.shard_metrics[shard].record_restart();
                if let Ok(h) = spawn_worker(shared, shard, incarnations[shard]) {
                    core.workers.lock().unwrap()[shard] = Some(h);
                }
            }
        }
        thread::sleep(SUPERVISOR_POLL);
    }
    // Shutdown: collect and join every worker...
    let slots: Vec<Option<thread::JoinHandle<()>>> = {
        let mut guard = core.workers.lock().unwrap();
        guard.iter_mut().map(|s| s.take()).collect()
    };
    for h in slots.into_iter().flatten() {
        let _ = h.join();
    }
    // ...then sweep anything a mid-drain death stranded. The queues
    // are closed, so this terminates; served requests are attributed
    // to shard 0's metrics (documented in DESIGN.md §2.9 — the
    // cluster-wide sums are what reconcile). Faults are disarmed here:
    // the sweep must complete.
    let mut state = shared.fresh_state();
    while let Some(req) = steal_any(0, &core.queues) {
        let _ = handle(&**shared, 0, req, &mut state, None);
    }
}

/// Spawn the incarnation-0 worker for every shard, then the supervisor
/// that owns them.
fn start_supervised<M: ServeMode>(shared: &Arc<M>) -> Result<thread::JoinHandle<()>, String> {
    let n = shared.core().queues.len();
    for shard in 0..n {
        let h = spawn_worker(shared, shard, 0)
            .map_err(|e| format!("spawn {} worker {shard}: {e}", M::NAME))?;
        shared.core().workers.lock().unwrap()[shard] = Some(h);
    }
    let sh = Arc::clone(shared);
    spawn_named(format!("minmax-{}-supervisor", M::NAME), move || supervisor_loop(&sh))
        .map_err(|e| format!("spawn {} supervisor: {e}", M::NAME))
}

// ------------------------------------------------------ retry policy

/// Jittered exponential backoff for the blocking batch clients —
/// replaces the hot-spin retry: `delay(attempt) = min(base · 2^attempt,
/// cap) · U[0.5, 1)`, with the jitter drawn from a seeded [`Pcg64`] so
/// a retry schedule is reproducible.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Backoff attempts before a request is declared degraded (the
    /// client then keeps waiting at `cap` — this closed-loop client
    /// wants every row answered, so "degraded" is accounting, not
    /// abandonment).
    pub max_attempts: u32,
    /// First-retry delay.
    pub base: Duration,
    /// Backoff ceiling.
    pub cap: Duration,
    /// Jitter seed.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 10,
            base: Duration::from_micros(100),
            cap: Duration::from_millis(10),
            seed: 0x5EED_BACC,
        }
    }
}

impl RetryPolicy {
    /// The delay before retry number `attempt` (0-based).
    fn delay(&self, attempt: u32, rng: &mut Pcg64) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(20));
        exp.min(self.cap).mul_f64(0.5 + 0.5 * rng.uniform())
    }
}

// ------------------------------------------------------------ shared

/// Merge per-shard metrics, histograms, and version tallies into the
/// cluster-wide view — shared by both router modes.
fn assemble_snapshot<R>(core: &Core<R>, started: Instant, current_version: u64) -> ClusterSnapshot {
    let shards: Vec<Snapshot> = core.shard_metrics.iter().map(|m| m.snapshot()).collect();
    let mut merged = Histogram::new(&LATENCY_BUCKETS_MS);
    for s in &shards {
        merged.merge(&Histogram::with_counts(&LATENCY_BUCKETS_MS, s.latency_hist.clone()));
    }
    let mut version_counts: BTreeMap<u64, u64> = BTreeMap::new();
    for vm in &core.shard_versions {
        for (&v, &c) in vm.lock().unwrap().iter() {
            *version_counts.entry(v).or_insert(0) += c;
        }
    }
    let elapsed = started.elapsed().as_secs_f64();
    let completed: u64 = shards.iter().map(|s| s.completed).sum();
    ClusterSnapshot {
        requests: shards.iter().map(|s| s.requests).sum(),
        completed,
        rejected: shards.iter().map(|s| s.rejected).sum(),
        shed: shards.iter().map(|s| s.shed).sum(),
        deadline_expired: shards.iter().map(|s| s.deadline_expired).sum(),
        panicked: shards.iter().map(|s| s.panicked).sum(),
        restarts: shards.iter().map(|s| s.restarts).sum(),
        retried: core.retried.load(Ordering::Acquire),
        degraded: core.degraded.load(Ordering::Acquire),
        queue_depths: core.queues.iter().map(|q| q.depth()).collect(),
        elapsed_s: elapsed,
        throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
        latency_p50_ms: merged.quantile(50.0),
        latency_p90_ms: merged.quantile(90.0),
        latency_p99_ms: merged.quantile(99.0),
        current_version,
        version_counts: version_counts.into_iter().collect(),
        shards,
    }
}

/// The start-time config checks shared by both router modes.
fn validate_config(cfg: &ClusterConfig) -> Result<(), String> {
    if cfg.shards == 0 {
        return Err("cluster needs at least one shard".into());
    }
    if cfg.queue_cap == 0 {
        return Err("queue_cap must be positive".into());
    }
    if let Some(w) = cfg.shed_watermark {
        if w == 0 || w > cfg.queue_cap {
            return Err(format!(
                "shed watermark {w} must be in 1..=queue_cap ({})",
                cfg.queue_cap
            ));
        }
    }
    Ok(())
}

// -------------------------------------------------------- score mode

impl RequestEnvelope for ClusterRequest {
    type Resp = ClusterScoreResponse;
    fn submitted(&self) -> Instant {
        self.submitted
    }
    fn expires(&self) -> Option<Instant> {
        self.expires
    }
    fn reply_to(&self) -> &mpsc::Sender<Reply<ClusterScoreResponse>> {
        &self.tx
    }
}

struct Shared {
    core: Core<ClusterRequest>,
    /// The hot-swap slot. Read (cheap: shared lock + `Arc` clone) at
    /// every dequeue; written only by `publish`.
    model: SwapCell<Versioned>,
}

impl ServeMode for Shared {
    const NAME: &'static str = "cluster";
    type Req = ClusterRequest;
    /// Scratch arena + decision staging. `k`/`dim` are invariant
    /// across published versions, so the scratch survives hot swaps;
    /// only the staging is (cheaply) resized per request.
    type State = (Option<Scratch>, Vec<f64>);

    fn core(&self) -> &Core<ClusterRequest> {
        &self.core
    }

    fn fresh_state(&self) -> Self::State {
        (None, Vec::new())
    }

    fn reset(&self, state: &mut Self::State) {
        // A panic may have interrupted `score_dense_into` mid-write;
        // the arena's contents are untrusted now. Drop and re-warm.
        *state = (None, Vec::new());
    }

    fn compute(
        &self,
        shard: usize,
        req: &ClusterRequest,
        state: &mut Self::State,
    ) -> (ClusterScoreResponse, u64) {
        let (scratch, staging) = state;
        // Pick up the current version; in-flight work keeps this Arc
        // alive through a concurrent publish (the drain half of the
        // swap protocol).
        let model: Arc<Versioned> = self.model.get();
        let scorer = &model.scorer;
        let s = scratch.get_or_insert_with(|| scorer.scratch());
        staging.clear();
        staging.resize(scorer.n_classes(), 0.0);
        scorer.score_dense_into(&req.vector, s, staging);
        let label = argmax(staging);
        let latency = req.submitted.elapsed();
        (
            ClusterScoreResponse {
                id: req.id,
                decisions: staging.clone(),
                label,
                version: model.version,
                shard,
                latency,
            },
            model.version,
        )
    }
}

// ------------------------------------------------------------ router

/// The sharded scoring front door. See the module docs for the queue,
/// swap, fault-tolerance, and shutdown contracts.
pub struct ScoreRouter {
    shared: Arc<Shared>,
    /// Owns the workers; joined (after the queues close) by
    /// `stop_and_join`.
    supervisor: Option<thread::JoinHandle<()>>,
    rr: AtomicU64,
    cfg: ClusterConfig,
    started: Instant,
    // Invariant shape every published version must match.
    k: usize,
    dim: usize,
    seed: u64,
    // Serving-plan invariants (PR 7): replicas must stream the same
    // slab precision and code packing, or a hot swap silently changes
    // latency/accuracy characteristics mid-fleet.
    precision: SlabPrecision,
    packed: bool,
}

/// An accepted submission: the response handle plus which shard's
/// queue took it.
pub struct Submitted {
    rx: mpsc::Receiver<Reply<ClusterScoreResponse>>,
    shard: usize,
}

impl Submitted {
    /// Shard whose queue accepted the request (a stealing worker may
    /// still serve it — the response's `shard` field is authoritative).
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block for the response. A caught worker panic or an expired
    /// deadline come back as typed errors
    /// ([`ClusterError::WorkerPanicked`] /
    /// [`ClusterError::DeadlineExceeded`]); `ShuttingDown` cannot
    /// happen for an accepted request — shutdown answers every one.
    pub fn wait(self) -> Result<ClusterScoreResponse, ClusterError> {
        self.rx.recv().map_err(|_| ClusterError::ShuttingDown)?.into_result()
    }

    /// Bounded wait: [`ClusterError::WaitTimeout`] after `dur` with no
    /// response. Non-consuming — the request may still complete, and a
    /// later `wait`/`wait_timeout` on the same handle picks it up.
    pub fn wait_timeout(&self, dur: Duration) -> Result<ClusterScoreResponse, ClusterError> {
        match self.rx.recv_timeout(dur) {
            Ok(reply) => reply.into_result(),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ClusterError::WaitTimeout),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ClusterError::ShuttingDown),
        }
    }
}

impl ScoreRouter {
    /// Start `cfg.shards` supervised workers serving `scorer` as
    /// version 1. The scorer is NOT cloned per shard — workers share
    /// one slab behind the version `Arc` (replication is of execution
    /// state: scratch arenas and queues, which is what actually needs
    /// to be per-worker).
    pub fn start(scorer: Scorer, cfg: ClusterConfig) -> Result<ScoreRouter, String> {
        validate_config(&cfg)?;
        let (k, dim, seed) = (scorer.k(), scorer.dim(), scorer.seed());
        let (precision, packed) = (scorer.precision(), scorer.packed_codes());
        let shared = Arc::new(Shared {
            core: Core::new(&cfg),
            model: SwapCell::new(Versioned { version: 1, scorer }),
        });
        let supervisor = Some(start_supervised(&shared)?);
        Ok(ScoreRouter {
            shared,
            supervisor,
            rr: AtomicU64::new(0),
            cfg,
            started: Instant::now(),
            k,
            dim,
            seed,
            precision,
            packed,
        })
    }

    pub fn shards(&self) -> usize {
        self.cfg.shards
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Version currently being published to workers.
    pub fn current_version(&self) -> u64 {
        self.shared.model.get().version
    }

    /// Class count of the current version.
    pub fn n_classes(&self) -> usize {
        self.shared.model.get().scorer.n_classes()
    }

    /// Per-shard metrics handle (tests / scraping).
    pub fn metrics(&self, shard: usize) -> &Metrics {
        &self.shared.core.shard_metrics[shard]
    }

    /// Publish a new model version: validate shape, swap the `Arc`.
    /// Returns the new version number. Zero downtime — requests
    /// dequeued before the swap drain against the old version (their
    /// workers hold its `Arc`); every later dequeue scores with the
    /// new slab, including workers the supervisor respawned. The class
    /// count MAY change between versions; each response reports the
    /// version that produced it.
    pub fn publish(&self, scorer: Scorer) -> Result<u64, ClusterError> {
        if scorer.k() != self.k {
            return Err(ClusterError::ShapeMismatch(format!(
                "k {} != cluster k {}",
                scorer.k(),
                self.k
            )));
        }
        if scorer.dim() != self.dim {
            return Err(ClusterError::ShapeMismatch(format!(
                "dim {} != cluster dim {}",
                scorer.dim(),
                self.dim
            )));
        }
        if scorer.seed() != self.seed {
            return Err(ClusterError::ShapeMismatch(format!(
                "seed {} != cluster seed {}",
                scorer.seed(),
                self.seed
            )));
        }
        if scorer.precision() != self.precision {
            return Err(ClusterError::ShapeMismatch(format!(
                "slab precision {} != cluster precision {}",
                scorer.precision(),
                self.precision
            )));
        }
        if scorer.packed_codes() != self.packed {
            return Err(ClusterError::ShapeMismatch(format!(
                "packed codes {} != cluster packing {}",
                scorer.packed_codes(),
                self.packed
            )));
        }
        let version = self.shared.model.update(|cur| {
            let version = cur.version + 1;
            (Versioned { version, scorer }, version)
        });
        Ok(version)
    }

    fn validate(&self, vector: &[f32]) -> Result<(), ClusterError> {
        if self.shared.core.stopping.load(Ordering::Acquire) {
            return Err(ClusterError::ShuttingDown);
        }
        if vector.len() != self.dim {
            return Err(ClusterError::BadInput(format!("dim {} != {}", vector.len(), self.dim)));
        }
        if vector.iter().any(|&v| v < 0.0 || !v.is_finite()) {
            return Err(ClusterError::BadInput("negative or non-finite entry".into()));
        }
        // NOTE: all-zero rows are accepted (they score `bias + 0` per
        // class), matching `Pipeline::predict` over a matrix with empty
        // rows — the cluster must be prediction-compatible with the
        // offline path, which the single service's stricter validation
        // is not.
        Ok(())
    }

    /// Least-deep shard with a rotating round-robin tie-break start, so
    /// equal-depth shards share arrivals instead of all landing on 0.
    fn pick(&self) -> usize {
        pick_least_deep(&self.shared.core.queues, &self.rr)
    }

    fn submit_inner(
        &self,
        id: u64,
        vector: &[f32],
        expires: Option<Instant>,
    ) -> Result<Submitted, ClusterError> {
        self.validate(vector)?;
        let core = &self.shared.core;
        let first = self.pick();
        let n = self.cfg.shards;
        // `requests` counts every VALIDATED submit, recorded on the
        // first-picked shard before the push so the outcome counters
        // (completed/rejected/shed/deadline/panicked) always partition
        // it — the reconciliation the snapshot pins.
        core.shard_metrics[first].record_request();
        let (rtx, rrx) = mpsc::channel();
        let mut req =
            ClusterRequest { id, vector: vector.to_vec(), submitted: Instant::now(), expires, tx: rtx };
        for off in 0..n {
            let i = (first + off) % n;
            match core.queues[i].push(req, self.cfg.queue_cap, self.cfg.shed_watermark) {
                Ok(()) => return Ok(Submitted { rx: rrx, shard: i }),
                Err((PushError::Shed { depth, watermark }, _)) => {
                    // Terminal: `first` was the least-loaded shard, so
                    // the whole cluster is past the watermark.
                    core.shard_metrics[first].record_shed();
                    return Err(ClusterError::Shed { depth, watermark });
                }
                Err((PushError::Closed, _)) => {
                    // Raced a shutdown past the validate() check;
                    // counted as a rejection so `requests` still
                    // partitions exactly.
                    core.shard_metrics[first].record_rejected();
                    return Err(ClusterError::ShuttingDown);
                }
                Err((PushError::Full, back)) => {
                    // Reclaim the request and fail over to the next
                    // shard.
                    req = back;
                }
            }
        }
        core.shard_metrics[first].record_rejected();
        Err(ClusterError::QueueFull)
    }

    /// Submit one dense row for scoring. Fail-fast flow control: `Shed`
    /// past the watermark (evaluated on the least-loaded shard, so it
    /// reflects cluster-wide pressure), `QueueFull` only when every
    /// shard is at the hard cap.
    pub fn submit(&self, id: u64, vector: &[f32]) -> Result<Submitted, ClusterError> {
        self.submit_inner(id, vector, None)
    }

    /// [`submit`](Self::submit) with a relative deadline: if no worker
    /// has STARTED the request `deadline` after submission, it is
    /// answered with [`ClusterError::DeadlineExceeded`] at dequeue
    /// (and counted in the snapshot's `deadline_expired`) instead of
    /// being served stale. Work already started always runs to
    /// completion — the deadline bounds queueing, not compute.
    pub fn submit_with_deadline(
        &self,
        id: u64,
        vector: &[f32],
        deadline: Duration,
    ) -> Result<Submitted, ClusterError> {
        self.submit_inner(id, vector, Some(Instant::now() + deadline))
    }

    /// Blocking submit-and-wait.
    pub fn score_blocking(
        &self,
        id: u64,
        vector: &[f32],
    ) -> Result<ClusterScoreResponse, ClusterError> {
        self.submit(id, vector)?.wait()
    }

    /// Blocking classification: label only.
    pub fn classify_blocking(&self, id: u64, vector: &[f32]) -> Result<i32, ClusterError> {
        Ok(self.score_blocking(id, vector)?.label)
    }

    /// Score a whole matrix through the cluster with the default
    /// [`RetryPolicy`] — see
    /// [`score_batch_blocking_with`](Self::score_batch_blocking_with).
    pub fn score_batch_blocking(&self, x: &Matrix) -> Result<Vec<i32>, ClusterError> {
        self.score_batch_blocking_with(x, &RetryPolicy::default())
    }

    /// Score a whole matrix through the cluster, in row order — the
    /// batch entry the saturation bench and parity tests drive. A
    /// backpressure-aware closed-loop client: submissions race ahead
    /// until a queue rejects, then the oldest outstanding response is
    /// reaped before retrying; when nothing is outstanding (another
    /// client owns the queue space) it backs off under `policy`
    /// instead of hot-spinning, counting `retried` submits and
    /// `degraded` requests (budget exhausted; the client keeps waiting
    /// at the cap — every row gets answered). Shed rejections are
    /// retried too.
    pub fn score_batch_blocking_with(
        &self,
        x: &Matrix,
        policy: &RetryPolicy,
    ) -> Result<Vec<i32>, ClusterError> {
        let dense = x.to_dense();
        let n = dense.rows();
        let mut out = vec![0i32; n];
        let mut pending: VecDeque<(usize, Submitted)> = VecDeque::new();
        let mut rng = Pcg64::new(policy.seed);
        let core = &self.shared.core;
        for i in 0..n {
            let mut attempt = 0u32;
            let mut degraded = false;
            loop {
                match self.submit(i as u64, dense.row(i)) {
                    Ok(s) => {
                        pending.push_back((i, s));
                        break;
                    }
                    Err(ClusterError::QueueFull) | Err(ClusterError::Shed { .. }) => {
                        core.retried.fetch_add(1, Ordering::Release);
                        if let Some((j, s)) = pending.pop_front() {
                            // Reaping our own oldest response frees
                            // queue space deterministically — no sleep
                            // needed on this path.
                            out[j] = s.wait()?.label;
                        } else if attempt >= policy.max_attempts {
                            if !degraded {
                                degraded = true;
                                core.degraded.fetch_add(1, Ordering::Release);
                            }
                            thread::sleep(policy.cap);
                        } else {
                            thread::sleep(policy.delay(attempt, &mut rng));
                            attempt += 1;
                        }
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        for (j, s) in pending {
            out[j] = s.wait()?.label;
        }
        Ok(out)
    }

    /// Cluster-wide snapshot: per-shard metrics plus merged totals,
    /// fleet latency quantiles from the merged histograms, queue
    /// depths, fault/restart counters, and per-version completion
    /// tallies.
    pub fn snapshot(&self) -> ClusterSnapshot {
        assemble_snapshot(&self.shared.core, self.started, self.current_version())
    }

    /// Graceful shutdown: close every queue (typed rejections from
    /// here on), then block until the workers have drained and
    /// answered every accepted request.
    pub fn shutdown(mut self) {
        self.shared.core.stop_and_join(&mut self.supervisor);
    }
}

impl Drop for ScoreRouter {
    fn drop(&mut self) {
        self.shared.core.stop_and_join(&mut self.supervisor);
    }
}

/// Aggregated cluster state. `requests` counts every VALIDATED submit
/// (accepted or not), and the outcome counters partition it exactly —
/// [`reconciles`](Self::reconciles) pins
/// `completed + rejected + shed + deadline_expired + panicked ==
/// requests`, even across worker deaths and respawns. Per-shard
/// `requests` vs `completed` may differ when work stealing or the
/// shutdown sweep moved a request between shards; the cluster-wide
/// sums always reconcile.
#[derive(Debug, Clone)]
pub struct ClusterSnapshot {
    pub shards: Vec<Snapshot>,
    /// Validated submissions, cluster-wide (accepted or rejected).
    pub requests: u64,
    pub completed: u64,
    /// Hard-cap backpressure rejections (plus submits that raced a
    /// shutdown).
    pub rejected: u64,
    /// Watermark load-shed rejections.
    pub shed: u64,
    /// Requests whose deadline expired before a worker started them.
    pub deadline_expired: u64,
    /// Requests answered with a caught worker panic.
    pub panicked: u64,
    /// Worker respawns performed by the supervisor.
    pub restarts: u64,
    /// Batch-client submits retried after QueueFull/Shed.
    pub retried: u64,
    /// Batch-client requests whose retry budget was exhausted.
    pub degraded: u64,
    pub queue_depths: Vec<usize>,
    pub elapsed_s: f64,
    /// Completions per second since the cluster started.
    pub throughput_rps: f64,
    /// Fleet latency quantiles estimated from the merged per-shard
    /// histograms (exact per-shard reservoir percentiles live in
    /// `shards`).
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub current_version: u64,
    /// `(version, completed)` tallies, ascending by version.
    pub version_counts: Vec<(u64, u64)>,
}

impl ClusterSnapshot {
    /// Requests the cluster accepted and therefore owes a response.
    pub fn accepted(&self) -> u64 {
        self.requests - self.rejected - self.shed
    }

    /// Responses actually delivered (success, caught panic, or expired
    /// deadline). At quiescence `answered() == accepted()`.
    pub fn answered(&self) -> u64 {
        self.completed + self.deadline_expired + self.panicked
    }

    /// The accounting invariant: every validated submit is in exactly
    /// one outcome bucket. Holds at quiescence (no in-flight work).
    pub fn reconciles(&self) -> bool {
        self.completed + self.rejected + self.shed + self.deadline_expired + self.panicked
            == self.requests
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("requests", self.requests)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("shed", self.shed)
            .set("deadline_expired", self.deadline_expired)
            .set("panicked", self.panicked)
            .set("restarts", self.restarts)
            .set("retried", self.retried)
            .set("degraded", self.degraded)
            .set("elapsed_s", self.elapsed_s)
            .set("throughput_rps", self.throughput_rps)
            .set("latency_p50_ms", self.latency_p50_ms)
            .set("latency_p90_ms", self.latency_p90_ms)
            .set("latency_p99_ms", self.latency_p99_ms)
            .set("current_version", self.current_version);
        j.set(
            "queue_depths",
            Json::Arr(self.queue_depths.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        j.set(
            "version_counts",
            Json::Arr(
                self.version_counts
                    .iter()
                    .map(|&(v, c)| Json::Arr(vec![Json::Num(v as f64), Json::Num(c as f64)]))
                    .collect(),
            ),
        );
        j.set("shards", Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()));
        j
    }

    pub fn render(&self) -> String {
        format!(
            "v{} requests={} completed={} rejected={} shed={} deadline={} panicked={} restarts={} retried={} rps={:.1} p50={:.2}ms p90={:.2}ms p99={:.2}ms depths={:?}",
            self.current_version,
            self.requests,
            self.completed,
            self.rejected,
            self.shed,
            self.deadline_expired,
            self.panicked,
            self.restarts,
            self.retried,
            self.throughput_rps,
            self.latency_p50_ms,
            self.latency_p90_ms,
            self.latency_p99_ms,
            self.queue_depths
        )
    }
}

// ------------------------------------------------------- query mode

/// One answered retrieval request — the `query` analog of
/// [`ClusterScoreResponse`]: ranked hits plus which index version and
/// shard served it.
pub struct ClusterQueryResponse {
    pub id: u64,
    /// `(row_id, min-max similarity)` descending, ties by ascending id —
    /// exactly `PackedLshIndex::query_with(query, top, params)` on the
    /// serving version.
    pub hits: Vec<(u32, f64)>,
    /// Index version that answered this request.
    pub version: u64,
    /// Shard whose worker served it (≠ accepting shard when stolen).
    pub shard: usize,
    /// Total time from submit to completion.
    pub latency: Duration,
}

struct QueryRequest {
    id: u64,
    indices: Vec<u32>,
    values: Vec<f32>,
    top: usize,
    submitted: Instant,
    /// Absolute deadline; checked at dequeue.
    expires: Option<Instant>,
    tx: mpsc::Sender<Reply<ClusterQueryResponse>>,
}

impl RequestEnvelope for QueryRequest {
    type Resp = ClusterQueryResponse;
    fn submitted(&self) -> Instant {
        self.submitted
    }
    fn expires(&self) -> Option<Instant> {
        self.expires
    }
    fn reply_to(&self) -> &mpsc::Sender<Reply<ClusterQueryResponse>> {
        &self.tx
    }
}

/// A versioned index: the immutable unit the query-mode `Arc` swap
/// publishes. The index itself is behind its own `Arc` so a caller can
/// keep a handle for direct comparison (and so republish is cheap).
struct VersionedIndex {
    version: u64,
    index: Arc<PackedLshIndex>,
}

struct QueryShared {
    core: Core<QueryRequest>,
    /// The hot-swap slot, same protocol as score mode: read (shared
    /// lock + `Arc` clone) at every dequeue, written only by `publish`.
    index: SwapCell<VersionedIndex>,
    /// Lookup knobs, fixed at start: every replica must probe and
    /// prefilter identically or responses would depend on which worker
    /// served them.
    params: QueryParams,
}

impl ServeMode for QueryShared {
    const NAME: &'static str = "query";
    type Req = QueryRequest;
    /// One long-lived retrieval scratch per worker: after warm-up the
    /// serve path is allocation-free except for the response hits Vec.
    type State = QueryScratch;

    fn core(&self) -> &Core<QueryRequest> {
        &self.core
    }

    fn fresh_state(&self) -> QueryScratch {
        QueryScratch::new()
    }

    fn reset(&self, state: &mut QueryScratch) {
        // A panic may have left probe buffers mid-mutation; start over.
        *state = QueryScratch::new();
    }

    fn compute(
        &self,
        shard: usize,
        req: &QueryRequest,
        scratch: &mut QueryScratch,
    ) -> (ClusterQueryResponse, u64) {
        // Pin the version for this request; a concurrent publish cannot
        // free the index under us (same drain rule as score mode).
        let model: Arc<VersionedIndex> = self.index.get();
        let row = SparseRow { indices: &req.indices, values: &req.values };
        let hits = model.index.query_with(row, req.top, self.params, scratch).to_vec();
        let latency = req.submitted.elapsed();
        (
            ClusterQueryResponse { id: req.id, hits, version: model.version, shard, latency },
            model.version,
        )
    }
}

/// An accepted query submission (see [`Submitted`]).
pub struct SubmittedQuery {
    rx: mpsc::Receiver<Reply<ClusterQueryResponse>>,
    shard: usize,
}

impl SubmittedQuery {
    /// Shard whose queue accepted the request (a stealing worker may
    /// still serve it — the response's `shard` field is authoritative).
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block for the response — same contract as [`Submitted::wait`].
    pub fn wait(self) -> Result<ClusterQueryResponse, ClusterError> {
        self.rx.recv().map_err(|_| ClusterError::ShuttingDown)?.into_result()
    }

    /// Bounded wait — same contract as [`Submitted::wait_timeout`].
    pub fn wait_timeout(&self, dur: Duration) -> Result<ClusterQueryResponse, ClusterError> {
        match self.rx.recv_timeout(dur) {
            Ok(reply) => reply.into_result(),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ClusterError::WaitTimeout),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ClusterError::ShuttingDown),
        }
    }
}

/// The sharded retrieval front door — the `query` service mode next to
/// [`ScoreRouter`]'s `score`. Same queues, backpressure, shedding,
/// stealing, versioned hot swap, metrics, supervision, and shutdown
/// drain; workers own a [`QueryScratch`] each and answer top-k
/// retrieval against a shared [`PackedLshIndex`] behind the version
/// `Arc`.
///
/// Responses are bit-identical to calling
/// [`PackedLshIndex::query_with`] directly with the router's params —
/// sharding, stealing, respawns, and hot swaps never change results,
/// only which version answers (pinned by `rust/tests/lsh_parity.rs`).
pub struct QueryRouter {
    shared: Arc<QueryShared>,
    /// Owns the workers; joined (after the queues close) by
    /// `stop_and_join`.
    supervisor: Option<thread::JoinHandle<()>>,
    rr: AtomicU64,
    cfg: ClusterConfig,
    started: Instant,
    // Invariant shape every published index must match: a swap that
    // changed the banding, seed, truncation width, or feature space
    // would silently change what "similar" means mid-fleet. The corpus
    // ROW COUNT may change — that is the point of a hot swap (fresh
    // corpus snapshots).
    bands: usize,
    rows_per_band: usize,
    seed: u64,
    bits: u8,
    cols: usize,
}

impl QueryRouter {
    /// Start `cfg.shards` supervised workers serving `index` as
    /// version 1. The index is NOT cloned per shard — workers share
    /// the slab and bucket tables behind the version `Arc`; per-worker
    /// state is the retrieval scratch.
    pub fn start(
        index: Arc<PackedLshIndex>,
        params: QueryParams,
        cfg: ClusterConfig,
    ) -> Result<QueryRouter, String> {
        validate_config(&cfg)?;
        let c = *index.config();
        let (bits, cols) = (index.bits(), index.corpus().cols());
        let shared = Arc::new(QueryShared {
            core: Core::new(&cfg),
            index: SwapCell::new(VersionedIndex { version: 1, index }),
            params,
        });
        let supervisor = Some(start_supervised(&shared)?);
        Ok(QueryRouter {
            shared,
            supervisor,
            rr: AtomicU64::new(0),
            cfg,
            started: Instant::now(),
            bands: c.bands,
            rows_per_band: c.rows_per_band,
            seed: c.seed,
            bits,
            cols,
        })
    }

    pub fn shards(&self) -> usize {
        self.cfg.shards
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Lookup knobs every worker serves with.
    pub fn params(&self) -> QueryParams {
        self.shared.params
    }

    /// Version currently being published to workers.
    pub fn current_version(&self) -> u64 {
        self.shared.index.get().version
    }

    /// Corpus rows of the current version.
    pub fn corpus_len(&self) -> usize {
        self.shared.index.get().index.len()
    }

    /// Per-shard metrics handle (tests / scraping).
    pub fn metrics(&self, shard: usize) -> &Metrics {
        &self.shared.core.shard_metrics[shard]
    }

    /// Publish a new index version: validate the shape invariants
    /// (banding, seed, bits, feature dim — the corpus row count may
    /// change), swap the `Arc`. Zero downtime, same drain protocol as
    /// score mode; every response carries the version that answered it.
    pub fn publish(&self, index: Arc<PackedLshIndex>) -> Result<u64, ClusterError> {
        let c = index.config();
        if c.bands != self.bands || c.rows_per_band != self.rows_per_band {
            return Err(ClusterError::ShapeMismatch(format!(
                "banding {}x{} != cluster banding {}x{}",
                c.bands, c.rows_per_band, self.bands, self.rows_per_band
            )));
        }
        if c.seed != self.seed {
            return Err(ClusterError::ShapeMismatch(format!(
                "seed {} != cluster seed {}",
                c.seed, self.seed
            )));
        }
        if index.bits() != self.bits {
            return Err(ClusterError::ShapeMismatch(format!(
                "bits {} != cluster bits {}",
                index.bits(),
                self.bits
            )));
        }
        if index.corpus().cols() != self.cols {
            return Err(ClusterError::ShapeMismatch(format!(
                "feature dim {} != cluster dim {}",
                index.corpus().cols(),
                self.cols
            )));
        }
        let version = self.shared.index.update(|cur| {
            let version = cur.version + 1;
            (VersionedIndex { version, index }, version)
        });
        Ok(version)
    }

    fn validate(&self, query: SparseRow<'_>) -> Result<(), ClusterError> {
        if self.shared.core.stopping.load(Ordering::Acquire) {
            return Err(ClusterError::ShuttingDown);
        }
        if query.indices.len() != query.values.len() {
            return Err(ClusterError::BadInput(format!(
                "indices/values length mismatch: {} != {}",
                query.indices.len(),
                query.values.len()
            )));
        }
        // Unlike score mode, all-zero input is REJECTED: CWS is
        // undefined on the empty vector, so there is no meaningful
        // "similar rows" answer (a direct query returns the empty set;
        // a service caller almost certainly sent a bug).
        if query.nnz() == 0 {
            return Err(ClusterError::BadInput("empty query (no nonzeros)".into()));
        }
        if !query.indices.windows(2).all(|w| w[0] < w[1]) {
            return Err(ClusterError::BadInput("indices not strictly increasing".into()));
        }
        if query.indices[query.indices.len() - 1] as usize >= self.cols {
            return Err(ClusterError::BadInput(format!(
                "index {} out of range for dim {}",
                query.indices[query.indices.len() - 1],
                self.cols
            )));
        }
        if query.values.iter().any(|&v| !v.is_finite() || v <= 0.0) {
            return Err(ClusterError::BadInput("non-finite or non-positive value".into()));
        }
        Ok(())
    }

    fn submit_inner(
        &self,
        id: u64,
        query: SparseRow<'_>,
        top: usize,
        expires: Option<Instant>,
    ) -> Result<SubmittedQuery, ClusterError> {
        self.validate(query)?;
        let core = &self.shared.core;
        let first = pick_least_deep(&core.queues, &self.rr);
        let n = self.cfg.shards;
        // Same accounting contract as score mode: every validated
        // submit is a request, recorded before the push.
        core.shard_metrics[first].record_request();
        let (rtx, rrx) = mpsc::channel();
        let mut req = QueryRequest {
            id,
            indices: query.indices.to_vec(),
            values: query.values.to_vec(),
            top,
            submitted: Instant::now(),
            expires,
            tx: rtx,
        };
        for off in 0..n {
            let i = (first + off) % n;
            match core.queues[i].push(req, self.cfg.queue_cap, self.cfg.shed_watermark) {
                Ok(()) => return Ok(SubmittedQuery { rx: rrx, shard: i }),
                Err((PushError::Shed { depth, watermark }, _)) => {
                    core.shard_metrics[first].record_shed();
                    return Err(ClusterError::Shed { depth, watermark });
                }
                Err((PushError::Closed, _)) => {
                    core.shard_metrics[first].record_rejected();
                    return Err(ClusterError::ShuttingDown);
                }
                Err((PushError::Full, back)) => {
                    req = back;
                }
            }
        }
        core.shard_metrics[first].record_rejected();
        Err(ClusterError::QueueFull)
    }

    /// Submit one sparse query for top-`top` retrieval. Identical
    /// flow-control contract to [`ScoreRouter::submit`]: `Shed` past
    /// the watermark, `QueueFull` only when every shard is at the hard
    /// cap, failover over full shards first.
    pub fn submit(
        &self,
        id: u64,
        query: SparseRow<'_>,
        top: usize,
    ) -> Result<SubmittedQuery, ClusterError> {
        self.submit_inner(id, query, top, None)
    }

    /// [`submit`](Self::submit) with a relative deadline — same
    /// contract as [`ScoreRouter::submit_with_deadline`].
    pub fn submit_with_deadline(
        &self,
        id: u64,
        query: SparseRow<'_>,
        top: usize,
        deadline: Duration,
    ) -> Result<SubmittedQuery, ClusterError> {
        self.submit_inner(id, query, top, Some(Instant::now() + deadline))
    }

    /// Blocking submit-and-wait.
    pub fn query_blocking(
        &self,
        id: u64,
        query: SparseRow<'_>,
        top: usize,
    ) -> Result<ClusterQueryResponse, ClusterError> {
        self.submit(id, query, top)?.wait()
    }

    /// Batch retrieval with the default [`RetryPolicy`] — see
    /// [`query_batch_blocking_with`](Self::query_batch_blocking_with).
    pub fn query_batch_blocking(
        &self,
        queries: &Csr,
        top: usize,
    ) -> Result<Vec<Vec<(u32, f64)>>, ClusterError> {
        self.query_batch_blocking_with(queries, top, &RetryPolicy::default())
    }

    /// Run every row of `queries` through the cluster in row order —
    /// the query-mode twin of
    /// [`ScoreRouter::score_batch_blocking_with`]: a closed-loop
    /// client that reaps its oldest outstanding response when a submit
    /// is rejected, and otherwise backs off under `policy` (seeded
    /// jittered exponential) instead of hot-spinning; `retried` and
    /// `degraded` are exported in the snapshot.
    pub fn query_batch_blocking_with(
        &self,
        queries: &Csr,
        top: usize,
        policy: &RetryPolicy,
    ) -> Result<Vec<Vec<(u32, f64)>>, ClusterError> {
        let n = queries.rows();
        let mut out: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
        let mut pending: VecDeque<(usize, SubmittedQuery)> = VecDeque::new();
        let mut rng = Pcg64::new(policy.seed);
        let core = &self.shared.core;
        for i in 0..n {
            let mut attempt = 0u32;
            let mut degraded = false;
            loop {
                match self.submit(i as u64, queries.row(i), top) {
                    Ok(s) => {
                        pending.push_back((i, s));
                        break;
                    }
                    Err(ClusterError::QueueFull) | Err(ClusterError::Shed { .. }) => {
                        core.retried.fetch_add(1, Ordering::Release);
                        if let Some((j, s)) = pending.pop_front() {
                            out[j] = s.wait()?.hits;
                        } else if attempt >= policy.max_attempts {
                            if !degraded {
                                degraded = true;
                                core.degraded.fetch_add(1, Ordering::Release);
                            }
                            thread::sleep(policy.cap);
                        } else {
                            thread::sleep(policy.delay(attempt, &mut rng));
                            attempt += 1;
                        }
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        for (j, s) in pending {
            out[j] = s.wait()?.hits;
        }
        Ok(out)
    }

    /// Cluster-wide snapshot — same shape and reconciliation contract
    /// as [`ScoreRouter::snapshot`].
    pub fn snapshot(&self) -> ClusterSnapshot {
        assemble_snapshot(&self.shared.core, self.started, self.current_version())
    }

    /// Graceful shutdown: close every queue, drain, join.
    pub fn shutdown(mut self) {
        self.shared.core.stop_and_join(&mut self.supervisor);
    }
}

impl Drop for QueryRouter {
    fn drop(&mut self) {
        self.shared.core.stop_and_join(&mut self.supervisor);
    }
}

#[cfg(test)]
mod tests {
    use super::super::faults::silence_injected_panics;
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::prelude::Pipeline;

    fn demo_scorer(seed: u64, k: usize, data_seed: u64) -> (Scorer, crate::data::Dataset) {
        let ds =
            generate("letter", SynthConfig { seed: data_seed, n_train: 90, n_test: 40 }).unwrap();
        let mut pipe = Pipeline::builder().seed(seed).samples(k).i_bits(4).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let scorer = pipe.scorer(ds.dim()).unwrap();
        (scorer, ds)
    }

    fn cfg(shards: usize) -> ClusterConfig {
        ClusterConfig { shards, queue_cap: 64, shed_watermark: None, steal: true, faults: None }
    }

    /// A plan injecting ONLY request panics, at certainty.
    fn all_panic_plan() -> FaultPlan {
        FaultPlan {
            seed: 1,
            panic_rate: 1.0,
            death_rate: 0.0,
            slow_rate: 0.0,
            slow: Duration::ZERO,
            stall_rate: 0.0,
            stall: Duration::ZERO,
        }
    }

    /// A plan injecting ONLY worker deaths (after answering), at
    /// certainty.
    fn all_death_plan() -> FaultPlan {
        FaultPlan {
            seed: 1,
            panic_rate: 0.0,
            death_rate: 1.0,
            slow_rate: 0.0,
            slow: Duration::ZERO,
            stall_rate: 0.0,
            stall: Duration::ZERO,
        }
    }

    #[test]
    fn cluster_matches_direct_scorer() {
        let (scorer, ds) = demo_scorer(9, 16, 2);
        let direct = scorer.clone();
        let cluster = ScoreRouter::start(scorer, cfg(2)).unwrap();
        assert_eq!(cluster.shards(), 2);
        assert_eq!(cluster.current_version(), 1);
        let test = ds.test_x.to_dense();
        let mut scratch = direct.scratch();
        let mut want = vec![0.0f64; direct.n_classes()];
        for i in 0..test.rows() {
            let resp = cluster.score_blocking(i as u64, test.row(i)).unwrap();
            direct.score_dense_into(test.row(i), &mut scratch, &mut want);
            assert_eq!(resp.decisions, want, "row {i}");
            assert_eq!(resp.label, argmax(&want));
            assert_eq!(resp.version, 1);
            assert!(resp.shard < 2);
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.requests, test.rows() as u64);
        assert_eq!(snap.completed, snap.requests);
        assert_eq!(snap.version_counts, vec![(1, snap.completed)]);
        assert!(snap.reconciles());
        assert_eq!(snap.restarts, 0, "healthy run must not respawn");
        cluster.shutdown();
    }

    #[test]
    fn batch_matches_predict_batch() {
        let (scorer, ds) = demo_scorer(5, 16, 3);
        let direct = scorer.clone();
        let cluster = ScoreRouter::start(scorer, ClusterConfig { queue_cap: 8, ..cfg(3) }).unwrap();
        let want = direct.predict_batch(&ds.test_x);
        let got = cluster.score_batch_blocking(&ds.test_x).unwrap();
        assert_eq!(got, want);
        let snap = cluster.snapshot();
        assert!(snap.reconciles());
        cluster.shutdown();
    }

    #[test]
    fn publish_swaps_version_and_validates_shape() {
        let (scorer, ds) = demo_scorer(9, 16, 2);
        // Same seed/k/dim, different training data → different weights.
        let (next, _) = demo_scorer(9, 16, 7);
        let next_direct = next.clone();
        let cluster = ScoreRouter::start(scorer, cfg(2)).unwrap();
        let test = ds.test_x.to_dense();
        let before = cluster.score_blocking(0, test.row(0)).unwrap();
        assert_eq!(before.version, 1);

        let v = cluster.publish(next).unwrap();
        assert_eq!(v, 2);
        assert_eq!(cluster.current_version(), 2);
        let mut scratch = next_direct.scratch();
        let mut want = vec![0.0f64; next_direct.n_classes()];
        for i in 0..test.rows() {
            let resp = cluster.score_blocking(i as u64, test.row(i)).unwrap();
            assert_eq!(resp.version, 2, "row {i} must score on the new version");
            next_direct.score_dense_into(test.row(i), &mut scratch, &mut want);
            assert_eq!(resp.decisions, want, "row {i}");
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.version_counts.len(), 2);
        assert_eq!(snap.version_counts[0].0, 1);
        assert_eq!(snap.version_counts[1].0, 2);

        // Wrong shape is a typed error, not a swap.
        let (wrong_k, _) = demo_scorer(9, 8, 2);
        assert!(matches!(cluster.publish(wrong_k), Err(ClusterError::ShapeMismatch(_))));
        let (wrong_seed, _) = demo_scorer(10, 16, 2);
        assert!(matches!(cluster.publish(wrong_seed), Err(ClusterError::ShapeMismatch(_))));
        assert_eq!(cluster.current_version(), 2);
        cluster.shutdown();
    }

    #[test]
    fn publish_rejects_precision_and_packing_mismatches() {
        let (scorer, ds) = demo_scorer(9, 16, 2);
        let cluster = ScoreRouter::start(scorer.clone(), cfg(2)).unwrap();
        // Same k/dim/seed but a different serving plan must not swap in.
        let f32_variant = scorer.clone().with_precision(SlabPrecision::F32);
        assert!(matches!(
            cluster.publish(f32_variant),
            Err(ClusterError::ShapeMismatch(_))
        ));
        let packed_variant = scorer.clone().with_packed_codes(true);
        assert!(packed_variant.packed_codes());
        assert!(matches!(
            cluster.publish(packed_variant),
            Err(ClusterError::ShapeMismatch(_))
        ));
        assert_eq!(cluster.current_version(), 1, "rejected publishes must not bump the version");
        cluster.shutdown();

        // A cluster serving a quantized, packed plan accepts a matching
        // publish and rejects the plain one — and still scores in
        // agreement with its direct twin.
        let quant = scorer.clone().with_precision(SlabPrecision::Int8).with_packed_codes(true);
        assert_eq!(quant.precision(), SlabPrecision::Int8);
        assert!(quant.packed_codes());
        let direct = quant.clone();
        let qcluster = ScoreRouter::start(quant, cfg(2)).unwrap();
        assert!(matches!(qcluster.publish(scorer), Err(ClusterError::ShapeMismatch(_))));
        let (retrain, _) = demo_scorer(9, 16, 7);
        let retrain = retrain.with_precision(SlabPrecision::Int8).with_packed_codes(true);
        assert_eq!(qcluster.publish(retrain).unwrap(), 2);
        let test = ds.test_x.to_dense();
        let mut scratch = direct.scratch();
        let mut want = vec![0.0f64; direct.n_classes()];
        direct.score_dense_into(test.row(0), &mut scratch, &mut want);
        // Version 2 has different weights; republish v1's twin to compare.
        let again = direct.clone();
        assert_eq!(qcluster.publish(again).unwrap(), 3);
        let resp = qcluster.score_blocking(0, test.row(0)).unwrap();
        assert_eq!(resp.decisions, want);
        qcluster.shutdown();
    }

    #[test]
    fn shed_and_backpressure_are_counted_and_typed() {
        let (scorer, ds) = demo_scorer(9, 256, 2);
        // One shard, tiny queue, low watermark: a burst must shed.
        let cluster = ScoreRouter::start(
            scorer,
            ClusterConfig {
                shards: 1,
                queue_cap: 4,
                shed_watermark: Some(2),
                steal: false,
                faults: None,
            },
        )
        .unwrap();
        let test = ds.test_x.to_dense();
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for i in 0..400u64 {
            match cluster.submit(i, test.row((i as usize) % test.rows())) {
                Ok(s) => accepted.push(s),
                Err(ClusterError::Shed { depth, watermark }) => {
                    assert!(depth >= watermark);
                    shed += 1;
                }
                Err(ClusterError::QueueFull) => {
                    unreachable!("watermark (2) trips before the hard cap (4)")
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(shed > 0, "burst against a 2-deep watermark must shed");
        let n_accepted = accepted.len() as u64;
        for s in accepted {
            s.wait().unwrap();
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.shed, shed);
        // `requests` counts every validated submit, shed included.
        assert_eq!(snap.requests, n_accepted + shed);
        assert_eq!(snap.completed, n_accepted);
        assert_eq!(snap.accepted(), n_accepted);
        assert!(snap.reconciles());
        cluster.shutdown();
    }

    #[test]
    fn shutdown_drains_every_accepted_request() {
        let (scorer, ds) = demo_scorer(9, 128, 2);
        let cluster = ScoreRouter::start(
            scorer,
            ClusterConfig {
                shards: 2,
                queue_cap: 256,
                shed_watermark: None,
                steal: true,
                faults: None,
            },
        )
        .unwrap();
        let test = ds.test_x.to_dense();
        let mut accepted = Vec::new();
        for i in 0..300u64 {
            match cluster.submit(i, test.row((i as usize) % test.rows())) {
                Ok(s) => accepted.push((i, s)),
                Err(ClusterError::QueueFull) => {}
                Err(e) => panic!("unexpected {e}"),
            }
        }
        let n = accepted.len() as u64;
        cluster.shutdown();
        for (i, s) in accepted {
            let resp = s.wait().expect("accepted request dropped at shutdown");
            assert_eq!(resp.id, i);
        }
        assert!(n > 0);
    }

    #[test]
    fn rejects_bad_vectors_and_bad_configs() {
        let (scorer, _) = demo_scorer(9, 16, 2);
        let cluster = ScoreRouter::start(scorer.clone(), cfg(1)).unwrap();
        assert!(matches!(cluster.submit(0, &[1.0; 3]), Err(ClusterError::BadInput(_))));
        assert!(matches!(cluster.submit(0, &[-1.0; 16]), Err(ClusterError::BadInput(_))));
        // All-zero rows are VALID here (empty-row parity with
        // Pipeline::predict).
        assert!(cluster.submit(0, &[0.0; 16]).is_ok());
        cluster.shutdown();
        assert!(ScoreRouter::start(scorer.clone(), ClusterConfig { shards: 0, ..cfg(1) }).is_err());
        assert!(ScoreRouter::start(
            scorer,
            ClusterConfig { shed_watermark: Some(9999), queue_cap: 8, ..cfg(1) }
        )
        .is_err());
    }

    // ----------------------------------------------- fault tolerance

    #[test]
    fn injected_panics_become_typed_errors_not_dead_shards() {
        silence_injected_panics();
        let (scorer, ds) = demo_scorer(9, 16, 2);
        let cluster = ScoreRouter::start(
            scorer,
            ClusterConfig { faults: Some(all_panic_plan()), ..cfg(2) },
        )
        .unwrap();
        let test = ds.test_x.to_dense();
        let n = 10u64;
        for i in 0..n {
            match cluster.score_blocking(i, test.row(i as usize % test.rows())) {
                Err(ClusterError::WorkerPanicked { message }) => {
                    assert!(message.contains(INJECTED), "unexpected message: {message}")
                }
                Err(other) => panic!("expected WorkerPanicked, got {other}"),
                Ok(_) => panic!("request {i} must hit the injected panic"),
            }
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.panicked, n);
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.restarts, 0, "caught panics must not kill workers");
        assert!(snap.reconciles());
        cluster.shutdown();
    }

    #[test]
    fn dead_workers_are_respawned_and_keep_serving() {
        silence_injected_panics();
        let (scorer, ds) = demo_scorer(9, 16, 2);
        let direct = scorer.clone();
        // One shard: every request must cross at least one death.
        let cluster = ScoreRouter::start(
            scorer,
            ClusterConfig { faults: Some(all_death_plan()), ..cfg(1) },
        )
        .unwrap();
        let test = ds.test_x.to_dense();
        let mut scratch = direct.scratch();
        let mut want = vec![0.0f64; direct.n_classes()];
        let n = 5u64;
        for i in 0..n {
            let resp = cluster
                .score_blocking(i, test.row(i as usize))
                .expect("deaths happen after the answer — requests still complete");
            direct.score_dense_into(test.row(i as usize), &mut scratch, &mut want);
            assert_eq!(resp.decisions, want, "respawned worker must score identically");
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.completed, n);
        assert!(snap.restarts >= 1, "the supervisor must have respawned the dead shard");
        assert!(snap.reconciles());
        cluster.shutdown();
    }

    #[test]
    fn deadlines_expire_and_waits_are_bounded() {
        let (scorer, ds) = demo_scorer(9, 16, 2);
        let slow_plan = FaultPlan {
            seed: 3,
            panic_rate: 0.0,
            death_rate: 0.0,
            slow_rate: 1.0,
            slow: Duration::from_millis(30),
            stall_rate: 0.0,
            stall: Duration::ZERO,
        };
        let cluster = ScoreRouter::start(
            scorer,
            ClusterConfig { faults: Some(slow_plan), ..cfg(1) },
        )
        .unwrap();
        let test = ds.test_x.to_dense();
        // Bounded wait: the 30ms injected slowdown outlasts a 1ms
        // budget; the handle stays live and a longer wait succeeds.
        let s = cluster.submit(0, test.row(0)).unwrap();
        assert!(matches!(
            s.wait_timeout(Duration::from_millis(1)),
            Err(ClusterError::WaitTimeout)
        ));
        let resp = s.wait_timeout(Duration::from_secs(10)).expect("request completes late");
        assert_eq!(resp.id, 0);
        // A zero deadline has expired by dequeue: answered immediately
        // with DeadlineExceeded, no compute (and no injected slowdown —
        // the deadline check precedes fault injection).
        let s = cluster.submit_with_deadline(1, test.row(1), Duration::ZERO).unwrap();
        assert!(matches!(s.wait(), Err(ClusterError::DeadlineExceeded)));
        let snap = cluster.snapshot();
        assert_eq!(snap.deadline_expired, 1);
        assert_eq!(snap.completed, 1);
        assert!(snap.reconciles());
        cluster.shutdown();
    }

    #[test]
    fn retry_policy_delay_is_bounded_and_deterministic() {
        let policy = RetryPolicy::default();
        let mut rng = Pcg64::new(policy.seed);
        let mut rng2 = Pcg64::new(policy.seed);
        for attempt in 0..32 {
            let d = policy.delay(attempt, &mut rng);
            assert!(d <= policy.cap, "attempt {attempt}: {d:?} above cap");
            assert!(d >= policy.base / 2, "attempt {attempt}: {d:?} below base/2");
            assert_eq!(d, policy.delay(attempt, &mut rng2), "same seed, same schedule");
        }
        // The exponential actually grows until the cap pins it.
        let mut rng = Pcg64::new(7);
        let d0 = policy.delay(0, &mut rng);
        assert!(d0 <= policy.base, "attempt 0 jitters within [base/2, base]");
    }

    #[test]
    fn query_mode_isolates_injected_panics_too() {
        silence_injected_panics();
        let index = demo_index(60, 48, 11);
        let cluster = QueryRouter::start(
            Arc::clone(&index),
            QueryParams::default(),
            ClusterConfig { faults: Some(all_panic_plan()), ..cfg(2) },
        )
        .unwrap();
        let q = index.corpus().row(0);
        match cluster.query_blocking(0, q, 3) {
            Err(ClusterError::WorkerPanicked { message }) => {
                assert!(message.contains(INJECTED))
            }
            other => panic!("expected WorkerPanicked, got {:?}", other.map(|r| r.hits)),
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.panicked, 1);
        assert!(snap.reconciles());
        cluster.shutdown();
    }

    // --------------------------------------------------- query mode

    /// Planted near-duplicate corpus + a packed index over it.
    fn demo_index(rows: usize, dim: usize, data_seed: u64) -> Arc<PackedLshIndex> {
        use crate::data::sparse::CsrBuilder;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(data_seed);
        let mut b = CsrBuilder::new(dim);
        for _ in 0..rows {
            let mut row: Vec<(u32, f32)> = Vec::new();
            for i in 0..dim {
                if rng.uniform() < 0.25 {
                    row.push((i as u32, rng.lognormal(0.0, 1.0) as f32));
                }
            }
            b.push_row(if row.is_empty() { vec![(0, 1.0)] } else { row });
        }
        let cfg = crate::cws::LshConfig { bands: 8, rows_per_band: 2, seed: 77 };
        Arc::new(PackedLshIndex::build(Arc::new(b.finish()), cfg, 8).unwrap())
    }

    #[test]
    fn query_cluster_matches_direct_index() {
        let index = demo_index(120, 48, 11);
        let params = QueryParams { probes: 2, min_agreement: 0.0 };
        let mut scratch = QueryScratch::new();
        for shards in [1usize, 4] {
            let cluster = QueryRouter::start(Arc::clone(&index), params, cfg(shards)).unwrap();
            assert_eq!(cluster.shards(), shards);
            assert_eq!(cluster.current_version(), 1);
            assert_eq!(cluster.corpus_len(), 120);
            let corpus = Arc::clone(index.corpus());
            for i in 0..corpus.rows() {
                let q = corpus.row(i);
                let resp = cluster.query_blocking(i as u64, q, 5).unwrap();
                let want = index.query_with(q, 5, params, &mut scratch);
                assert_eq!(resp.hits, want, "row {i} at {shards} shards");
                assert_eq!(resp.version, 1);
                assert!(resp.shard < shards);
                // The index never misses its own row as the top hit.
                assert_eq!(resp.hits[0].0, i as u32);
            }
            let snap = cluster.snapshot();
            assert_eq!(snap.requests, corpus.rows() as u64);
            assert_eq!(snap.completed, snap.requests);
            assert_eq!(snap.version_counts, vec![(1, snap.completed)]);
            assert!(snap.reconciles());
            cluster.shutdown();
        }
    }

    #[test]
    fn query_batch_matches_direct_index() {
        let index = demo_index(80, 48, 19);
        let params = QueryParams { probes: 2, min_agreement: 0.0 };
        let cluster = QueryRouter::start(
            Arc::clone(&index),
            params,
            ClusterConfig { queue_cap: 8, ..cfg(2) },
        )
        .unwrap();
        let corpus = Arc::clone(index.corpus());
        let got = cluster.query_batch_blocking(&corpus, 5).unwrap();
        let mut scratch = QueryScratch::new();
        for i in 0..corpus.rows() {
            let want = index.query_with(corpus.row(i), 5, params, &mut scratch);
            assert_eq!(got[i], want, "row {i}");
        }
        assert!(cluster.snapshot().reconciles());
        cluster.shutdown();
    }

    #[test]
    fn query_publish_hot_swap_and_validation() {
        let index = demo_index(100, 48, 11);
        let params = QueryParams::default();
        let cluster = QueryRouter::start(Arc::clone(&index), params, cfg(2)).unwrap();
        let probe = index.corpus().row(3);
        assert_eq!(cluster.query_blocking(0, probe, 3).unwrap().version, 1);

        // Same banding/seed/bits/dim over a LARGER corpus snapshot:
        // the legitimate hot-swap case.
        let next = demo_index(160, 48, 12);
        assert_eq!(cluster.publish(Arc::clone(&next)).unwrap(), 2);
        assert_eq!(cluster.current_version(), 2);
        assert_eq!(cluster.corpus_len(), 160);
        let mut scratch = QueryScratch::new();
        for i in 0..20 {
            let q = next.corpus().row(i);
            let resp = cluster.query_blocking(i as u64, q, 5).unwrap();
            assert_eq!(resp.version, 2, "row {i} must serve on the new version");
            assert_eq!(resp.hits, next.query_with(q, 5, params, &mut scratch));
        }

        // Shape mismatches are typed errors, not silent meaning drift.
        let corpus = Arc::clone(next.corpus());
        let rebuilt = |bands, rpb, seed, bits| {
            let c = crate::cws::LshConfig { bands, rows_per_band: rpb, seed };
            Arc::new(PackedLshIndex::build(Arc::clone(&corpus), c, bits).unwrap())
        };
        for bad in [
            rebuilt(4, 2, 77, 8),  // bands
            rebuilt(8, 4, 77, 8),  // rows_per_band
            rebuilt(8, 2, 78, 8),  // seed
            rebuilt(8, 2, 77, 4),  // bits
            demo_index(50, 64, 13), // feature dim
        ] {
            assert!(matches!(cluster.publish(bad), Err(ClusterError::ShapeMismatch(_))));
        }
        assert_eq!(cluster.current_version(), 2, "rejected publishes must not bump");

        // Input validation: typed BadInput, never a worker panic.
        let bad_input = |ix: &[u32], vs: &[f32]| {
            let r = cluster.submit(0, SparseRow { indices: ix, values: vs }, 3);
            assert!(matches!(r, Err(ClusterError::BadInput(_))), "{ix:?}/{vs:?}");
        };
        bad_input(&[], &[]); // empty query
        bad_input(&[2, 1], &[1.0, 1.0]); // unsorted
        bad_input(&[1, 1], &[1.0, 1.0]); // duplicate
        bad_input(&[1], &[1.0, 2.0]); // length mismatch
        bad_input(&[48], &[1.0]); // out of range for dim 48
        bad_input(&[1], &[-1.0]); // negative
        bad_input(&[1], &[f32::NAN]); // non-finite
        bad_input(&[1], &[0.0]); // explicit zero ⇒ empty support

        let snap = cluster.snapshot();
        assert_eq!(snap.completed, snap.requests);
        assert_eq!(snap.version_counts.len(), 2);
        assert!(snap.reconciles());
        cluster.shutdown();
    }
}
