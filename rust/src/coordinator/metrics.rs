//! Service metrics: request/batch counters, latency percentiles,
//! throughput — the observability layer of the hashing/serving stack.
//!
//! Counters are lock-free atomics (the submit path increments
//! `requests` on every attempt — putting that behind the distribution
//! mutex made every submitter serialize on the worker's latency
//! recording). Distribution state (reservoirs, histogram, batch fill)
//! stays behind one mutex; it is only touched by workers and
//! `snapshot()`.
//!
//! ## Counter-ordering contract
//!
//! Increments use `Release`, snapshot loads use `Acquire`, and
//! [`Metrics::snapshot`] reads the *outcome* counters (`completed`,
//! `rejected`, `shed`, `panicked`, `deadline`) **before** the
//! `requests` counter. Every
//! outcome increment is preceded by its request increment (same thread
//! for rejections; via the request queue's happens-before edge for
//! completions), so observing an outcome implies the matching request
//! increment is visible: a concurrent snapshot can never report
//! `completed + rejected > requests`. Read them in the other order and
//! torn totals appear under load — `metrics::tests::
//! concurrent_counters_reconcile` hammers exactly this.

use std::time::Instant;

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Mutex;

use crate::util::stats::{Histogram, Online, Reservoir};

/// Upper bounds (milliseconds) of the per-request latency histogram —
/// log-ish spacing from service-local microseconds to multi-second
/// outliers; the final implicit bucket is overflow.
pub const LATENCY_BUCKETS_MS: [f64; 12] =
    [0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 1000.0];

/// Distribution state that genuinely needs a lock. The hot-path
/// counters live outside as atomics.
#[derive(Debug)]
struct Dists {
    batch_fill: Online,
    latency_ms: Reservoir,
    /// Bucketed latency distribution: O(1) memory for long-lived
    /// services (the reservoir's exact percentiles keep working; the
    /// histogram is what gets exported/scraped and merged across
    /// shards).
    latency_hist: Histogram,
    queue_wait_ms: Reservoir,
}

/// Thread-safe metrics sink shared by the service and its workers.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    /// Submit attempts (the service increments this before the queue
    /// push, so rejected attempts are included).
    requests: AtomicU64,
    /// Typed rejections: queue full (backpressure) at submit time.
    rejected: AtomicU64,
    /// Load-shed rejections: queue depth crossed the configured
    /// watermark (cluster deployments; always 0 for a bare service).
    shed: AtomicU64,
    /// Requests answered — exactly one latency observation each.
    completed: AtomicU64,
    /// Requests answered with a typed `WorkerPanicked` reply: the
    /// request's own work panicked inside the unwind boundary. Counts
    /// toward the outcome total, never toward `completed`.
    panicked: AtomicU64,
    /// Requests answered with `DeadlineExceeded` at dequeue — the
    /// client-requested deadline had already passed, so the work was
    /// skipped. Accounted next to `shed` in the cluster snapshot.
    deadline: AtomicU64,
    /// Worker threads respawned by the supervisor after an abnormal
    /// (panicking) death. Not an outcome counter: restarts are a
    /// property of the shard, not of any one request.
    restarts: AtomicU64,
    batches: AtomicU64,
    dists: Mutex<Dists>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            panicked: AtomicU64::new(0),
            deadline: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            dists: Mutex::new(Dists {
                batch_fill: Online::new(),
                latency_ms: Reservoir::new(),
                latency_hist: Histogram::new(&LATENCY_BUCKETS_MS),
                queue_wait_ms: Reservoir::new(),
            }),
        }
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Release);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Release);
    }

    /// A request rejected by load shedding (queue-depth watermark).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Release);
    }

    /// A request whose work panicked inside the unwind boundary and
    /// was answered with a typed `WorkerPanicked` reply — an outcome
    /// counter, mutually exclusive with `completed`.
    pub fn record_panicked(&self) {
        self.panicked.fetch_add(1, Ordering::Release);
    }

    /// A request answered `DeadlineExceeded` at dequeue — an outcome
    /// counter, mutually exclusive with `completed`.
    pub fn record_deadline(&self) {
        self.deadline.fetch_add(1, Ordering::Release);
    }

    /// The supervisor respawned this shard's worker after an abnormal
    /// death (NOT an outcome counter — see the field docs).
    pub fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Release);
    }

    /// `fill` is the fraction of the batch capacity actually used.
    pub fn record_batch(&self, size: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Release);
        let mut d = self.dists.lock().unwrap();
        d.batch_fill.push(size as f64 / capacity.max(1) as f64);
    }

    /// Record a finished request: one latency observation AND the
    /// completion count — callers must invoke this exactly once per
    /// answered request so `completed` reconciles against `requests`.
    pub fn record_latency_ms(&self, ms: f64) {
        {
            let mut d = self.dists.lock().unwrap();
            d.latency_ms.push(ms);
            d.latency_hist.push(ms);
        }
        // After the observation lands: a snapshot that sees this
        // completion also sees its latency in the locked state.
        self.completed.fetch_add(1, Ordering::Release);
    }

    pub fn record_queue_wait_ms(&self, ms: f64) {
        self.dists.lock().unwrap().queue_wait_ms.push(ms);
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut d = self.dists.lock().unwrap();
        // Outcome counters BEFORE the request counter — see the
        // module-level ordering contract.
        let completed = self.completed.load(Ordering::Acquire);
        let rejected = self.rejected.load(Ordering::Acquire);
        let shed = self.shed.load(Ordering::Acquire);
        let panicked = self.panicked.load(Ordering::Acquire);
        let deadline = self.deadline.load(Ordering::Acquire);
        let restarts = self.restarts.load(Ordering::Acquire);
        let batches = self.batches.load(Ordering::Acquire);
        let requests = self.requests.load(Ordering::Acquire);
        let elapsed = self.started.elapsed().as_secs_f64();
        Snapshot {
            requests,
            rejected,
            shed,
            completed,
            panicked,
            deadline_expired: deadline,
            restarts,
            batches,
            elapsed_s: elapsed,
            throughput_rps: if elapsed > 0.0 { requests as f64 / elapsed } else { 0.0 },
            mean_batch_fill: d.batch_fill.mean(),
            latency_p50_ms: d.latency_ms.percentile(50.0),
            latency_p95_ms: d.latency_ms.percentile(95.0),
            latency_p99_ms: d.latency_ms.percentile(99.0),
            latency_hist_p50_ms: d.latency_hist.quantile(50.0),
            latency_hist_p90_ms: d.latency_hist.quantile(90.0),
            latency_hist_p99_ms: d.latency_hist.quantile(99.0),
            latency_hist: d.latency_hist.counts().to_vec(),
            queue_wait_p50_ms: d.queue_wait_ms.percentile(50.0),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub rejected: u64,
    /// Load-shed rejections (watermark crossings) — disjoint from
    /// `rejected`.
    pub shed: u64,
    /// Requests answered; at quiescence
    /// `requests == completed + rejected + shed + deadline_expired +
    /// panicked` (the fault-model reconciliation — see `Metrics`).
    pub completed: u64,
    /// Requests answered with a typed worker-panic reply.
    pub panicked: u64,
    /// Requests answered `DeadlineExceeded` at dequeue.
    pub deadline_expired: u64,
    /// Supervisor respawns of this shard's worker (not an outcome).
    pub restarts: u64,
    pub batches: u64,
    pub elapsed_s: f64,
    pub throughput_rps: f64,
    pub mean_batch_fill: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    /// Bucket-estimated quantiles from `latency_hist` (the O(buckets)
    /// answer that stays cheap forever and merges across shards; the
    /// exact reservoir percentiles above are the reference).
    pub latency_hist_p50_ms: f64,
    pub latency_hist_p90_ms: f64,
    pub latency_hist_p99_ms: f64,
    /// Latency bucket counts over [`LATENCY_BUCKETS_MS`] (last slot =
    /// overflow).
    pub latency_hist: Vec<u64>,
    pub queue_wait_p50_ms: f64,
}

impl Snapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("requests", self.requests)
            .set("rejected", self.rejected)
            .set("shed", self.shed)
            .set("completed", self.completed)
            .set("panicked", self.panicked)
            .set("deadline_expired", self.deadline_expired)
            .set("restarts", self.restarts)
            .set("batches", self.batches)
            .set("elapsed_s", self.elapsed_s)
            .set("throughput_rps", self.throughput_rps)
            .set("mean_batch_fill", self.mean_batch_fill)
            .set("latency_p50_ms", self.latency_p50_ms)
            .set("latency_p95_ms", self.latency_p95_ms)
            .set("latency_p99_ms", self.latency_p99_ms)
            .set("latency_hist_p50_ms", self.latency_hist_p50_ms)
            .set("latency_hist_p90_ms", self.latency_hist_p90_ms)
            .set("latency_hist_p99_ms", self.latency_hist_p99_ms);
        j.set(
            "latency_bucket_le_ms",
            crate::util::json::Json::Arr(
                LATENCY_BUCKETS_MS.iter().map(|&b| crate::util::json::Json::Num(b)).collect(),
            ),
        );
        j.set(
            "latency_bucket_counts",
            crate::util::json::Json::Arr(
                self.latency_hist.iter().map(|&c| crate::util::json::Json::Num(c as f64)).collect(),
            ),
        );
        j
    }

    pub fn render(&self) -> String {
        format!(
            "requests={} completed={} rejected={} shed={} panicked={} deadline={} restarts={} batches={} rps={:.1} fill={:.2} p50={:.2}ms p95={:.2}ms p99={:.2}ms",
            self.requests,
            self.completed,
            self.rejected,
            self.shed,
            self.panicked,
            self.deadline_expired,
            self.restarts,
            self.batches,
            self.throughput_rps,
            self.mean_batch_fill,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_p99_ms
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_request();
        }
        m.record_rejected();
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        m.record_latency_ms(1.0);
        m.record_latency_ms(3.0);
        m.record_panicked();
        m.record_deadline();
        m.record_restart();
        let s = m.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 2);
        assert_eq!(s.shed, 0);
        assert_eq!(s.panicked, 1);
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.restarts, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_fill - 0.875).abs() < 1e-9);
        assert!(s.latency_p50_ms >= 1.0 && s.latency_p50_ms <= 3.0);
        assert!(s.throughput_rps > 0.0);
        // Histogram: one observation at <=1 ms, one at <=5 ms.
        assert_eq!(s.latency_hist.len(), LATENCY_BUCKETS_MS.len() + 1);
        assert_eq!(s.latency_hist.iter().sum::<u64>(), 2);
        let le_1 = LATENCY_BUCKETS_MS.iter().position(|&b| b == 1.0).unwrap();
        let le_5 = LATENCY_BUCKETS_MS.iter().position(|&b| b == 2.5).unwrap() + 1;
        assert_eq!(s.latency_hist[le_1], 1);
        assert_eq!(s.latency_hist[le_5], 1);
        // Bucket-estimated quantiles track the exact ones to within a
        // bucket width.
        assert!(s.latency_hist_p50_ms >= 0.5 && s.latency_hist_p50_ms <= 5.0);
        assert!(s.latency_hist_p99_ms <= 5.0);
    }

    #[test]
    fn latency_histogram_serializes() {
        let m = Metrics::new();
        m.record_latency_ms(0.2);
        m.record_latency_ms(5000.0); // overflow bucket
        let s = m.snapshot();
        assert_eq!(*s.latency_hist.last().unwrap(), 1);
        let json = s.to_json().to_string();
        assert!(json.contains("latency_bucket_counts"));
        assert!(json.contains("latency_bucket_le_ms"));
        assert!(json.contains("latency_hist_p99_ms"));
        assert!(json.contains("\"shed\""));
        assert!(json.contains("\"panicked\""));
        assert!(json.contains("\"deadline_expired\""));
        assert!(json.contains("\"restarts\""));
    }

    #[test]
    fn snapshot_renders_and_serializes() {
        let m = Metrics::new();
        m.record_request();
        let s = m.snapshot();
        assert!(s.render().contains("requests=1"));
        assert!(s.to_json().to_string().contains("\"requests\""));
    }

    /// The satellite audit's regression test: outcome counters must
    /// never be observed ahead of their request increments, and totals
    /// must reconcile exactly at quiescence. Writers follow the service
    /// protocol (request first, then exactly one outcome); concurrent
    /// snapshotters assert the invariant the read ordering guarantees.
    ///
    /// Regression note (ISSUE 9): `service.rs` once read its `stopping`
    /// lifecycle flag with `Ordering::Relaxed` while the cluster used
    /// Acquire/Release for the same role. Lifecycle and counter flags
    /// must all use the Release-store/Acquire-load protocol this test
    /// hammers — `xtask lint` now rejects any `Ordering::Relaxed` in
    /// `rust/src` without an explicit `relaxed-ok` allowlist marker,
    /// and `rust/tests/loom_models.rs` model-checks the read-order
    /// invariant exhaustively at small thread counts.
    #[test]
    fn concurrent_counters_reconcile() {
        const WRITERS: u64 = 4;
        const PER_WRITER: u64 = 2_000;
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for t in 0..WRITERS {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    m.record_request();
                    match (i + t) % 8 {
                        0 => m.record_rejected(),
                        1 => m.record_panicked(),
                        2 => m.record_deadline(),
                        _ => m.record_latency_ms(0.5),
                    }
                }
            }));
        }
        for _ in 0..2 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..300 {
                    let s = m.snapshot();
                    let outcomes = s.completed + s.rejected + s.panicked + s.deadline_expired;
                    assert!(
                        outcomes <= s.requests,
                        "torn snapshot: outcomes={} > requests={}",
                        outcomes,
                        s.requests
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, WRITERS * PER_WRITER);
        assert_eq!(s.completed + s.rejected + s.panicked + s.deadline_expired, s.requests);
        // Every completion left exactly one histogram observation.
        assert_eq!(s.latency_hist.iter().sum::<u64>(), s.completed);
    }
}
