//! Service metrics: request/batch counters, latency percentiles,
//! throughput — the observability layer of the hashing service.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::{Histogram, Online, Reservoir};

/// Upper bounds (milliseconds) of the per-request latency histogram —
/// log-ish spacing from service-local microseconds to multi-second
/// outliers; the final implicit bucket is overflow.
pub const LATENCY_BUCKETS_MS: [f64; 12] =
    [0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 1000.0];

#[derive(Debug)]
struct Inner {
    started: Instant,
    requests: u64,
    rejected: u64,
    batches: u64,
    batch_fill: Online,
    latency_ms: Reservoir,
    /// Bucketed latency distribution: O(1) memory for long-lived
    /// services (the reservoir's exact percentiles keep working; the
    /// histogram is what gets exported/scraped).
    latency_hist: Histogram,
    queue_wait_ms: Reservoir,
}

/// Thread-safe metrics sink shared by the service and its workers.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner {
                started: Instant::now(),
                requests: 0,
                rejected: 0,
                batches: 0,
                batch_fill: Online::new(),
                latency_ms: Reservoir::new(),
                latency_hist: Histogram::new(&LATENCY_BUCKETS_MS),
                queue_wait_ms: Reservoir::new(),
            }),
        }
    }

    pub fn record_request(&self) {
        self.inner.lock().unwrap().requests += 1;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// `fill` is the fraction of the batch capacity actually used.
    pub fn record_batch(&self, size: usize, capacity: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_fill.push(size as f64 / capacity.max(1) as f64);
    }

    pub fn record_latency_ms(&self, ms: f64) {
        let mut m = self.inner.lock().unwrap();
        m.latency_ms.push(ms);
        m.latency_hist.push(ms);
    }

    pub fn record_queue_wait_ms(&self, ms: f64) {
        self.inner.lock().unwrap().queue_wait_ms.push(ms);
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut m = self.inner.lock().unwrap();
        let elapsed = m.started.elapsed().as_secs_f64();
        Snapshot {
            requests: m.requests,
            rejected: m.rejected,
            batches: m.batches,
            elapsed_s: elapsed,
            throughput_rps: if elapsed > 0.0 { m.requests as f64 / elapsed } else { 0.0 },
            mean_batch_fill: m.batch_fill.mean(),
            latency_p50_ms: m.latency_ms.percentile(50.0),
            latency_p95_ms: m.latency_ms.percentile(95.0),
            latency_p99_ms: m.latency_ms.percentile(99.0),
            latency_hist: m.latency_hist.counts().to_vec(),
            queue_wait_p50_ms: m.queue_wait_ms.percentile(50.0),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub rejected: u64,
    pub batches: u64,
    pub elapsed_s: f64,
    pub throughput_rps: f64,
    pub mean_batch_fill: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    /// Latency bucket counts over [`LATENCY_BUCKETS_MS`] (last slot =
    /// overflow).
    pub latency_hist: Vec<u64>,
    pub queue_wait_p50_ms: f64,
}

impl Snapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("requests", self.requests)
            .set("rejected", self.rejected)
            .set("batches", self.batches)
            .set("elapsed_s", self.elapsed_s)
            .set("throughput_rps", self.throughput_rps)
            .set("mean_batch_fill", self.mean_batch_fill)
            .set("latency_p50_ms", self.latency_p50_ms)
            .set("latency_p95_ms", self.latency_p95_ms)
            .set("latency_p99_ms", self.latency_p99_ms);
        j.set(
            "latency_bucket_le_ms",
            crate::util::json::Json::Arr(
                LATENCY_BUCKETS_MS.iter().map(|&b| crate::util::json::Json::Num(b)).collect(),
            ),
        );
        j.set(
            "latency_bucket_counts",
            crate::util::json::Json::Arr(
                self.latency_hist.iter().map(|&c| crate::util::json::Json::Num(c as f64)).collect(),
            ),
        );
        j
    }

    pub fn render(&self) -> String {
        format!(
            "requests={} rejected={} batches={} rps={:.1} fill={:.2} p50={:.2}ms p95={:.2}ms p99={:.2}ms",
            self.requests,
            self.rejected,
            self.batches,
            self.throughput_rps,
            self.mean_batch_fill,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_p99_ms
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_request();
        }
        m.record_rejected();
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        m.record_latency_ms(1.0);
        m.record_latency_ms(3.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_fill - 0.875).abs() < 1e-9);
        assert!(s.latency_p50_ms >= 1.0 && s.latency_p50_ms <= 3.0);
        assert!(s.throughput_rps > 0.0);
        // Histogram: one observation at <=1 ms, one at <=5 ms.
        assert_eq!(s.latency_hist.len(), LATENCY_BUCKETS_MS.len() + 1);
        assert_eq!(s.latency_hist.iter().sum::<u64>(), 2);
        let le_1 = LATENCY_BUCKETS_MS.iter().position(|&b| b == 1.0).unwrap();
        let le_5 = LATENCY_BUCKETS_MS.iter().position(|&b| b == 2.5).unwrap() + 1;
        assert_eq!(s.latency_hist[le_1], 1);
        assert_eq!(s.latency_hist[le_5], 1);
    }

    #[test]
    fn latency_histogram_serializes() {
        let m = Metrics::new();
        m.record_latency_ms(0.2);
        m.record_latency_ms(5000.0); // overflow bucket
        let s = m.snapshot();
        assert_eq!(*s.latency_hist.last().unwrap(), 1);
        let json = s.to_json().to_string();
        assert!(json.contains("latency_bucket_counts"));
        assert!(json.contains("latency_bucket_le_ms"));
    }

    #[test]
    fn snapshot_renders_and_serializes() {
        let m = Metrics::new();
        m.record_request();
        let s = m.snapshot();
        assert!(s.render().contains("requests=1"));
        assert!(s.to_json().to_string().contains("\"requests\""));
    }
}
