//! Layer 3: the coordinator — the deployable system around the paper's
//! algorithm.
//!
//! * [`backend`] — the open [`SketcherBackend`] factory trait that
//!   replaced the closed `Backend` enum: [`NativeBackend`],
//!   [`PjrtBackend`], or any closure/custom impl building a
//!   `Box<dyn Sketcher>` on the worker thread.
//! * [`service`] — the online hashing/scoring service: bounded-queue
//!   submission (backpressure), dynamic batching (size/deadline),
//!   backend-agnostic hashing OR fused `serve::Scorer` classification
//!   (score mode), per-request latency metrics + histogram.
//! * [`router`] — least-loaded routing over replicated services (hash
//!   or score mode).
//! * [`cluster`] — the sharded serving cluster: N workers behind
//!   bounded MPMC queues with work stealing, watermark load-shedding,
//!   atomic model hot-swap (versioned `Arc` publish), and per-shard
//!   metrics merged into a cluster snapshot. Two service modes over
//!   the same machinery: `score` ([`ScoreRouter`], fused linear
//!   classification) and `query` ([`QueryRouter`], sub-linear top-k
//!   retrieval against a shared `PackedLshIndex`). Workers are
//!   panic-isolated and supervised: request panics come back as typed
//!   errors, dead workers are respawned, deadlines bound queueing, and
//!   batch clients retry under a seeded backoff [`RetryPolicy`].
//! * [`faults`] — the seeded fault-injection harness
//!   ([`FaultPlan`]) the chaos tests and resilience benches drive;
//!   env-activation is compiled out of release builds.
//! * [`pipeline`] — the offline batch pipeline: hash a dataset, encode
//!   0-bit CWS one-hot codes (`features::CodeMatrix`, with CSR export
//!   for IO), train/evaluate the linear model, and export weights in
//!   the layout the `hash_score` AOT serving artifact consumes. (The
//!   composable object API is [`crate::pipeline`].)
//! * [`metrics`] — shared observability.
//! * `queue` (doc-hidden) — the generic MPMC shard-queue + hot-swap
//!   primitives both cluster modes are built from, exposed so the loom
//!   models in `rust/tests/loom_models.rs` can explore the production
//!   implementation directly. Not a supported API surface.

pub mod backend;
pub mod cluster;
pub mod faults;
pub mod metrics;
pub mod pipeline;
#[doc(hidden)]
pub mod queue;
pub mod router;
pub mod service;

pub use backend::{NativeBackend, PjrtBackend, PjrtSketcher, SketcherBackend};
pub use cluster::{
    ClusterConfig, ClusterError, ClusterQueryResponse, ClusterScoreResponse, ClusterSnapshot,
    QueryRouter, RetryPolicy, ScoreRouter, Submitted, SubmittedQuery,
};
pub use faults::{silence_injected_panics, FaultPlan, INJECTED};
pub use metrics::{Metrics, Snapshot, LATENCY_BUCKETS_MS};
pub use pipeline::{
    export_scorer_slab, export_scorer_weights, hash_dataset, hash_matrix_native,
    hashed_linear_accuracy, hashed_linear_sweep, sketch_matrix, HashedDataset, PipelineConfig,
};
pub use router::{Routed, RoutedResponse, RoutedScore, Router};
pub use service::{HashResponse, HashService, ScoreResponse, ServiceConfig, SubmitError};
