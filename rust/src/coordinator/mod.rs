//! Layer 3: the coordinator — the deployable system around the paper's
//! algorithm.
//!
//! * [`service`] — the online hashing service: bounded-queue submission
//!   (backpressure), dynamic batching (size/deadline), native or PJRT
//!   execution, per-request latency metrics.
//! * [`pipeline`] — the offline batch pipeline: hash a dataset, expand
//!   0-bit CWS one-hot features, train/evaluate the linear model, and
//!   export weights in the layout the `hash_score` AOT serving artifact
//!   consumes.
//! * [`metrics`] — shared observability.

pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod service;

pub use metrics::{Metrics, Snapshot};
pub use pipeline::{
    export_scorer_weights, hash_dataset, hashed_linear_accuracy, hashed_linear_sweep,
    HashedDataset, PipelineConfig,
};
pub use router::{RoutedResponse, Router};
pub use service::{Backend, HashResponse, HashService, ServiceConfig, SubmitError};
