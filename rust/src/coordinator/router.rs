//! Request router over multiple hashing-service replicas — the vLLM-
//! router-shaped front door for multi-worker deployments. On this
//! single-core container it exists for correctness (and because the L3
//! contribution of a serving stack *is* this layer); on real hardware
//! each replica owns a core / PJRT device.
//!
//! Routing policy: least-outstanding-requests with round-robin
//! tie-breaking; full replicas are skipped; if every queue is full the
//! submit fails fast with backpressure, preserving the per-replica
//! semantics. The same policy fronts the sharded cluster modes —
//! `score` ([`super::cluster::ScoreRouter`]) and `query`
//! ([`super::cluster::QueryRouter`]) — via `pick_least_deep` over
//! queue depths instead of outstanding counts.

use std::time::Duration;

use crate::serve::Scorer;
use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::mpsc;

use crate::util::stats::Histogram;

use super::backend::SketcherBackend;
use super::metrics::{Snapshot, LATENCY_BUCKETS_MS};
use super::service::{HashResponse, HashService, ScoreResponse, ServiceConfig, SubmitError};

pub struct Router {
    replicas: Vec<HashService>,
    outstanding: Vec<AtomicUsize>,
    rr: AtomicU64,
}

impl Router {
    /// Spawn `n` replicas of the same service configuration; the factory
    /// is called with each replica index (heterogeneous fleets — e.g.
    /// one PJRT replica per device plus native spill — are one closure
    /// away). Replica i uses the SAME hashing seed: replicas must be
    /// interchangeable.
    pub fn start<B: SketcherBackend>(
        n: usize,
        cfg: ServiceConfig,
        backend: impl Fn(usize) -> B,
    ) -> Result<Router, String> {
        assert!(n > 0);
        let replicas: Vec<HashService> = (0..n)
            .map(|i| {
                HashService::start(cfg.clone(), backend(i))
                    .map_err(|e| format!("replica {i}: {e}"))
            })
            .collect::<Result<_, String>>()?;
        let outstanding = (0..n).map(|_| AtomicUsize::new(0)).collect();
        Ok(Router { replicas, outstanding, rr: AtomicU64::new(0) })
    }

    /// Spawn `n` **score-mode** replicas, each owning a clone of the
    /// fused scorer (its parameter and weight slabs) — the
    /// classification front door: `score_blocking` returns decisions +
    /// label. Clones are bit-identical, so replicas stay
    /// interchangeable.
    pub fn start_scoring(n: usize, cfg: ServiceConfig, scorer: Scorer) -> Result<Router, String> {
        assert!(n > 0);
        let mut replicas = Vec::with_capacity(n);
        for i in 0..n - 1 {
            replicas.push(
                HashService::start_scoring(cfg.clone(), scorer.clone())
                    .map_err(|e| format!("replica {i}: {e}"))?,
            );
        }
        replicas.push(
            HashService::start_scoring(cfg, scorer)
                .map_err(|e| format!("replica {}: {e}", n - 1))?,
        );
        let outstanding = (0..n).map(|_| AtomicUsize::new(0)).collect();
        Ok(Router { replicas, outstanding, rr: AtomicU64::new(0) })
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// `Some(n_classes)` when the replicas are score-mode services.
    pub fn n_classes(&self) -> Option<usize> {
        self.replicas[0].n_classes()
    }

    /// Pick the replica with the fewest outstanding requests (ties by
    /// rotating round-robin start so load spreads under uniform traffic).
    fn pick(&self) -> usize {
        let n = self.replicas.len();
        // relaxed-ok: rotating tie-break hint — any counter value
        // yields a valid start replica; no data is synchronized.
        let start = (self.rr.fetch_add(1, Ordering::Relaxed) as usize) % n;
        let mut best = start;
        let mut best_load = usize::MAX;
        for off in 0..n {
            let i = (start + off) % n;
            // relaxed-ok: load estimate for routing only — a stale
            // read routes slightly unevenly, never incorrectly.
            let load = self.outstanding[i].load(Ordering::Relaxed);
            if load < best_load {
                best_load = load;
                best = i;
            }
        }
        best
    }

    /// The one routing body: try the least-loaded pick, then fail over
    /// the rest; only a fully-full fleet rejects. The outstanding
    /// counter for the accepting replica is incremented here and
    /// decremented by [`Routed::wait`].
    fn route<R>(
        &self,
        try_submit: impl Fn(
            &HashService,
        ) -> Result<mpsc::Receiver<Result<R, SubmitError>>, SubmitError>,
    ) -> Result<Routed<'_, R>, SubmitError> {
        let n = self.replicas.len();
        let first = self.pick();
        for off in 0..n {
            let i = (first + off) % n;
            match try_submit(&self.replicas[i]) {
                Ok(rx) => {
                    // relaxed-ok: outstanding-count routing hint; the
                    // matching decrement is in `Routed::wait`.
                    self.outstanding[i].fetch_add(1, Ordering::Relaxed);
                    return Ok(Routed { router: self, replica: i, rx });
                }
                Err(SubmitError::QueueFull) => continue,
                Err(e) => return Err(e),
            }
        }
        Err(SubmitError::QueueFull)
    }

    /// Route one hashing request. Borrows the vector: an owned copy is
    /// made only per submit attempt.
    pub fn submit(&self, id: u64, vector: &[f32]) -> Result<RoutedResponse<'_>, SubmitError> {
        self.route(|svc| svc.submit(id, vector.to_vec()))
    }

    /// Route one scoring request (score-mode routers only) — same
    /// least-loaded policy and failover as [`Router::submit`].
    pub fn submit_score(&self, id: u64, vector: &[f32]) -> Result<RoutedScore<'_>, SubmitError> {
        self.route(|svc| svc.submit_score(id, vector))
    }

    pub fn hash_blocking(&self, id: u64, vector: &[f32]) -> Result<HashResponse, SubmitError> {
        let routed = self.submit(id, vector)?;
        routed.wait()
    }

    /// Blocking scoring through the router: decisions + argmax label.
    pub fn score_blocking(&self, id: u64, vector: &[f32]) -> Result<ScoreResponse, SubmitError> {
        let routed = self.submit_score(id, vector)?;
        routed.wait()
    }

    /// Blocking classification through the router: label only.
    pub fn classify_blocking(&self, id: u64, vector: &[f32]) -> Result<i32, SubmitError> {
        Ok(self.score_blocking(id, vector)?.label)
    }

    /// Aggregate metrics across replicas.
    pub fn snapshot(&self) -> Vec<Snapshot> {
        self.replicas.iter().map(|r| r.metrics().snapshot()).collect()
    }

    pub fn total_requests(&self) -> u64 {
        self.snapshot().iter().map(|s| s.requests).sum()
    }

    /// Fleet-wide latency quantile estimates `(p50, p90, p99)` in
    /// milliseconds: per-replica histogram exports merged bucket-wise,
    /// then estimated — the aggregation exact reservoir percentiles
    /// cannot do across replicas without shipping every sample.
    pub fn latency_quantiles_ms(&self) -> (f64, f64, f64) {
        let mut merged = Histogram::new(&LATENCY_BUCKETS_MS);
        for s in self.snapshot() {
            merged.merge(&Histogram::with_counts(&LATENCY_BUCKETS_MS, s.latency_hist));
        }
        (merged.quantile(50.0), merged.quantile(90.0), merged.quantile(99.0))
    }

    /// Shut every replica down gracefully — each drains and answers
    /// its accepted requests before its worker exits (see
    /// [`HashService::shutdown`]).
    pub fn shutdown(self) {
        for r in self.replicas {
            r.shutdown();
        }
    }
}

/// A response handle that keeps the router's load accounting correct:
/// one type for both response kinds — [`RoutedResponse`] (hash) and
/// [`RoutedScore`] (score) are aliases.
pub struct Routed<'r, R> {
    router: &'r Router,
    replica: usize,
    rx: mpsc::Receiver<Result<R, SubmitError>>,
}

/// Hash-mode response handle.
pub type RoutedResponse<'r> = Routed<'r, HashResponse>;

/// Score-mode response handle.
pub type RoutedScore<'r> = Routed<'r, ScoreResponse>;

impl<'r, R> Routed<'r, R> {
    pub fn replica(&self) -> usize {
        self.replica
    }

    pub fn wait(self) -> Result<R, SubmitError> {
        let res = self.rx.recv().map_err(|_| SubmitError::ShuttingDown);
        // relaxed-ok: outstanding-count routing hint (pairs with the
        // increment in `route`); staleness only skews load spreading.
        self.router.outstanding[self.replica].fetch_sub(1, Ordering::Relaxed);
        // A worker panic arrives as an `Err(WorkerPanicked)` payload —
        // one typed response per accepted request, even for poison.
        res?
    }

    /// Bounded wait: like [`Routed::wait`] but gives up after
    /// `timeout` with [`SubmitError::WaitTimeout`]. On timeout the
    /// request is still in flight — the handle stays usable (`&self`)
    /// and the replica's outstanding count is only decremented once a
    /// response (or disconnection) is actually observed, keeping the
    /// router's load accounting truthful about the straggler.
    pub fn wait_timeout(&self, timeout: Duration) -> Result<R, SubmitError> {
        match self.rx.recv_timeout(timeout) {
            Ok(inner) => {
                // relaxed-ok: routing hint, pairs with `route`.
                self.router.outstanding[self.replica].fetch_sub(1, Ordering::Relaxed);
                inner
            }
            Err(mpsc::RecvTimeoutError::Timeout) => Err(SubmitError::WaitTimeout),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.router.outstanding[self.replica].fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::ShuttingDown)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::cws::CwsHasher;
    use std::time::Duration;

    fn cfg() -> ServiceConfig {
        ServiceConfig {
            seed: 11,
            k: 8,
            dim: 16,
            max_batch: 4,
            max_wait: Duration::from_micros(300),
            queue_cap: 64,
        }
    }

    #[test]
    fn replicas_are_interchangeable() {
        let router = Router::start(3, cfg(), |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let want = CwsHasher::new(11, 8).hash_dense(&v);
        for i in 0..30 {
            let resp = router.hash_blocking(i, &v).unwrap();
            assert_eq!(resp.samples, want, "request {i}");
        }
        assert_eq!(router.total_requests(), 30);
        assert!(router.n_classes().is_none());
        router.shutdown();
    }

    #[test]
    fn scoring_replicas_agree_with_direct_scorer() {
        use crate::data::synth::{generate, SynthConfig};
        use crate::prelude::Pipeline;
        let ds = generate("letter", SynthConfig { seed: 6, n_train: 90, n_test: 30 }).unwrap();
        let scfg = ServiceConfig { seed: 3, k: 16, dim: 16, ..cfg() };
        let mut pipe = Pipeline::builder().seed(3).samples(16).i_bits(4).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let scorer = pipe.scorer(16).unwrap();
        let direct = scorer.clone();
        let router = Router::start_scoring(2, scfg, scorer).unwrap();
        assert_eq!(router.n_classes(), Some(direct.n_classes()));
        let test = ds.test_x.to_dense();
        let mut scratch = direct.scratch();
        for i in 0..test.rows() {
            let resp = router.score_blocking(i as u64, test.row(i)).unwrap();
            assert_eq!(resp.label, direct.predict_dense(test.row(i), &mut scratch), "row {i}");
            assert_eq!(resp.decisions.len(), direct.n_classes());
            assert_eq!(
                router.classify_blocking(1000 + i as u64, test.row(i)).unwrap(),
                resp.label
            );
        }
        assert!(router.total_requests() >= 2 * test.rows() as u64);
        router.shutdown();
    }

    #[test]
    fn load_spreads_across_replicas() {
        let router = Router::start(4, cfg(), |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        // Submit a burst without waiting, then collect.
        let mut handles = Vec::new();
        for i in 0..40 {
            handles.push(router.submit(i, &v).unwrap());
        }
        let mut used = [0usize; 4];
        for h in handles {
            used[h.replica()] += 1;
            h.wait().unwrap();
        }
        // Every replica sees some work under round-robin + least-loaded.
        assert!(used.iter().all(|&u| u > 0), "replica usage {used:?}");
        router.shutdown();
    }

    #[test]
    fn failover_on_full_queue() {
        // Tiny queues: the router must keep accepting while ANY replica
        // has room, and fail fast only when all are full.
        let small = ServiceConfig { queue_cap: 1, max_batch: 1, ..cfg() };
        let router = Router::start(2, small, |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let mut accepted = 0;
        let mut rejected = 0;
        let mut handles = Vec::new();
        for i in 0..50 {
            match router.submit(i, &v) {
                Ok(h) => {
                    accepted += 1;
                    handles.push(h);
                }
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(accepted > 0);
        for h in handles {
            h.wait().unwrap();
        }
        // Whether rejections occur depends on timing; the invariant is
        // that accepted + rejected == 50 and nothing is lost.
        assert_eq!(accepted + rejected, 50);
        router.shutdown();
    }

    #[test]
    fn wait_timeout_bounds_the_client_and_keeps_the_response() {
        // A lone request sits in the batcher for max_wait before the
        // flush: a shorter wait_timeout must return WaitTimeout, and
        // the response must still be receivable afterwards.
        let slow_batcher = ServiceConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(200),
            ..cfg()
        };
        let router = Router::start(1, slow_batcher, |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let h = router.submit(0, &v).unwrap();
        assert!(matches!(h.wait_timeout(Duration::from_millis(5)), Err(SubmitError::WaitTimeout)));
        // The request was not cancelled: a patient wait still gets it.
        let resp = h.wait_timeout(Duration::from_secs(10)).expect("response after timeout");
        assert_eq!(resp.id, 0);
        router.shutdown();
    }

    #[test]
    fn snapshot_aggregates() {
        let router = Router::start(2, cfg(), |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        for i in 0..10 {
            router.hash_blocking(i, &v).unwrap();
        }
        let snaps = router.snapshot();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps.iter().map(|s| s.requests).sum::<u64>(), 10);
        // Fleet-wide histogram-estimated quantiles are finite and
        // ordered once any replica has completions.
        let (p50, p90, p99) = router.latency_quantiles_ms();
        assert!(p50.is_finite() && p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        router.shutdown();
    }
}
