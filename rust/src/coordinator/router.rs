//! Request router over multiple hashing-service replicas — the vLLM-
//! router-shaped front door for multi-worker deployments. On this
//! single-core container it exists for correctness (and because the L3
//! contribution of a serving stack *is* this layer); on real hardware
//! each replica owns a core / PJRT device.
//!
//! Routing policy: least-outstanding-requests with round-robin
//! tie-breaking; full replicas are skipped; if every queue is full the
//! submit fails fast with backpressure, preserving the per-replica
//! semantics.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;

use super::backend::SketcherBackend;
use super::metrics::Snapshot;
use super::service::{HashResponse, HashService, ServiceConfig, SubmitError};

pub struct Router {
    replicas: Vec<HashService>,
    outstanding: Vec<AtomicUsize>,
    rr: AtomicU64,
}

impl Router {
    /// Spawn `n` replicas of the same service configuration; the factory
    /// is called with each replica index (heterogeneous fleets — e.g.
    /// one PJRT replica per device plus native spill — are one closure
    /// away). Replica i uses the SAME hashing seed: replicas must be
    /// interchangeable.
    pub fn start<B: SketcherBackend>(
        n: usize,
        cfg: ServiceConfig,
        backend: impl Fn(usize) -> B,
    ) -> Result<Router, String> {
        assert!(n > 0);
        let replicas: Vec<HashService> = (0..n)
            .map(|i| {
                HashService::start(cfg.clone(), backend(i))
                    .map_err(|e| format!("replica {i}: {e}"))
            })
            .collect::<Result<_, String>>()?;
        let outstanding = (0..n).map(|_| AtomicUsize::new(0)).collect();
        Ok(Router { replicas, outstanding, rr: AtomicU64::new(0) })
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Pick the replica with the fewest outstanding requests (ties by
    /// rotating round-robin start so load spreads under uniform traffic).
    fn pick(&self) -> usize {
        let n = self.replicas.len();
        let start = (self.rr.fetch_add(1, Ordering::Relaxed) as usize) % n;
        let mut best = start;
        let mut best_load = usize::MAX;
        for off in 0..n {
            let i = (start + off) % n;
            let load = self.outstanding[i].load(Ordering::Relaxed);
            if load < best_load {
                best_load = load;
                best = i;
            }
        }
        best
    }

    /// Route one request. The outstanding counter for the chosen replica
    /// is decremented when the response is received (wrapped receiver).
    pub fn submit(
        &self,
        id: u64,
        vector: Vec<f32>,
    ) -> Result<RoutedResponse<'_>, SubmitError> {
        let n = self.replicas.len();
        let first = self.pick();
        // Try the least-loaded pick, then fall over the rest.
        for off in 0..n {
            let i = (first + off) % n;
            match self.replicas[i].submit(id, vector.clone()) {
                Ok(rx) => {
                    self.outstanding[i].fetch_add(1, Ordering::Relaxed);
                    return Ok(RoutedResponse { router: self, replica: i, rx });
                }
                Err(SubmitError::QueueFull) => continue,
                Err(e) => return Err(e),
            }
        }
        Err(SubmitError::QueueFull)
    }

    pub fn hash_blocking(&self, id: u64, vector: Vec<f32>) -> Result<HashResponse, SubmitError> {
        let routed = self.submit(id, vector)?;
        routed.wait()
    }

    /// Aggregate metrics across replicas.
    pub fn snapshot(&self) -> Vec<Snapshot> {
        self.replicas.iter().map(|r| r.metrics().snapshot()).collect()
    }

    pub fn total_requests(&self) -> u64 {
        self.snapshot().iter().map(|s| s.requests).sum()
    }

    pub fn shutdown(self) {
        for r in self.replicas {
            r.shutdown();
        }
    }
}

/// A response handle that keeps the router's load accounting correct.
pub struct RoutedResponse<'r> {
    router: &'r Router,
    replica: usize,
    rx: mpsc::Receiver<HashResponse>,
}

impl<'r> RoutedResponse<'r> {
    pub fn replica(&self) -> usize {
        self.replica
    }

    pub fn wait(self) -> Result<HashResponse, SubmitError> {
        let res = self.rx.recv().map_err(|_| SubmitError::ShuttingDown);
        self.router.outstanding[self.replica].fetch_sub(1, Ordering::Relaxed);
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::cws::CwsHasher;
    use std::time::Duration;

    fn cfg() -> ServiceConfig {
        ServiceConfig {
            seed: 11,
            k: 8,
            dim: 16,
            max_batch: 4,
            max_wait: Duration::from_micros(300),
            queue_cap: 64,
        }
    }

    #[test]
    fn replicas_are_interchangeable() {
        let router = Router::start(3, cfg(), |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let want = CwsHasher::new(11, 8).hash_dense(&v);
        for i in 0..30 {
            let resp = router.hash_blocking(i, v.clone()).unwrap();
            assert_eq!(resp.samples, want, "request {i}");
        }
        assert_eq!(router.total_requests(), 30);
        router.shutdown();
    }

    #[test]
    fn load_spreads_across_replicas() {
        let router = Router::start(4, cfg(), |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        // Submit a burst without waiting, then collect.
        let mut handles = Vec::new();
        for i in 0..40 {
            handles.push(router.submit(i, v.clone()).unwrap());
        }
        let mut used = [0usize; 4];
        for h in handles {
            used[h.replica()] += 1;
            h.wait().unwrap();
        }
        // Every replica sees some work under round-robin + least-loaded.
        assert!(used.iter().all(|&u| u > 0), "replica usage {used:?}");
        router.shutdown();
    }

    #[test]
    fn failover_on_full_queue() {
        // Tiny queues: the router must keep accepting while ANY replica
        // has room, and fail fast only when all are full.
        let small = ServiceConfig { queue_cap: 1, max_batch: 1, ..cfg() };
        let router = Router::start(2, small, |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let mut accepted = 0;
        let mut rejected = 0;
        let mut handles = Vec::new();
        for i in 0..50 {
            match router.submit(i, v.clone()) {
                Ok(h) => {
                    accepted += 1;
                    handles.push(h);
                }
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(accepted > 0);
        for h in handles {
            h.wait().unwrap();
        }
        // Whether rejections occur depends on timing; the invariant is
        // that accepted + rejected == 50 and nothing is lost.
        assert_eq!(accepted + rejected, 50);
        router.shutdown();
    }

    #[test]
    fn snapshot_aggregates() {
        let router = Router::start(2, cfg(), |_| NativeBackend).unwrap();
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        for i in 0..10 {
            router.hash_blocking(i, v.clone()).unwrap();
        }
        let snaps = router.snapshot();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps.iter().map(|s| s.requests).sum::<u64>(), 10);
        router.shutdown();
    }
}
