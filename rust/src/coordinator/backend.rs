//! Service backends: how a [`super::service::HashService`] obtains the
//! [`Sketcher`] its worker thread runs.
//!
//! The old design was a closed `Backend` enum the worker matched on;
//! this is the open replacement. A [`SketcherBackend`] is a **factory**
//! shipped into the worker thread (factories are `Send`; the sketchers
//! they build need not be — the PJRT client is thread-bound, and the
//! worker exclusively owns whatever it constructs). Third-party
//! backends plug in without touching the coordinator: implement the
//! trait, or just pass a closure
//! `|cfg: &ServiceConfig| -> Result<Box<dyn Sketcher>, String>`.
//!
//! The two built-in impls mirror the old enum variants:
//!
//! * [`NativeBackend`] — rust-native ICWS with the `(r, c, β)` grid
//!   materialized once per service (any D, any k);
//! * [`PjrtBackend`] — the AOT `cws_hash*` artifact on the PJRT CPU
//!   client, wrapped as [`PjrtSketcher`] (fixed B, D, K; same
//!   counter-based randomness as the native path).

use std::path::PathBuf;

use crate::cws::{materialize_params, CwsHasher, CwsSample};
use crate::runtime::{literal_f32, Engine, Literal};
use crate::sketch::Sketcher;

use super::service::ServiceConfig;

/// Factory for the sketcher a service worker thread will own. `build`
/// runs ON the worker thread, so non-`Send` sketchers (PJRT) are fine.
pub trait SketcherBackend: Send + 'static {
    /// Label for logs/metrics.
    fn label(&self) -> &'static str;

    /// Construct the sketcher for this service configuration.
    fn build(self: Box<Self>, cfg: &ServiceConfig) -> Result<Box<dyn Sketcher>, String>;
}

/// Boxed trait objects are backends too, so callers can pick one at
/// runtime: `let b: Box<dyn SketcherBackend> = …; HashService::start(cfg, b)`.
impl SketcherBackend for Box<dyn SketcherBackend> {
    fn label(&self) -> &'static str {
        (**self).label()
    }

    fn build(self: Box<Self>, cfg: &ServiceConfig) -> Result<Box<dyn Sketcher>, String> {
        (*self).build(cfg)
    }
}

/// Closures are backends: `HashService::start(cfg, |cfg| … )`.
impl<F> SketcherBackend for F
where
    F: FnOnce(&ServiceConfig) -> Result<Box<dyn Sketcher>, String> + Send + 'static,
{
    fn label(&self) -> &'static str {
        "custom"
    }

    fn build(self: Box<Self>, cfg: &ServiceConfig) -> Result<Box<dyn Sketcher>, String> {
        (*self)(cfg)
    }
}

/// Rust-native ICWS: amortizes `(r, c, β)` materialization across the
/// whole service lifetime (identical output to per-row hashing). The
/// built sketcher is a `DenseBatchHasher` facade over the
/// `cws::SketchEngine`, so the service's per-batch
/// `sketch_dense_batch` call shards rows across `MINMAX_THREADS`
/// scoped threads — identical output at any thread count.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeBackend;

impl SketcherBackend for NativeBackend {
    fn label(&self) -> &'static str {
        "native"
    }

    fn build(self: Box<Self>, cfg: &ServiceConfig) -> Result<Box<dyn Sketcher>, String> {
        Ok(Box::new(CwsHasher::new(cfg.seed, cfg.k).dense_batch(cfg.dim)))
    }
}

/// PJRT engine over `artifacts_dir`, running `artifact` (which fixes
/// B, D, K at AOT time; D and K must match the service config).
#[derive(Debug, Clone)]
pub struct PjrtBackend {
    pub artifacts_dir: PathBuf,
    pub artifact: String,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: impl Into<PathBuf>, artifact: impl Into<String>) -> Self {
        Self { artifacts_dir: artifacts_dir.into(), artifact: artifact.into() }
    }
}

impl SketcherBackend for PjrtBackend {
    fn label(&self) -> &'static str {
        "pjrt"
    }

    fn build(self: Box<Self>, cfg: &ServiceConfig) -> Result<Box<dyn Sketcher>, String> {
        let s = PjrtSketcher::load(&self.artifacts_dir, &self.artifact, cfg.seed)?;
        if s.dim() != cfg.dim {
            return Err(format!("artifact D {} != service dim {}", s.dim(), cfg.dim));
        }
        if Sketcher::k(&s) != cfg.k {
            return Err(format!("artifact K {} != service k {}", Sketcher::k(&s), cfg.k));
        }
        Ok(Box::new(s))
    }
}

/// The AOT `cws_hash` executable behind the [`Sketcher`] interface:
/// fixed-shape batches, parameters pre-materialized as device literals
/// from the SAME counter-based randomness as [`CwsHasher`] — so which
/// backend a deployment uses is a pure throughput/operational choice
/// (validated by `rust/tests/pipeline_integration.rs`).
///
/// NOT `Send` (the PJRT client is thread-bound); construct it on the
/// thread that will run it, normally via [`PjrtBackend`].
pub struct PjrtSketcher {
    engine: Engine,
    artifact: String,
    seed: u64,
    batch: usize,
    dim: usize,
    k: usize,
    params: (Literal, Literal, Literal),
}

impl PjrtSketcher {
    /// Compile (once) and bind `artifact` from `artifacts_dir`. Fails
    /// when artifacts are missing or the build lacks the `pjrt` feature.
    pub fn load(artifacts_dir: &std::path::Path, artifact: &str, seed: u64) -> Result<Self, String> {
        let engine = Engine::load_subset(artifacts_dir, &[artifact])
            .map_err(|e| format!("loading PJRT engine: {e}"))?;
        let spec = engine.spec(artifact)?.clone();
        let (batch, dim) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        let k = spec.inputs[1].shape[0];
        let (r, c, beta) = materialize_params(seed, dim, k);
        let params = (
            literal_f32(&r, &[k, dim])?,
            literal_f32(&c, &[k, dim])?,
            literal_f32(&beta, &[k, dim])?,
        );
        Ok(Self { engine, artifact: artifact.to_string(), seed, batch, dim, k, params })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The artifact's fixed batch size B (inputs are padded up to it).
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// One padded fixed-B execution over at most `batch_size()` rows.
    fn run_chunk(&self, chunk: &[&[f32]]) -> Vec<Vec<CwsSample>> {
        assert!(chunk.len() <= self.batch);
        let (b, d, k) = (self.batch, self.dim, self.k);
        // Pad the batch to the artifact's fixed B with a safe dummy row
        // (all ones).
        let mut x = vec![1.0f32; b * d];
        for (row, vec) in chunk.iter().enumerate() {
            assert_eq!(vec.len(), d, "dimension mismatch");
            x[row * d..(row + 1) * d].copy_from_slice(vec);
        }
        let xl = literal_f32(&x, &[b, d]).expect("input literal");
        let (rl, cl, bl) = &self.params;
        let outs = self
            .engine
            .run_decoded(&self.artifact, &[xl, rl.clone(), cl.clone(), bl.clone()])
            .expect("pjrt execute");
        let i_star = outs[0].as_i32().unwrap();
        let t_star = outs[1].as_i32().unwrap();
        chunk
            .iter()
            .enumerate()
            .map(|(row, _)| {
                (0..k)
                    .map(|j| CwsSample {
                        i_star: i_star[row * k + j] as u32,
                        t_star: t_star[row * k + j] as i64,
                    })
                    .collect()
            })
            .collect()
    }
}

impl Sketcher for PjrtSketcher {
    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn name(&self) -> &'static str {
        "icws-pjrt"
    }

    fn sketch_sparse(&self, row: crate::data::sparse::SparseRow<'_>) -> Vec<CwsSample> {
        assert!(row.nnz() > 0, "CWS is undefined on the all-zero vector");
        let mut dense = vec![0.0f32; self.dim];
        for (&i, &v) in row.indices.iter().zip(row.values) {
            dense[i as usize] = v;
        }
        self.sketch_dense(&dense)
    }

    fn sketch_dense(&self, u: &[f32]) -> Vec<CwsSample> {
        self.run_chunk(&[u]).pop().expect("one row in, one sample stream out")
    }

    fn sketch_dense_batch(&self, rows: &[&[f32]]) -> Vec<Vec<CwsSample>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.batch.max(1)) {
            out.extend(self.run_chunk(chunk));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::Sketcher;

    #[test]
    fn native_backend_builds_a_parity_sketcher() {
        if crate::cws::engine::fast_math_requested() {
            eprintln!("skipped: bit parity is only claimed without MINMAX_FAST_MATH");
            return;
        }
        let cfg = ServiceConfig { seed: 5, k: 12, dim: 9, ..Default::default() };
        let s = Box::new(NativeBackend).build(&cfg).unwrap();
        assert_eq!(s.k(), 12);
        assert_eq!(s.seed(), 5);
        let v: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        assert_eq!(s.sketch_dense(&v), CwsHasher::new(5, 12).hash_dense(&v));
    }

    #[test]
    fn closure_backend_works() {
        let cfg = ServiceConfig::default();
        let backend = |cfg: &ServiceConfig| -> Result<Box<dyn Sketcher>, String> {
            Ok(Box::new(CwsHasher::new(cfg.seed, cfg.k)))
        };
        let s = Box::new(backend).build(&cfg).unwrap();
        assert_eq!(s.name(), "icws");
        assert_eq!(s.k(), cfg.k);
    }

    #[test]
    fn pjrt_backend_fails_cleanly_without_artifacts() {
        let b = PjrtBackend::new("/nonexistent/artifacts", "cws_hash");
        let err = Box::new(b).build(&ServiceConfig::default()).unwrap_err();
        assert!(err.contains("PJRT") || err.contains("manifest") || err.contains("pjrt"), "{err}");
    }
}
