//! Offline batch-pipeline helpers: hash a whole dataset, train a linear
//! model in min-max space, evaluate — the batch counterpart of the
//! online [`super::service::HashService`], and the substrate the
//! experiment drivers (Figures 7–8) run on.
//!
//! The composable, object-shaped API over the same flow is
//! [`crate::pipeline::Pipeline`] (fit/transform/predict); these free
//! functions remain for drivers that sweep configurations and for the
//! offline→serving weight export.

use crate::cws::{CwsHasher, CwsSample};
use crate::data::{Csr, Dataset, Matrix};
use crate::features::{CodeMatrix, Expansion, ExpansionError};
use crate::serve::{ExportedWeights, SlabPrecision};
use crate::sketch::Sketcher;
use crate::svm::{linear_svm_accuracy, LinearSvmParams, RowSet};

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub seed: u64,
    pub k: usize,
    pub i_bits: u8,
    /// Figure 8's variant: also keep this many bits of t*.
    pub t_bits: u8,
}

impl PipelineConfig {
    pub fn new(seed: u64, k: usize, i_bits: u8) -> Self {
        Self { seed, k, i_bits, t_bits: 0 }
    }

    /// The validated feature expansion this configuration describes.
    pub fn expansion(&self) -> Result<Expansion, ExpansionError> {
        Expansion::checked(self.k, self.i_bits, self.t_bits)
    }
}

/// Hash every row of a matrix with any [`Sketcher`]; empty rows yield
/// `None`. (Kept as a free function for drivers; identical to calling
/// `sketcher.sketch_matrix(m)`.)
pub fn sketch_matrix(sketcher: &dyn Sketcher, m: &Matrix) -> Vec<Option<Vec<CwsSample>>> {
    sketcher.sketch_matrix(m)
}

/// Backward-compatible native hashing: ICWS with the `(r, c, β)` slabs
/// amortized across dense rows. Both arms land on the parallel
/// `SketchEngine` batch entry through the `Sketcher` overrides, so
/// whole-dataset hashing (Figures 7–8 drivers, `hash_dataset`) scales
/// with `MINMAX_THREADS`.
pub fn hash_matrix_native(m: &Matrix, seed: u64, k: usize) -> Vec<Option<Vec<CwsSample>>> {
    let hasher = CwsHasher::new(seed, k);
    match m {
        Matrix::Sparse(_) => hasher.sketch_matrix(m),
        // Amortize (r, c, β) materialization across all rows.
        Matrix::Dense(d) => hasher.dense_batch(d.cols()).sketch_matrix(m),
    }
}

/// The hashed features of one dataset split, in the one-hot
/// [`CodeMatrix`] representation the learning layer trains on directly
/// (`k` `u32` codes per row — no CSR scaffolding, no values array).
pub struct HashedDataset {
    pub train: CodeMatrix,
    pub test: CodeMatrix,
    pub expansion: Expansion,
}

impl HashedDataset {
    /// Train split in the legacy CSR representation (LIBSVM IO,
    /// CSR-consuming learners) — identical to what `Expansion::expand`
    /// builds for the same samples.
    pub fn train_csr(&self) -> Csr {
        self.train.to_csr()
    }

    /// Test split as CSR — see [`HashedDataset::train_csr`].
    pub fn test_csr(&self) -> Csr {
        self.test.to_csr()
    }
}

/// Hash train and test under one seed and encode the one-hot codes.
/// Invalid bit budgets surface as an error instead of a panic.
pub fn hash_dataset(ds: &Dataset, cfg: &PipelineConfig) -> Result<HashedDataset, ExpansionError> {
    let expansion = cfg.expansion()?;
    let train_samples = hash_matrix_native(&ds.train_x, cfg.seed, cfg.k);
    let test_samples = hash_matrix_native(&ds.test_x, cfg.seed, cfg.k);
    Ok(HashedDataset {
        train: expansion.encode(&train_samples),
        test: expansion.encode(&test_samples),
        expansion,
    })
}

/// Full §4 pipeline at one C: hash → expand → linear SVM → test accuracy.
/// Panics on an invalid bit budget — experiment drivers construct their
/// configs statically; request paths go through [`crate::pipeline`].
pub fn hashed_linear_accuracy(ds: &Dataset, cfg: &PipelineConfig, c: f64) -> f64 {
    let hashed = hash_dataset(ds, cfg).expect("invalid expansion config");
    linear_svm_accuracy(
        &hashed.train,
        &ds.train_y,
        &hashed.test,
        &ds.test_y,
        ds.n_classes(),
        c,
    )
}

/// Sweep C on pre-hashed features (hashing dominates cost; reuse it).
pub fn hashed_linear_sweep(ds: &Dataset, cfg: &PipelineConfig, cs: &[f64]) -> Vec<(f64, f64)> {
    let hashed = hash_dataset(ds, cfg).expect("invalid expansion config");
    cs.iter()
        .map(|&c| {
            (
                c,
                linear_svm_accuracy(
                    &hashed.train,
                    &ds.train_y,
                    &hashed.test,
                    &ds.test_y,
                    ds.n_classes(),
                    c,
                ),
            )
        })
        .collect()
}

/// Train the final hashed linear model and export its weights in the
/// `[K, 2^bits, C]` layout the `hash_score` AOT serving artifact
/// consumes — the bridge from offline training to PJRT serving. Takes
/// any [`RowSet`] training representation (the `hash_dataset` code
/// matrix by default; CSR via [`HashedDataset::train_csr`]).
pub fn export_scorer_weights<X: RowSet + ?Sized>(
    train: &X,
    train_y: &[i32],
    n_classes: usize,
    expansion: &Expansion,
    c: f64,
) -> Vec<f32> {
    match export_scorer_slab(train, train_y, n_classes, expansion, c, SlabPrecision::F32) {
        ExportedWeights::F32(w) => w,
        _ => unreachable!("an F32 export always carries an F32 slab"),
    }
}

/// Precision-parameterized counterpart of [`export_scorer_weights`]:
/// train the final hashed linear model and export its serving slab as
/// an [`ExportedWeights`] at `precision` (f64 master, f32, or gated
/// per-class affine int8 — see
/// `svm::LinearOvR::export_scorer_weights`). The bias is folded into
/// every code of slot 0 in all three variants, so the scorer built by
/// `serve::Scorer::from_exported_slab` needs no training structs.
pub fn export_scorer_slab<X: RowSet + ?Sized>(
    train: &X,
    train_y: &[i32],
    n_classes: usize,
    expansion: &Expansion,
    c: f64,
    precision: SlabPrecision,
) -> ExportedWeights {
    use crate::svm::LinearOvR;
    let p = LinearSvmParams { c, ..Default::default() };
    let model = LinearOvR::train(train, train_y, n_classes, &p);
    model.export_scorer_weights(expansion, precision)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::svm::c_grid;

    fn small(name: &str) -> Dataset {
        generate(name, SynthConfig { seed: 3, n_train: 120, n_test: 120 }).unwrap()
    }

    #[test]
    fn hashing_is_deterministic_across_calls() {
        let ds = small("letter");
        let cfg = PipelineConfig::new(1, 32, 8);
        let a = hash_dataset(&ds, &cfg).unwrap();
        let b = hash_dataset(&ds, &cfg).unwrap();
        assert_eq!(a.train, b.train);
        assert_eq!(a.test, b.test);
        a.train.check_invariants().unwrap();
    }

    #[test]
    fn invalid_bit_budget_is_an_error_not_a_panic() {
        let ds = small("letter");
        let cfg = PipelineConfig { seed: 1, k: 8, i_bits: 16, t_bits: 16 };
        assert!(hash_dataset(&ds, &cfg).is_err());
    }

    #[test]
    fn hashed_rows_have_k_codes() {
        let ds = small("letter");
        let cfg = PipelineConfig::new(2, 16, 4);
        let h = hash_dataset(&ds, &cfg).unwrap();
        for i in 0..h.train.rows() {
            assert_eq!(h.train.codes_of(i).len(), 16);
        }
        assert_eq!(h.train.cols(), 16 * 16);
        // CSR export carries the same structure: k ones per row.
        let csr = h.train_csr();
        for i in 0..csr.rows() {
            assert_eq!(csr.row(i).nnz(), 16);
            assert!(csr.row(i).values.iter().all(|&v| v == 1.0));
        }
    }

    #[test]
    fn accuracy_improves_with_k() {
        // The Figure-7 trend: larger k → closer to the min-max kernel.
        let ds = small("letter");
        let acc_small = hashed_linear_accuracy(&ds, &PipelineConfig::new(5, 8, 8), 1.0);
        let acc_large = hashed_linear_accuracy(&ds, &PipelineConfig::new(5, 256, 8), 1.0);
        assert!(
            acc_large > acc_small + 0.05,
            "k=8 {acc_small} vs k=256 {acc_large}"
        );
    }

    #[test]
    fn sweep_reuses_hash_and_returns_curve() {
        let ds = small("vowel");
        let curve = hashed_linear_sweep(&ds, &PipelineConfig::new(7, 64, 4), &c_grid(3));
        assert_eq!(curve.len(), 3);
        assert!(curve.iter().all(|&(_, a)| (0.0..=1.0).contains(&a)));
    }

    #[test]
    fn sketch_matrix_free_fn_matches_trait_call() {
        let ds = small("vowel");
        let h = CwsHasher::new(4, 8);
        let a = sketch_matrix(&h, &ds.train_x);
        let b = h.sketch_matrix(&ds.train_x);
        assert_eq!(a, b);
    }

    #[test]
    fn exported_weights_reproduce_ovr_decisions() {
        use crate::svm::LinearOvR;
        let ds = small("vowel");
        let cfg = PipelineConfig::new(9, 16, 4);
        let h = hash_dataset(&ds, &cfg).unwrap();
        let c = 1.0;
        let w = export_scorer_weights(&h.train, &ds.train_y, ds.n_classes(), &h.expansion, c);
        // Reference decisions from the OvR model directly.
        let p = LinearSvmParams { c, ..Default::default() };
        let model = LinearOvR::train(&h.train, &ds.train_y, ds.n_classes(), &p);
        let codes = h.expansion.code_space();
        let n_classes = ds.n_classes();
        for i in 0..h.test.rows().min(20) {
            let want = model.decisions_on(&h.test, i);
            // Score via the exported layout (gather + sum).
            let mut got = vec![0.0f64; n_classes];
            for &col in h.test.codes_of(i) {
                let j = col as usize / codes;
                let code = col as usize % codes;
                for cls in 0..n_classes {
                    got[cls] += w[(j * codes + code) * n_classes + cls] as f64;
                }
            }
            for cls in 0..n_classes {
                assert!(
                    (got[cls] - want[cls]).abs() < 1e-4 * (1.0 + want[cls].abs()),
                    "row {i} class {cls}: {} vs {}",
                    got[cls],
                    want[cls]
                );
            }
        }
    }

    #[test]
    fn f64_slab_export_reproduces_decisions_near_exactly() {
        // The f32 export's 1e-4 tolerance (test above) is all rounding;
        // the f64 slab carries the model weights verbatim, so the only
        // slack left is summation order.
        use crate::svm::LinearOvR;
        let ds = small("vowel");
        let cfg = PipelineConfig::new(9, 16, 4);
        let h = hash_dataset(&ds, &cfg).unwrap();
        let c = 1.0;
        let slab = export_scorer_slab(
            &h.train,
            &ds.train_y,
            ds.n_classes(),
            &h.expansion,
            c,
            SlabPrecision::F64,
        );
        assert_eq!(slab.precision(), SlabPrecision::F64);
        let w = match &slab {
            ExportedWeights::F64(w) => w,
            _ => unreachable!(),
        };
        let p = LinearSvmParams { c, ..Default::default() };
        let model = LinearOvR::train(&h.train, &ds.train_y, ds.n_classes(), &p);
        let n_classes = ds.n_classes();
        for i in 0..h.test.rows().min(20) {
            if h.test.codes_of(i).is_empty() {
                continue; // empty rows miss the slot-0 bias fold by design
            }
            let want = model.decisions_on(&h.test, i);
            let mut got = vec![0.0f64; n_classes];
            for &col in h.test.codes_of(i) {
                for cls in 0..n_classes {
                    got[cls] += w[col as usize * n_classes + cls];
                }
            }
            for cls in 0..n_classes {
                assert!(
                    (got[cls] - want[cls]).abs() < 1e-9 * (1.0 + want[cls].abs()),
                    "row {i} class {cls}: {} vs {}",
                    got[cls],
                    want[cls]
                );
            }
        }
    }
}
