//! Seeded fault injection for the serving stack's chaos harness.
//!
//! A [`FaultPlan`] describes *rates* of injected misbehavior; a
//! [`FaultStream`] turns the plan into a deterministic per-worker
//! decision sequence keyed by `(seed, shard, incarnation)`, so a chaos
//! run is exactly reproducible from one u64 seed — including across
//! supervisor respawns, because each incarnation of a shard's worker
//! draws from its own stream instead of resuming the corpse's.
//!
//! Two injection points, matching the cluster's unwind boundary:
//!
//! * **In-work faults** run *inside* `catch_unwind`, before the real
//!   computation: a panic (exercising the typed `WorkerPanicked` reply
//!   path) or a slow-down (exercising deadlines and queue backlog).
//! * **Post faults** run *after* the request has been answered: worker
//!   death (a panic that escapes the worker loop, exercising the
//!   supervisor's join/respawn path) or a queue stall (the worker
//!   sleeps while its queue backs up, exercising stealing and shed).
//!   Deaths deliberately never hold an unanswered request — losing one
//!   would be a *bug* in the serving stack, not a simulated fault, and
//!   the chaos tests assert exactly that by reconciling the snapshot.
//!
//! ## Gating
//!
//! Ambient (environment-variable) activation via [`FaultPlan::from_env`]
//! is compiled out of release builds: a production binary ignores
//! `MINMAX_FAULT_RATE`, so stray environment can never inject faults
//! into a serving deployment. Programmatic plans passed through
//! `ClusterConfig::faults` work in every profile — the coordinator
//! bench measures fault-rate overhead in release mode that way.

use std::time::Duration;

use crate::util::rng::Pcg64;

/// Marker embedded in every injected panic payload. The unwind
/// boundary surfaces it in `ClusterError::WorkerPanicked` messages
/// (chaos tests use it to tell injected panics from real bugs) and
/// [`silence_injected_panics`] uses it to keep test stderr readable.
pub const INJECTED: &str = "minmax-injected-fault";

/// Rates and shapes of injected faults. All rates are per-request
/// probabilities in `[0, 1]`; in-work rates (`panic_rate`,
/// `slow_rate`) and post rates (`death_rate`, `stall_rate`) are drawn
/// independently, and within each group the outcomes are mutually
/// exclusive (panic wins over slow, death wins over stall).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed for the deterministic decision streams.
    pub seed: u64,
    /// P(injected panic inside the request's unwind boundary).
    pub panic_rate: f64,
    /// P(worker death — a panic escaping the worker loop — after a
    /// request is answered).
    pub death_rate: f64,
    /// P(sleeping `slow` inside the unwind boundary before computing).
    pub slow_rate: f64,
    pub slow: Duration,
    /// P(worker sleeping `stall` after a request is answered, letting
    /// its queue back up).
    pub stall_rate: f64,
    pub stall: Duration,
}

impl FaultPlan {
    /// The standard chaos mix at a single headline `rate`: panics at
    /// `rate`, deaths at `rate/2`, slow-downs and stalls at `rate/4`
    /// each. This is the shape the CI chaos matrix sweeps.
    pub fn with_rate(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan {
            seed,
            panic_rate: rate,
            death_rate: rate / 2.0,
            slow_rate: rate / 4.0,
            slow: Duration::from_micros(500),
            stall_rate: rate / 4.0,
            stall: Duration::from_millis(1),
        }
    }

    /// Ambient activation from `MINMAX_FAULT_RATE` (headline rate) and
    /// `MINMAX_FAULT_SEED` (optional; defaults to a fixed constant so
    /// bare `MINMAX_FAULT_RATE=0.2 cargo test` is still deterministic).
    ///
    /// Returns `None` in release builds unconditionally — see the
    /// module-level gating notes.
    pub fn from_env() -> Option<FaultPlan> {
        if !cfg!(debug_assertions) {
            return None;
        }
        let rate: f64 = std::env::var("MINMAX_FAULT_RATE").ok()?.trim().parse().ok()?;
        if rate <= 0.0 {
            return None;
        }
        let seed: u64 = std::env::var("MINMAX_FAULT_SEED")
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(0xC0FFEE);
        Some(FaultPlan::with_rate(seed, rate))
    }

    /// The decision stream for one worker incarnation. Streams are
    /// keyed so that shard 3's second respawn draws the same sequence
    /// in every run with the same seed, independent of timing.
    pub(crate) fn stream(&self, shard: usize, incarnation: u64) -> FaultStream {
        FaultStream {
            plan: self.clone(),
            rng: Pcg64::new_stream(self.seed, (shard as u64) ^ incarnation.rotate_left(32)),
        }
    }
}

/// Deterministic per-worker fault decisions — one [`FaultDecision`]
/// per served request, always drawing the same number of variates so
/// the sequence is rate-independent.
pub(crate) struct FaultStream {
    plan: FaultPlan,
    rng: Pcg64,
}

/// What to inject around one request.
#[derive(Default)]
pub(crate) struct FaultDecision {
    /// Sleep this long inside the unwind boundary before computing.
    pub slow: Option<Duration>,
    /// Panic inside the unwind boundary instead of computing.
    pub panic: bool,
    /// After the request is answered: die or stall.
    pub post: Option<PostFault>,
}

/// A fault the worker executes *after* answering a request.
pub(crate) enum PostFault {
    /// Panic out of the worker loop (the supervisor respawns).
    Die,
    /// Sleep with the queue untouched (stealing/shed take over).
    Stall(Duration),
}

impl FaultStream {
    pub fn next(&mut self) -> FaultDecision {
        let work = self.rng.uniform();
        let post = self.rng.uniform();
        let plan = &self.plan;
        let mut d = FaultDecision::default();
        if work < plan.panic_rate {
            d.panic = true;
        } else if work < plan.panic_rate + plan.slow_rate {
            d.slow = Some(plan.slow);
        }
        if post < plan.death_rate {
            d.post = Some(PostFault::Die);
        } else if post < plan.death_rate + plan.stall_rate {
            d.post = Some(PostFault::Stall(plan.stall));
        }
        d
    }
}

/// Best-effort extraction of a panic payload's message — `&str` and
/// `String` payloads (everything `panic!` produces) come back verbatim;
/// anything else gets a placeholder.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic payload of unknown type".to_string()
    }
}

/// Install a process-wide panic hook that suppresses the default
/// stderr report for *injected* panics (payloads containing
/// [`INJECTED`]) and delegates everything else to the previously
/// installed hook. Chaos tests and the fault-rate bench call this once
/// at startup so thousands of injected panics don't drown real
/// diagnostics; calling it more than once just deepens the delegation
/// chain harmlessly.
pub fn silence_injected_panics() {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let injected = info
            .payload()
            .downcast_ref::<String>()
            .map(|s| s.contains(INJECTED))
            .or_else(|| info.payload().downcast_ref::<&str>().map(|s| s.contains(INJECTED)))
            .unwrap_or(false);
        if !injected {
            prev(info);
        }
    }));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(stream: &mut FaultStream, n: usize) -> Vec<(bool, bool, bool, bool)> {
        (0..n)
            .map(|_| {
                let d = stream.next();
                let (die, stall) = match d.post {
                    Some(PostFault::Die) => (true, false),
                    Some(PostFault::Stall(_)) => (false, true),
                    None => (false, false),
                };
                (d.panic, d.slow.is_some(), die, stall)
            })
            .collect()
    }

    #[test]
    fn streams_are_deterministic_per_incarnation() {
        let plan = FaultPlan::with_rate(42, 0.3);
        let a = drain(&mut plan.stream(1, 0), 200);
        let b = drain(&mut plan.stream(1, 0), 200);
        assert_eq!(a, b, "same (seed, shard, incarnation) must replay identically");
        let c = drain(&mut plan.stream(1, 1), 200);
        let d = drain(&mut plan.stream(2, 0), 200);
        assert_ne!(a, c, "a respawned worker draws a fresh stream");
        assert_ne!(a, d, "shards draw distinct streams");
    }

    #[test]
    fn rates_are_roughly_honored() {
        let plan = FaultPlan::with_rate(7, 0.2);
        let n = 20_000;
        let draws = drain(&mut plan.stream(0, 0), n);
        let panics = draws.iter().filter(|d| d.0).count() as f64 / n as f64;
        let deaths = draws.iter().filter(|d| d.2).count() as f64 / n as f64;
        assert!((panics - 0.2).abs() < 0.02, "panic rate {panics}");
        assert!((deaths - 0.1).abs() < 0.02, "death rate {deaths}");
    }

    #[test]
    fn zero_rate_injects_nothing() {
        let plan = FaultPlan::with_rate(7, 0.0);
        let draws = drain(&mut plan.stream(0, 0), 1000);
        assert!(draws.iter().all(|d| !d.0 && !d.1 && !d.2 && !d.3));
    }

    #[test]
    fn panic_message_extracts_str_and_string() {
        let s: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_message(s.as_ref()), "boom");
        let s: Box<dyn std::any::Any + Send> = Box::new(format!("{INJECTED}: x"));
        assert!(panic_message(s.as_ref()).contains(INJECTED));
        let s: Box<dyn std::any::Any + Send> = Box::new(17u32);
        assert!(panic_message(s.as_ref()).contains("unknown"));
    }
}
