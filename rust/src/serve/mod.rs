//! The fused serving path: `sketch → b-bit code → score` in one pass.
//!
//! This is the inference-side counterpart of the training fast path
//! (PR 3's `CodeMatrix`): the paper's whole pitch is that 0-bit CWS
//! turns the min-max kernel into a *linear* scorer cheap enough for
//! massive-traffic serving (§1, §4), and a linear scorer over one-hot
//! codes is just `k` gathers per class. The layered path the crate used
//! to serve with (`Pipeline::predict`) materialized a full
//! [`CodeMatrix`] for the batch, allocated a `Vec<CwsSample>` per row
//! and a `Vec<f64>` of decisions per row — all scaffolding the gather
//! never needed.
//!
//! [`Scorer`] collapses the three stages:
//!
//! 1. **Sketch** — the ICWS argmin runs on [`SketchEngine`]'s
//!    transposed `(r, c, β)` slabs through the zero-allocation
//!    `sketch_dense_with`/`sketch_sparse_with` entries (gather buffers
//!    and argmin accumulators live in the reusable [`Scratch`]).
//! 2. **Code** — each of the `k` samples is truncated to its b-bit
//!    code (`Expansion::column`) straight into a scratch buffer; no
//!    `CodeMatrix`, no CSR.
//! 3. **Score** — the codes gather into the class-minor
//!    `[K, 2^bits, C]` weight slab with four per-class lane
//!    accumulators that mirror `svm::rowset::dot_onehot`'s reduction
//!    tree **exactly**, so decisions (not just labels) are
//!    bit-identical to `LinearOvR::decisions_on` over the codes path.
//!
//! The hard invariant (pinned by `rust/tests/serve_parity.rs`): scorer
//! predictions are bit-identical to the layered
//! `transform_codes → predict_on` path at every thread count, every
//! b-bit width, fast math on or off. That holds because each stage
//! reuses the exact arithmetic of the layer it fuses — same sketch
//! bits, same code function, same reduction tree, same argmax order.
//!
//! Construction:
//! * [`crate::pipeline::Pipeline::scorer`] — from a fitted pipeline
//!   (weights copied out of the `LinearOvR` at full f64 precision,
//!   per-class bias kept separate so empty rows score like the layered
//!   path);
//! * [`Scorer::from_exported`] — from the f32 `[K, 2^bits, C]` slab
//!   `export_scorer_weights` emits (the bias is folded into slot 0
//!   there, so a coordinator can serve without any training structs —
//!   decisions then match to f32 precision and predictions agree).
//!
//! Batch entry: [`Scorer::predict_batch`] shards rows across
//! `MINMAX_THREADS` scoped threads like `SketchEngine::sketch_rows`,
//! with one [`Scratch`] per chunk. Single-row entries
//! ([`Scorer::score_dense_into`], [`Scorer::predict_dense`], sparse
//! twins) are allocation-free in steady state — the serving bench
//! (`rust/benches/bench_serve.rs`) verifies 0 allocs/row with a
//! counting allocator.

use crate::cws::engine::{self, SketchEngine, SketchScratch};
use crate::cws::CwsSample;
use crate::data::{scale, Matrix, SparseRow};
use crate::features::Expansion;
use crate::pipeline::Scaling;
use crate::svm::LinearOvR;

/// Errors constructing a [`Scorer`] from weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// Weight slab length disagrees with `expansion.dim() × n_classes`.
    WeightShape { expected: usize, got: usize },
    /// A scorer needs at least one class.
    NoClasses,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::WeightShape { expected, got } => {
                write!(f, "weight slab holds {got} values, expansion × classes needs {expected}")
            }
            ServeError::NoClasses => write!(f, "scorer needs at least one class"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Placeholder sample for scratch prefill; every scored row overwrites
/// its slots before they are read.
const EMPTY_SAMPLE: CwsSample = CwsSample { i_star: u32::MAX, t_star: 0 };

/// Reusable per-thread scoring arena: the sketch gather/argmin buffers,
/// the k-sample and k-code staging slots, the four gather lanes, and
/// the scaling buffer. Create one per serving thread with
/// [`Scorer::scratch`] and reuse it across requests — every buffer
/// resets per row (reuse is bit-identical to a fresh scratch, pinned by
/// `serve_parity.rs`), and after the first few calls no entry allocates.
pub struct Scratch {
    sketch: SketchScratch,
    samples: Vec<CwsSample>,
    codes: Vec<u32>,
    /// Per-class lane accumulators (4 × n_classes) mirroring the 4-lane
    /// reduction of `svm::rowset::dot_onehot`.
    lanes: Vec<f64>,
    /// Decision staging for the `predict_*` entries.
    decisions: Vec<f64>,
    /// Scaled copy of the input row (dense values or sparse values),
    /// used only when the scorer carries a non-`None` [`Scaling`].
    scaled: Vec<f32>,
}

/// Argmax with `LinearOvR::predict_on`'s exact semantics: start at
/// class 0, strict `>`, so the first of tied maxima wins.
pub fn argmax(decisions: &[f64]) -> i32 {
    let mut best = 0usize;
    let mut best_dec = f64::NEG_INFINITY;
    for (c, &d) in decisions.iter().enumerate() {
        if d > best_dec {
            best_dec = d;
            best = c;
        }
    }
    best as i32
}

/// The fused single-pass scoring kernel. Owns the ICWS parameter slabs
/// (via [`SketchEngine`]), the b-bit expansion, and the class-minor
/// `[K, 2^bits, C]` weight slab (f64) plus per-class biases. `Clone`
/// duplicates everything so router replicas can each own one.
#[derive(Clone)]
pub struct Scorer {
    engine: SketchEngine,
    expansion: Expansion,
    scaling: Scaling,
    n_classes: usize,
    /// `[K, 2^bits, C]` class-minor: weight of absolute column `col`
    /// for class `cls` at `weights[col * n_classes + cls]`.
    weights: Vec<f64>,
    /// Per-class bias, added after the gather (separate — NOT folded
    /// into slot 0 — so empty rows score `bias + 0` exactly like
    /// `LinearModel::decision_on` over an empty feature row).
    bias: Vec<f64>,
}

impl Scorer {
    /// Build from an explicit weight slab + biases. `weights` is the
    /// class-minor `[K, 2^bits, C]` layout (`expansion.dim() ×
    /// bias.len()` values); `dim` is the raw input dimensionality the
    /// ICWS parameter slabs are materialized for. Fast math follows
    /// `MINMAX_FAST_MATH` (like `SketchEngine::new`); pin it explicitly
    /// with [`Scorer::with_fast_math`].
    pub fn from_parts(
        seed: u64,
        dim: usize,
        expansion: Expansion,
        weights: Vec<f64>,
        bias: Vec<f64>,
    ) -> Result<Self, ServeError> {
        if bias.is_empty() {
            return Err(ServeError::NoClasses);
        }
        let expected = expansion.dim() * bias.len();
        if weights.len() != expected {
            return Err(ServeError::WeightShape { expected, got: weights.len() });
        }
        Ok(Self {
            engine: SketchEngine::new(seed, expansion.k, dim),
            expansion,
            scaling: Scaling::None,
            n_classes: bias.len(),
            weights,
            bias,
        })
    }

    /// Build from a trained [`LinearOvR`]: per-class weight vectors are
    /// transposed into the class-minor slab at full f64 precision and
    /// biases kept separate — decisions are bit-identical to
    /// `model.decisions_on` over the codes of the same sketches.
    pub fn from_model(
        seed: u64,
        dim: usize,
        expansion: Expansion,
        model: &LinearOvR,
    ) -> Result<Self, ServeError> {
        let c = model.models().len();
        if c == 0 {
            return Err(ServeError::NoClasses);
        }
        let d = expansion.dim();
        let mut weights = vec![0.0f64; d * c];
        let mut bias = vec![0.0f64; c];
        for (cls, m) in model.models().iter().enumerate() {
            if m.w.len() != d {
                return Err(ServeError::WeightShape { expected: d, got: m.w.len() });
            }
            bias[cls] = m.b;
            for (col, &wv) in m.w.iter().enumerate() {
                weights[col * c + cls] = wv;
            }
        }
        Self::from_parts(seed, dim, expansion, weights, bias)
    }

    /// Build from the exported f32 `[K, 2^bits, C]` serving slab
    /// (`coordinator::export_scorer_weights` /
    /// `Pipeline::export_weights`) — no training structs needed, which
    /// is how a coordinator deploys a model it only has weights for.
    /// The export folds each class bias into every code of slot 0, so
    /// the separate bias here is zero; decisions agree with the
    /// from-model scorer to f32 precision and predictions agree
    /// (pinned by `serve_parity.rs`). Empty input rows score 0 for
    /// every class (the fold is unrecoverable without the row's slot-0
    /// gather).
    pub fn from_exported(
        seed: u64,
        dim: usize,
        expansion: Expansion,
        n_classes: usize,
        weights: &[f32],
    ) -> Result<Self, ServeError> {
        if n_classes == 0 {
            return Err(ServeError::NoClasses);
        }
        let w64: Vec<f64> = weights.iter().map(|&v| v as f64).collect();
        Self::from_parts(seed, dim, expansion, w64, vec![0.0f64; n_classes])
    }

    /// Apply this row preprocessing before sketching (mirrors the
    /// fitted pipeline's `Scaling` stage, bit-exactly per row).
    pub fn with_scaling(mut self, scaling: Scaling) -> Self {
        self.scaling = scaling;
        self
    }

    /// Pin the sketching fast-math toggle (see
    /// `SketchEngine::with_fast_math` — enabling still runs the
    /// accuracy gate).
    pub fn with_fast_math(mut self, fast: bool) -> Self {
        self.engine = self.engine.with_fast_math(fast);
        self
    }

    pub fn k(&self) -> usize {
        self.expansion.k
    }

    /// Raw input dimensionality the parameter slabs cover.
    pub fn dim(&self) -> usize {
        self.engine.dim()
    }

    pub fn seed(&self) -> u64 {
        self.engine.seed()
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn expansion(&self) -> &Expansion {
        &self.expansion
    }

    pub fn scaling(&self) -> Scaling {
        self.scaling
    }

    pub fn fast_math(&self) -> bool {
        self.engine.fast_math()
    }

    /// The sketching core (exposed so a score-mode service can answer
    /// plain hashing requests from the same parameter slabs).
    pub fn engine(&self) -> &SketchEngine {
        &self.engine
    }

    /// A scoring arena sized for this scorer. One per serving thread.
    pub fn scratch(&self) -> Scratch {
        Scratch {
            sketch: SketchScratch::new(),
            samples: vec![EMPTY_SAMPLE; self.expansion.k],
            codes: Vec::with_capacity(self.expansion.k),
            lanes: vec![0.0f64; 4 * self.n_classes],
            decisions: vec![0.0f64; self.n_classes],
            scaled: Vec::new(),
        }
    }

    // ------------------------------------------------------ single row

    /// Per-class decision values for one dense row, written into `out`
    /// (`len == n_classes`). Zero heap allocations in steady state. A
    /// row with no positive entry (after scaling) scores `bias + 0`
    /// per class, exactly like an empty feature row on the layered
    /// path.
    pub fn score_dense_into(&self, u: &[f32], s: &mut Scratch, out: &mut [f64]) {
        let Scratch { sketch, samples, codes, lanes, scaled, .. } = s;
        self.score_dense_core(u, sketch, samples, codes, lanes, scaled, out);
    }

    /// Argmax label for one dense row (low-latency serving entry).
    pub fn predict_dense(&self, u: &[f32], s: &mut Scratch) -> i32 {
        let Scratch { sketch, samples, codes, lanes, scaled, decisions } = s;
        decisions.clear();
        decisions.resize(self.n_classes, 0.0);
        self.score_dense_core(u, sketch, samples, codes, lanes, scaled, decisions);
        argmax(decisions)
    }

    /// Per-class decisions for one sparse row — see
    /// [`Scorer::score_dense_into`].
    pub fn score_sparse_into(&self, row: SparseRow<'_>, s: &mut Scratch, out: &mut [f64]) {
        let Scratch { sketch, samples, codes, lanes, scaled, .. } = s;
        self.score_sparse_core(row, sketch, samples, codes, lanes, scaled, out);
    }

    /// Argmax label for one sparse row.
    pub fn predict_sparse(&self, row: SparseRow<'_>, s: &mut Scratch) -> i32 {
        let Scratch { sketch, samples, codes, lanes, scaled, decisions } = s;
        decisions.clear();
        decisions.resize(self.n_classes, 0.0);
        self.score_sparse_core(row, sketch, samples, codes, lanes, scaled, decisions);
        argmax(decisions)
    }

    // ----------------------------------------------------------- batch

    /// Predict labels for every row of a matrix, sharding contiguous
    /// row chunks across scoped threads like `SketchEngine::sketch_rows`
    /// (sequential below the engine's minimum work size). One
    /// [`Scratch`] per chunk; results are identical at any thread
    /// count.
    pub fn predict_batch(&self, x: &Matrix) -> Vec<i32> {
        self.predict_batch_with_threads(x, engine::batch_threads(x.rows(), self.expansion.k))
    }

    /// [`Scorer::predict_batch`] with an explicit thread count (honored
    /// as given, so tests can pin both paths).
    pub fn predict_batch_with_threads(&self, x: &Matrix, threads: usize) -> Vec<i32> {
        let mut out = vec![0i32; x.rows()];
        engine::par_fill_chunks_ctx(
            &mut out,
            threads,
            || self.scratch(),
            |i, slot, s| {
                *slot = match x {
                    Matrix::Dense(d) => self.predict_dense(d.row(i), s),
                    Matrix::Sparse(m) => self.predict_sparse(m.row(i), s),
                };
            },
        );
        out
    }

    // ------------------------------------------------------- internals

    #[allow(clippy::too_many_arguments)]
    fn score_dense_core(
        &self,
        u: &[f32],
        sketch: &mut SketchScratch,
        samples: &mut Vec<CwsSample>,
        codes: &mut Vec<u32>,
        lanes: &mut Vec<f64>,
        scaled: &mut Vec<f32>,
        out: &mut [f64],
    ) {
        let row = self.scale_dense(u, scaled);
        codes.clear();
        // Liveness check AFTER scaling, mirroring the layered path
        // (scale, then `sketch_matrix` filters rows with no positive
        // entry into all-zero feature rows).
        if row.iter().any(|&v| v > 0.0) {
            if samples.len() != self.expansion.k {
                samples.resize(self.expansion.k, EMPTY_SAMPLE);
            }
            self.engine.sketch_dense_with(row, sketch, samples);
            codes.extend(samples.iter().enumerate().map(|(j, smp)| self.expansion.column(j, smp)));
        }
        self.gather(codes, lanes, out);
    }

    #[allow(clippy::too_many_arguments)]
    fn score_sparse_core(
        &self,
        row: SparseRow<'_>,
        sketch: &mut SketchScratch,
        samples: &mut Vec<CwsSample>,
        codes: &mut Vec<u32>,
        lanes: &mut Vec<f64>,
        scaled: &mut Vec<f32>,
        out: &mut [f64],
    ) {
        codes.clear();
        // Scaling preserves sparse structure, so the layered path's
        // emptiness test (`nnz() == 0`) is scaling-independent.
        if row.nnz() > 0 {
            let row = self.scale_sparse(row, scaled);
            if samples.len() != self.expansion.k {
                samples.resize(self.expansion.k, EMPTY_SAMPLE);
            }
            self.engine.sketch_sparse_with(row, sketch, samples);
            codes.extend(samples.iter().enumerate().map(|(j, smp)| self.expansion.column(j, smp)));
        }
        self.gather(codes, lanes, out);
    }

    /// The fused gather: `out[cls] = bias[cls] + Σⱼ w[codeⱼ, cls]`,
    /// accumulated code-outer/class-inner (each code reads its C
    /// contiguous weights once) into four per-class lanes whose final
    /// combine `((a0+a1)+(a2+a3))+tail` replays
    /// `svm::rowset::dot_onehot` exactly — per class, the same values
    /// are added in the same order through the same tree, so decisions
    /// are bit-identical to `LinearModel::decision_on` on the codes
    /// path. Change that reduction tree, change this (and
    /// `serve_parity.rs` will catch it).
    #[allow(clippy::needless_range_loop)]
    fn gather(&self, codes: &[u32], lanes: &mut Vec<f64>, out: &mut [f64]) {
        let c = self.n_classes;
        assert_eq!(out.len(), c, "decision buffer must hold n_classes values");
        lanes.clear();
        lanes.resize(4 * c, 0.0);
        let (l01, l23) = lanes.split_at_mut(2 * c);
        let (l0, l1) = l01.split_at_mut(c);
        let (l2, l3) = l23.split_at_mut(c);
        // `out` doubles as the tail accumulator until the final combine.
        out.fill(0.0);
        let w = &self.weights[..];
        let mut chunks = codes.chunks_exact(4);
        for q in chunks.by_ref() {
            let w0 = &w[q[0] as usize * c..q[0] as usize * c + c];
            let w1 = &w[q[1] as usize * c..q[1] as usize * c + c];
            let w2 = &w[q[2] as usize * c..q[2] as usize * c + c];
            let w3 = &w[q[3] as usize * c..q[3] as usize * c + c];
            for cls in 0..c {
                l0[cls] += w0[cls];
                l1[cls] += w1[cls];
                l2[cls] += w2[cls];
                l3[cls] += w3[cls];
            }
        }
        for &code in chunks.remainder() {
            let wt = &w[code as usize * c..code as usize * c + c];
            for (t, &wv) in out.iter_mut().zip(wt) {
                *t += wv;
            }
        }
        for cls in 0..c {
            out[cls] = self.bias[cls] + (((l0[cls] + l1[cls]) + (l2[cls] + l3[cls])) + out[cls]);
        }
    }

    /// Per-row mirror of the dense scaling stage: copy the row into the
    /// scratch buffer and apply the SAME per-row helper the matrix
    /// transforms use (`data::scale::{l1,l2}_scale_row` /
    /// `binarize_value`) — one source of arithmetic, so a scaled row
    /// sketches bit-identically to a row of the pre-scaled matrix.
    fn scale_dense<'a>(&self, u: &'a [f32], buf: &'a mut Vec<f32>) -> &'a [f32] {
        match self.scaling {
            Scaling::None => u,
            Scaling::L1 => {
                buf.clear();
                buf.extend_from_slice(u);
                scale::l1_scale_row(buf);
                buf
            }
            Scaling::L2 => {
                buf.clear();
                buf.extend_from_slice(u);
                scale::l2_scale_row(buf);
                buf
            }
            Scaling::Binarize => {
                buf.clear();
                buf.extend(u.iter().map(|&v| scale::binarize_value(v)));
                buf
            }
        }
    }

    /// Per-row mirror of the CSR scaling stage: stored values scaled by
    /// the same per-row factor helper `data::scale::csr_row_*_factor`
    /// the matrix transforms use; structure untouched.
    fn scale_sparse<'a>(&self, row: SparseRow<'a>, buf: &'a mut Vec<f32>) -> SparseRow<'a> {
        let factor = match self.scaling {
            Scaling::None => return row,
            Scaling::L1 => scale::csr_row_l1_factor(row),
            Scaling::L2 => scale::csr_row_l2_factor(row),
            Scaling::Binarize => {
                buf.clear();
                buf.extend(row.values.iter().map(|&v| scale::binarize_value(v)));
                return SparseRow { indices: row.indices, values: buf };
            }
        };
        buf.clear();
        buf.extend(row.values.iter().map(|&v| v * factor));
        SparseRow { indices: row.indices, values: buf }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::scale;
    use crate::data::synth::{generate, SynthConfig};
    use crate::data::{Csr, Dense};
    use crate::svm::LinearSvmParams;

    fn letter() -> crate::data::Dataset {
        generate("letter", SynthConfig { seed: 4, n_train: 120, n_test: 80 }).unwrap()
    }

    fn fitted(ds: &crate::data::Dataset, k: usize, i_bits: u8) -> (LinearOvR, Expansion, u64) {
        let seed = 7u64;
        let expansion = Expansion::new(k, i_bits);
        let sketcher = crate::cws::CwsHasher::new(seed, k);
        let samples = crate::sketch::Sketcher::sketch_matrix(&sketcher, &ds.train_x);
        let codes = expansion.encode(&samples);
        let n_classes = ds.n_classes();
        let model =
            LinearOvR::train(&codes, &ds.train_y, n_classes, &LinearSvmParams::default());
        (model, expansion, seed)
    }

    #[test]
    fn fused_decisions_bit_match_codes_path() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 33, 5); // odd k: unroll tail
        let scorer = Scorer::from_model(seed, ds.dim(), expansion, &model)
            .unwrap()
            .with_fast_math(false);
        let sketcher = crate::cws::CwsHasher::new(seed, 33);
        let samples = crate::sketch::Sketcher::sketch_matrix(&sketcher, &ds.test_x);
        let codes = expansion.encode(&samples);
        let d = ds.test_x.to_dense();
        let mut scratch = scorer.scratch();
        let mut got = vec![0.0f64; ds.n_classes()];
        for i in 0..d.rows() {
            scorer.score_dense_into(d.row(i), &mut scratch, &mut got);
            let want = model.decisions_on(&codes, i);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
            }
            assert_eq!(scorer.predict_dense(d.row(i), &mut scratch), model.predict_on(&codes, i));
        }
    }

    #[test]
    fn batch_is_thread_count_invariant() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 16, 4);
        let scorer =
            Scorer::from_model(seed, ds.dim(), expansion, &model).unwrap().with_fast_math(false);
        let one = scorer.predict_batch_with_threads(&ds.test_x, 1);
        for threads in [2usize, 4, 8] {
            assert_eq!(one, scorer.predict_batch_with_threads(&ds.test_x, threads));
        }
    }

    #[test]
    fn empty_rows_score_bias_exactly() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 8, 4);
        let dim = ds.dim();
        let scorer =
            Scorer::from_model(seed, dim, expansion, &model).unwrap().with_fast_math(false);
        let zero = vec![0.0f32; dim];
        let mut scratch = scorer.scratch();
        let mut got = vec![0.0f64; ds.n_classes()];
        scorer.score_dense_into(&zero, &mut scratch, &mut got);
        // The layered path's empty feature row: decision = b + dot(∅).
        let empty = Expansion::new(8, 4).encode(&[None]);
        let want = model.decisions_on(&empty, 0);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(scorer.predict_dense(&zero, &mut scratch), model.predict_on(&empty, 0));
    }

    #[test]
    fn scaling_mirrors_match_matrix_scaling() {
        // Per-row scaling inside the scorer must reproduce the matrix
        // transforms bit-exactly (same f64 norm, same f32 factor).
        let rows: Vec<Vec<f32>> = vec![
            vec![0.5, 0.0, 2.0, 0.25],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![3.0, 1.0, 0.0, 7.5],
        ];
        let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let dense = Dense::from_rows(&refs);
        let csr = Csr::from_dense(&dense);
        for (scaling, dense_fn) in [
            (Scaling::L1, scale::l1_normalize_dense as fn(&mut Dense)),
            (Scaling::L2, scale::l2_normalize_dense as fn(&mut Dense)),
            (Scaling::Binarize, scale::binarize_dense as fn(&mut Dense)),
        ] {
            let scorer = Scorer::from_parts(1, 4, Expansion::new(4, 4), vec![0.0; 64], vec![0.0])
                .unwrap()
                .with_scaling(scaling);
            let mut want_dense = dense.clone();
            dense_fn(&mut want_dense);
            let mut buf = Vec::new();
            for i in 0..dense.rows() {
                let got = scorer.scale_dense(dense.row(i), &mut buf).to_vec();
                assert_eq!(got, want_dense.row(i), "{scaling:?} dense row {i}");
            }
            let mut want_csr = csr.clone();
            match scaling {
                Scaling::L1 => scale::l1_normalize_csr(&mut want_csr),
                Scaling::L2 => scale::l2_normalize_csr(&mut want_csr),
                Scaling::Binarize => scale::binarize_csr(&mut want_csr),
                Scaling::None => {}
            }
            let mut sbuf = Vec::new();
            for i in 0..csr.rows() {
                let got = scorer.scale_sparse(csr.row(i), &mut sbuf);
                assert_eq!(got.indices, want_csr.row(i).indices);
                assert_eq!(got.values, want_csr.row(i).values, "{scaling:?} sparse row {i}");
            }
        }
    }

    #[test]
    fn constructors_validate_shapes() {
        let e = Expansion::new(4, 4);
        assert_eq!(
            Scorer::from_parts(1, 8, e, vec![0.0; 7], vec![0.0; 2]).err(),
            Some(ServeError::WeightShape { expected: 2 * e.dim(), got: 7 })
        );
        assert_eq!(
            Scorer::from_parts(1, 8, e, Vec::new(), Vec::new()).err(),
            Some(ServeError::NoClasses)
        );
        assert_eq!(Scorer::from_exported(1, 8, e, 0, &[]).err(), Some(ServeError::NoClasses));
        assert!(Scorer::from_exported(1, 8, e, 2, &vec![0.0f32; 2 * e.dim()]).is_ok());
    }

    #[test]
    fn argmax_matches_predict_on_semantics() {
        assert_eq!(argmax(&[0.0]), 0);
        assert_eq!(argmax(&[1.0, 2.0, 2.0]), 1); // first max wins
        assert_eq!(argmax(&[-3.0, -1.0, -2.0]), 1);
        assert_eq!(argmax(&[f64::NEG_INFINITY, f64::NEG_INFINITY]), 0);
    }
}
