//! The fused serving path: `sketch → b-bit code → score` in one pass.
//!
//! This is the inference-side counterpart of the training fast path
//! (PR 3's `CodeMatrix`): the paper's whole pitch is that 0-bit CWS
//! turns the min-max kernel into a *linear* scorer cheap enough for
//! massive-traffic serving (§1, §4), and a linear scorer over one-hot
//! codes is just `k` gathers per class. The layered path the crate used
//! to serve with (`Pipeline::predict`) materialized a full
//! [`CodeMatrix`] for the batch, allocated a `Vec<CwsSample>` per row
//! and a `Vec<f64>` of decisions per row — all scaffolding the gather
//! never needed.
//!
//! [`Scorer`] collapses the three stages:
//!
//! 1. **Sketch** — the ICWS argmin runs on [`SketchEngine`]'s
//!    transposed `(r, c, β)` slabs through the zero-allocation
//!    `sketch_dense_with`/`sketch_sparse_with` entries (gather buffers
//!    and argmin accumulators live in the reusable [`Scratch`]).
//! 2. **Code** — each of the `k` samples is truncated to its b-bit
//!    code (`Expansion::column`) straight into a scratch buffer; no
//!    `CodeMatrix`, no CSR.
//! 3. **Score** — the codes gather into the class-minor
//!    `[K, 2^bits, C]` weight slab with four per-class lane
//!    accumulators that mirror `svm::rowset::dot_onehot`'s reduction
//!    tree **exactly**, so decisions (not just labels) are
//!    bit-identical to `LinearOvR::decisions_on` over the codes path.
//!
//! The hard invariant (pinned by `rust/tests/serve_parity.rs`): scorer
//! predictions are bit-identical to the layered
//! `transform_codes → predict_on` path at every thread count, every
//! b-bit width, fast math on or off. That holds because each stage
//! reuses the exact arithmetic of the layer it fuses — same sketch
//! bits, same code function, same reduction tree, same argmax order.
//!
//! **Vectorization and quantization (PR 7, DESIGN.md §2.6).** The
//! gather is memory-bandwidth bound, so the path scales three ways:
//!
//! * **SIMD dispatch** — the lane adds route through
//!   [`crate::util::simd`], which picks AVX2 intrinsics / portable
//!   chunked kernels / the scalar fallback at runtime (`MINMAX_SIMD`
//!   forces the fallback). Every level performs the same element-wise
//!   adds, so dispatch never changes bits.
//! * **[`SlabPrecision`]** — alongside the f64 master slab the scorer
//!   can carry an f32 copy (half the memory stream; decisions equal
//!   the f64 gather over the f32-rounded weights bit-for-bit, because
//!   accumulation stays f64) or a per-class affine int8 slab (quarter
//!   stream; integer lane sums are exact, so the only error is the
//!   ≤ scale/2 per-weight rounding, bounded per decision by
//!   `k·scale/2`). Like `MINMAX_FAST_MATH`, requesting int8 runs an
//!   accuracy gate first and silently stays on f64 if it fails — the
//!   precision is a request, not a promise.
//! * **Packed codes** — [`Scorer::with_packed_codes`] stages each
//!   row's k codes as b-bit words ([`PackedCodes`]) and decodes during
//!   the gather; same codes, same adds, bit-identical decisions.
//!
//! Construction:
//! * [`crate::pipeline::Pipeline::scorer`] — from a fitted pipeline
//!   (weights copied out of the `LinearOvR` at full f64 precision,
//!   per-class bias kept separate so empty rows score like the layered
//!   path);
//! * [`Scorer::from_exported`] — from the f32 `[K, 2^bits, C]` slab
//!   `export_scorer_weights` emits (the bias is folded into slot 0
//!   there, so a coordinator can serve without any training structs —
//!   decisions then match to f32 precision and predictions agree);
//! * [`Scorer::from_exported_slab`] — the same deployment story for
//!   all three precisions via [`ExportedWeights`]
//!   (`LinearOvR::export_scorer_weights`).
//!
//! Batch entry: [`Scorer::predict_batch`] shards rows across
//! `MINMAX_THREADS` scoped threads like `SketchEngine::sketch_rows`,
//! with one [`Scratch`] per chunk. Single-row entries
//! ([`Scorer::score_dense_into`], [`Scorer::predict_dense`], sparse
//! twins) are allocation-free in steady state — the serving bench
//! (`rust/benches/bench_serve.rs`) verifies 0 allocs/row with a
//! counting allocator.

use crate::cws::engine::{self, SketchEngine, SketchScratch};
use crate::cws::CwsSample;
use crate::data::{scale, Matrix, SparseRow};
use crate::features::{Expansion, PackedCodes};
use crate::pipeline::Scaling;
use crate::svm::LinearOvR;
use crate::util::simd;

/// Errors constructing a [`Scorer`] from weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// Weight slab length disagrees with `expansion.dim() × n_classes`.
    WeightShape { expected: usize, got: usize },
    /// A scorer needs at least one class.
    NoClasses,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::WeightShape { expected, got } => {
                write!(f, "weight slab holds {got} values, expansion × classes needs {expected}")
            }
            ServeError::NoClasses => write!(f, "scorer needs at least one class"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Numeric precision of the serving weight slab a [`Scorer`] gathers
/// from. The f64 master slab is always kept (it is what `with_precision`
/// derives the narrow slabs from, and the fallback when the int8 gate
/// refuses); the enum names which slab the hot path streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlabPrecision {
    /// Full-precision f64 slab — the PR 5 baseline, bit-identical to
    /// the layered training path.
    F64,
    /// f32 slab, accumulated in f64. Decisions are bit-identical to an
    /// f64 gather over the f32-rounded weights: the only loss is the
    /// one-time per-weight rounding, the memory stream halves.
    F32,
    /// Per-class affine int8 quantization (`w ≈ offset + scale·q`).
    /// Integer lane sums are exact; per-decision error is bounded by
    /// `k · scale/2` per class. Guarded by an accuracy gate.
    Int8,
}

impl std::fmt::Display for SlabPrecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SlabPrecision::F64 => "f64",
            SlabPrecision::F32 => "f32",
            SlabPrecision::Int8 => "int8",
        })
    }
}

/// A class-minor `[K, 2^bits, C]` serving slab exported from a trained
/// model at a chosen precision (`LinearOvR::export_scorer_weights`),
/// with each class bias folded into every code of slot 0 — the
/// training-struct-free deployment format [`Scorer::from_exported_slab`]
/// consumes. The int8 variant ships the quantized bytes *and* the
/// per-class `(scale, offset)` pair so serving reuses the training-side
/// quantization verbatim instead of re-deriving it.
#[derive(Debug, Clone, PartialEq)]
pub enum ExportedWeights {
    F64(Vec<f64>),
    F32(Vec<f32>),
    Int8 { q: Vec<i8>, scale: Vec<f64>, offset: Vec<f64> },
}

impl ExportedWeights {
    pub fn precision(&self) -> SlabPrecision {
        match self {
            ExportedWeights::F64(_) => SlabPrecision::F64,
            ExportedWeights::F32(_) => SlabPrecision::F32,
            ExportedWeights::Int8 { .. } => SlabPrecision::Int8,
        }
    }

    /// Slab entries (`expansion.dim() × n_classes` when well-formed).
    pub fn len(&self) -> usize {
        match self {
            ExportedWeights::F64(w) => w.len(),
            ExportedWeights::F32(w) => w.len(),
            ExportedWeights::Int8 { q, .. } => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-class affine int8 quantization of a class-minor f64 slab:
/// `scale[cls] = (max − min)/255` over class `cls`'s column,
/// `q = round((w − min)/scale) − 128`, `offset[cls] = min + 128·scale`,
/// so `w ≈ offset + scale·q` with |error| ≤ scale/2 per weight
/// (round-to-nearest). A constant column gets `scale = 0` and
/// reconstructs exactly. One shared implementation so the
/// training-side export and the serving-side [`Scorer::with_precision`]
/// produce bit-identical `(q, scale, offset)` triples.
pub(crate) fn quantize_slab(w: &[f64], n_classes: usize) -> (Vec<i8>, Vec<f64>, Vec<f64>) {
    let c = n_classes;
    let mut q = vec![0i8; w.len()];
    let mut scale = vec![0.0f64; c];
    let mut offset = vec![0.0f64; c];
    for cls in 0..c {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        let mut col = cls;
        while col < w.len() {
            lo = lo.min(w[col]);
            hi = hi.max(w[col]);
            col += c;
        }
        let s = if hi > lo { (hi - lo) / 255.0 } else { 0.0 };
        scale[cls] = s;
        if s == 0.0 {
            // Constant (possibly empty-range) column: q = 0 everywhere
            // and offset carries the constant exactly.
            offset[cls] = if lo.is_finite() { lo } else { 0.0 };
            continue;
        }
        offset[cls] = lo + 128.0 * s;
        let mut col = cls;
        while col < w.len() {
            // Clamp defensively: round((hi−lo)/s) = 255 exactly in
            // theory, but one ulp of slop must not wrap the i8.
            let t = ((w[col] - lo) / s).round() as i32 - 128;
            q[col] = t.clamp(-128, 127) as i8;
            col += c;
        }
    }
    (q, scale, offset)
}

/// Accuracy gate for an int8 slab, in the `MINMAX_FAST_MATH` style:
/// verify on the *actual* slab that every dequantized weight sits
/// within half a quantization step of its f64 master (what
/// round-to-nearest guarantees — a degenerate class range or an odd
/// platform rounding shows up here) and that the worst-case
/// per-decision error `k·scale/2` is finite. `false` → the caller
/// stays on the exact f64 slab.
fn int8_slab_ok(w: &[f64], q: &[i8], scale: &[f64], offset: &[f64], k: usize) -> bool {
    let c = scale.len();
    if c == 0 || q.len() != w.len() || offset.len() != c {
        return false;
    }
    for (col, (&wv, &qv)) in w.iter().zip(q).enumerate() {
        let cls = col % c;
        let tol = 0.5 * scale[cls] * (1.0 + 1e-9) + 1e-300;
        if !((offset[cls] + scale[cls] * qv as f64 - wv).abs() <= tol) {
            return false;
        }
    }
    let worst = scale.iter().fold(0.0f64, |m, &s| m.max(s)) * 0.5 * k as f64;
    worst.is_finite()
}

/// Placeholder sample for scratch prefill; every scored row overwrites
/// its slots before they are read.
const EMPTY_SAMPLE: CwsSample = CwsSample { i_star: u32::MAX, t_star: 0 };

/// Lane accumulators + packed-word staging for the gather stage, split
/// out of [`Scratch`] so the score cores can borrow them disjointly
/// from the sketch buffers.
#[derive(Default)]
struct GatherScratch {
    /// Per-class f64 lanes (4 × n_classes) mirroring the 4-lane
    /// reduction of `svm::rowset::dot_onehot` (f64/f32 slabs).
    lanes: Vec<f64>,
    /// Per-class i32 lanes (4 × n_classes) for the int8 slab.
    lanes_i: Vec<i32>,
    /// b-bit packed code words for the packed-codes path.
    words: Vec<u64>,
}

/// Reusable per-thread scoring arena: the sketch gather/argmin buffers,
/// the k-sample and k-code staging slots, the gather lanes (f64 and
/// i32) plus packed-word staging, and the scaling buffer. Create one
/// per serving thread with [`Scorer::scratch`] and reuse it across
/// requests — every buffer resets per row (reuse is bit-identical to a
/// fresh scratch, pinned by `serve_parity.rs`), and after the first few
/// calls no entry allocates.
pub struct Scratch {
    sketch: SketchScratch,
    samples: Vec<CwsSample>,
    codes: Vec<u32>,
    gather: GatherScratch,
    /// Decision staging for the `predict_*` entries.
    decisions: Vec<f64>,
    /// Scaled copy of the input row (dense values or sparse values),
    /// used only when the scorer carries a non-`None` [`Scaling`].
    scaled: Vec<f32>,
}

/// Argmax with `LinearOvR::predict_on`'s exact semantics: start at
/// class 0, strict `>`, so the first of tied maxima wins.
pub fn argmax(decisions: &[f64]) -> i32 {
    let mut best = 0usize;
    let mut best_dec = f64::NEG_INFINITY;
    for (c, &d) in decisions.iter().enumerate() {
        if d > best_dec {
            best_dec = d;
            best = c;
        }
    }
    best as i32
}

/// The fused single-pass scoring kernel. Owns the ICWS parameter slabs
/// (via [`SketchEngine`]), the b-bit expansion, the class-minor
/// `[K, 2^bits, C]` f64 master slab plus per-class biases, and — when
/// [`Scorer::with_precision`] selects one — a derived f32 or int8 slab
/// the gather streams instead. `Clone` duplicates everything so router
/// replicas can each own one.
#[derive(Clone)]
pub struct Scorer {
    engine: SketchEngine,
    expansion: Expansion,
    scaling: Scaling,
    n_classes: usize,
    /// `[K, 2^bits, C]` class-minor: weight of absolute column `col`
    /// for class `cls` at `weights[col * n_classes + cls]`. Always the
    /// f64 master, whatever precision the gather runs at.
    weights: Vec<f64>,
    /// Per-class bias, added after the gather (separate — NOT folded
    /// into slot 0 — so empty rows score `bias + 0` exactly like
    /// `LinearModel::decision_on` over an empty feature row).
    bias: Vec<f64>,
    /// Which slab the gather streams; the derived slabs below are empty
    /// unless their precision is active (same pattern as the engine's
    /// fast-math `inv_r`/`shift`).
    precision: SlabPrecision,
    /// f32 copy of `weights` (precision == F32 only).
    w32: Vec<f32>,
    /// int8 quantized slab + per-class scale/offset (Int8 only).
    q8: Vec<i8>,
    q_scale: Vec<f64>,
    q_offset: Vec<f64>,
    /// Route the gather through b-bit packed code words.
    packed: bool,
    /// Packed width `b_i + b_t` when this expansion supports word-
    /// aligned packing, else 0 (packing requests are then ignored).
    pack_bits: u8,
}

impl Scorer {
    /// Build from an explicit weight slab + biases. `weights` is the
    /// class-minor `[K, 2^bits, C]` layout (`expansion.dim() ×
    /// bias.len()` values); `dim` is the raw input dimensionality the
    /// ICWS parameter slabs are materialized for. Fast math follows
    /// `MINMAX_FAST_MATH` (like `SketchEngine::new`); pin it explicitly
    /// with [`Scorer::with_fast_math`].
    pub fn from_parts(
        seed: u64,
        dim: usize,
        expansion: Expansion,
        weights: Vec<f64>,
        bias: Vec<f64>,
    ) -> Result<Self, ServeError> {
        if bias.is_empty() {
            return Err(ServeError::NoClasses);
        }
        let expected = expansion.dim() * bias.len();
        if weights.len() != expected {
            return Err(ServeError::WeightShape { expected, got: weights.len() });
        }
        Ok(Self {
            engine: SketchEngine::new(seed, expansion.k, dim),
            expansion,
            scaling: Scaling::None,
            n_classes: bias.len(),
            weights,
            bias,
            precision: SlabPrecision::F64,
            w32: Vec::new(),
            q8: Vec::new(),
            q_scale: Vec::new(),
            q_offset: Vec::new(),
            packed: false,
            pack_bits: PackedCodes::supported_bits(expansion.code_space()).unwrap_or(0),
        })
    }

    /// Build from a trained [`LinearOvR`]: per-class weight vectors are
    /// transposed into the class-minor slab at full f64 precision and
    /// biases kept separate — decisions are bit-identical to
    /// `model.decisions_on` over the codes of the same sketches.
    pub fn from_model(
        seed: u64,
        dim: usize,
        expansion: Expansion,
        model: &LinearOvR,
    ) -> Result<Self, ServeError> {
        let c = model.models().len();
        if c == 0 {
            return Err(ServeError::NoClasses);
        }
        let d = expansion.dim();
        let mut weights = vec![0.0f64; d * c];
        let mut bias = vec![0.0f64; c];
        for (cls, m) in model.models().iter().enumerate() {
            if m.w.len() != d {
                return Err(ServeError::WeightShape { expected: d, got: m.w.len() });
            }
            bias[cls] = m.b;
            for (col, &wv) in m.w.iter().enumerate() {
                weights[col * c + cls] = wv;
            }
        }
        Self::from_parts(seed, dim, expansion, weights, bias)
    }

    /// Build from the exported f32 `[K, 2^bits, C]` serving slab
    /// (`coordinator::export_scorer_weights` /
    /// `Pipeline::export_weights`) — no training structs needed, which
    /// is how a coordinator deploys a model it only has weights for.
    ///
    /// **Precision contract.** The export folds each class bias into
    /// every code of slot 0, so the separate bias here is zero and
    /// empty input rows score 0 for every class (the fold is
    /// unrecoverable without the row's slot-0 gather). This legacy f32
    /// entry widens the slab back to an f64 master and serves at
    /// [`SlabPrecision::F64`] — exactly the PR 5 behaviour: decisions
    /// agree with the from-model scorer to f32 precision and
    /// predictions agree (pinned by `serve_parity.rs`). For a scorer
    /// that *serves* at the exported precision, use
    /// [`Scorer::from_exported_slab`]: `F64` slabs reproduce this
    /// constructor's decisions exactly, `F32` slabs gather the f32
    /// bytes directly (bit-identical decisions to this constructor,
    /// since both accumulate the same f32-rounded values in f64), and
    /// `Int8` slabs reuse the exported `(q, scale, offset)` verbatim so
    /// serving-side dequantization is bit-identical to the
    /// training-side quantizer that passed the accuracy gate.
    pub fn from_exported(
        seed: u64,
        dim: usize,
        expansion: Expansion,
        n_classes: usize,
        weights: &[f32],
    ) -> Result<Self, ServeError> {
        if n_classes == 0 {
            return Err(ServeError::NoClasses);
        }
        let w64: Vec<f64> = weights.iter().map(|&v| v as f64).collect();
        Self::from_parts(seed, dim, expansion, w64, vec![0.0f64; n_classes])
    }

    /// Build from an [`ExportedWeights`] slab at its exported
    /// precision — the all-precisions deployment entry (see the
    /// precision contract on [`Scorer::from_exported`]). The f64
    /// master is always populated (widened or dequantized), so
    /// [`Scorer::with_precision`] can still re-derive other slabs.
    pub fn from_exported_slab(
        seed: u64,
        dim: usize,
        expansion: Expansion,
        n_classes: usize,
        weights: &ExportedWeights,
    ) -> Result<Self, ServeError> {
        if n_classes == 0 {
            return Err(ServeError::NoClasses);
        }
        let zero_bias = vec![0.0f64; n_classes];
        match weights {
            ExportedWeights::F64(w) => Self::from_parts(seed, dim, expansion, w.clone(), zero_bias),
            ExportedWeights::F32(w) => {
                let w64: Vec<f64> = w.iter().map(|&v| v as f64).collect();
                let mut s = Self::from_parts(seed, dim, expansion, w64, zero_bias)?;
                s.w32 = w.clone();
                s.precision = SlabPrecision::F32;
                Ok(s)
            }
            ExportedWeights::Int8 { q, scale, offset } => {
                let expected = expansion.dim() * n_classes;
                if q.len() != expected {
                    return Err(ServeError::WeightShape { expected, got: q.len() });
                }
                if scale.len() != n_classes || offset.len() != n_classes {
                    return Err(ServeError::WeightShape {
                        expected: n_classes,
                        got: scale.len().max(offset.len()),
                    });
                }
                // Master = dequantized weights; the gather streams the
                // exported bytes verbatim (no re-quantization, so the
                // served arithmetic is exactly what the trainer gated).
                let mut w64 = vec![0.0f64; expected];
                for (col, &qv) in q.iter().enumerate() {
                    let cls = col % n_classes;
                    w64[col] = offset[cls] + scale[cls] * qv as f64;
                }
                let mut s = Self::from_parts(seed, dim, expansion, w64, zero_bias)?;
                s.q8 = q.clone();
                s.q_scale = scale.clone();
                s.q_offset = offset.clone();
                s.precision = SlabPrecision::Int8;
                Ok(s)
            }
        }
    }

    /// Apply this row preprocessing before sketching (mirrors the
    /// fitted pipeline's `Scaling` stage, bit-exactly per row).
    pub fn with_scaling(mut self, scaling: Scaling) -> Self {
        self.scaling = scaling;
        self
    }

    /// Pin the sketching fast-math toggle (see
    /// `SketchEngine::with_fast_math` — enabling still runs the
    /// accuracy gate).
    pub fn with_fast_math(mut self, fast: bool) -> Self {
        self.engine = self.engine.with_fast_math(fast);
        self
    }

    /// Select the slab precision the gather streams, deriving the
    /// narrow slab from the f64 master. Requesting
    /// [`SlabPrecision::Int8`] runs the accuracy gate first
    /// (`MINMAX_FAST_MATH` pattern): if the quantized slab cannot
    /// reproduce the master within half a step per weight, the scorer
    /// silently stays on f64 — check [`Scorer::precision`] for what
    /// actually engaged. Switching precision drops previously derived
    /// slabs.
    pub fn with_precision(mut self, precision: SlabPrecision) -> Self {
        self.w32 = Vec::new();
        self.q8 = Vec::new();
        self.q_scale = Vec::new();
        self.q_offset = Vec::new();
        self.precision = SlabPrecision::F64;
        match precision {
            SlabPrecision::F64 => {}
            SlabPrecision::F32 => {
                self.w32 = self.weights.iter().map(|&v| v as f32).collect();
                self.precision = SlabPrecision::F32;
            }
            SlabPrecision::Int8 => {
                let (q, scale, offset) = quantize_slab(&self.weights, self.n_classes);
                if int8_slab_ok(&self.weights, &q, &scale, &offset, self.expansion.k) {
                    self.q8 = q;
                    self.q_scale = scale;
                    self.q_offset = offset;
                    self.precision = SlabPrecision::Int8;
                }
            }
        }
        self
    }

    /// Route the per-row gather through b-bit packed code words
    /// ([`PackedCodes`]) — the sketch output shrinks from `k × u32` to
    /// `k × b` bits before it is re-read by the gather, which is the
    /// whole point at small b. Engages only when the expansion's code
    /// width divides 64 ([`PackedCodes::supported_bits`]); otherwise
    /// the request is ignored (check [`Scorer::packed_codes`]).
    /// Decisions are bit-identical either way: packing is lossless and
    /// the gather performs the same adds in the same order.
    pub fn with_packed_codes(mut self, packed: bool) -> Self {
        self.packed = packed && self.pack_bits != 0;
        self
    }

    pub fn k(&self) -> usize {
        self.expansion.k
    }

    /// Raw input dimensionality the parameter slabs cover.
    pub fn dim(&self) -> usize {
        self.engine.dim()
    }

    pub fn seed(&self) -> u64 {
        self.engine.seed()
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn expansion(&self) -> &Expansion {
        &self.expansion
    }

    pub fn scaling(&self) -> Scaling {
        self.scaling
    }

    pub fn fast_math(&self) -> bool {
        self.engine.fast_math()
    }

    /// The slab precision the gather actually streams (what engaged,
    /// not what was requested — see [`Scorer::with_precision`]).
    pub fn precision(&self) -> SlabPrecision {
        self.precision
    }

    /// Whether the gather routes through packed b-bit code words.
    pub fn packed_codes(&self) -> bool {
        self.packed
    }

    /// The sketching core (exposed so a score-mode service can answer
    /// plain hashing requests from the same parameter slabs).
    pub fn engine(&self) -> &SketchEngine {
        &self.engine
    }

    /// A scoring arena sized for this scorer. One per serving thread.
    pub fn scratch(&self) -> Scratch {
        let words_cap = if self.pack_bits != 0 {
            PackedCodes::words_per_row(self.expansion.k, self.pack_bits)
        } else {
            0
        };
        Scratch {
            sketch: SketchScratch::new(),
            samples: vec![EMPTY_SAMPLE; self.expansion.k],
            codes: Vec::with_capacity(self.expansion.k),
            gather: GatherScratch {
                lanes: vec![0.0f64; 4 * self.n_classes],
                lanes_i: vec![0i32; 4 * self.n_classes],
                words: Vec::with_capacity(words_cap),
            },
            decisions: vec![0.0f64; self.n_classes],
            scaled: Vec::new(),
        }
    }

    // ------------------------------------------------------ single row

    /// Per-class decision values for one dense row, written into `out`
    /// (`len == n_classes`). Zero heap allocations in steady state. A
    /// row with no positive entry (after scaling) scores `bias + 0`
    /// per class, exactly like an empty feature row on the layered
    /// path.
    pub fn score_dense_into(&self, u: &[f32], s: &mut Scratch, out: &mut [f64]) {
        let Scratch { sketch, samples, codes, gather, scaled, .. } = s;
        self.score_dense_core(u, sketch, samples, codes, gather, scaled, out);
    }

    /// Argmax label for one dense row (low-latency serving entry).
    pub fn predict_dense(&self, u: &[f32], s: &mut Scratch) -> i32 {
        let Scratch { sketch, samples, codes, gather, scaled, decisions } = s;
        decisions.clear();
        decisions.resize(self.n_classes, 0.0);
        self.score_dense_core(u, sketch, samples, codes, gather, scaled, decisions);
        argmax(decisions)
    }

    /// Per-class decisions for one sparse row — see
    /// [`Scorer::score_dense_into`].
    pub fn score_sparse_into(&self, row: SparseRow<'_>, s: &mut Scratch, out: &mut [f64]) {
        let Scratch { sketch, samples, codes, gather, scaled, .. } = s;
        self.score_sparse_core(row, sketch, samples, codes, gather, scaled, out);
    }

    /// Argmax label for one sparse row.
    pub fn predict_sparse(&self, row: SparseRow<'_>, s: &mut Scratch) -> i32 {
        let Scratch { sketch, samples, codes, gather, scaled, decisions } = s;
        decisions.clear();
        decisions.resize(self.n_classes, 0.0);
        self.score_sparse_core(row, sketch, samples, codes, gather, scaled, decisions);
        argmax(decisions)
    }

    // ----------------------------------------------------------- batch

    /// Predict labels for every row of a matrix, sharding contiguous
    /// row chunks across scoped threads like `SketchEngine::sketch_rows`
    /// (sequential below the engine's minimum work size). One
    /// [`Scratch`] per chunk; results are identical at any thread
    /// count.
    pub fn predict_batch(&self, x: &Matrix) -> Vec<i32> {
        self.predict_batch_with_threads(x, engine::batch_threads(x.rows(), self.expansion.k))
    }

    /// [`Scorer::predict_batch`] with an explicit thread count (honored
    /// as given, so tests can pin both paths).
    pub fn predict_batch_with_threads(&self, x: &Matrix, threads: usize) -> Vec<i32> {
        let mut out = vec![0i32; x.rows()];
        engine::par_fill_chunks_ctx(
            &mut out,
            threads,
            || self.scratch(),
            |i, slot, s| {
                *slot = match x {
                    Matrix::Dense(d) => self.predict_dense(d.row(i), s),
                    Matrix::Sparse(m) => self.predict_sparse(m.row(i), s),
                };
            },
        );
        out
    }

    // ------------------------------------------------------- internals

    #[allow(clippy::too_many_arguments)]
    fn score_dense_core(
        &self,
        u: &[f32],
        sketch: &mut SketchScratch,
        samples: &mut Vec<CwsSample>,
        codes: &mut Vec<u32>,
        gather: &mut GatherScratch,
        scaled: &mut Vec<f32>,
        out: &mut [f64],
    ) {
        let row = self.scale_dense(u, scaled);
        codes.clear();
        // Liveness check AFTER scaling, mirroring the layered path
        // (scale, then `sketch_matrix` filters rows with no positive
        // entry into all-zero feature rows).
        if row.iter().any(|&v| v > 0.0) {
            if samples.len() != self.expansion.k {
                samples.resize(self.expansion.k, EMPTY_SAMPLE);
            }
            self.engine.sketch_dense_with(row, sketch, samples);
            codes.extend(samples.iter().enumerate().map(|(j, smp)| self.expansion.column(j, smp)));
        }
        self.gather(codes, gather, out);
    }

    #[allow(clippy::too_many_arguments)]
    fn score_sparse_core(
        &self,
        row: SparseRow<'_>,
        sketch: &mut SketchScratch,
        samples: &mut Vec<CwsSample>,
        codes: &mut Vec<u32>,
        gather: &mut GatherScratch,
        scaled: &mut Vec<f32>,
        out: &mut [f64],
    ) {
        codes.clear();
        // Scaling preserves sparse structure, so the layered path's
        // emptiness test (`nnz() == 0`) is scaling-independent.
        if row.nnz() > 0 {
            let row = self.scale_sparse(row, scaled);
            if samples.len() != self.expansion.k {
                samples.resize(self.expansion.k, EMPTY_SAMPLE);
            }
            self.engine.sketch_sparse_with(row, sketch, samples);
            codes.extend(samples.iter().enumerate().map(|(j, smp)| self.expansion.column(j, smp)));
        }
        self.gather(codes, gather, out);
    }

    /// The fused gather, dispatched on slab precision and code packing.
    /// Every variant accumulates code-outer/class-inner (each code
    /// reads its C contiguous weights once) into four per-class lanes;
    /// the f64/f32 combines replay `svm::rowset::dot_onehot`'s
    /// `((a0+a1)+(a2+a3))+tail` tree exactly (see
    /// [`Scorer::gather_f64_core`]). The packed paths decode the same
    /// codes from b-bit words and perform the same adds in the same
    /// order, so packing never changes bits.
    fn gather(&self, codes: &[u32], g: &mut GatherScratch, out: &mut [f64]) {
        let c = self.n_classes;
        assert_eq!(out.len(), c, "decision buffer must hold n_classes values");
        let GatherScratch { lanes, lanes_i, words } = g;
        if self.packed {
            let cs = self.expansion.code_space();
            let bits = self.pack_bits;
            PackedCodes::pack_row_into(codes, cs, bits, words);
            let words = &words[..];
            let fetch = |j: usize| PackedCodes::unpack_abs(words, cs, bits, j) as usize;
            match self.precision {
                SlabPrecision::F64 => self.gather_f64_core(codes.len(), fetch, lanes, out),
                SlabPrecision::F32 => self.gather_f32_core(codes.len(), fetch, lanes, out),
                SlabPrecision::Int8 => self.gather_i8_core(codes.len(), fetch, lanes_i, out),
            }
        } else {
            let fetch = |j: usize| codes[j] as usize;
            match self.precision {
                SlabPrecision::F64 => self.gather_f64_core(codes.len(), fetch, lanes, out),
                SlabPrecision::F32 => self.gather_f32_core(codes.len(), fetch, lanes, out),
                SlabPrecision::Int8 => self.gather_i8_core(codes.len(), fetch, lanes_i, out),
            }
        }
    }

    /// f64 gather core: `out[cls] = bias[cls] + Σⱼ w[fetch(j), cls]`,
    /// four per-class lanes whose final combine `((a0+a1)+(a2+a3))+tail`
    /// replays `svm::rowset::dot_onehot` exactly — per class, the same
    /// values are added in the same order through the same tree, so
    /// decisions are bit-identical to `LinearModel::decision_on` on the
    /// codes path. Change that reduction tree, change this (and
    /// `serve_parity.rs` will catch it). Generic over `fetch` so the
    /// unpacked (`codes[j]`) and packed (b-bit word decode) paths share
    /// one arithmetic definition; the lane adds route through
    /// [`simd::add_assign`], which is element-wise and therefore
    /// bit-invisible.
    #[allow(clippy::needless_range_loop)]
    fn gather_f64_core(
        &self,
        n: usize,
        fetch: impl Fn(usize) -> usize,
        lanes: &mut Vec<f64>,
        out: &mut [f64],
    ) {
        let c = self.n_classes;
        lanes.clear();
        lanes.resize(4 * c, 0.0);
        let (l01, l23) = lanes.split_at_mut(2 * c);
        let (l0, l1) = l01.split_at_mut(c);
        let (l2, l3) = l23.split_at_mut(c);
        // `out` doubles as the tail accumulator until the final combine.
        out.fill(0.0);
        let w = &self.weights[..];
        let mut j = 0;
        while j + 4 <= n {
            let (q0, q1, q2, q3) = (fetch(j), fetch(j + 1), fetch(j + 2), fetch(j + 3));
            simd::add_assign(l0, &w[q0 * c..q0 * c + c]);
            simd::add_assign(l1, &w[q1 * c..q1 * c + c]);
            simd::add_assign(l2, &w[q2 * c..q2 * c + c]);
            simd::add_assign(l3, &w[q3 * c..q3 * c + c]);
            j += 4;
        }
        while j < n {
            let q = fetch(j);
            simd::add_assign(out, &w[q * c..q * c + c]);
            j += 1;
        }
        for cls in 0..c {
            out[cls] = self.bias[cls] + (((l0[cls] + l1[cls]) + (l2[cls] + l3[cls])) + out[cls]);
        }
    }

    /// f32 gather core: same lane structure and combine tree as
    /// [`Scorer::gather_f64_core`], but streaming the f32 slab and
    /// widening each weight to f64 at the add (exact). Decisions are
    /// therefore bit-identical to the f64 core run over the
    /// f32-rounded master — the precision loss is entirely the
    /// one-time rounding in `with_precision`, never the accumulation.
    #[allow(clippy::needless_range_loop)]
    fn gather_f32_core(
        &self,
        n: usize,
        fetch: impl Fn(usize) -> usize,
        lanes: &mut Vec<f64>,
        out: &mut [f64],
    ) {
        let c = self.n_classes;
        lanes.clear();
        lanes.resize(4 * c, 0.0);
        let (l01, l23) = lanes.split_at_mut(2 * c);
        let (l0, l1) = l01.split_at_mut(c);
        let (l2, l3) = l23.split_at_mut(c);
        out.fill(0.0);
        let w = &self.w32[..];
        let mut j = 0;
        while j + 4 <= n {
            let (q0, q1, q2, q3) = (fetch(j), fetch(j + 1), fetch(j + 2), fetch(j + 3));
            simd::add_assign_f32(l0, &w[q0 * c..q0 * c + c]);
            simd::add_assign_f32(l1, &w[q1 * c..q1 * c + c]);
            simd::add_assign_f32(l2, &w[q2 * c..q2 * c + c]);
            simd::add_assign_f32(l3, &w[q3 * c..q3 * c + c]);
            j += 4;
        }
        while j < n {
            let q = fetch(j);
            simd::add_assign_f32(out, &w[q * c..q * c + c]);
            j += 1;
        }
        for cls in 0..c {
            out[cls] = self.bias[cls] + (((l0[cls] + l1[cls]) + (l2[cls] + l3[cls])) + out[cls]);
        }
    }

    /// int8 gather core: the lanes accumulate raw `q` bytes in i32
    /// (integer addition is exact and associative, so the lane split is
    /// purely for ILP — no reduction-tree contract here), and the
    /// affine map is applied once per class at the end:
    /// `out = bias + offset·n + scale·Σq`. A row with no codes scores
    /// its bias exactly (early return, no `0·offset` float noise).
    fn gather_i8_core(
        &self,
        n: usize,
        fetch: impl Fn(usize) -> usize,
        lanes_i: &mut Vec<i32>,
        out: &mut [f64],
    ) {
        let c = self.n_classes;
        if n == 0 {
            out.copy_from_slice(&self.bias);
            return;
        }
        lanes_i.clear();
        lanes_i.resize(4 * c, 0);
        let (l01, l23) = lanes_i.split_at_mut(2 * c);
        let (l0, l1) = l01.split_at_mut(c);
        let (l2, l3) = l23.split_at_mut(c);
        let q8 = &self.q8[..];
        let mut j = 0;
        while j + 4 <= n {
            let (q0, q1, q2, q3) = (fetch(j), fetch(j + 1), fetch(j + 2), fetch(j + 3));
            simd::add_assign_i8(l0, &q8[q0 * c..q0 * c + c]);
            simd::add_assign_i8(l1, &q8[q1 * c..q1 * c + c]);
            simd::add_assign_i8(l2, &q8[q2 * c..q2 * c + c]);
            simd::add_assign_i8(l3, &q8[q3 * c..q3 * c + c]);
            j += 4;
        }
        while j < n {
            let q = fetch(j);
            simd::add_assign_i8(l0, &q8[q * c..q * c + c]);
            j += 1;
        }
        let live = n as f64;
        for (cls, slot) in out.iter_mut().enumerate() {
            let sum = (l0[cls] + l1[cls]) + (l2[cls] + l3[cls]);
            *slot = self.bias[cls] + self.q_offset[cls] * live + self.q_scale[cls] * sum as f64;
        }
    }

    /// Per-row mirror of the dense scaling stage: copy the row into the
    /// scratch buffer and apply the SAME per-row helper the matrix
    /// transforms use (`data::scale::{l1,l2}_scale_row` /
    /// `binarize_value`) — one source of arithmetic, so a scaled row
    /// sketches bit-identically to a row of the pre-scaled matrix.
    fn scale_dense<'a>(&self, u: &'a [f32], buf: &'a mut Vec<f32>) -> &'a [f32] {
        match self.scaling {
            Scaling::None => u,
            Scaling::L1 => {
                buf.clear();
                buf.extend_from_slice(u);
                scale::l1_scale_row(buf);
                buf
            }
            Scaling::L2 => {
                buf.clear();
                buf.extend_from_slice(u);
                scale::l2_scale_row(buf);
                buf
            }
            Scaling::Binarize => {
                buf.clear();
                buf.extend(u.iter().map(|&v| scale::binarize_value(v)));
                buf
            }
        }
    }

    /// Per-row mirror of the CSR scaling stage: stored values scaled by
    /// the same per-row factor helper `data::scale::csr_row_*_factor`
    /// the matrix transforms use; structure untouched.
    fn scale_sparse<'a>(&self, row: SparseRow<'a>, buf: &'a mut Vec<f32>) -> SparseRow<'a> {
        let factor = match self.scaling {
            Scaling::None => return row,
            Scaling::L1 => scale::csr_row_l1_factor(row),
            Scaling::L2 => scale::csr_row_l2_factor(row),
            Scaling::Binarize => {
                buf.clear();
                buf.extend(row.values.iter().map(|&v| scale::binarize_value(v)));
                return SparseRow { indices: row.indices, values: buf };
            }
        };
        buf.clear();
        buf.extend(row.values.iter().map(|&v| v * factor));
        SparseRow { indices: row.indices, values: buf }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::scale;
    use crate::data::synth::{generate, SynthConfig};
    use crate::data::{Csr, Dense};
    use crate::svm::LinearSvmParams;

    fn letter() -> crate::data::Dataset {
        generate("letter", SynthConfig { seed: 4, n_train: 120, n_test: 80 }).unwrap()
    }

    fn fitted(ds: &crate::data::Dataset, k: usize, i_bits: u8) -> (LinearOvR, Expansion, u64) {
        let seed = 7u64;
        let expansion = Expansion::new(k, i_bits);
        let sketcher = crate::cws::CwsHasher::new(seed, k);
        let samples = crate::sketch::Sketcher::sketch_matrix(&sketcher, &ds.train_x);
        let codes = expansion.encode(&samples);
        let n_classes = ds.n_classes();
        let model =
            LinearOvR::train(&codes, &ds.train_y, n_classes, &LinearSvmParams::default());
        (model, expansion, seed)
    }

    #[test]
    fn fused_decisions_bit_match_codes_path() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 33, 5); // odd k: unroll tail
        let scorer = Scorer::from_model(seed, ds.dim(), expansion, &model)
            .unwrap()
            .with_fast_math(false);
        let sketcher = crate::cws::CwsHasher::new(seed, 33);
        let samples = crate::sketch::Sketcher::sketch_matrix(&sketcher, &ds.test_x);
        let codes = expansion.encode(&samples);
        let d = ds.test_x.to_dense();
        let mut scratch = scorer.scratch();
        let mut got = vec![0.0f64; ds.n_classes()];
        for i in 0..d.rows() {
            scorer.score_dense_into(d.row(i), &mut scratch, &mut got);
            let want = model.decisions_on(&codes, i);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
            }
            assert_eq!(scorer.predict_dense(d.row(i), &mut scratch), model.predict_on(&codes, i));
        }
    }

    #[test]
    fn batch_is_thread_count_invariant() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 16, 4);
        let scorer =
            Scorer::from_model(seed, ds.dim(), expansion, &model).unwrap().with_fast_math(false);
        let one = scorer.predict_batch_with_threads(&ds.test_x, 1);
        for threads in [2usize, 4, 8] {
            assert_eq!(one, scorer.predict_batch_with_threads(&ds.test_x, threads));
        }
    }

    #[test]
    fn empty_rows_score_bias_exactly() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 8, 4);
        let dim = ds.dim();
        let scorer =
            Scorer::from_model(seed, dim, expansion, &model).unwrap().with_fast_math(false);
        let zero = vec![0.0f32; dim];
        let mut scratch = scorer.scratch();
        let mut got = vec![0.0f64; ds.n_classes()];
        scorer.score_dense_into(&zero, &mut scratch, &mut got);
        // The layered path's empty feature row: decision = b + dot(∅).
        let empty = Expansion::new(8, 4).encode(&[None]);
        let want = model.decisions_on(&empty, 0);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(scorer.predict_dense(&zero, &mut scratch), model.predict_on(&empty, 0));
        // The int8 path's stronger guarantee: bias verbatim.
        let q = Scorer::from_model(seed, dim, expansion, &model)
            .unwrap()
            .with_fast_math(false)
            .with_precision(SlabPrecision::Int8);
        assert_eq!(q.precision(), SlabPrecision::Int8);
        let mut qs = q.scratch();
        q.score_dense_into(&zero, &mut qs, &mut got);
        for (a, b) in got.iter().zip(&q.bias) {
            assert_eq!(a.to_bits(), b.to_bits(), "int8 empty row must score bias verbatim");
        }
    }

    #[test]
    fn scaling_mirrors_match_matrix_scaling() {
        // Per-row scaling inside the scorer must reproduce the matrix
        // transforms bit-exactly (same f64 norm, same f32 factor).
        let rows: Vec<Vec<f32>> = vec![
            vec![0.5, 0.0, 2.0, 0.25],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![3.0, 1.0, 0.0, 7.5],
        ];
        let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let dense = Dense::from_rows(&refs);
        let csr = Csr::from_dense(&dense);
        for (scaling, dense_fn) in [
            (Scaling::L1, scale::l1_normalize_dense as fn(&mut Dense)),
            (Scaling::L2, scale::l2_normalize_dense as fn(&mut Dense)),
            (Scaling::Binarize, scale::binarize_dense as fn(&mut Dense)),
        ] {
            let scorer = Scorer::from_parts(1, 4, Expansion::new(4, 4), vec![0.0; 64], vec![0.0])
                .unwrap()
                .with_scaling(scaling);
            let mut want_dense = dense.clone();
            dense_fn(&mut want_dense);
            let mut buf = Vec::new();
            for i in 0..dense.rows() {
                let got = scorer.scale_dense(dense.row(i), &mut buf).to_vec();
                assert_eq!(got, want_dense.row(i), "{scaling:?} dense row {i}");
            }
            let mut want_csr = csr.clone();
            match scaling {
                Scaling::L1 => scale::l1_normalize_csr(&mut want_csr),
                Scaling::L2 => scale::l2_normalize_csr(&mut want_csr),
                Scaling::Binarize => scale::binarize_csr(&mut want_csr),
                Scaling::None => {}
            }
            let mut sbuf = Vec::new();
            for i in 0..csr.rows() {
                let got = scorer.scale_sparse(csr.row(i), &mut sbuf);
                assert_eq!(got.indices, want_csr.row(i).indices);
                assert_eq!(got.values, want_csr.row(i).values, "{scaling:?} sparse row {i}");
            }
        }
    }

    #[test]
    fn constructors_validate_shapes() {
        let e = Expansion::new(4, 4);
        assert_eq!(
            Scorer::from_parts(1, 8, e, vec![0.0; 7], vec![0.0; 2]).err(),
            Some(ServeError::WeightShape { expected: 2 * e.dim(), got: 7 })
        );
        assert_eq!(
            Scorer::from_parts(1, 8, e, Vec::new(), Vec::new()).err(),
            Some(ServeError::NoClasses)
        );
        assert_eq!(Scorer::from_exported(1, 8, e, 0, &[]).err(), Some(ServeError::NoClasses));
        assert!(Scorer::from_exported(1, 8, e, 2, &vec![0.0f32; 2 * e.dim()]).is_ok());
        // The slab entry enforces per-variant shapes too.
        let short =
            ExportedWeights::Int8 { q: vec![0; 3], scale: vec![0.0; 2], offset: vec![0.0; 2] };
        assert_eq!(
            Scorer::from_exported_slab(1, 8, e, 2, &short).err(),
            Some(ServeError::WeightShape { expected: 2 * e.dim(), got: 3 })
        );
        let bad_meta = ExportedWeights::Int8 {
            q: vec![0; 2 * e.dim()],
            scale: vec![0.0; 1],
            offset: vec![0.0; 2],
        };
        assert_eq!(
            Scorer::from_exported_slab(1, 8, e, 2, &bad_meta).err(),
            Some(ServeError::WeightShape { expected: 2, got: 2 })
        );
        assert_eq!(
            Scorer::from_exported_slab(1, 8, e, 0, &ExportedWeights::F64(Vec::new())).err(),
            Some(ServeError::NoClasses)
        );
    }

    #[test]
    fn argmax_matches_predict_on_semantics() {
        assert_eq!(argmax(&[0.0]), 0);
        assert_eq!(argmax(&[1.0, 2.0, 2.0]), 1); // first max wins
        assert_eq!(argmax(&[-3.0, -1.0, -2.0]), 1);
        assert_eq!(argmax(&[f64::NEG_INFINITY, f64::NEG_INFINITY]), 0);
    }

    #[test]
    fn quantize_slab_roundtrips_within_half_a_step() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 16, 4);
        let scorer = Scorer::from_model(seed, ds.dim(), expansion, &model).unwrap();
        let (q, s, o) = quantize_slab(&scorer.weights, scorer.n_classes);
        assert!(int8_slab_ok(&scorer.weights, &q, &s, &o, scorer.k()));
        for (col, &wv) in scorer.weights.iter().enumerate() {
            let cls = col % scorer.n_classes;
            let back = o[cls] + s[cls] * q[col] as f64;
            assert!(
                (back - wv).abs() <= 0.5 * s[cls] * (1.0 + 1e-9) + 1e-300,
                "col {col}: {back} vs {wv} (scale {})",
                s[cls]
            );
        }
        // Constant columns reconstruct exactly (scale 0, offset = value).
        let (q, s, o) = quantize_slab(&[2.5, -1.0, 2.5, -1.0, 2.5, -1.0], 2);
        assert_eq!(s, vec![0.0, 0.0]);
        assert_eq!(o, vec![2.5, -1.0]);
        assert!(q.iter().all(|&v| v == 0));
    }

    #[test]
    fn f32_precision_gathers_the_rounded_master_bit_for_bit() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 16, 4);
        let f64_scorer = Scorer::from_model(seed, ds.dim(), expansion, &model)
            .unwrap()
            .with_fast_math(false);
        let f32_scorer = f64_scorer.clone().with_precision(SlabPrecision::F32);
        assert_eq!(f32_scorer.precision(), SlabPrecision::F32);
        // Reference: an f64 scorer whose master IS the rounded slab.
        let rounded: Vec<f64> = f64_scorer.weights.iter().map(|&v| v as f32 as f64).collect();
        let reference =
            Scorer::from_parts(seed, ds.dim(), expansion, rounded, f64_scorer.bias.clone())
                .unwrap()
                .with_fast_math(false);
        let d = ds.test_x.to_dense();
        let mut s32 = f32_scorer.scratch();
        let mut sref = reference.scratch();
        let (mut got, mut want) = (vec![0.0; ds.n_classes()], vec![0.0; ds.n_classes()]);
        for i in 0..d.rows() {
            f32_scorer.score_dense_into(d.row(i), &mut s32, &mut got);
            reference.score_dense_into(d.row(i), &mut sref, &mut want);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
            }
        }
    }

    #[test]
    fn int8_gate_engages_and_decisions_stay_within_bound() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 16, 4);
        let exact = Scorer::from_model(seed, ds.dim(), expansion, &model)
            .unwrap()
            .with_fast_math(false);
        let quant = exact.clone().with_precision(SlabPrecision::Int8);
        assert_eq!(quant.precision(), SlabPrecision::Int8, "gate must engage on a real slab");
        let bound: f64 = quant.q_scale.iter().fold(0.0f64, |m, &s| m.max(s)) * 0.5
            * quant.k() as f64
            + 1e-9;
        let d = ds.test_x.to_dense();
        let mut se = exact.scratch();
        let mut sq = quant.scratch();
        let (mut want, mut got) = (vec![0.0; ds.n_classes()], vec![0.0; ds.n_classes()]);
        let (mut agree, mut total) = (0usize, 0usize);
        for i in 0..d.rows() {
            exact.score_dense_into(d.row(i), &mut se, &mut want);
            quant.score_dense_into(d.row(i), &mut sq, &mut got);
            for (cls, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() <= bound,
                    "row {i} class {cls}: |{a} − {b}| > k·scale/2 = {bound}"
                );
            }
            total += 1;
            agree += (argmax(&got) == argmax(&want)) as usize;
        }
        // Quantization can only flip near-ties; large-scale agreement
        // is the accuracy-parity pin (the serve_parity matrix retests
        // this across widths and packings).
        assert!(agree * 10 >= total * 9, "int8 prediction agreement {agree}/{total}");
    }

    #[test]
    fn packed_codes_are_bit_identical_across_precisions() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 16, 4); // 4-bit codes: packable
        let d = ds.test_x.to_dense();
        for precision in [SlabPrecision::F64, SlabPrecision::F32, SlabPrecision::Int8] {
            let plain = Scorer::from_model(seed, ds.dim(), expansion, &model)
                .unwrap()
                .with_fast_math(false)
                .with_precision(precision);
            let packed = plain.clone().with_packed_codes(true);
            assert!(packed.packed_codes(), "4-bit codes must pack");
            let mut sp = plain.scratch();
            let mut sk = packed.scratch();
            let (mut a, mut b) = (vec![0.0; ds.n_classes()], vec![0.0; ds.n_classes()]);
            for i in 0..d.rows() {
                plain.score_dense_into(d.row(i), &mut sp, &mut a);
                packed.score_dense_into(d.row(i), &mut sk, &mut b);
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{precision} row {i}");
                }
            }
        }
    }

    #[test]
    fn unsupported_pack_width_ignores_the_request() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 8, 5); // 5-bit codes: unpackable
        let scorer = Scorer::from_model(seed, ds.dim(), expansion, &model)
            .unwrap()
            .with_fast_math(false)
            .with_packed_codes(true);
        assert!(!scorer.packed_codes(), "5-bit codes must not pack");
        // And scoring still works on the plain path.
        let d = ds.test_x.to_dense();
        let mut s = scorer.scratch();
        let mut out = vec![0.0; ds.n_classes()];
        scorer.score_dense_into(d.row(0), &mut s, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn exported_slab_roundtrips_match_the_legacy_f32_entry() {
        let ds = letter();
        let (model, expansion, seed) = fitted(&ds, 16, 4);
        let f64_export = match model.export_scorer_weights(&expansion, SlabPrecision::F64) {
            ExportedWeights::F64(w) => w,
            _ => unreachable!(),
        };
        let f32_slab: Vec<f32> = f64_export.iter().map(|&v| v as f32).collect();
        let legacy = Scorer::from_exported(seed, ds.dim(), expansion, ds.n_classes(), &f32_slab)
            .unwrap()
            .with_fast_math(false);
        let via_slab = Scorer::from_exported_slab(
            seed,
            ds.dim(),
            expansion,
            ds.n_classes(),
            &ExportedWeights::F32(f32_slab.clone()),
        )
        .unwrap()
        .with_fast_math(false);
        assert_eq!(via_slab.precision(), SlabPrecision::F32);
        let d = ds.test_x.to_dense();
        let mut sl = legacy.scratch();
        let mut sv = via_slab.scratch();
        let (mut a, mut b) = (vec![0.0; ds.n_classes()], vec![0.0; ds.n_classes()]);
        for i in 0..d.rows() {
            legacy.score_dense_into(d.row(i), &mut sl, &mut a);
            via_slab.score_dense_into(d.row(i), &mut sv, &mut b);
            for (x, y) in a.iter().zip(&b) {
                // Both gathers add f64(w32[i]) in the same order.
                assert_eq!(x.to_bits(), y.to_bits(), "row {i}");
            }
        }
        // Int8 export → from_exported_slab ≡ F64 export → with_precision
        // (the shared quantizer makes both sides bit-identical).
        let int8 = model.export_scorer_weights(&expansion, SlabPrecision::Int8);
        let served =
            Scorer::from_exported_slab(seed, ds.dim(), expansion, ds.n_classes(), &int8)
                .unwrap()
                .with_fast_math(false);
        assert_eq!(served.precision(), SlabPrecision::Int8);
        let local = Scorer::from_exported_slab(
            seed,
            ds.dim(),
            expansion,
            ds.n_classes(),
            &ExportedWeights::F64(f64_export),
        )
        .unwrap()
        .with_fast_math(false)
        .with_precision(SlabPrecision::Int8);
        assert_eq!(local.precision(), SlabPrecision::Int8);
        assert_eq!(served.q8, local.q8);
        assert_eq!(served.q_scale, local.q_scale);
        assert_eq!(served.q_offset, local.q_offset);
    }
}
