//! Hashed-feature expansion (§4 of the paper): turn 0-bit CWS samples
//! into the sparse one-hot matrix a linear learner consumes.
//!
//! For `b_i` bits of `i*` and `k` samples, sample `j`'s code
//! `c_j = i*_j mod 2^{b_i}` becomes a 1 at column `j · 2^{b_i} + c_j`.
//! The result is a `2^{b_i} × k`-dimensional binary matrix with exactly
//! `k` ones per row, so `⟨φ(u), φ(v)⟩ / k` is precisely the b-bit
//! collision estimator of `K_MM(u, v)` — a linear kernel approximating
//! the min-max kernel, which is the whole point of the pipeline.

use crate::cws::sampler::CwsSample;
use crate::cws::schemes::Scheme;
use crate::data::sparse::{Csr, CsrBuilder};

/// Configuration of the expansion: bits of `i*` and (rarely) of `t*`.
/// With `t_bits > 0` the code space per sample is `2^{b_i + b_t}`
/// (Figure 8's 2-bit-t* variant).
#[derive(Debug, Clone, Copy)]
pub struct Expansion {
    pub k: usize,
    pub i_bits: u8,
    pub t_bits: u8,
}

impl Expansion {
    pub fn new(k: usize, i_bits: u8) -> Self {
        assert!(i_bits >= 1 && i_bits <= 16, "i_bits in [1,16]");
        Self { k, i_bits, t_bits: 0 }
    }

    pub fn with_t_bits(mut self, t_bits: u8) -> Self {
        assert!(self.i_bits as usize + t_bits as usize <= 24, "code space too large");
        self.t_bits = t_bits;
        self
    }

    /// Codes per sample.
    pub fn code_space(&self) -> usize {
        1usize << (self.i_bits + self.t_bits)
    }

    /// Total output dimensionality `k · 2^{b_i + b_t}`.
    pub fn dim(&self) -> usize {
        self.k * self.code_space()
    }

    /// The scheme whose collision event this expansion's inner product
    /// counts (used by tests to cross-validate).
    pub fn scheme(&self) -> Scheme {
        Scheme { i_bits: Some(self.i_bits), t_bits: Some(self.t_bits) }
    }

    /// Column index for sample `j`.
    #[inline]
    pub fn column(&self, j: usize, s: &CwsSample) -> u32 {
        let i_part = (s.i_star as u64) & ((1u64 << self.i_bits) - 1);
        let code = if self.t_bits == 0 {
            i_part
        } else {
            let t_part = s.t_star.rem_euclid(1i64 << self.t_bits) as u64;
            (t_part << self.i_bits) | i_part
        };
        (j * self.code_space()) as u32 + code as u32
    }

    /// Expand one vector's samples into a sorted sparse row (indices,
    /// values) with exactly `k` ones.
    pub fn expand_row(&self, samples: &[CwsSample]) -> (Vec<u32>, Vec<f32>) {
        assert_eq!(samples.len(), self.k);
        let idx: Vec<u32> =
            samples.iter().enumerate().map(|(j, s)| self.column(j, s)).collect();
        // One column per sample block ⇒ already strictly increasing.
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]));
        (idx, vec![1.0; self.k])
    }

    /// Expand a batch of per-row samples (rows with `None` — empty input
    /// vectors — become empty feature rows).
    pub fn expand(&self, samples: &[Option<Vec<CwsSample>>]) -> Csr {
        let mut b = CsrBuilder::new(self.dim());
        for row in samples {
            match row {
                Some(s) => {
                    let (idx, vals) = self.expand_row(s);
                    b.push_sorted_row(&idx, &vals);
                }
                None => b.push_sorted_row(&[], &[]),
            }
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::sampler::CwsHasher;
    use crate::cws::schemes::collision_fraction;
    use crate::data::sparse::dot;

    fn samples_for(u: &[f32], k: usize, seed: u64) -> Vec<CwsSample> {
        CwsHasher::new(seed, k).hash_dense(u)
    }

    #[test]
    fn row_has_exactly_k_ones() {
        let u = [1.0f32, 0.5, 2.0, 0.0];
        let e = Expansion::new(64, 4);
        let (idx, vals) = e.expand_row(&samples_for(&u, 64, 1));
        assert_eq!(idx.len(), 64);
        assert!(vals.iter().all(|&v| v == 1.0));
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        // Sample j's column lands in block j.
        for (j, &c) in idx.iter().enumerate() {
            assert!((c as usize) / e.code_space() == j);
        }
    }

    #[test]
    fn inner_product_equals_collision_count() {
        let u = [1.0f32, 3.0, 0.5, 2.0, 0.0, 1.0];
        let v = [2.0f32, 1.0, 0.5, 1.0, 1.0, 0.0];
        for i_bits in [1u8, 2, 4, 8] {
            let k = 512;
            let e = Expansion::new(k, i_bits);
            let su = samples_for(&u, k, 9);
            let sv = samples_for(&v, k, 9);
            let m = e.expand(&[Some(su.clone()), Some(sv.clone())]);
            let ip = dot(m.row(0), m.row(1));
            let coll = collision_fraction(e.scheme(), &su, &sv) * k as f64;
            assert!((ip - coll).abs() < 1e-9, "b_i={i_bits}: {ip} vs {coll}");
        }
    }

    #[test]
    fn t_bits_variant_matches_its_scheme() {
        let u = [1.0f32, 3.0, 0.5, 2.0];
        let v = [2.0f32, 1.0, 0.5, 1.0];
        let k = 512;
        let e = Expansion::new(k, 4).with_t_bits(2);
        let su = samples_for(&u, k, 17);
        let sv = samples_for(&v, k, 17);
        let m = e.expand(&[Some(su.clone()), Some(sv.clone())]);
        let ip = dot(m.row(0), m.row(1));
        let coll = collision_fraction(e.scheme(), &su, &sv) * k as f64;
        assert!((ip - coll).abs() < 1e-9);
        assert_eq!(e.dim(), k * 64);
    }

    #[test]
    fn dims_and_bounds() {
        let e = Expansion::new(128, 8);
        assert_eq!(e.dim(), 128 * 256);
        let u = [0.1f32, 5.0, 0.2];
        let m = e.expand(&[Some(samples_for(&u, 128, 3))]);
        assert_eq!(m.cols(), e.dim());
        m.check_invariants().unwrap();
    }

    #[test]
    fn empty_rows_expand_empty() {
        let e = Expansion::new(8, 2);
        let m = e.expand(&[None, Some(samples_for(&[1.0f32, 2.0], 8, 5))]);
        assert_eq!(m.row(0).nnz(), 0);
        assert_eq!(m.row(1).nnz(), 8);
    }

    #[test]
    #[should_panic(expected = "i_bits")]
    fn zero_i_bits_rejected() {
        Expansion::new(4, 0);
    }
}
